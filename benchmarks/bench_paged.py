"""Paged vs contiguous KV cache at an equal cache-memory budget
(DESIGN.md §9): the occupancy case for block tables.

Both engines get the same physical KV capacity — ``POOL_TOKENS`` cache
positions. The contiguous layout must carve it into ``max_seq``-sized
slots (POOL_TOKENS / MAX_SEQ concurrent sessions, however short they
are); the paged backend reserves pages for each session's actual
worst-case length, so short chat sessions pack many-per-slot-equivalent
and admitted concurrency rises. Greedy outputs must stay byte-identical
— paging is a layout change, not a model change.

Reported per backend: peak admitted concurrency, mean/peak occupancy
(live tokens / reserved tokens), mean wall TTFT, decode steps to drain
the workload. Emits BENCH_paged.json for CI trending.
"""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import emit

N_SESSIONS = 8
MAX_SEQ = 128
BLOCK_SIZE = 16
POOL_TOKENS = 2 * MAX_SEQ       # = 2 contiguous slots of cache memory
GEN_TOKENS = 4


def _build_model():
    import jax
    import jax.numpy as jnp
    from repro.config.arch import reduced_for_smoke
    from repro.configs import get_arch
    from repro.distributed.sharding import default_rules
    from repro.launch.mesh import make_mesh
    from repro.models import Model
    from repro.models.module import split

    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = reduced_for_smoke(get_arch("llama2-7b"))
    model = Model(cfg, rules=default_rules(mesh), model_axis=1,
                  dtype=jnp.float32, remat="none")
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def _run_engine(cfg, model, params, *, backend: str):
    from repro.config.hardware import PAPER_A100
    from repro.core.hcache import HCacheManager
    from repro.serving import InferenceEngine, Request
    from repro.storage import ChunkStore, make_array

    store = ChunkStore(make_array("dram", 4), chunk_tokens=16)
    mgr = HCacheManager(model, store, hw=PAPER_A100,
                        schedule_override="hidden", store_dtype=np.float32)
    if backend == "contiguous":
        # the memory budget fixes the slot count: POOL_TOKENS / MAX_SEQ
        eng_kw = dict(max_batch=POOL_TOKENS // MAX_SEQ)
    else:
        # same KV bytes as a page pool; slots are now free to exceed it
        eng_kw = dict(max_batch=N_SESSIONS, block_size=BLOCK_SIZE,
                      cache_blocks=POOL_TOKENS // BLOCK_SIZE)
    engine = InferenceEngine(model, params, mgr, max_seq=MAX_SEQ,
                             prefill_chunk=8, backend=backend, **eng_kw)
    rng = np.random.default_rng(0)              # same workload per backend
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in rng.integers(8, 24, size=N_SESSIONS)]
    for i, p in enumerate(prompts):
        engine.submit(Request(f"chat-{i}", p, max_new_tokens=GEN_TOKENS))
    engine.run()
    outputs = {f"chat-{i}": engine.result(f"chat-{i}")
               for i in range(N_SESSIONS)}
    m = engine.metrics
    stats = {
        "backend": backend,
        "cache_capacity_tokens": POOL_TOKENS,
        "sessions": N_SESSIONS,
        "max_batch": eng_kw["max_batch"],
        "concurrent_peak": m.concurrent_peak,
        "live_tokens_peak": m.live_tokens_peak,
        "reserved_tokens_peak": m.reserved_tokens_peak,
        "occupancy_mean": m.occupancy_mean,
        "fragmentation_mean": m.fragmentation_mean,
        "alloc_stalls": m.alloc_stalls,
        "decode_steps": m.decode_steps,
        "engine_steps": engine.step_count,
        "mean_ttft_wall_s": float(np.mean(m.ttft_wall)),
        "max_ttft_wall_s": float(np.max(m.ttft_wall)),
        "mean_tbt_wall_s": (float(np.mean(m.tbt_wall))
                            if m.tbt_wall else 0.0),
    }
    engine.close()
    return stats, outputs


def run_paged_comparison(out_path: str = "BENCH_paged.json"):
    cfg, model, params = _build_model()
    results = {"workload": {"sessions": N_SESSIONS, "max_seq": MAX_SEQ,
                            "block_size": BLOCK_SIZE,
                            "cache_capacity_tokens": POOL_TOKENS,
                            "gen_tokens": GEN_TOKENS},
               "backends": {}}
    rows, outs = [], {}
    for backend in ("contiguous", "paged"):
        stats, outputs = _run_engine(cfg, model, params, backend=backend)
        results["backends"][backend] = stats
        outs[backend] = outputs
        rows.append((f"bench_paged_{backend}",
                     stats["mean_ttft_wall_s"] * 1e6,
                     f"concurrency={stats['concurrent_peak']};"
                     f"occupancy={stats['occupancy_mean']:.2f};"
                     f"steps={stats['engine_steps']}"))
    co = results["backends"]["contiguous"]
    pa = results["backends"]["paged"]
    results["outputs_identical"] = outs["contiguous"] == outs["paged"]
    results["paged_admits_more"] = bool(
        pa["concurrent_peak"] > co["concurrent_peak"])
    results["concurrency_gain"] = (pa["concurrent_peak"]
                                   / max(co["concurrent_peak"], 1))
    results["occupancy_gain"] = (pa["occupancy_mean"]
                                 / max(co["occupancy_mean"], 1e-9))
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    return emit(rows)
