"""Paper Figs 9/10: TTFT by restoration method, ShareGPT-like and
L-Eval-like workloads, on the paper's A100+4SSD testbed (analytical replay
through the cost model + pipeline simulator, validated against the paper's
reported speedup bands)."""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import emit
from repro.config.hardware import PAPER_A100
from repro.configs import get_arch
from repro.core.cost_model import layer_costs, method_times
from repro.core.pipeline import prefill_time, ttft
from repro.core.restoration import replay
from repro.core.scheduler import solve
from repro.training.data import leval_trace, sharegpt_trace

MODELS = ("llama2-7b", "llama2-13b", "opt-30b")


def run_pipeline_comparison(out_path: str = "BENCH_restoration.json"):
    """bench_restoration mode: blocking vs pipelined restoration TTFT.

    Both numbers come from the SAME compiled task graph (core/restoration):
    pipelined = two-stream replay makespan (what the serving engine's
    incremental executor achieves); blocking = the old monolithic path
    that ran all IO, then all compute (io_busy + compute_busy, zero
    overlap). Emits BENCH_restoration.json for CI trending."""
    results = []
    rows = []
    for m in MODELS:
        cfg = get_arch(m)
        for n in (2048, 8192, 16384):
            sched = solve(cfg, n, PAPER_A100)
            times = [method_times(c, PAPER_A100)
                     for c in layer_costs(cfg, n)]
            tl = replay(sched.tasks(), times)
            pf = prefill_time(cfg, 64, n, PAPER_A100)
            blocking = tl.io_busy + tl.compute_busy + pf
            pipelined = tl.makespan + pf
            results.append({
                "model": m, "n_tokens": n,
                "ttft_blocking_s": blocking,
                "ttft_pipelined_s": pipelined,
                "speedup": blocking / pipelined,
                "io_bubble": tl.io_bubble,
                "compute_bubble": tl.compute_bubble,
                "schedule": sched.summary(),
            })
            rows.append((f"bench_restoration_{m}_n{n}_pipelined",
                         pipelined * 1e6,
                         f"blocking_us={blocking * 1e6:.1f};"
                         f"speedup={blocking / pipelined:.2f}x"))
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    return emit(rows)


def _methods(cfg, n):
    sched = solve(cfg, n, PAPER_A100)
    return {
        "hcache": sched.methods,
        "kv_offload": ["kv"] * cfg.n_layers,
        "recompute": ["recompute"] * cfg.n_layers,
    }


def run():
    rows = []
    # --- multi-round conversation (ShareGPT4-like, Fig 9) ------------------
    trace = sharegpt_trace(40, rounds_per_session=5, seed=0)
    hist = {}
    samples = {m: {k: [] for k in ("hcache", "kv_offload", "recompute")}
               for m in MODELS}
    for r in trace:
        h = hist.get(r.session_id, 0)
        if h > 0:
            for m in MODELS:
                cfg = get_arch(m)
                for method, scheme in _methods(cfg, h).items():
                    samples[m][method].append(
                        ttft(cfg, h, r.input_len, PAPER_A100, scheme))
        hist[r.session_id] = h + r.input_len + r.output_len
    for m in MODELS:
        base = np.mean(samples[m]["hcache"])
        for method in ("hcache", "kv_offload", "recompute"):
            mean = np.mean(samples[m][method])
            rows.append((f"fig9_ttft_sharegpt_{m}_{method}", mean * 1e6,
                         f"speedup_vs_hcache={mean / base:.2f}x"))

    # --- long-context (L-Eval-like, Fig 10) --------------------------------
    trace = leval_trace(100, seed=1)
    ctx_lens = {}
    for m in MODELS:
        cfg = get_arch(m)
        vals = {k: [] for k in ("hcache", "kv_offload", "recompute")}
        rng = np.random.default_rng(2)
        for r in trace:
            n = int(rng.integers(4096, 16385))
            for method, scheme in _methods(cfg, n).items():
                vals[method].append(ttft(cfg, n, r.input_len, PAPER_A100,
                                         scheme))
        base = np.mean(vals["hcache"])
        for method, v in vals.items():
            rows.append((f"fig10_ttft_leval_{m}_{method}",
                         float(np.mean(v)) * 1e6,
                         f"speedup_vs_hcache={np.mean(v) / base:.2f}x"))
    return emit(rows)
