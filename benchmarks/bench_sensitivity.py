"""Paper Fig 11: restoration-speed sensitivity to (a) GPU compute power,
(b) number of SSDs, (c) history length — tokens/second restored."""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit
from repro.config.hardware import DRAM_BW, GB, PROFILES, PAPER_A100
from repro.configs import get_arch
from repro.core.pipeline import restore_timeline
from repro.core.scheduler import solve

MODELS = ("llama2-7b", "llama2-13b", "opt-30b")


def _speed(cfg, n, hw, methods):
    t = restore_timeline(cfg, n, hw, methods).makespan
    return n / t


def run():
    rows = []
    n = 1024
    # (a) varying GPU, DRAM as storage backend (Fig 11a-c)
    for gpu in ("a30", "a100", "4090", "l20", "h800"):
        hw = dataclasses.replace(PROFILES[gpu], storage_bw=DRAM_BW)
        for m in MODELS:
            cfg = get_arch(m)
            s = solve(cfg, n, hw)
            sp_h = _speed(cfg, n, hw, s.methods)
            sp_kv = _speed(cfg, n, hw, ["kv"] * cfg.n_layers)
            sp_re = _speed(cfg, n, hw, ["recompute"] * cfg.n_layers)
            rows.append((f"fig11a_{gpu}_{m}", 1e6 * n / sp_h,
                         f"tok_per_s={sp_h:.0f};vs_kv={sp_h / sp_kv:.2f}x;"
                         f"vs_rec={sp_h / sp_re:.2f}x"))
    # (b) varying SSD count (Fig 11d-f)
    for n_ssd in (1, 2, 4, 8, 16):
        hw = dataclasses.replace(PAPER_A100, storage_bw=n_ssd * 6.9 * GB)
        for m in MODELS:
            cfg = get_arch(m)
            s = solve(cfg, n, hw)
            sp_h = _speed(cfg, n, hw, s.methods)
            sp_kv = _speed(cfg, n, hw, ["kv"] * cfg.n_layers)
            rows.append((f"fig11b_{n_ssd}ssd_{m}", 1e6 * n / sp_h,
                         f"tok_per_s={sp_h:.0f};vs_kv={sp_h / sp_kv:.2f}x"))
    # (c) varying history length (Fig 11g-i)
    for length in (1024, 4096, 8192, 16384):
        for m in MODELS:
            cfg = get_arch(m)
            s = solve(cfg, length, PAPER_A100)
            sp_h = _speed(cfg, length, PAPER_A100, s.methods)
            sp_re = _speed(cfg, length, PAPER_A100,
                           ["recompute"] * cfg.n_layers)
            rows.append((f"fig11c_len{length}_{m}", 1e6 * length / sp_h,
                         f"tok_per_s={sp_h:.0f};vs_rec={sp_h / sp_re:.2f}x"))
    return emit(rows)
