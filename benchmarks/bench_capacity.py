"""Capacity bake-off (DESIGN.md §8): N multi-round chat sessions
time-sharing B << N batch slots through mid-stream eviction + pipelined
restoration.

Three scenarios on a tiny LM (functional engine, greedy sampling):

  * eviction-policy comparison — LRU vs restore-cost-aware victim
    selection over a heterogeneous-history workload. The headline metric
    is the mean simulated restoration makespan per (re)admission: the
    restoration component of TTFT under the paper's hardware profile
    (the prefill component is policy-independent).
  * host-budget degradation — the same workload under a storage byte
    budget with a cold tier: the CapacityManager's ladder (cold -> int8
    -> recompute -> drop) keeps the hot tier inside budget while every
    session still completes.

Emits BENCH_capacity.json next to BENCH_restoration.json for CI trending.
"""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import emit

N_SESSIONS = 8
MAX_BATCH = 4          # >1 eviction-eligible resident at preemption time,
ROUNDS = 2             # so LRU and cost-aware actually diverge
GEN_TOKENS = 5
PREEMPT_QUANTUM = 2


def _build_model():
    import jax
    import jax.numpy as jnp
    from repro.config.arch import reduced_for_smoke
    from repro.configs import get_arch
    from repro.distributed.sharding import default_rules
    from repro.launch.mesh import make_mesh
    from repro.models import Model
    from repro.models.module import split

    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = reduced_for_smoke(get_arch("llama2-7b"))
    model = Model(cfg, rules=default_rules(mesh), model_axis=1,
                  dtype=jnp.float32, remat="none")
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def _prompts(cfg, rng):
    """Heterogeneous histories: short chat sessions next to long ones, so
    victim selection has a real cost spread to exploit."""
    # shuffled so arrival order is uncorrelated with history length —
    # otherwise LRU's FIFO tie-break coincides with shortest-first and
    # the policies never diverge
    lengths = rng.permutation(np.linspace(6, 34, N_SESSIONS).astype(int))
    first = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
             for n in lengths]
    follow = [rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
              for _ in range(N_SESSIONS)]
    return first, follow


def _run_engine(cfg, model, params, *, eviction_policy: str,
                budget_frac=None):
    from repro.config.hardware import PAPER_A100
    from repro.core.capacity import CapacityManager, EVICTION_POLICIES
    from repro.core.hcache import HCacheManager
    from repro.serving import InferenceEngine, Request
    from repro.storage import ChunkStore, make_array

    cold = make_array("dram", 4) if budget_frac is not None else None
    store = ChunkStore(make_array("dram", 4), chunk_tokens=16,
                       cold_devices=cold)
    # store_dtype matches the functional model dtype (fp32): restoration
    # is lossless, so greedy outputs are invariant across eviction
    # policies (the simulated costs still assume the paper's 2-byte
    # elements via the hardware profile)
    mgr = HCacheManager(model, store, hw=PAPER_A100,
                        schedule_override="hidden",
                        store_dtype=np.float32)
    capacity = None
    if budget_frac is not None:
        capacity = CapacityManager(
            mgr, host_budget_bytes=int(budget_frac))
    engine = InferenceEngine(
        model, params, mgr, max_batch=MAX_BATCH, max_seq=128,
        prefill_chunk=8, preempt_quantum=PREEMPT_QUANTUM,
        eviction=EVICTION_POLICIES[eviction_policy](), capacity=capacity)

    rng = np.random.default_rng(0)           # same workload every policy
    first, follow = _prompts(cfg, rng)
    for rnd in range(ROUNDS):
        prompts = first if rnd == 0 else follow
        for i in range(N_SESSIONS):
            engine.submit(Request(f"chat-{i}", prompts[i],
                                  max_new_tokens=GEN_TOKENS))
        engine.run()
    outputs = {f"chat-{i}": engine.result(f"chat-{i}")
               for i in range(N_SESSIONS)}
    m = engine.metrics
    # the bake-off metric: restoration makespans of RESUMES (victims the
    # policy chose to evict). Round-boundary restores are identical
    # across policies and would dilute the comparison.
    resume = m.restore_sim_resume or m.restore_sim_all
    stats = {
        "eviction_policy": eviction_policy,
        "sessions": N_SESSIONS, "slots": MAX_BATCH, "rounds": ROUNDS,
        "preemptions": m.preemptions,
        "restores": len(m.restore_sim_all),
        "mean_ttft_restore_sim_s": float(np.mean(resume)) if resume else 0.0,
        "max_ttft_restore_sim_s": float(np.max(resume)) if resume else 0.0,
        "total_restore_sim_s": float(np.sum(m.restore_sim_all)),
        "mean_ttft_wall_s": float(np.mean(m.ttft_wall)),
        "mean_tbt_wall_s": float(np.mean(m.tbt_wall)),
        "restored_tokens": m.restored_tokens,
        "bytes_hot": store.bytes_used,
        "bytes_cold": store.bytes_cold,
    }
    if capacity is not None:
        stats["budget_bytes"] = capacity.host_budget_bytes
        stats["over_budget_final"] = capacity.over_budget()
        actions = {}
        for stage, _sid in capacity.actions:
            actions[stage] = actions.get(stage, 0) + 1
        stats["ladder_actions"] = actions
    engine.close()
    return stats, outputs


def run_capacity_comparison(out_path: str = "BENCH_capacity.json"):
    cfg, model, params = _build_model()
    rows = []
    results = {"workload": {"sessions": N_SESSIONS, "slots": MAX_BATCH,
                            "rounds": ROUNDS, "gen_tokens": GEN_TOKENS,
                            "preempt_quantum": PREEMPT_QUANTUM},
               "policies": {}}
    baseline_out = None
    for policy in ("lru", "restore_cost"):
        stats, outputs = _run_engine(cfg, model, params,
                                     eviction_policy=policy)
        results["policies"][policy] = stats
        if baseline_out is None:
            baseline_out = outputs
        else:
            # interleaving differs between policies but greedy outputs
            # must not (lossless store_dtype): eviction is
            # generation-invisible
            stats["outputs_match_lru"] = outputs == baseline_out
        rows.append((f"bench_capacity_{policy}",
                     stats["mean_ttft_restore_sim_s"] * 1e6,
                     f"preemptions={stats['preemptions']};"
                     f"restores={stats['restores']};"
                     f"tbt_us={stats['mean_tbt_wall_s'] * 1e6:.1f}"))

    lru = results["policies"]["lru"]["mean_ttft_restore_sim_s"]
    ca = results["policies"]["restore_cost"]["mean_ttft_restore_sim_s"]
    results["cost_aware_beats_lru"] = bool(ca < lru)
    results["cost_aware_speedup"] = float(lru / ca) if ca else 0.0

    # budgeted run: cap the hot tier at ~35% of the unconstrained peak
    peak = results["policies"]["lru"]["bytes_hot"]
    stats, _ = _run_engine(cfg, model, params, eviction_policy="lru",
                           budget_frac=max(int(peak * 0.35), 1))
    results["budgeted"] = stats
    rows.append(("bench_capacity_budgeted",
                 stats["mean_ttft_restore_sim_s"] * 1e6,
                 f"bytes_hot={stats['bytes_hot']};"
                 f"budget={stats['budget_bytes']};"
                 f"ladder={stats.get('ladder_actions')}"))

    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    return emit(rows)
