"""Cross-session prefix sharing on vs off at an equal page pool
(DESIGN.md §12): the concurrency and TTFT case for CoW pages.

Workload: ``N_SESSIONS`` chat sessions over one ``SHARED_TOKENS``-token
system prompt plus short unique suffixes, served through the paged
backend with a pool deliberately smaller than ``N_SESSIONS`` private
reservations. Without sharing every session must reserve (and prefill)
the whole prompt, so the pool admits them nearly one at a time; with
sharing the first publisher's pages are adopted copy-on-write by every
later session — each costs only its private suffix pages, admitted
concurrency multiplies, and the shared prefill is skipped outright
(lower TTFT). Greedy outputs must stay byte-identical — sharing is a
residency optimization, not a model change.

Reported per mode: peak admitted concurrency, alloc stalls, mean wall
TTFT, prefix hit rate, skipped tokens, CoW copies, host bytes deduped.
Emits BENCH_prefix.json for CI trending.
"""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import emit

N_SESSIONS = 6
MAX_SEQ = 128
BLOCK_SIZE = 16
SHARED_TOKENS = 96              # the common system prompt (6 full pages)
SUFFIX_TOKENS = 6
GEN_TOKENS = 4
POOL_PAGES = 10                 # < 2 private sessions' worth (7 pages each)
MAX_BATCH = 4


def _build_model():
    import jax
    import jax.numpy as jnp
    from repro.config.arch import reduced_for_smoke
    from repro.configs import get_arch
    from repro.distributed.sharding import default_rules
    from repro.launch.mesh import make_mesh
    from repro.models import Model
    from repro.models.module import split

    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = reduced_for_smoke(get_arch("llama2-7b"))
    model = Model(cfg, rules=default_rules(mesh), model_axis=1,
                  dtype=jnp.float32, remat="none")
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def _run_engine(cfg, model, params, *, sharing: bool):
    from repro.config.hardware import PAPER_A100
    from repro.core.hcache import HCacheManager
    from repro.serving import InferenceEngine, Request
    from repro.storage import ChunkStore, make_array

    store = ChunkStore(make_array("dram", 4), chunk_tokens=16)
    mgr = HCacheManager(model, store, hw=PAPER_A100,
                        schedule_override="hidden", store_dtype=np.float32)
    engine = InferenceEngine(model, params, mgr, max_batch=MAX_BATCH,
                             max_seq=MAX_SEQ, prefill_chunk=8,
                             backend="paged", block_size=BLOCK_SIZE,
                             cache_blocks=POOL_PAGES,
                             prefix_sharing=sharing)
    rng = np.random.default_rng(0)              # same workload per mode
    system = rng.integers(0, cfg.vocab_size, SHARED_TOKENS)
    prompts = [np.concatenate([system, rng.integers(
        0, cfg.vocab_size, SUFFIX_TOKENS)]).astype(np.int32)
        for _ in range(N_SESSIONS)]
    for i, p in enumerate(prompts):
        engine.submit(Request(f"chat-{i}", p, max_new_tokens=GEN_TOKENS))
    engine.run()
    outputs = {f"chat-{i}": engine.result(f"chat-{i}")
               for i in range(N_SESSIONS)}
    m = engine.metrics
    stats = {
        "prefix_sharing": sharing,
        "pool_pages": POOL_PAGES,
        "sessions": N_SESSIONS,
        "concurrent_peak": m.concurrent_peak,
        "alloc_stalls": m.alloc_stalls,
        "engine_steps": engine.step_count,
        "decode_steps": m.decode_steps,
        "mean_ttft_wall_s": float(np.mean(m.ttft_wall)),
        "max_ttft_wall_s": float(np.max(m.ttft_wall)),
        "prefix_hit_rate": m.prefix_hit_rate,
        "prefix_hits": m.prefix_hits,
        "prefix_hit_tokens": m.prefix_hit_tokens,
        "restore_skipped_tokens": m.restore_skipped_tokens,
        "cow_copies": m.cow_copies,
        "shared_pages": m.shared_pages,
        "dedup_host_bytes": m.dedup_host_bytes,
    }
    engine.close()
    return stats, outputs


def run_prefix_comparison(out_path: str = "BENCH_prefix.json"):
    cfg, model, params = _build_model()
    results = {"workload": {"sessions": N_SESSIONS,
                            "shared_tokens": SHARED_TOKENS,
                            "suffix_tokens": SUFFIX_TOKENS,
                            "pool_pages": POOL_PAGES,
                            "block_size": BLOCK_SIZE,
                            "gen_tokens": GEN_TOKENS},
               "modes": {}}
    rows, outs = [], {}
    for sharing in (False, True):
        stats, outputs = _run_engine(cfg, model, params, sharing=sharing)
        key = "sharing" if sharing else "private"
        results["modes"][key] = stats
        outs[key] = outputs
        rows.append((f"bench_prefix_{key}",
                     stats["mean_ttft_wall_s"] * 1e6,
                     f"concurrency={stats['concurrent_peak']};"
                     f"skipped={stats['restore_skipped_tokens']};"
                     f"hit_rate={stats['prefix_hit_rate']:.2f}"))
    off = results["modes"]["private"]
    on = results["modes"]["sharing"]
    results["outputs_identical"] = outs["private"] == outs["sharing"]
    results["concurrency_gain"] = (on["concurrent_peak"]
                                   / max(off["concurrent_peak"], 1))
    results["sharing_admits_2x"] = bool(
        on["concurrent_peak"] >= 2 * off["concurrent_peak"])
    results["ttft_gain"] = (off["mean_ttft_wall_s"]
                            / max(on["mean_ttft_wall_s"], 1e-9))
    results["sharing_lowers_ttft"] = bool(
        on["mean_ttft_wall_s"] < off["mean_ttft_wall_s"])
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    return emit(rows)
