"""Paper Table 3: bubble-free schedules + per-token storage cost, for the
paper's models AND all 10 assigned archs (GQA/SSM generalization — the
beyond-paper §7 extension)."""
from __future__ import annotations

from benchmarks.common import emit
from repro.config.hardware import PAPER_A100, TPU_V5E
from repro.configs import ASSIGNED, PAPER, get_arch
from repro.core.cost_model import storage_per_token
from repro.core.scheduler import solve


def run():
    rows = []
    for name in list(PAPER) + list(ASSIGNED):
        cfg = get_arch(name)
        for hw, hw_name in ((PAPER_A100, "a100"), (TPU_V5E, "v5e")):
            s = solve(cfg, 1024, hw,
                      allow_recompute=cfg.family in ("dense", "moe", "vlm",
                                                     "audio"))
            st = storage_per_token(cfg, s.methods)
            st_kv = storage_per_token(cfg, ["kv"] * cfg.n_layers)
            c = s.counts
            ratio = st_kv / st if st else float("inf")
            rows.append((
                f"table3_{hw_name}_{name}", s.makespan * 1e6,
                f"sched={c['hidden']}H+{c['kv']}KV+{c['recompute']}RE;"
                f"KiB_per_tok={st / 1024:.0f};kv_KiB={st_kv / 1024:.0f};"
                f"saving={ratio:.2f}x;bubble={s.bubble:.1%}"))
    return emit(rows)
