"""Enc-dec (whisper) serving through the family-agnostic engine
(DESIGN.md §11): the batching and restoration case for the paired
self/cross EncDecBackend.

Two comparisons on one synthetic whisper workload:

  * batched vs sequential — the same N sessions served by one engine
    with N slots (continuous batching: one decode dispatch per step for
    the whole batch) vs an engine with a single slot (sessions run
    back-to-back). Decode throughput and engine steps to drain are the
    headline; greedy outputs must be identical — batching is a
    scheduling change, not a model change.
  * restore vs recompute TTFT — round-2 requests on stored sessions,
    restored through the grouped hidden→KV projection + encoder-blob
    cross path, against the analytic full-recompute prefill of the same
    history (``pipeline.prefill_time``); simulated makespans under the
    paper's A100 profile, now including the io_enc/project_cross tasks.

Emits BENCH_encdec.json for CI trending.
"""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import emit

N_SESSIONS = 4
ENC_FRAMES = 24
PROMPT_LEN = 10
GEN_TOKENS = 6
MAX_SEQ = 96


def _build_model():
    import jax
    import jax.numpy as jnp
    from repro.config.arch import reduced_for_smoke
    from repro.configs import get_arch
    from repro.distributed.sharding import default_rules
    from repro.launch.mesh import make_mesh
    from repro.models import Model
    from repro.models.module import split

    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = reduced_for_smoke(get_arch("whisper-medium"))
    model = Model(cfg, rules=default_rules(mesh), model_axis=1,
                  dtype=jnp.float32, remat="none")
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def _workload(cfg, rng):
    prompts = [rng.integers(0, cfg.vocab_size, PROMPT_LEN).astype(np.int32)
               for _ in range(N_SESSIONS)]
    frames = [(rng.standard_normal((ENC_FRAMES + 2 * i, cfg.d_model))
               * 0.1).astype(np.float32) for i in range(N_SESSIONS)]
    return prompts, frames


def _fresh_engine(cfg, model, params, *, max_batch):
    from repro.config.hardware import PAPER_A100
    from repro.core.hcache import HCacheManager
    from repro.serving import InferenceEngine
    from repro.storage import ChunkStore, make_array

    store = ChunkStore(make_array("dram", 4), chunk_tokens=16)
    mgr = HCacheManager(model, store, hw=PAPER_A100,
                        schedule_override="hidden", store_dtype=np.float32)
    return InferenceEngine(model, params, mgr, max_batch=max_batch,
                           max_seq=MAX_SEQ, prefill_chunk=8), mgr


def _serve_round1(cfg, model, params, *, max_batch):
    import time

    from repro.serving import Request

    rng = np.random.default_rng(0)
    prompts, frames = _workload(cfg, rng)
    engine, mgr = _fresh_engine(cfg, model, params, max_batch=max_batch)
    t0 = time.perf_counter()
    for i, p in enumerate(prompts):
        engine.submit(Request(f"w{i}", p, max_new_tokens=GEN_TOKENS,
                              frames=frames[i]))
    engine.run()
    wall = time.perf_counter() - t0
    outputs = {f"w{i}": engine.result(f"w{i}") for i in range(N_SESSIONS)}
    m = engine.metrics
    stats = {
        "max_batch": max_batch,
        "wall_s": wall,
        "decode_steps": m.decode_steps,
        "engine_steps": engine.step_count,
        "concurrent_peak": m.concurrent_peak,
        "decode_tokens_per_dispatch": (
            N_SESSIONS * GEN_TOKENS / max(m.decode_steps, 1)),
        "mean_tbt_wall_s": float(np.mean(m.tbt_wall)) if m.tbt_wall else 0.0,
    }
    return engine, mgr, stats, outputs


def _analytic_full_model():
    """Restore vs recompute TTFT at FULL whisper-medium scale (cost
    model only — the functional runs above use the smoke config, whose
    tiny tensors make recompute artificially cheap). History: a full
    448-token transcript over 1500 encoder frames, 64 new decoder
    tokens. Recompute must re-run the encoder AND re-prefill the
    decoder; restore reads hidden states + the encoder blob and projects
    (io_enc/project_cross modeled in the task graph)."""
    from types import SimpleNamespace

    from repro.config.hardware import PAPER_A100
    from repro.configs import get_arch
    from repro.core.cost_model import layer_costs, method_times
    from repro.core.pipeline import prefill_time
    from repro.core.restoration import (compile_tasks, cross_restore_times,
                                        replay)
    from repro.core.scheduler import solve

    cfg = get_arch("whisper-medium")
    hw = PAPER_A100
    hist, enc_len, new = 448, 1500, 64
    sched = solve(cfg, hist, hw, dtype_bytes=2, allow_recompute=False)
    times = [method_times(c, hw) for c in layer_costs(cfg, hist, 2)]
    ct = cross_restore_times(
        SimpleNamespace(cfg=cfg, hw=hw, dtype_bytes=2), enc_len)
    restore = replay(
        compile_tasks(sched.methods, group_size=8, cross=True), times,
        dispatch_overhead=getattr(hw, "dispatch_overhead", 0.0),
        cross_times=ct).makespan
    # whisper's encoder depth == decoder depth, so a same-depth pass
    # over the frames approximates the encoder recompute
    recompute = (prefill_time(cfg, hist, 0, hw)
                 + prefill_time(cfg, enc_len, 0, hw))
    tail = prefill_time(cfg, new, hist, hw)
    return {"hist_tokens": hist, "enc_frames": enc_len, "new_tokens": new,
            "restore_s": float(restore), "recompute_s": float(recompute),
            "restore_ttft_s": float(restore + tail),
            "recompute_ttft_s": float(recompute + tail),
            "ttft_speedup": float((recompute + tail) / (restore + tail))}


def run_encdec_bench(out_path: str = "BENCH_encdec.json"):
    from repro.core.capacity import session_restore_cost
    from repro.core.pipeline import prefill_time
    from repro.config.hardware import PAPER_A100
    from repro.serving import Request

    cfg, model, params = _build_model()
    results = {"workload": {"sessions": N_SESSIONS, "prompt_len": PROMPT_LEN,
                            "enc_frames": ENC_FRAMES, "gen": GEN_TOKENS,
                            "max_seq": MAX_SEQ}, "modes": {}}

    # batched vs sequential throughput
    outs = {}
    for label, mb in (("batched", N_SESSIONS), ("sequential", 1)):
        engine, mgr, stats, outputs = _serve_round1(cfg, model, params,
                                                    max_batch=mb)
        results["modes"][label] = stats
        outs[label] = outputs
        if label == "batched":
            keep = (engine, mgr)            # reused for the restore round
        else:
            engine.close()
    results["outputs_identical"] = outs["batched"] == outs["sequential"]
    ba, se = results["modes"]["batched"], results["modes"]["sequential"]
    results["decode_dispatch_reduction"] = (
        se["decode_steps"] / max(ba["decode_steps"], 1))

    # restore-vs-recompute TTFT on round 2 (stored sessions)
    engine, mgr = keep
    restore_sims = [session_restore_cost(mgr, f"w{i}")
                    for i in range(N_SESSIONS)]
    hist = PROMPT_LEN + GEN_TOKENS - 1
    recompute_s = prefill_time(cfg, hist + PROMPT_LEN, 0, PAPER_A100)
    rng = np.random.default_rng(1)
    for i in range(N_SESSIONS):
        p2 = rng.integers(0, cfg.vocab_size, PROMPT_LEN).astype(np.int32)
        engine.submit(Request(f"w{i}", p2, max_new_tokens=GEN_TOKENS))
    engine.run()
    m = engine.metrics
    results["restore"] = {
        "restored_tokens": m.restored_tokens,
        "mean_restore_sim_s": float(np.mean(m.restore_sim_all)),
        "mean_restore_cost_model_s": float(np.mean(restore_sims)),
        "recompute_prefill_sim_s": float(recompute_s),
        "ttft_speedup_vs_recompute": float(
            recompute_s / max(np.mean(m.restore_sim_all), 1e-12)),
        "mean_ttft_wall_restored_s": (
            float(np.mean(m.ttft_wall_restored))
            if m.ttft_wall_restored else 0.0),
        "mean_ttft_wall_cold_s": float(np.mean(m.ttft_wall_cold)),
    }
    engine.close()
    results["full_model"] = _analytic_full_model()

    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    fm = results["full_model"]
    rows = [
        ("bench_encdec_batched", ba["wall_s"] * 1e6,
         f"decode_steps={ba['decode_steps']};"
         f"tok_per_dispatch={ba['decode_tokens_per_dispatch']:.1f}"),
        ("bench_encdec_sequential", se["wall_s"] * 1e6,
         f"decode_steps={se['decode_steps']};"
         f"tok_per_dispatch={se['decode_tokens_per_dispatch']:.1f}"),
        ("bench_encdec_restore_sim",
         results["restore"]["mean_restore_sim_s"] * 1e6,
         f"recompute_sim_us="
         f"{results['restore']['recompute_prefill_sim_s'] * 1e6:.1f};"
         f"identical={results['outputs_identical']}"),
        ("bench_encdec_full_ttft", fm["restore_ttft_s"] * 1e6,
         f"recompute_ttft_us={fm['recompute_ttft_s'] * 1e6:.1f};"
         f"speedup={fm['ttft_speedup']:.2f}x"),
    ]
    return emit(rows)


def run():
    return run_encdec_bench()


if __name__ == "__main__":
    run()
