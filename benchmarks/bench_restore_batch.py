"""Batched restoration data path (DESIGN.md §10): grouped projections,
cached weight packs, bucketed shapes.

For ``group_size`` ∈ {1, 2, 4, 8} over an 8-attention-layer stack the
bench restores the same stored session and reports, per restore:

  * device dispatch count (uploads + projection launches + sink writes),
  * projection wall seconds (the batched GEMM path, incl. blocking),
  * timeline makespan under a dispatch-overhead-aware hardware profile
    (the bubbles-vs-dispatch trade-off the group size tunes),
  * projection recompile count — and that a second, different-length
    session in the same power-of-two bucket adds ZERO recompiles.

It also replays a small preempting serving workload on both KV-cache
backends at group sizes 1 and 8 and checks greedy outputs are identical
everywhere — restoration batching is a data-path change, not a model
change. Emits BENCH_restore_batch.json for CI trending.
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np

from benchmarks.common import emit

N_LAYERS = 8
N_TOKENS = 96          # restored history length (bucket 128)
N_TOKENS_B = 112       # same bucket, different length (zero recompiles)
GROUP_SIZES = (1, 2, 4, 8)
DISPATCH_OVERHEAD = 25e-6


def _build_model():
    import jax
    import jax.numpy as jnp
    from repro.config.arch import reduced_for_smoke
    from repro.configs import get_arch
    from repro.distributed.sharding import default_rules
    from repro.launch.mesh import make_mesh
    from repro.models import Model
    from repro.models.module import split

    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = dataclasses.replace(reduced_for_smoke(get_arch("llama2-7b")),
                              n_layers=N_LAYERS)
    model = Model(cfg, rules=default_rules(mesh), model_axis=1,
                  dtype=jnp.float32, remat="none")
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def _manager(model, group_size):
    from repro.config.hardware import PAPER_A100
    from repro.core.hcache import HCacheManager
    from repro.storage import ChunkStore, make_array

    hw = dataclasses.replace(PAPER_A100,
                             dispatch_overhead=DISPATCH_OVERHEAD)
    store = ChunkStore(make_array("dram", 4), chunk_tokens=16)
    return HCacheManager(model, store, hw=hw, schedule_override="hidden",
                         store_dtype=np.float32,
                         restore_group_size=group_size)


def _save(cfg, model, params, mgr, sid, n_tokens, key=1):
    import jax
    toks = jax.random.randint(jax.random.PRNGKey(key), (1, n_tokens), 0,
                              cfg.vocab_size)
    pre = model.prefill(params, {"tokens": toks}, capture_hidden=True)
    mgr.save_prefill(sid, np.asarray(toks[0]), pre)


def _restore_once(model, params, mgr, sid):
    from repro.core.restoration import CacheAssembler
    sink = CacheAssembler(model)
    ex = mgr.begin_restore(params, sid, sink=sink)
    ex.run()
    return ex, sink.cache


def _engine_outputs(cfg, model, params, *, backend, group_size):
    """Preempting serving workload with a second (restoring) round;
    returns every session's greedy tokens."""
    from repro.config.hardware import PAPER_A100
    from repro.core.hcache import HCacheManager
    from repro.serving import InferenceEngine, Request
    from repro.storage import ChunkStore, make_array

    store = ChunkStore(make_array("dram", 4), chunk_tokens=16)
    mgr = HCacheManager(model, store, hw=PAPER_A100,
                        schedule_override="hidden", store_dtype=np.float32,
                        restore_group_size=group_size)
    engine = InferenceEngine(model, params, mgr, max_batch=2, max_seq=128,
                             prefill_chunk=8, preempt_quantum=2,
                             backend=backend)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in rng.integers(8, 24, size=4)]
    outputs = {}
    for rnd in range(2):                      # round 2 restores round 1
        for i, p in enumerate(prompts):
            engine.submit(Request(f"s{i}", p if rnd == 0 else p[:4],
                                  max_new_tokens=5))
        engine.run()
        for i in range(len(prompts)):
            outputs[f"r{rnd}-s{i}"] = engine.result(f"s{i}")
    engine.close()
    return outputs


def run_restore_batch(out_path: str = "BENCH_restore_batch.json"):
    from repro.core.restoration import projection_trace_count

    cfg, model, params = _build_model()
    results = {"workload": {"n_layers": N_LAYERS, "n_tokens": N_TOKENS,
                            "dispatch_overhead_s": DISPATCH_OVERHEAD,
                            "group_sizes": list(GROUP_SIZES)},
               "group_size": {}}
    rows = []
    caches = {}
    for gs in GROUP_SIZES:
        mgr = _manager(model, gs)
        _save(cfg, model, params, mgr, "bench", N_TOKENS)
        _save(cfg, model, params, mgr, "bench-b", N_TOKENS_B, key=2)
        t_before = projection_trace_count()
        ex, cache = _restore_once(model, params, mgr, "bench")
        first_traces = projection_trace_count() - t_before
        t_before = projection_trace_count()
        ex_b, _ = _restore_once(model, params, mgr, "bench-b")
        same_bucket_recompiles = projection_trace_count() - t_before
        caches[gs] = cache
        stats = {
            "dispatches_per_restore": ex.dispatch_count,
            "projection_wall_s": ex.project_wall,
            # second restore reuses the compiled projection: steady state
            "projection_wall_warm_s": ex_b.project_wall,
            "restore_wall_s": ex.wall_time,
            "timeline_makespan_s": ex.timeline().makespan,
            "compute_bubble": ex.timeline().compute_bubble,
            "projection_compiles_first_restore": first_traces,
            "same_bucket_recompiles": same_bucket_recompiles,
            "n_project_tasks": sum(1 for t in ex.tasks
                                   if t.kind == "project"),
        }
        results["group_size"][str(gs)] = stats
        rows.append((f"bench_restore_batch_g{gs}",
                     stats["projection_wall_warm_s"] * 1e6,
                     f"dispatches={stats['dispatches_per_restore']};"
                     f"makespan_us={stats['timeline_makespan_s'] * 1e6:.1f};"
                     f"recompiles={same_bucket_recompiles}"))
        mgr.saver.close()

    k1 = np.asarray(caches[1]["k"])
    v1 = np.asarray(caches[1]["v"])
    results["caches_byte_identical"] = all(
        np.array_equal(k1, np.asarray(caches[g]["k"]))
        and np.array_equal(v1, np.asarray(caches[g]["v"]))
        for g in GROUP_SIZES)
    d1 = results["group_size"]["1"]["dispatches_per_restore"]
    d8 = results["group_size"]["8"]["dispatches_per_restore"]
    results["dispatch_reduction_8_vs_1"] = d1 / max(d8, 1)
    results["zero_same_bucket_recompiles"] = all(
        s["same_bucket_recompiles"] == 0
        for s in results["group_size"].values())

    outs = {}
    for backend in ("contiguous", "paged"):
        for gs in (1, 8):
            outs[(backend, gs)] = _engine_outputs(
                cfg, model, params, backend=backend, group_size=gs)
    base = outs[("contiguous", 1)]
    results["greedy_outputs_identical"] = all(o == base
                                              for o in outs.values())
    rows.append(("bench_restore_batch_dispatch_reduction",
                 results["dispatch_reduction_8_vs_1"],
                 f"byte_identical={results['caches_byte_identical']};"
                 f"outputs_identical={results['greedy_outputs_identical']}"))
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    return emit(rows)
