"""Tensor-parallel restoration bake-off (DESIGN.md §16).

Three questions, one artifact (``BENCH_tp.json``):

  1. Does sharding the projection over the mesh actually cut the
     modeled restore cost? The grouped-replay timeline (the same cost
     model ``choose_group_size`` and the scheduler price with) is run
     at tp ∈ {1, 2, 4} with the auto group-size knob live at each
     width. Acceptance: tp=4 projection makespan ≥ 1.7x over tp=1.
  2. What does the real engine see? A preemption-heavy serving
     workload runs at each width on forced host devices
     (``--xla_force_host_platform_device_count``); the per-restore
     projection wall and end-to-end wall come from EngineMetrics.
     (Forced host devices share one physical CPU, so wall time shows
     SPMD *overhead*, not speedup — the modeled numbers are the
     scaling claim, the wall numbers the sanity bound.)
  3. Are greedy outputs byte-identical at every width? (If not,
     nothing else matters.)

Runs the reduced-smoke model — the mesh, sharded page pool, SPMD
projection and seam collectives are the real ones; only the
transformer is shrunk.
"""
from __future__ import annotations

import json

from benchmarks.common import emit

N_TOKENS = 2048
TP_WIDTHS = (1, 2, 4)
DISPATCH_OVERHEAD = 2e-3        # heavy-launch regime (matches bench_sched)
ACCEPT_SPEEDUP = 1.7
N_SESSIONS = 6
MAX_NEW = 5


def _modeled(arch="llama2-13b"):
    """Replay the grouped restore graph at each mesh width with the
    auto group-size knob live: per-width argmin group, end-to-end
    makespan, and the projection (compute) component."""
    import dataclasses

    from repro.config.hardware import PAPER_A100
    from repro.configs import get_arch
    from repro.core.cost_model import layer_costs, method_times
    from repro.core.restoration import (choose_group_size, compile_tasks,
                                        replay)

    cfg = get_arch(arch)
    methods = ["hidden"] * cfg.n_layers
    base = dataclasses.replace(PAPER_A100,
                               dispatch_overhead=DISPATCH_OVERHEAD)
    out = {}
    for tp in TP_WIDTHS:
        hw = base.with_mesh(tp)
        g = choose_group_size(cfg, hw, N_TOKENS, methods)
        times = [method_times(c, hw) for c in layer_costs(cfg, N_TOKENS)]
        span = replay(compile_tasks(tuple(methods), group_size=g), times,
                      dispatch_overhead=hw.dispatch_overhead).makespan
        # the sharded component: per-layer projection compute (already
        # divided by mesh_devices in method_times) + per-launch overhead
        n_launches = (len(g) if isinstance(g, tuple)
                      else -(-cfg.n_layers // g))
        proj = sum(t.c_h for t in times) + n_launches * hw.dispatch_overhead
        out[tp] = {"group_size": g if isinstance(g, int) else list(g),
                   "restore_makespan_ms": span * 1e3,
                   "projection_makespan_ms": proj * 1e3}
    return out


def _serve(tp):
    """The preemption-heavy paged workload at one mesh width; returns
    (greedy outputs, metrics)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.config.arch import reduced_for_smoke
    from repro.config.hardware import PAPER_A100
    from repro.configs import get_arch
    from repro.core.hcache import HCacheManager
    from repro.distributed.sharding import default_rules
    from repro.launch.mesh import make_mesh
    from repro.models import Model
    from repro.models.module import split
    from repro.serving import InferenceEngine, Request
    from repro.storage import ChunkStore, make_array

    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = reduced_for_smoke(get_arch("llama2-7b"))
    model = Model(cfg, rules=default_rules(mesh), model_axis=1,
                  dtype=jnp.float32, remat="none")
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    store = ChunkStore(make_array("dram", 4), chunk_tokens=16)
    mgr = HCacheManager(model, store, hw=PAPER_A100,
                        schedule_override="hidden", store_dtype=np.float32)
    eng = InferenceEngine(model, params, mgr, max_batch=2, max_seq=128,
                          prefill_chunk=8, backend="paged",
                          preempt_quantum=3, tp=tp)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, int(k)).astype(np.int32)
               for k in rng.integers(6, 24, size=N_SESSIONS)]
    for i, p in enumerate(prompts):
        eng.submit(Request(f"s{i}", p, max_new_tokens=MAX_NEW))
    eng.run()
    outs = {f"s{i}": eng.result(f"s{i}") for i in range(N_SESSIONS)}
    m = eng.metrics
    eng.close()
    return outs, m


def run_tp_bench(out_path: str = "BENCH_tp.json"):
    import os
    import sys
    if "XLA_FLAGS" not in os.environ and "jax" not in sys.modules:
        # must land before the first jax import; the CI step also sets
        # it explicitly so the SPMD path is never silently skipped
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=4"
    import jax

    results = {"workload": {"model_arch": "llama2-13b (modeled) / "
                                          "llama2-7b reduced (served)",
                            "n_tokens_modeled": N_TOKENS,
                            "n_sessions": N_SESSIONS,
                            "tp_widths": list(TP_WIDTHS),
                            "visible_devices": len(jax.devices())},
               "modeled": {}, "served": {}}
    rows = []

    modeled = _modeled()
    for tp, r in modeled.items():
        results["modeled"][f"tp{tp}"] = r
        rows.append((f"bench_tp_modeled_tp{tp}",
                     r["restore_makespan_ms"] * 1e3,
                     f"g={r['group_size']} "
                     f"proj={r['projection_makespan_ms']:.2f}ms"))
    proj_speedup = (modeled[1]["projection_makespan_ms"]
                    / modeled[4]["projection_makespan_ms"])
    e2e_speedup = (modeled[1]["restore_makespan_ms"]
                   / modeled[4]["restore_makespan_ms"])
    results["modeled"]["projection_speedup_tp4"] = proj_speedup
    results["modeled"]["restore_speedup_tp4"] = e2e_speedup

    base = None
    identical = True
    for tp in TP_WIDTHS:
        outs, m = _serve(tp)
        if base is None:
            base = outs
        same = outs == base
        identical = identical and same
        results["served"][f"tp{tp}"] = {
            "byte_identical": bool(same),
            "preemptions": m.preemptions,
            "restored_tokens": m.restored_tokens,
            "restore_wall_s": m.restore_wall_sum,
            "restore_projection_wall_s": m.restore_project_wall,
            "device_gauges": [dict(r) for r in m.device_gauges]}
        rows.append((f"bench_tp_served_tp{tp}", m.restore_wall_sum * 1e6,
                     f"identical={same} restored={m.restored_tokens}"))

    results["acceptance_projection_speedup_tp4"] = proj_speedup
    results["acceptance_byte_identical"] = bool(identical)
    results["acceptance_met"] = bool(proj_speedup >= ACCEPT_SPEEDUP
                                     and identical)
    rows.append(("bench_tp_acceptance", proj_speedup,
                 f"met={results['acceptance_met']}"))
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    emit(rows)
    assert identical, "greedy outputs diverged across tp widths"
    assert proj_speedup >= ACCEPT_SPEEDUP, \
        f"modeled projection speedup {proj_speedup:.2f}x < {ACCEPT_SPEEDUP}x"
    return results


if __name__ == "__main__":
    run_tp_bench()
