"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Run:
    PYTHONPATH=src python -m benchmarks.run [--only fig9,fig12] [--smoke]
    PYTHONPATH=src python -m benchmarks.run --mode bench_restoration

``--smoke`` runs the fast analytic suites only (CI gate). ``--mode
bench_restoration`` compares blocking vs pipelined restoration TTFT from
the executor's task graph and writes BENCH_restoration.json. ``--mode
bench_capacity`` runs the capacity bake-off (mid-stream eviction policy
comparison + host-budget degradation) and writes BENCH_capacity.json.
"""
from __future__ import annotations

import argparse
import sys
import time

SUITES = [
    ("fig1/kernels", "benchmarks.bench_kernels"),
    ("fig9/fig10 TTFT", "benchmarks.bench_restoration"),
    ("fig11 sensitivity", "benchmarks.bench_sensitivity"),
    ("fig12 scheduler ablation", "benchmarks.bench_scheduler"),
    ("fig13 partition methods", "benchmarks.bench_partition"),
    ("fig14 two-stage saving", "benchmarks.bench_two_stage"),
    ("fig15 kv reuse", "benchmarks.bench_kv_reuse"),
    ("table3 storage cost", "benchmarks.bench_storage_cost"),
]

# analytic suites that finish in seconds without a model forward pass
SMOKE = ("bench_restoration", "bench_sensitivity", "bench_scheduler",
         "bench_partition", "bench_storage_cost")


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", default=None,
                   help="comma-separated substring filters")
    p.add_argument("--smoke", action="store_true",
                   help="fast analytic suites only (CI)")
    p.add_argument("--mode", default=None,
                   choices=["bench_restoration", "bench_capacity",
                            "bench_paged", "bench_restore_batch",
                            "bench_encdec", "bench_prefix",
                            "bench_sched"],
                   help="special modes: bench_restoration compares "
                        "blocking vs pipelined TTFT -> "
                        "BENCH_restoration.json; bench_capacity runs the "
                        "eviction-policy + host-budget bake-off -> "
                        "BENCH_capacity.json; bench_paged compares paged "
                        "vs contiguous KV layouts at equal cache memory "
                        "-> BENCH_paged.json; bench_restore_batch sweeps "
                        "the grouped-restoration group size (dispatches, "
                        "projection wall time, makespan) -> "
                        "BENCH_restore_batch.json; bench_encdec compares "
                        "batched vs sequential whisper serving and "
                        "restore-vs-recompute TTFT -> BENCH_encdec.json; "
                        "bench_prefix compares prefix sharing on vs off "
                        "at an equal page pool -> BENCH_prefix.json; "
                        "bench_sched compares static vs calibrated vs "
                        "fetch-aligned restore plans under 1/2/4-way "
                        "concurrency -> BENCH_sched.json")
    args = p.parse_args()
    print("name,us_per_call,derived")
    if args.mode == "bench_restoration":
        from benchmarks.bench_restoration import run_pipeline_comparison
        rows = run_pipeline_comparison()
        print(f"# {len(rows)} rows -> BENCH_restoration.json",
              file=sys.stderr)
        return
    if args.mode == "bench_capacity":
        from benchmarks.bench_capacity import run_capacity_comparison
        rows = run_capacity_comparison()
        print(f"# {len(rows)} rows -> BENCH_capacity.json",
              file=sys.stderr)
        return
    if args.mode == "bench_paged":
        from benchmarks.bench_paged import run_paged_comparison
        rows = run_paged_comparison()
        print(f"# {len(rows)} rows -> BENCH_paged.json", file=sys.stderr)
        return
    if args.mode == "bench_restore_batch":
        from benchmarks.bench_restore_batch import run_restore_batch
        rows = run_restore_batch()
        print(f"# {len(rows)} rows -> BENCH_restore_batch.json",
              file=sys.stderr)
        return
    if args.mode == "bench_encdec":
        from benchmarks.bench_encdec import run_encdec_bench
        rows = run_encdec_bench()
        print(f"# {len(rows)} rows -> BENCH_encdec.json", file=sys.stderr)
        return
    if args.mode == "bench_prefix":
        from benchmarks.bench_prefix import run_prefix_comparison
        rows = run_prefix_comparison()
        print(f"# {len(rows)} rows -> BENCH_prefix.json", file=sys.stderr)
        return
    if args.mode == "bench_sched":
        from benchmarks.bench_sched import run_sched_bench
        rows = run_sched_bench()
        print(f"# {len(rows)} rows -> BENCH_sched.json", file=sys.stderr)
        return
    filters = args.only.split(",") if args.only else None
    t0 = time.time()
    n_rows = 0
    for label, module in SUITES:
        if filters and not any(f in label or f in module for f in filters):
            continue
        if args.smoke and module.rsplit(".", 1)[-1] not in SMOKE:
            continue
        print(f"# --- {label} ({module}) ---", file=sys.stderr)
        mod = __import__(module, fromlist=["run"])
        rows = mod.run()
        n_rows += len(rows)
    print(f"# {n_rows} rows in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
