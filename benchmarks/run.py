"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Run:
    PYTHONPATH=src python -m benchmarks.run [--only fig9,fig12] [--smoke]
    PYTHONPATH=src python -m benchmarks.run --mode bench_restoration

``--smoke`` runs the fast analytic suites only (CI gate). ``--mode X``
runs one special-mode entry and writes its ``BENCH_X.json`` artifact.
Everything — figure suites, smoke membership, mode names, artifacts —
is enumerated from the single ``REGISTRY`` below, so a new mode can't
be silently skipped by a stale hand-maintained list.
"""
from __future__ import annotations

import argparse
import sys
import time

# One entry per benchmark module. Fields:
#   label    — figure-suite label; present iff the module has a ``run()``
#              the default full sweep should execute
#   smoke    — label runs under --smoke (fast analytic, no forward pass)
#   mode     — ``--mode`` name; present iff the module has a special mode
#   entry    — the mode's entry function (writes ``artifact``)
#   artifact — JSON file the mode emits (CI uploads exactly these)
REGISTRY = [
    dict(label="fig1/kernels", module="benchmarks.bench_kernels"),
    dict(label="fig9/fig10 TTFT", module="benchmarks.bench_restoration",
         smoke=True, mode="bench_restoration",
         entry="run_pipeline_comparison", artifact="BENCH_restoration.json",
         help="blocking vs pipelined restoration TTFT"),
    dict(label="fig11 sensitivity", module="benchmarks.bench_sensitivity",
         smoke=True),
    dict(label="fig12 scheduler ablation", module="benchmarks.bench_sched",
         smoke=True, mode="bench_sched", entry="run_sched_bench",
         artifact="BENCH_sched.json",
         help="static vs calibrated vs fetch-aligned restore plans "
              "under 1/2/4-way concurrency"),
    dict(label="fig13 partition methods", module="benchmarks.bench_partition",
         smoke=True),
    dict(label="fig14 two-stage saving", module="benchmarks.bench_two_stage"),
    dict(label="fig15 kv reuse", module="benchmarks.bench_kv_reuse"),
    dict(label="table3 storage cost",
         module="benchmarks.bench_storage_cost", smoke=True),
    dict(module="benchmarks.bench_capacity", mode="bench_capacity",
         entry="run_capacity_comparison", artifact="BENCH_capacity.json",
         help="eviction-policy + host-budget bake-off"),
    dict(module="benchmarks.bench_paged", mode="bench_paged",
         entry="run_paged_comparison", artifact="BENCH_paged.json",
         help="paged vs contiguous KV layouts at equal cache memory"),
    dict(module="benchmarks.bench_restore_batch", mode="bench_restore_batch",
         entry="run_restore_batch", artifact="BENCH_restore_batch.json",
         help="grouped-restoration group-size sweep"),
    dict(module="benchmarks.bench_encdec", mode="bench_encdec",
         entry="run_encdec_bench", artifact="BENCH_encdec.json",
         help="batched vs sequential whisper serving and "
              "restore-vs-recompute TTFT"),
    dict(module="benchmarks.bench_prefix", mode="bench_prefix",
         entry="run_prefix_comparison", artifact="BENCH_prefix.json",
         help="prefix sharing on vs off at an equal page pool"),
    dict(module="benchmarks.bench_slo", mode="bench_slo",
         entry="run_slo_bench", artifact="BENCH_slo.json",
         help="front-door SLO harness: steered vs route-blind "
              "multi-tenant mix (DESIGN.md §14)"),
    dict(module="benchmarks.bench_distributed", mode="bench_distributed",
         entry="run_distributed_bench", artifact="BENCH_distributed.json",
         help="sharded restore across {1,2,4} hosts x both placements + "
              "sync vs async IO on real file reads (DESIGN.md §15)"),
    dict(module="benchmarks.bench_tp", mode="bench_tp",
         entry="run_tp_bench", artifact="BENCH_tp.json",
         help="tensor-parallel restore at tp={1,2,4}: modeled projection "
              "speedup + served byte-identity (DESIGN.md §16)"),
]

MODES = {e["mode"]: e for e in REGISTRY if "mode" in e}


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", default=None,
                   help="comma-separated substring filters")
    p.add_argument("--smoke", action="store_true",
                   help="fast analytic suites only (CI)")
    p.add_argument("--mode", default=None, choices=sorted(MODES),
                   help="special modes: " + "; ".join(
                       f"{m} — {e.get('help', e['entry'])} -> "
                       f"{e['artifact']}" for m, e in sorted(MODES.items())))
    args = p.parse_args()
    print("name,us_per_call,derived")
    if args.mode:
        e = MODES[args.mode]
        mod = __import__(e["module"], fromlist=[e["entry"]])
        rows = getattr(mod, e["entry"])()
        print(f"# {len(rows)} rows -> {e['artifact']}", file=sys.stderr)
        return
    filters = args.only.split(",") if args.only else None
    t0 = time.time()
    n_rows = 0
    for e in REGISTRY:
        label = e.get("label")
        if label is None:
            continue
        module = e["module"]
        if filters and not any(f in label or f in module for f in filters):
            continue
        if args.smoke and not e.get("smoke"):
            continue
        print(f"# --- {label} ({module}) ---", file=sys.stderr)
        mod = __import__(module, fromlist=["run"])
        rows = mod.run()
        n_rows += len(rows)
    print(f"# {n_rows} rows in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
