"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Run:
    PYTHONPATH=src python -m benchmarks.run [--only fig9,fig12]
"""
from __future__ import annotations

import argparse
import sys
import time

SUITES = [
    ("fig1/kernels", "benchmarks.bench_kernels"),
    ("fig9/fig10 TTFT", "benchmarks.bench_restoration"),
    ("fig11 sensitivity", "benchmarks.bench_sensitivity"),
    ("fig12 scheduler ablation", "benchmarks.bench_scheduler"),
    ("fig13 partition methods", "benchmarks.bench_partition"),
    ("fig14 two-stage saving", "benchmarks.bench_two_stage"),
    ("fig15 kv reuse", "benchmarks.bench_kv_reuse"),
    ("table3 storage cost", "benchmarks.bench_storage_cost"),
]


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", default=None,
                   help="comma-separated substring filters")
    args = p.parse_args()
    filters = args.only.split(",") if args.only else None
    print("name,us_per_call,derived")
    t0 = time.time()
    n_rows = 0
    for label, module in SUITES:
        if filters and not any(f in label or f in module for f in filters):
            continue
        print(f"# --- {label} ({module}) ---", file=sys.stderr)
        mod = __import__(module, fromlist=["run"])
        rows = mod.run()
        n_rows += len(rows)
    print(f"# {n_rows} rows in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
