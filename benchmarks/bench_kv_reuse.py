"""Paper Fig 15: on-GPU KV reuse with an LRU cache over Zipfian context
popularity — cache hit ratio + TTFT per restoration method on misses."""
from __future__ import annotations

from collections import OrderedDict

import numpy as np

from benchmarks.common import emit
from repro.config.hardware import PAPER_A100
from repro.configs import get_arch
from repro.core.pipeline import prefill_time, ttft
from repro.core.scheduler import solve
from repro.training.data import leval_trace

GPU_CACHE_CONTEXTS = 3          # ~A100-40G capacity for 7B @ 16k ctx


def run():
    rows = []
    cfg = get_arch("llama2-7b")
    n_ctx_tokens = 8192
    sched = solve(cfg, n_ctx_tokens, PAPER_A100)
    methods = {"hcache": sched.methods,
               "kv_offload": ["kv"] * cfg.n_layers,
               "recompute": ["recompute"] * cfg.n_layers}
    for alpha in (None, 0.5, 1.0, 2.0):
        trace = leval_trace(400, seed=3, zipf_alpha=alpha)
        lru: OrderedDict = OrderedDict()
        hits = 0
        ttfts = {k: [] for k in methods}
        for r in trace:
            if r.session_id in lru:
                hits += 1
                lru.move_to_end(r.session_id)
                hit_t = prefill_time(cfg, r.input_len, n_ctx_tokens,
                                     PAPER_A100)
                for k in methods:
                    ttfts[k].append(hit_t)
            else:
                lru[r.session_id] = True
                if len(lru) > GPU_CACHE_CONTEXTS:
                    lru.popitem(last=False)
                for k, scheme in methods.items():
                    ttfts[k].append(ttft(cfg, n_ctx_tokens, r.input_len,
                                         PAPER_A100, scheme))
        hr = hits / len(trace)
        base = np.mean(ttfts["hcache"])
        for k in methods:
            rows.append((
                f"fig15_zipf{alpha}_{k}", float(np.mean(ttfts[k])) * 1e6,
                f"hit_ratio={hr:.2f};vs_hcache="
                f"{np.mean(ttfts[k]) / base:.2f}x"))
    return emit(rows)
