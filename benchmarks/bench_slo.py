"""Multi-tenant SLO harness (DESIGN.md §14): the front door measured
end to end, steered vs route-blind at equal resources.

One seeded synthetic tenant mix, Poisson arrivals, served twice through
the exact ``FrontDoor.handle`` request path the HTTP binding exposes
(no sockets — the handler layer is the product):

  * **multi-round chat** — conversations that return every round with
    their growing transcript. Half pass the ``conversation_id`` back
    (exact router hit), half only resend the transcript (the router must
    recover them by prefix similarity);
  * **shared-system-prompt RAG** — one-shot requests over a common
    retrieval preamble plus a unique question (placement/displacement
    load on the slot table);
  * **enc-dec audio** — whisper requests through their own engine pump
    (frames on round 1; round 2 restores the paired self/cross state),
    coexisting with the text tenants.

Modes at EQUAL engine configuration (prefix sharing off on both — the
delta is routing, nothing else):

  * ``steered`` — ``SessionRouter(steer=True)``: exact/similarity hits
    trim the prompt to the new suffix and the engine restores the
    stored history (HCache restoration instead of recomputation);
  * ``blind``   — ``SessionRouter(steer=False)``: every request lands
    on a fresh session and re-prefills its full transcript.

TTFT is wall time from request send to the first streamed content
chunk; TBT from inter-chunk gaps — measured at the API surface, so
queueing, routing and restoration are all inside the number. The
acceptance criterion is steered beating blind by ≥1.3x p50 TTFT on
round-≥2 chat requests with byte-identical greedy transcripts per
conversation. Emits BENCH_slo.json for CI trending.
"""
from __future__ import annotations

import asyncio
import json
import time

import numpy as np

from benchmarks.common import emit

SEED = 0
N_CHAT = 4                      # conversations; even index -> passes conv id
ROUNDS = 3
N_RAG = 3
N_ENCDEC = 2
GEN_TOKENS = 6
MAX_BATCH = 4
MAX_SEQ = 256
BLOCK_SIZE = 16
ARRIVAL_MEAN_S = 0.03           # Poisson inter-arrival between clients
THINK_MEAN_S = 0.05             # per-round think time within a chat
ACCEPT_SPEEDUP = 1.3


def _build_lm():
    import jax
    import jax.numpy as jnp
    from repro.config.arch import reduced_for_smoke
    from repro.configs import get_arch
    from repro.distributed.sharding import default_rules
    from repro.launch.mesh import make_mesh
    from repro.models import Model
    from repro.models.module import split

    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = reduced_for_smoke(get_arch("llama2-7b"))
    model = Model(cfg, rules=default_rules(mesh), model_axis=1,
                  dtype=jnp.float32, remat="none")
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def _build_encdec():
    import jax
    import jax.numpy as jnp
    from repro.config.arch import reduced_for_smoke
    from repro.configs import get_arch
    from repro.distributed.sharding import default_rules
    from repro.launch.mesh import make_mesh
    from repro.models import Model
    from repro.models.module import split

    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = reduced_for_smoke(get_arch("whisper-medium"))
    model = Model(cfg, rules=default_rules(mesh), model_axis=1,
                  dtype=jnp.float32, remat="none")
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def _fresh_engine(model, params, *, max_batch, max_seq):
    from repro.config.hardware import PAPER_A100
    from repro.core.hcache import HCacheManager
    from repro.serving import InferenceEngine
    from repro.storage import ChunkStore, make_array

    store = ChunkStore(make_array("dram", 4), chunk_tokens=16)
    mgr = HCacheManager(model, store, hw=PAPER_A100,
                        schedule_override="hidden", store_dtype=np.float32)
    return InferenceEngine(model, params, mgr, max_batch=max_batch,
                           max_seq=max_seq, prefill_chunk=8)


# ---------------------------------------------------------------- workload
def _words(rng, n: int) -> str:
    letters = "abcdefghijklmnopqrstuvwxyz      "
    return "".join(letters[i]
                   for i in rng.integers(0, len(letters), n)).strip() or "x"


def _mk_workload(lm_cfg, enc_cfg):
    """The full tenant mix, generated once from SEED so both modes see
    byte-identical prompts, arrival offsets and think times."""
    rng = np.random.default_rng(SEED)
    clock = 0.0
    clients = []
    for c in range(N_CHAT):
        clock += float(rng.exponential(ARRIVAL_MEAN_S))
        clients.append({
            "kind": "chat", "name": f"chat{c}", "start": clock,
            "use_id": c % 2 == 0,
            "system": _words(rng, 24),
            "users": [_words(rng, int(rng.integers(10, 18)))
                      for _ in range(ROUNDS)],
            "think": [float(rng.exponential(THINK_MEAN_S))
                      for _ in range(ROUNDS)],
        })
    rag_system = _words(rng, 64)    # the shared retrieval preamble
    for r in range(N_RAG):
        clock += float(rng.exponential(ARRIVAL_MEAN_S))
        clients.append({
            "kind": "rag", "name": f"rag{r}", "start": clock,
            "system": rag_system,
            "users": [_words(rng, int(rng.integers(10, 18)))],
        })
    for a in range(N_ENCDEC):
        clock += float(rng.exponential(ARRIVAL_MEAN_S))
        clients.append({
            "kind": "encdec", "name": f"audio{a}", "start": clock,
            "frames": (rng.standard_normal(
                (20 + 4 * a, enc_cfg.d_model)) * 0.1).astype(np.float32),
            "prompts": [rng.integers(0, enc_cfg.vocab_size,
                                     8).astype(np.int32)
                        for _ in range(2)],
            "think": float(rng.exponential(THINK_MEAN_S)),
        })
    return clients


# ----------------------------------------------------------------- clients
async def _stream_round(api, body, sample):
    """POST a streaming chat round; fill ``sample`` with TTFT/TBT/route
    read off the SSE chunks exactly as an HTTP client would see them."""
    t_send = time.perf_counter()
    status, payload = await api.handle("POST", "/v1/chat/completions", body)
    assert status == 200, payload
    times, content, route, conv_id = [], [], None, None
    async for chunk in payload:
        if not chunk.startswith("data: ") or chunk.startswith("data: ["):
            continue
        obj = json.loads(chunk[len("data: "):])
        conv_id = obj.get("conversation_id", conv_id)
        if obj.get("hcache"):
            route = obj["hcache"]
        delta = obj["choices"][0].get("delta", {})
        if delta.get("content"):
            times.append(time.perf_counter())
            content.append(delta["content"])
    sample["ttft"] = times[0] - t_send
    sample["tbt"] = [b - a for a, b in zip(times, times[1:])]
    sample["route"] = route["route"]
    sample["matched_tokens"] = route["matched_tokens"]
    return "".join(content), conv_id


async def _run_chat(api, spec, samples, transcripts):
    await asyncio.sleep(spec["start"])
    messages = [{"role": "system", "content": spec["system"]},
                {"role": "user", "content": spec["users"][0]}]
    conv_id, out = None, []
    for rnd in range(ROUNDS):
        body = {"messages": messages, "max_tokens": GEN_TOKENS,
                "stream": True}
        if spec["use_id"] and conv_id is not None:
            body["conversation_id"] = conv_id
        sample = {"kind": "chat", "client": spec["name"], "round": rnd}
        content, conv_id = await _stream_round(api, body, sample)
        samples.append(sample)
        out.append(content)
        if rnd + 1 < ROUNDS:
            messages = messages + [
                {"role": "assistant", "content": content},
                {"role": "user", "content": spec["users"][rnd + 1]}]
            await asyncio.sleep(spec["think"][rnd])
    transcripts[spec["name"]] = out


async def _run_rag(api, spec, samples, transcripts):
    await asyncio.sleep(spec["start"])
    body = {"messages": [{"role": "system", "content": spec["system"]},
                         {"role": "user", "content": spec["users"][0]}],
            "max_tokens": GEN_TOKENS, "stream": True}
    sample = {"kind": "rag", "client": spec["name"], "round": 0}
    content, _ = await _stream_round(api, body, sample)
    samples.append(sample)
    transcripts[spec["name"]] = [content]


async def _run_encdec(pump, spec, samples, transcripts):
    from repro.serving import Request

    await asyncio.sleep(spec["start"])
    out = []
    for rnd, prompt in enumerate(spec["prompts"]):
        req = Request(spec["name"], prompt, max_new_tokens=GEN_TOKENS,
                      frames=spec["frames"] if rnd == 0 else None)
        sub = pump.submit(req)
        async for _ in sub.events():
            pass
        samples.append({"kind": "encdec", "client": spec["name"],
                        "round": rnd, "ttft": sub.ttft, "tbt": sub.tbt,
                        "route": "restore" if rnd else "fresh",
                        "matched_tokens": 0})
        out.append(list(sub.tokens))
        if rnd + 1 < len(spec["prompts"]):
            await asyncio.sleep(spec["think"])
    transcripts[spec["name"]] = out


# -------------------------------------------------------------------- mode
def _pcts(xs):
    if not xs:
        return {"p50_s": 0.0, "p99_s": 0.0, "mean_s": 0.0, "n": 0}
    a = np.asarray(xs, np.float64)
    return {"p50_s": float(np.percentile(a, 50)),
            "p99_s": float(np.percentile(a, 99)),
            "mean_s": float(a.mean()), "n": int(a.size)}


async def _run_mode(lm, enc, clients, *, steer: bool):
    from repro.frontend import EnginePump, FrontDoor, SessionRouter

    lm_cfg, lm_model, lm_params = lm
    enc_cfg, enc_model, enc_params = enc
    engine = _fresh_engine(lm_model, lm_params, max_batch=MAX_BATCH,
                           max_seq=MAX_SEQ)
    enc_engine = _fresh_engine(enc_model, enc_params, max_batch=N_ENCDEC,
                               max_seq=96)
    pump = EnginePump(engine).start()
    enc_pump = EnginePump(enc_engine).start()
    router = SessionRouter(engine, n_slots=N_CHAT + N_RAG + 1,
                           block_size=BLOCK_SIZE, steer=steer)
    api = FrontDoor(pump, router)
    samples, transcripts = [], {}
    t0 = time.perf_counter()
    tasks = []
    for spec in clients:
        if spec["kind"] == "chat":
            tasks.append(_run_chat(api, spec, samples, transcripts))
        elif spec["kind"] == "rag":
            tasks.append(_run_rag(api, spec, samples, transcripts))
        else:
            tasks.append(_run_encdec(enc_pump, spec, samples, transcripts))
    await asyncio.gather(*tasks)
    wall = time.perf_counter() - t0
    metrics = engine.metrics.to_dict()
    enc_metrics = enc_engine.metrics.to_dict()
    stats = {
        "steer": steer,
        "wall_s": wall,
        "requests": len(samples),
        "ttft": _pcts([s["ttft"] for s in samples]),
        "tbt": _pcts([t for s in samples for t in s["tbt"]]),
        "chat_round2plus_ttft": _pcts(
            [s["ttft"] for s in samples
             if s["kind"] == "chat" and s["round"] >= 1]),
        "by_kind": {k: _pcts([s["ttft"] for s in samples
                              if s["kind"] == k])
                    for k in ("chat", "rag", "encdec")},
        "routes": {k: sum(1 for s in samples if s["route"] == k)
                   for k in ("exact", "restore", "fork", "fresh")},
        "router": router.stats(),
        "engine": metrics,
        "enc_engine": {"restored_tokens": enc_metrics["restored_tokens"],
                       "ttft_wall_restored":
                           enc_metrics["ttft_wall_restored"],
                       "ttft_wall_cold": enc_metrics["ttft_wall_cold"]},
    }
    pump.close()
    enc_pump.close()
    return stats, transcripts


def _warmup(lm, enc):
    """Compile the prefill/decode/restore/save paths once so neither
    measured mode pays jit time (the first mode to run would otherwise
    eat every compile)."""
    from repro.serving import Request

    lm_cfg, lm_model, lm_params = lm
    enc_cfg, enc_model, enc_params = enc
    rng = np.random.default_rng(99)
    engine = _fresh_engine(lm_model, lm_params, max_batch=MAX_BATCH,
                           max_seq=MAX_SEQ)
    p1 = rng.integers(0, lm_cfg.vocab_size, 70).astype(np.int32)
    engine.submit(Request("warm", p1, max_new_tokens=GEN_TOKENS))
    engine.run()
    engine.submit(Request("warm", rng.integers(
        0, lm_cfg.vocab_size, 40).astype(np.int32),
        max_new_tokens=GEN_TOKENS))
    engine.run()                    # round 2: the restore path compiles
    engine.close()
    engine = _fresh_engine(enc_model, enc_params, max_batch=N_ENCDEC,
                           max_seq=96)
    frames = (rng.standard_normal((20, enc_cfg.d_model)) * 0.1
              ).astype(np.float32)
    engine.submit(Request("warm", rng.integers(
        0, enc_cfg.vocab_size, 8).astype(np.int32),
        max_new_tokens=GEN_TOKENS, frames=frames))
    engine.run()
    engine.submit(Request("warm", rng.integers(
        0, enc_cfg.vocab_size, 8).astype(np.int32),
        max_new_tokens=GEN_TOKENS))
    engine.run()
    engine.close()


def run_slo_bench(out_path: str = "BENCH_slo.json"):
    lm = _build_lm()
    enc = _build_encdec()
    clients = _mk_workload(lm[0], enc[0])
    _warmup(lm, enc)
    results = {"workload": {
        "chat_conversations": N_CHAT, "rounds": ROUNDS,
        "rag_requests": N_RAG, "encdec_sessions": N_ENCDEC,
        "gen_tokens": GEN_TOKENS, "max_batch": MAX_BATCH,
        "arrival_mean_s": ARRIVAL_MEAN_S, "think_mean_s": THINK_MEAN_S,
        "seed": SEED}, "modes": {}}
    outs = {}
    rows = []
    for label, steer in (("steered", True), ("blind", False)):
        stats, transcripts = asyncio.run(_run_mode(lm, enc, clients,
                                                   steer=steer))
        results["modes"][label] = stats
        outs[label] = transcripts
        rows.append((
            f"bench_slo_{label}", stats["ttft"]["p50_s"] * 1e6,
            f"round2_ttft_p50_us="
            f"{stats['chat_round2plus_ttft']['p50_s'] * 1e6:.0f};"
            f"tbt_p99_us={stats['tbt']['p99_s'] * 1e6:.0f};"
            f"hit_rate={stats['router']['hit_rate']:.2f};"
            f"restored={stats['engine']['restored_tokens']}"))
    st = results["modes"]["steered"]
    bl = results["modes"]["blind"]
    results["outputs_identical"] = outs["steered"] == outs["blind"]
    results["acceptance_speedup"] = (
        bl["chat_round2plus_ttft"]["p50_s"]
        / max(st["chat_round2plus_ttft"]["p50_s"], 1e-9))
    results["acceptance_met"] = bool(
        results["acceptance_speedup"] >= ACCEPT_SPEEDUP
        and results["outputs_identical"])
    results["restore_vs_recompute"] = {
        "steered_restored_tokens": st["engine"]["restored_tokens"],
        "blind_restored_tokens": bl["engine"]["restored_tokens"],
        "steered_ttft_wall_restored": st["engine"]["ttft_wall_restored"],
        "blind_ttft_wall_cold": bl["engine"]["ttft_wall_cold"],
    }
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    rows.append(("bench_slo_acceptance", 0.0,
                 f"{results['acceptance_speedup']:.2f}x;"
                 f"met={results['acceptance_met']};"
                 f"identical={results['outputs_identical']}"))
    return emit(rows)


if __name__ == "__main__":
    run_slo_bench()
