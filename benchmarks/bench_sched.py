"""Scheduler benchmarks: the paper's Fig 12 ablation (``run``) and the
self-calibrating bake-off (``run_sched_bench``, DESIGN.md §13).

Fig 12: bubble-free scheduler — HCACHE (full) vs HCACHE-O (hidden only,
no complementary method) vs naive hybrid (recompute+KV mix, no hidden
states) under balanced / compute-sufficient / IO-sufficient platforms.

Self-calibrating bake-off:

The datasheet says the machine is a PAPER_A100; the machine actually
delivers ~40% of the datasheet storage bandwidth, ~75% of the sustained
GEMM fraction, and a 25 µs per-dispatch overhead (the usual shape of the
gap: shared PCIe lanes, filesystem overhead, launch latency). Three
planners restore the same session under the TRUE machine at 1/2/4-way
restore concurrency:

  * static          — solve() + uniform group 8 priced off the datasheet
                      (what the seed shipped),
  * calibrated      — solve() + auto group size priced off a
                      MeasuredProfile fitted to the true machine and the
                      current IO multiplicity,
  * calibrated+fetch — calibrated split with the fetch-aligned
                      non-uniform group partition.

Every plan is scored by the SAME judge: the two-stream replay of its
compiled task graph under the true machine's times at that multiplicity.
The acceptance criterion is calibrated+fetch beating static by ≥1.2x
makespan under 4-way concurrency. Emits BENCH_sched.json for CI
trending. Fully analytic — no model forward pass.
"""
from __future__ import annotations

import json

from benchmarks.common import emit

ARCH = "llama2-13b"
N_TOKENS = 2048
STATIC_GROUP = 8
STREAMS = (1, 2, 4)
# the synthetic "true machine": how it diverges from its datasheet
TRUE_STORAGE = 0.4
TRUE_FLOPS = 0.75
TRUE_OVERHEAD = 25e-6
CALIBRATION_ROUNDS = 2          # "converges within a few restores"


def _true_profile(guess):
    return guess.derated(storage=TRUE_STORAGE, flops=TRUE_FLOPS,
                         dispatch_overhead=TRUE_OVERHEAD)


def _measure(cfg, true_hw):
    """The profile the executor would converge to: per-kind (work,
    seconds) observations priced under the true machine, including the
    per-dispatch overhead the intercept fit recovers."""
    from repro.core.cost_model import layer_costs, method_times
    from repro.core.profiler import MeasuredProfile

    p = MeasuredProfile()
    for _ in range(CALIBRATION_ROUNDS):
        for bucket in (N_TOKENS // 2, N_TOKENS):
            c = layer_costs(cfg, bucket)[0]
            t = method_times(c, true_hw)
            p.record("io_h", bucket, c.io_hidden, t.io_h)
            p.record("io_kv", bucket, c.io_kv, t.io_kv)
            p.record("project", bucket, c.c_hidden,
                     t.c_h + TRUE_OVERHEAD)
            p.record("recompute", bucket, c.c_token,
                     t.c_token + TRUE_OVERHEAD)
    return p


def _score(cfg, methods, group, true_hw, streams):
    """Replay a plan's compiled graph under the TRUE machine at the
    given restore multiplicity — the one judge every planner faces."""
    from repro.core.cost_model import layer_costs, method_times
    from repro.core.restoration import compile_tasks, replay

    times = [method_times(c, true_hw, io_streams=streams)
             for c in layer_costs(cfg, N_TOKENS)]
    tasks = compile_tasks(tuple(methods), group_size=group)
    tl = replay(tasks, times, dispatch_overhead=TRUE_OVERHEAD)
    return tl


def run():
    """Paper Fig 12 ablation (the analytic smoke suite entry)."""
    import dataclasses

    from repro.config.hardware import GB, PAPER_A100
    from repro.configs import get_arch
    from repro.core.pipeline import restore_timeline
    from repro.core.scheduler import solve

    settings = {
        "balanced": PAPER_A100,
        "compute_sufficient": dataclasses.replace(
            PAPER_A100, flops=990e12, storage_bw=6.9 * GB),
        "io_sufficient": dataclasses.replace(
            PAPER_A100, flops=80e12, storage_bw=16 * 6.9 * GB),
    }
    rows = []
    cfg = get_arch("llama2-13b")
    n = 4096
    for name, hw in settings.items():
        full = solve(cfg, n, hw)
        only_h = solve(cfg, n, hw, force_hidden=True)
        # naive hybrid = scheduler WITHOUT hidden states
        best_naive = None
        for n_kv in range(cfg.n_layers + 1):
            methods = (["recompute"] * (cfg.n_layers - n_kv)
                       + ["kv"] * n_kv)
            t = restore_timeline(cfg, n, hw, methods).makespan
            if best_naive is None or t < best_naive[0]:
                best_naive = (t, methods)
        t_full = restore_timeline(cfg, n, hw, full.methods).makespan
        t_only = restore_timeline(cfg, n, hw, only_h.methods).makespan
        t_kv = restore_timeline(cfg, n, hw, ["kv"] * cfg.n_layers).makespan
        rows.append((f"fig12_{name}_hcache", t_full * 1e6,
                     f"sched={full.summary().split('|')[0].strip()}"))
        rows.append((f"fig12_{name}_hcache_only", t_only * 1e6,
                     f"vs_full={t_only / t_full:.2f}x"))
        rows.append((f"fig12_{name}_naive_hybrid", best_naive[0] * 1e6,
                     f"vs_full={best_naive[0] / t_full:.2f}x"))
        rows.append((f"fig12_{name}_kv_offload", t_kv * 1e6,
                     f"vs_full={t_kv / t_full:.2f}x"))
    return emit(rows)


def run_sched_bench(out_path: str = "BENCH_sched.json"):
    from repro.config.hardware import PAPER_A100
    from repro.configs import get_arch
    from repro.core.restoration import choose_group_size
    from repro.core.scheduler import solve

    cfg = get_arch(ARCH)
    guess = PAPER_A100
    true_hw = _true_profile(guess)
    profile = _measure(cfg, true_hw)

    results = {"workload": {"arch": ARCH, "n_tokens": N_TOKENS,
                            "true_storage_frac": TRUE_STORAGE,
                            "true_flops_frac": TRUE_FLOPS,
                            "true_dispatch_overhead_s": TRUE_OVERHEAD,
                            "calibration_rounds": CALIBRATION_ROUNDS},
               "streams": {}}
    rows = []
    static_sched = solve(cfg, N_TOKENS, guess)
    for m in STREAMS:
        cal_sched = solve(cfg, N_TOKENS, guess, profile=profile,
                          io_streams=m)
        cal_group = choose_group_size(cfg, guess, N_TOKENS,
                                      cal_sched.methods, profile=profile,
                                      io_streams=m)
        fetch_group = choose_group_size(cfg, guess, N_TOKENS,
                                        cal_sched.methods,
                                        profile=profile, io_streams=m,
                                        fetch_aligned=True)
        plans = {
            "static": (static_sched.methods, STATIC_GROUP),
            "calibrated": (cal_sched.methods, cal_group),
            "calibrated_fetch": (cal_sched.methods, fetch_group),
        }
        per = {}
        for name, (methods, group) in plans.items():
            tl = _score(cfg, methods, group, true_hw, m)
            bubble = max(tl.io_bubble, tl.compute_bubble)
            per[name] = {
                "makespan_s": tl.makespan,
                "bubble": bubble,
                "counts": {k: list(methods).count(k)
                           for k in ("hidden", "kv", "recompute")},
                "group": (list(group) if isinstance(group, tuple)
                          else group),
            }
            rows.append((f"bench_sched_m{m}_{name}",
                         tl.makespan * 1e6,
                         f"bubble={bubble:.3f};group={group}"))
        per["speedup_calibrated"] = (per["static"]["makespan_s"]
                                     / per["calibrated"]["makespan_s"])
        per["speedup_calibrated_fetch"] = (
            per["static"]["makespan_s"]
            / per["calibrated_fetch"]["makespan_s"])
        results["streams"][str(m)] = per

    final = results["streams"][str(STREAMS[-1])]
    results["acceptance_speedup_4way"] = final["speedup_calibrated_fetch"]
    results["acceptance_met"] = final["speedup_calibrated_fetch"] >= 1.2
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    rows.append(("bench_sched_acceptance_4way_speedup",
                 0.0, f"{final['speedup_calibrated_fetch']:.2f}x;"
                 f"met={results['acceptance_met']}"))
    return emit(rows)


if __name__ == "__main__":
    run_sched_bench()
