"""Fig 1 analog + kernel microbench: per-layer restoration resource costs
(compute FLOPs, IO bytes) for every model, plus the Pallas restore_kv
kernel's interpret-mode wall time vs the jnp oracle (CPU-indicative only;
the TPU numbers come from the roofline model)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.config.hardware import GEMM_EFFICIENCY, TPU_V5E
from repro.configs import get_arch
from repro.core.cost_model import layer_costs
from repro.kernels import ops, ref


def run():
    rows = []
    # Fig 1: resource comparison per token per layer
    for m in ("llama2-7b", "qwen2-7b", "gemma2-9b", "grok-1-314b"):
        cfg = get_arch(m)
        c = layer_costs(cfg, 1024)[0]
        rows.append((
            f"fig1_resources_{m}", 0.0,
            f"compute_saving_vs_rec={c.c_token / c.c_hidden:.1f}x;"
            f"io_vs_kv={c.io_kv / c.io_hidden:.2f}x"))
        # modeled MXU time of the fused restore kernel per 1k tokens
        t_mxu = c.c_hidden / (TPU_V5E.flops * GEMM_EFFICIENCY)
        rows.append((f"kernel_restore_kv_model_{m}", t_mxu * 1e6,
                     "modeled_v5e_us_per_1k_tokens_per_layer"))

    # interpret-mode microbench (correctness-path cost, not TPU perf)
    S, D, Kv, hd = 128, 256, 4, 64
    rng = np.random.default_rng(0)
    h = jnp.asarray(rng.normal(size=(S, D)), jnp.float32)
    wk = jnp.asarray(rng.normal(size=(D, Kv * hd)) * D ** -0.5, jnp.float32)
    wv = jnp.asarray(rng.normal(size=(D, Kv * hd)) * D ** -0.5, jnp.float32)
    ang = (jnp.arange(S, dtype=jnp.float32)[:, None]
           * 10000.0 ** (-jnp.arange(hd // 2) / (hd // 2)))
    cos, sin = jnp.cos(ang), jnp.sin(ang)

    def pallas_call():
        k, v = ops.restore_kv(h, wk, wv, None, None, cos, sin, head_dim=hd,
                              use_pallas=True)
        k.block_until_ready()

    def ref_call():
        k, v = ref.restore_kv_ref(h, wk, wv, None, None, cos, sin,
                                  head_dim=hd)
        k.block_until_ready()

    pallas_call()
    ref_call()
    rows.append(("kernel_restore_kv_interpret", timed(pallas_call),
                 "pallas_interpret_cpu"))
    rows.append(("kernel_restore_kv_ref", timed(ref_call), "jnp_oracle_cpu"))
    return emit(rows)
