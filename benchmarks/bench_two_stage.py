"""Paper Fig 14: two-stage saving vs DirectIO — TBT impact vs decode batch.

Virtual-time model: per decode step each layer produces (batch, 1, D)
hidden states. Two-stage charges the host-copy time (DRAM BW); DirectIO
charges the SSD write time whenever it exceeds the layer's decode compute
time (write stalls the pipeline). TBT = layer_time + stall, summed over
layers."""
from __future__ import annotations

from benchmarks.common import emit
from repro.config.hardware import DRAM_BW, PAPER_A100, SSD_WRITE_BW
from repro.configs import get_arch
from repro.core.pipeline import decode_step_time

HIST = 512


def run():
    rows = []
    for m in ("llama2-7b", "llama2-13b"):
        cfg = get_arch(m)
        for batch in (1, 4, 8, 16, 32):
            layer_t = decode_step_time(cfg, batch, HIST,
                                       PAPER_A100) / cfg.n_layers
            h_bytes = batch * cfg.d_model * 2
            copy_t = h_bytes / DRAM_BW
            ssd_t = h_bytes / SSD_WRITE_BW + 80e-6 / 8  # amortized IO lat.
            tbt_ideal = layer_t * cfg.n_layers
            tbt_two = (layer_t + copy_t) * cfg.n_layers
            tbt_direct = (layer_t + max(ssd_t - layer_t, 0.0)
                          + copy_t) * cfg.n_layers
            rows.append((f"fig14_{m}_b{batch}_two_stage", tbt_two * 1e6,
                         f"overhead={(tbt_two / tbt_ideal - 1) * 100:.1f}%"))
            rows.append((f"fig14_{m}_b{batch}_directio", tbt_direct * 1e6,
                         f"overhead={(tbt_direct / tbt_ideal - 1) * 100:.1f}%"))
    return emit(rows)
