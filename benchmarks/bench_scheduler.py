"""Paper Fig 12: bubble-free scheduler ablation — HCACHE (full) vs
HCACHE-O (hidden only, no complementary method) vs naive hybrid
(recompute+KV mix, no hidden states) under balanced / compute-sufficient /
IO-sufficient platforms."""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit
from repro.config.hardware import GB, PAPER_A100
from repro.configs import get_arch
from repro.core.pipeline import restore_timeline
from repro.core.scheduler import solve

SETTINGS = {
    "balanced": PAPER_A100,
    "compute_sufficient": dataclasses.replace(
        PAPER_A100, flops=990e12, storage_bw=6.9 * GB),
    "io_sufficient": dataclasses.replace(
        PAPER_A100, flops=80e12, storage_bw=16 * 6.9 * GB),
}


def run():
    rows = []
    cfg = get_arch("llama2-13b")
    n = 4096
    for name, hw in SETTINGS.items():
        full = solve(cfg, n, hw)
        only_h = solve(cfg, n, hw, force_hidden=True)
        naive = solve(cfg, n, hw, allow_kv=True, allow_recompute=True)
        # naive hybrid = scheduler WITHOUT hidden states
        import itertools
        best_naive = None
        for n_kv in range(cfg.n_layers + 1):
            methods = (["recompute"] * (cfg.n_layers - n_kv)
                       + ["kv"] * n_kv)
            t = restore_timeline(cfg, n, hw, methods).makespan
            if best_naive is None or t < best_naive[0]:
                best_naive = (t, methods)
        t_full = restore_timeline(cfg, n, hw, full.methods).makespan
        t_only = restore_timeline(cfg, n, hw, only_h.methods).makespan
        t_kv = restore_timeline(cfg, n, hw, ["kv"] * cfg.n_layers).makespan
        rows.append((f"fig12_{name}_hcache", t_full * 1e6,
                     f"sched={full.summary().split('|')[0].strip()}"))
        rows.append((f"fig12_{name}_hcache_only", t_only * 1e6,
                     f"vs_full={t_only / t_full:.2f}x"))
        rows.append((f"fig12_{name}_naive_hybrid", best_naive[0] * 1e6,
                     f"vs_full={best_naive[0] / t_full:.2f}x"))
        rows.append((f"fig12_{name}_kv_offload", t_kv * 1e6,
                     f"vs_full={t_kv / t_full:.2f}x"))
    return emit(rows)
