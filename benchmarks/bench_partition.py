"""Paper Fig 13: layer-wise vs token-wise state partition.

Token-wise partitions produce irregular GEMM shapes that the matmul unit
executes at reduced efficiency (the paper measures cuBLAS; we model the
same effect with a tile-quantization efficiency curve: eff = n_tokens /
(ceil(n_tokens / tile) * tile), tile = 256 — the MXU analog)."""
from __future__ import annotations

import math

from benchmarks.common import emit
from repro.config.hardware import GB, PAPER_A100
from repro.configs import get_arch
from repro.core.cost_model import layer_costs, method_times
from repro.core.pipeline import restore_timeline, simulate
from repro.core.scheduler import solve

TILE = 256


def gemm_eff(n_tokens: int) -> float:
    return n_tokens / (math.ceil(n_tokens / TILE) * TILE)


def token_wise_time(cfg, n, hw, n_hidden_tokens, round_up=False):
    """All layers split tokens: n_hidden via HCache, rest via KV offload."""
    if round_up:
        n_hidden_tokens = min(
            (n_hidden_tokens + TILE - 1) // TILE * TILE, n)
    t = method_times(layer_costs(cfg, n, 2)[0], hw)
    frac_h = n_hidden_tokens / n
    eff = gemm_eff(n_hidden_tokens)
    compute = cfg.n_layers * t.c_h * frac_h / max(eff, 1e-6)
    io = cfg.n_layers * (t.io_h * frac_h + t.io_kv * (1 - frac_h))
    return max(compute, io)


def run():
    rows = []
    import dataclasses
    cfg = get_arch("llama2-13b")
    n = 1024
    hw = dataclasses.replace(PAPER_A100, storage_bw=6.9 * GB)  # 1 SSD
    layer = solve(cfg, n, hw)
    t_layer = restore_timeline(cfg, n, hw, layer.methods).makespan

    best_naive = min(
        (token_wise_time(cfg, n, hw, k) for k in range(64, n + 1, 10)))
    best_round = min(
        (token_wise_time(cfg, n, hw, k, round_up=True)
         for k in range(64, n + 1, 10)))
    rows.append(("fig13_layerwise", t_layer * 1e6,
                 f"sched={layer.summary().split('|')[0].strip()}"))
    rows.append(("fig13_tokenwise_naive", best_naive * 1e6,
                 f"slowdown={best_naive / t_layer:.3f}x"))
    rows.append(("fig13_tokenwise_roundup", best_round * 1e6,
                 f"slowdown={best_round / t_layer:.3f}x"))
    # Fig 13b: GEMM time vs token count (tile quantization)
    for k in (256, 512, 700, 768, 794, 1000, 1024):
        t = method_times(layer_costs(cfg, k, 2)[0], hw)
        rows.append((f"fig13b_gemm_{k}tok",
                     t.c_h / max(gemm_eff(k), 1e-6) * 1e6,
                     f"eff={gemm_eff(k):.3f}"))
    return emit(rows)
