"""Shared benchmark helpers. Every bench prints ``name,us_per_call,derived``
CSV rows (the harness contract) and returns a list of row tuples."""
from __future__ import annotations

import time
from typing import Callable, Iterable, List, Tuple

Row = Tuple[str, float, str]


def emit(rows: Iterable[Row]) -> List[Row]:
    rows = list(rows)
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")
    return rows


def timed(fn: Callable, *args, repeat: int = 3, **kw) -> float:
    """Median wall time of fn in microseconds."""
    times = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn(*args, **kw)
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]
