"""Distributed ChunkStore bake-off (DESIGN.md §15).

Three questions, one artifact (``BENCH_distributed.json``):

  1. Does cross-host striping actually cut the restore makespan? A real
     session is restored through the executor over {1, 2, 4} SSD-backed
     host shards under both placements; the virtual-clock timeline (the
     same per-link replay the planner prices with) is the judge.
     Acceptance: 4-shard striped ≥ 1.5x over 1-shard.
  2. Does the async IO engine beat sync inline IO on WALL-CLOCK TTFT
     when the reads are real? The same restore over ``FileBackend``
     shards (np.load from disk), sync vs engine-attached — the engine
     fans reads over per-shard workers that overlap the projection
     compute, sync blocks the executor thread per stripe.
  3. Are restored caches byte-identical across every shard count and
     placement? (If not, nothing else matters.)

Runs the reduced-smoke model — the restore graph, store, links and IO
engine are the real ones; only the transformer is shrunk.
"""
from __future__ import annotations

import json
import shutil
import tempfile
import time

from benchmarks.common import emit

N_TOKENS = 2048
CHUNK_TOKENS = 64
SHARD_COUNTS = (1, 2, 4)
DEVS_PER_SHARD = 2
GROUP_SIZE = 2                  # several projections -> overlap window
ACCEPT_SPEEDUP = 1.5


def _setup():
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.config.arch import reduced_for_smoke
    from repro.configs import get_arch
    from repro.distributed.sharding import default_rules
    from repro.launch.mesh import make_mesh
    from repro.models import Model
    from repro.models.module import split

    mesh = make_mesh((1, 1), ("data", "model"))
    # wider + deeper than the smoke config: the wall-clock comparison
    # needs real bytes on disk (8 layers x 2048 tokens x 256 dims), but
    # still CPU-friendly
    # GQA (1 kv head) keeps the projection compute small relative to the
    # hidden-state bytes on disk — the regime where restoration is
    # IO-bound and overlapping IO with compute pays
    cfg = dataclasses.replace(reduced_for_smoke(get_arch("llama2-7b")),
                              n_layers=8, d_model=256, head_dim=64,
                              n_kv_heads=1, d_ff=512)
    model = Model(cfg, rules=default_rules(mesh), model_axis=1,
                  dtype=jnp.float32, remat="none")
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, N_TOKENS), 0,
                              cfg.vocab_size)
    pre = model.prefill(params, {"tokens": toks}, capture_hidden=True)
    return model, params, np.asarray(toks[0]), pre


def _drop_page_cache(root):
    """fadvise(DONTNEED) every stored file: a restore happens long after
    its save (the session was evicted), so the OS page cache is cold —
    without this the np.load reads are warm memcpys and the sync/async
    comparison measures the cache, not the IO."""
    import os
    for dirpath, _, files in os.walk(root):
        for name in files:
            try:
                fd = os.open(os.path.join(dirpath, name), os.O_RDONLY)
                try:
                    os.fsync(fd)
                    os.posix_fadvise(fd, 0, 0, os.POSIX_FADV_DONTNEED)
                finally:
                    os.close(fd)
            except OSError:
                pass


def _restore(model, params, tokens, pre, store, io_engine=None,
             cold_root=None):
    """One full executor restore; returns (cache_k, cache_v,
    virtual_makespan_s, wall_s)."""
    import numpy as np

    from repro.config.hardware import PAPER_A100
    from repro.core.hcache import HCacheManager
    from repro.core.restoration import CacheAssembler, RestorationExecutor

    mgr = HCacheManager(model, store, hw=PAPER_A100,
                        schedule_override="hidden",
                        restore_group_size=GROUP_SIZE)
    mgr.save_prefill("s", tokens, pre)
    if cold_root is not None:
        _drop_page_cache(cold_root)
    if io_engine is not None:
        store.attach_io_engine(io_engine)
    sink = CacheAssembler(model)
    t0 = time.perf_counter()
    ex = RestorationExecutor(mgr, params, "s", sink=sink)
    while not ex.step(max_tasks=4):
        pass
    wall = time.perf_counter() - t0
    return (np.asarray(sink.cache["k"]), np.asarray(sink.cache["v"]),
            ex.timeline().makespan, wall)


def run_distributed_bench(out_path: str = "BENCH_distributed.json"):
    import numpy as np

    from repro.storage import AsyncIOEngine, ChunkStore, make_array, \
        make_shards

    model, params, tokens, pre = _setup()
    results = {"workload": {"arch": "llama2-7b (reduced)",
                            "n_tokens": N_TOKENS,
                            "chunk_tokens": CHUNK_TOKENS,
                            "devices_per_shard": DEVS_PER_SHARD},
               "virtual": {}, "wall": {}}
    rows = []

    # baseline cache for byte-identity
    k0, v0, _, _ = _restore(model, params, tokens, pre,
                            ChunkStore(make_array("dram", 2),
                                       chunk_tokens=CHUNK_TOKENS))
    identical = True

    # 1 + 3: virtual-clock makespan across the shard matrix + identity
    for placement in ("layer", "chunk"):
        for n in SHARD_COUNTS:
            store = ChunkStore(shards=make_shards(n, DEVS_PER_SHARD, "ssd"),
                               chunk_tokens=CHUNK_TOKENS,
                               placement=placement)
            k, v, makespan, _ = _restore(model, params, tokens, pre, store)
            store.close()
            same = (np.array_equal(k, k0) and np.array_equal(v, v0))
            identical = identical and same
            results["virtual"][f"{placement}_x{n}"] = {
                "restore_makespan_ms": makespan * 1e3,
                "byte_identical": bool(same)}
            rows.append((f"bench_distributed_{placement}_x{n}",
                         makespan * 1e6, f"identical={same}"))

    v1 = results["virtual"]["layer_x1"]["restore_makespan_ms"]
    v4 = results["virtual"]["layer_x4"]["restore_makespan_ms"]
    speedup = v1 / v4 if v4 > 0 else float("inf")
    results["virtual"]["speedup_4shard_layer"] = speedup

    # 2: sync inline vs async engine on real file IO, best of 3
    root = tempfile.mkdtemp(prefix="bench_dist_")
    try:
        walls = {"sync": [], "async": []}
        ident_async = True
        for rep in range(3):
            for mode in ("sync", "async"):
                store = ChunkStore(
                    shards=make_shards(4, DEVS_PER_SHARD, "file",
                                       root=f"{root}/{mode}{rep}"),
                    chunk_tokens=CHUNK_TOKENS, placement="layer")
                eng = AsyncIOEngine(4) if mode == "async" else None
                k, v, _, wall = _restore(model, params, tokens, pre,
                                         store, io_engine=eng,
                                         cold_root=f"{root}/{mode}{rep}")
                store.close()
                walls[mode].append(wall)
                if mode == "async":
                    ident_async = ident_async and np.array_equal(k, k0)
        sync_wall = min(walls["sync"])
        async_wall = min(walls["async"])
        identical = identical and ident_async
    finally:
        shutil.rmtree(root, ignore_errors=True)
    results["wall"] = {
        "file_backend_sync_restore_s": sync_wall,
        "file_backend_async_restore_s": async_wall,
        "async_speedup": sync_wall / async_wall if async_wall else 0.0}
    rows.append(("bench_distributed_file_sync", sync_wall * 1e6, ""))
    rows.append(("bench_distributed_file_async", async_wall * 1e6,
                 f"speedup={sync_wall / async_wall:.2f}x"))

    results["acceptance_speedup_4shard"] = speedup
    results["acceptance_async_beats_sync"] = bool(async_wall < sync_wall)
    results["acceptance_byte_identical"] = bool(identical)
    results["acceptance_met"] = bool(speedup >= ACCEPT_SPEEDUP
                                     and async_wall < sync_wall
                                     and identical)
    rows.append(("bench_distributed_acceptance", speedup,
                 f"met={results['acceptance_met']}"))
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    emit(rows)
    print(f"wrote {out_path} (4-shard speedup {speedup:.2f}x, async "
          f"{sync_wall / async_wall:.2f}x, identical={identical})")
    return results


if __name__ == "__main__":
    run_distributed_bench()
