"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("S,D,Kv,hd", [(32, 64, 2, 16), (128, 128, 4, 32),
                                       (64, 256, 1, 64), (96, 64, 2, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bias", [False, True])
def test_restore_kv_sweep(S, D, Kv, hd, dtype, bias):
    h = jnp.asarray(RNG.normal(size=(S, D)), dtype)
    wk = jnp.asarray(RNG.normal(size=(D, Kv * hd)) * D ** -0.5, dtype)
    wv = jnp.asarray(RNG.normal(size=(D, Kv * hd)) * D ** -0.5, dtype)
    bk = jnp.asarray(RNG.normal(size=(Kv * hd,)) * 0.1, dtype) if bias \
        else None
    bv = jnp.asarray(RNG.normal(size=(Kv * hd,)) * 0.1, dtype) if bias \
        else None
    ang = (jnp.arange(S, dtype=jnp.float32)[:, None]
           * 10000.0 ** (-jnp.arange(hd // 2) / (hd // 2)))
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    got = ops.restore_kv(h, wk, wv, bk, bv, cos, sin, head_dim=hd,
                         use_pallas=True)
    want = ref.restore_kv_ref(h, wk, wv, bk, bv, cos, sin, head_dim=hd)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32), **_tol(dtype))


@pytest.mark.parametrize("S,D,Kv,hd", [(32, 64, 10, 96), (32, 64, 3, 80)])
def test_restore_kv_non_pow2_head_dim(S, D, Kv, hd):
    """Regression: the default-block fallback used to halve block_kv
    blindly (KV=960 → 64 < head_dim=96), splitting a head across tiles
    and corrupting the rotate-half pairing. The fallback must stay a
    multiple of head_dim."""
    from repro.kernels.restore_kv import _pick_block_kv
    bkv = _pick_block_kv(Kv * hd, hd, 0)
    assert bkv % hd == 0 and (Kv * hd) % bkv == 0
    h = jnp.asarray(RNG.normal(size=(S, D)), jnp.float32)
    wk = jnp.asarray(RNG.normal(size=(D, Kv * hd)) * D ** -0.5, jnp.float32)
    wv = jnp.asarray(RNG.normal(size=(D, Kv * hd)) * D ** -0.5, jnp.float32)
    ang = (jnp.arange(S, dtype=jnp.float32)[:, None]
           * 10000.0 ** (-jnp.arange(hd // 2) / (hd // 2)))
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    got = ops.restore_kv(h, wk, wv, None, None, cos, sin, head_dim=hd,
                         use_pallas=True)
    want = ref.restore_kv_ref(h, wk, wv, None, None, cos, sin, head_dim=hd)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("G,S,D,Kv,hd", [(1, 32, 64, 2, 16),
                                         (4, 32, 64, 2, 16),
                                         (3, 64, 128, 4, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bias", [False, True])
def test_restore_kv_grouped_sweep(G, S, D, Kv, hd, dtype, bias):
    """Grouped kernel (leading weight-stack grid dim) == per-layer oracle
    applied row by row — the batched executor's byte contract."""
    h = jnp.asarray(RNG.normal(size=(G, S, D)), dtype)
    wk = jnp.asarray(RNG.normal(size=(G, D, Kv * hd)) * D ** -0.5, dtype)
    wv = jnp.asarray(RNG.normal(size=(G, D, Kv * hd)) * D ** -0.5, dtype)
    bk = jnp.asarray(RNG.normal(size=(G, Kv * hd)) * 0.1, dtype) if bias \
        else None
    bv = jnp.asarray(RNG.normal(size=(G, Kv * hd)) * 0.1, dtype) if bias \
        else None
    ang = (jnp.arange(S, dtype=jnp.float32)[:, None]
           * 10000.0 ** (-jnp.arange(hd // 2) / (hd // 2)))
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    for use_pallas in (False, True):
        got = ops.restore_kv_grouped(h, wk, wv, bk, bv, cos, sin,
                                     head_dim=hd, use_pallas=use_pallas)
        for g in range(G):
            want = ref.restore_kv_ref(
                h[g], wk[g], wv[g],
                bk[g] if bias else None, bv[g] if bias else None,
                cos, sin, head_dim=hd)
            for got_part, want_part in zip(got, want):
                np.testing.assert_allclose(
                    np.asarray(got_part[g], np.float32),
                    np.asarray(want_part, np.float32), **_tol(dtype))


@pytest.mark.parametrize("Sq,Skv,hd,group", [(64, 64, 16, 1), (64, 64, 32, 2),
                                             (32, 96, 16, 4)])
@pytest.mark.parametrize("kwargs", [dict(causal=True), dict(causal=False),
                                    dict(causal=True, window=24),
                                    dict(causal=True, softcap=30.0)])
def test_flash_attention_sweep(Sq, Skv, hd, group, kwargs):
    BKv = 2
    q = jnp.asarray(RNG.normal(size=(BKv * group, Sq, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(BKv, Skv, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(BKv, Skv, hd)), jnp.float32)
    got = ops.flash_attention(q, k, v, group=group, use_pallas=True,
                              **kwargs)
    want = ref.flash_attention_ref(q, k, v, group=group, **kwargs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("G,Smax", [(1, 64), (4, 128), (7, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(G, Smax, dtype):
    BKv, hd = 3, 32
    q = jnp.asarray(RNG.normal(size=(BKv, G, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(BKv, Smax, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(BKv, Smax, hd)), dtype)
    kl = jnp.asarray(RNG.integers(1, Smax, BKv), jnp.int32)
    got = ops.decode_attention(q, k, v, kl, use_pallas=True)
    want = ref.decode_attention_ref(q, k, v, kl)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("G,bs,MB", [(1, 16, 4), (4, 8, 8), (7, 32, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_paged_sweep(G, bs, MB, dtype):
    """Paged kernel + its oracle vs the dense reference: pages land in a
    permuted physical pool full of junk, with sentinel table entries past
    each sequence's live pages."""
    BKv, hd = 3, 32
    S = MB * bs
    q = jnp.asarray(RNG.normal(size=(BKv, G, hd)), dtype)
    k = RNG.normal(size=(BKv, S, hd))
    v = RNG.normal(size=(BKv, S, hd))
    kl = RNG.integers(1, S, BKv).astype(np.int32)
    NB = BKv * MB + 3
    perm = RNG.permutation(NB)[:BKv * MB]
    k_pool = RNG.normal(size=(NB, bs, hd))         # junk everywhere else
    v_pool = RNG.normal(size=(NB, bs, hd))
    table = np.full((BKv, MB), NB + 5, np.int32)   # sentinel = OOB
    for b in range(BKv):
        for j in range(MB):
            if j * bs < kl[b]:                     # only live pages mapped
                p = perm[b * MB + j]
                table[b, j] = p
                k_pool[p] = k[b, j * bs:(j + 1) * bs]
                v_pool[p] = v[b, j * bs:(j + 1) * bs]
    k_pool = jnp.asarray(k_pool, dtype)
    v_pool = jnp.asarray(v_pool, dtype)
    table, kl = jnp.asarray(table), jnp.asarray(kl)
    want = ref.decode_attention_ref(q, jnp.asarray(k, dtype),
                                    jnp.asarray(v, dtype), kl)
    for use_pallas in (False, True):
        got = ops.decode_attention_paged(q, k_pool, v_pool, table, kl,
                                         use_pallas=use_pallas)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   **_tol(dtype))


@pytest.mark.parametrize("Bt,I,N", [(1, 64, 16), (2, 128, 8), (3, 96, 4)])
def test_ssm_update_sweep(Bt, I, N):
    h = jnp.asarray(RNG.normal(size=(Bt, I, N)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(Bt, I)), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(Bt, I)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, size=(I, N)), jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(Bt, N)), jnp.float32)
    C = jnp.asarray(RNG.normal(size=(Bt, N)), jnp.float32)
    dsk = jnp.ones((I,), jnp.float32)
    got = ops.ssm_update(h, dt, x, A, Bm, C, dsk, use_pallas=True)
    want = ref.ssm_update_ref(h, dt, x, A, Bm, C, dsk)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=3e-5, atol=3e-5)


def test_flash_matches_model_attention():
    """Pallas flash kernel == the model's jnp chunked attention path."""
    from repro.models.layers.attention import (AttnHyper,
                                               flash_attention_jnp)
    B, S, Kv, g, hd = 2, 64, 2, 3, 16
    Hp = Kv * g
    q = jnp.asarray(RNG.normal(size=(B, S, Hp, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, Kv, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, Kv, hd)), jnp.float32)
    hyp = AttnHyper(n_heads=Hp, n_kv_heads=Kv, head_dim=hd, padded_heads=Hp,
                    chunk=16)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    want = flash_attention_jnp(q, k, v, hyp, q_positions=pos, causal=True)
    # kernel layout: (B*H, S, hd) grouped by kv head
    qk = q.reshape(B, S, Kv, g, hd).transpose(0, 2, 3, 1, 4).reshape(
        B * Kv * g, S, hd)
    kk = k.transpose(0, 2, 1, 3).reshape(B * Kv, S, hd)
    vv = v.transpose(0, 2, 1, 3).reshape(B * Kv, S, hd)
    got = ops.flash_attention(qk, kk, vv, group=g, causal=True,
                              use_pallas=True)
    got = got.reshape(B, Kv, g, S, hd).transpose(0, 3, 1, 2, 4).reshape(
        B, S, Hp, hd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
