"""HCache core correctness: restoration must reproduce the exact
accelerator state the prefill produced (the paper's lossless claim)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.arch import reduced_for_smoke
from repro.config.hardware import PAPER_A100
from repro.configs import get_arch
from repro.core.hcache import HCacheManager
from repro.models import Model
from repro.models.module import split
from repro.storage import ChunkStore, make_array

B, S = 1, 40


def build(arch, rules, override=None, compress="none"):
    cfg = reduced_for_smoke(get_arch(arch))
    model = Model(cfg, rules=rules, model_axis=1, dtype=jnp.float32,
                  remat="none")
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    store = ChunkStore(make_array("dram", 4), chunk_tokens=16)
    mgr = HCacheManager(model, store, hw=PAPER_A100,
                        schedule_override=override, compress=compress)
    return cfg, model, params, mgr


def prefill_and_save(cfg, model, params, mgr, key=1):
    toks = jax.random.randint(jax.random.PRNGKey(key), (B, S), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(jax.random.PRNGKey(2),
                                            (B, 24, cfg.d_model)) * 0.1
    pre = model.prefill(params, batch, capture_hidden=True)
    mgr.save_prefill("sess", np.asarray(toks[0]), pre)
    return toks, pre


def test_restore_equals_prefill_kv_exact(rules):
    """K,V restored from hidden states == prefill K,V (paper's core op)."""
    cfg, model, params, mgr = build("llama2-7b", rules, override="hidden")
    toks, pre = prefill_and_save(cfg, model, params, mgr)
    res = mgr.restore(params, "sess")
    # fp16 storage round-trip is the only loss source
    np.testing.assert_allclose(np.asarray(res.cache["k"]),
                               np.asarray(pre["kv"][0]), atol=2e-3)
    np.testing.assert_allclose(np.asarray(res.cache["v"]),
                               np.asarray(pre["kv"][1]), atol=2e-3)


@pytest.mark.parametrize("override", ["hidden", "kv", None])
@pytest.mark.parametrize("arch", ["qwen2-7b", "llama2-7b", "zamba2-2.7b",
                                  "whisper-medium", "falcon-mamba-7b",
                                  "gemma2-9b", "internvl2-26b"])
def test_restore_then_decode_matches_ground_truth(arch, override, rules):
    cfg, model, params, mgr = build(arch, rules, override=override)
    toks, pre = prefill_and_save(cfg, model, params, mgr)
    res = mgr.restore(params, "sess")
    nt = jnp.argmax(pre["logits"][:, -1], -1).astype(jnp.int32)[:, None]
    lg_r, _ = model.decode_step(params, _pad(model, res.cache), nt)
    lg_g, _ = model.decode_step(params, _gt_cache(model, pre), nt)
    err = float(jnp.abs(lg_r - lg_g).max())
    # tolerance: the only loss source is the fp16 hidden-state storage
    # round-trip; gemma2's sqrt(d)-scaled embeddings push |hidden|≈32, so
    # its quantization error lands at ~2e-3 on the logits (measured)
    assert err < 5e-3, f"{arch}/{override}: {err}"


def test_int8_compression_bounded_error(rules):
    """Beyond-paper: int8 hidden-state storage halves IO again at small,
    bounded restoration error."""
    cfg, model, params, mgr = build("llama2-7b", rules, override="hidden",
                                    compress="int8")
    toks, pre = prefill_and_save(cfg, model, params, mgr)
    res = mgr.restore(params, "sess")
    k_err = np.abs(np.asarray(res.cache["k"])
                   - np.asarray(pre["kv"][0]))
    scale = np.abs(np.asarray(pre["kv"][0])).max()
    assert k_err.max() / scale < 0.05
    # and it actually stores ~half the bytes of fp16
    h_bytes = sum(d.bytes_used for d in mgr.store.devices)
    mgr2 = build("llama2-7b", rules, override="hidden")[3]
    cfg2, model2, params2 = cfg, model, params
    prefill_and_save(cfg2, model2, params2, mgr2)
    f16_bytes = sum(d.bytes_used for d in mgr2.store.devices)
    assert h_bytes < 0.75 * f16_bytes


def test_restoration_timeline_simulated(rules):
    cfg, model, params, mgr = build("llama2-7b", rules)
    prefill_and_save(cfg, model, params, mgr)
    res = mgr.restore(params, "sess")
    assert res.timeline.makespan > 0
    assert res.n_tokens == S


def test_evict_removes_state(rules):
    cfg, model, params, mgr = build("llama2-7b", rules)
    prefill_and_save(cfg, model, params, mgr)
    assert "sess" in mgr.sessions()
    mgr.evict("sess")
    assert "sess" not in mgr.sessions()
    with pytest.raises(KeyError):
        mgr.restore(params, "sess")


def _pad(model, cache, ctx=64):
    def padkv(x):
        return jnp.pad(x, ((0, 0), (0, 0), (0, ctx - x.shape[2]),
                           (0, 0), (0, 0)))

    out = dict(cache)
    for key in ("k", "v", "attn_k", "attn_v", "self_k", "self_v"):
        if key in out:
            out[key] = padkv(out[key])
    return out


def _gt_cache(model, pre, ctx=64):
    lengths = jnp.full((B,), S, jnp.int32)
    if model.kind == "lm":
        cache = {"k": pre["kv"][0], "v": pre["kv"][1], "lengths": lengths}
    elif model.kind == "ssm":
        conv, ssm = pre["states"]
        return {"conv": conv, "ssm": ssm, "lengths": lengths}
    elif model.kind == "hybrid":
        conv, ssm = pre["mamba_states"]
        cache = {"attn_k": pre["kv"][0], "attn_v": pre["kv"][1],
                 "conv": conv, "ssm": ssm, "lengths": lengths}
    else:
        ck, cv = pre["cross_kv"]
        cache = {"self_k": pre["kv"][0], "self_v": pre["kv"][1],
                 "cross_k": ck, "cross_v": cv,
                 "enc_len": jnp.asarray(ck.shape[2], jnp.int32),
                 "lengths": lengths}
    return _pad(model, cache, ctx)
