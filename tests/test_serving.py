"""Serving engine: continuous batching, restoration phase, multi-round
equivalence, crash recovery."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.arch import reduced_for_smoke
from repro.config.hardware import PAPER_A100
from repro.configs import get_arch
from repro.core.hcache import HCacheManager
from repro.models import Model
from repro.models.module import split
from repro.serving import InferenceEngine, Phase, Request
from repro.storage import ChunkStore, make_array


@pytest.fixture(scope="module")
def setup(rules=None):
    from repro.distributed.sharding import default_rules
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    rules = default_rules(mesh)
    cfg = reduced_for_smoke(get_arch("llama2-7b"))
    model = Model(cfg, rules=rules, model_axis=1, dtype=jnp.float32,
                  remat="none")
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def fresh_engine(setup, **kw):
    cfg, model, params = setup
    store = ChunkStore(make_array("dram", 4), chunk_tokens=16)
    mgr = HCacheManager(model, store, hw=PAPER_A100,
                        schedule_override="hidden")
    defaults = dict(max_batch=2, max_seq=128, prefill_chunk=8)
    defaults.update(kw)
    return InferenceEngine(model, params, mgr, **defaults), mgr


def test_continuous_batching_mixed_lengths(setup):
    cfg, model, params = setup
    engine, _ = fresh_engine(setup)
    rng = np.random.default_rng(0)
    p1 = rng.integers(0, cfg.vocab_size, 20).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
    engine.submit(Request("a", p1, max_new_tokens=6))
    engine.submit(Request("b", p2, max_new_tokens=9))
    engine.run()
    assert len(engine.result("a")) == 6
    assert len(engine.result("b")) == 9


def test_multi_round_restoration_matches_no_eviction(setup):
    """Round-2 generation after evict+restore == never-evicted decoding."""
    cfg, model, params = setup
    engine, _ = fresh_engine(setup)
    rng = np.random.default_rng(1)
    p1 = rng.integers(0, cfg.vocab_size, 18).astype(np.int32)
    engine.submit(Request("alice", p1, max_new_tokens=5))
    engine.run()
    g1 = engine.result("alice")
    p2 = rng.integers(0, cfg.vocab_size, 7).astype(np.int32)
    engine.submit(Request("alice", p2, max_new_tokens=4))
    engine.run()
    g2 = engine.result("alice")

    # ground truth: single prefill over the whole history
    full = np.concatenate([p1, np.asarray(g1[:-1], np.int32), p2])
    pre = model.prefill(params, {"tokens": jnp.asarray(full)[None]})
    n = len(full)
    k = jnp.pad(pre["kv"][0], ((0, 0), (0, 0), (0, 128 - n), (0, 0), (0, 0)))
    v = jnp.pad(pre["kv"][1], ((0, 0), (0, 0), (0, 128 - n), (0, 0), (0, 0)))
    cache = {"k": k, "v": v, "lengths": jnp.asarray([n], jnp.int32)}
    nt = jnp.argmax(pre["logits"][:, -1], -1).astype(jnp.int32)[:, None]
    want = []
    for _ in range(4):
        want.append(int(nt[0, 0]))
        lg, cache = model.decode_step(params, cache, nt)
        nt = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]
    assert g2 == want


def test_crash_recovery_resumes_sessions(setup):
    """A fresh engine over the same store restores evicted sessions —
    serving fault tolerance IS HCache."""
    cfg, model, params = setup
    engine, mgr = fresh_engine(setup)
    rng = np.random.default_rng(2)
    p1 = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
    engine.submit(Request("carol", p1, max_new_tokens=5))
    engine.run()
    g1 = engine.result("carol")

    engine2 = InferenceEngine(model, params, mgr, max_batch=2, max_seq=128,
                              prefill_chunk=8)     # "restarted" process
    assert "carol" in engine2.recoverable_sessions()
    p2 = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    engine2.submit(Request("carol", p2, max_new_tokens=3))
    engine2.run()
    assert len(engine2.result("carol")) == 3
    assert engine2.sessions["carol"].history_len == 12 + 5 - 1 > 0


def test_metrics_populated(setup):
    cfg, model, params = setup
    engine, _ = fresh_engine(setup)
    p = np.arange(10, dtype=np.int32) % cfg.vocab_size
    engine.submit(Request("m", p, max_new_tokens=4))
    engine.run()
    assert len(engine.metrics.ttft_wall) == 1
    assert engine.metrics.decode_steps >= 3
    assert engine.metrics.ttft_wall[0] > 0
