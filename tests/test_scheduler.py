"""Bubble-free scheduler: paper-claim replication + hypothesis properties."""
import dataclasses
import math

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis - seeded shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.config.hardware import GB, PAPER_A100, HardwareProfile
from repro.configs import get_arch
from repro.core.cost_model import (layer_costs, method_times,
                                   restoration_time, storage_per_token)
from repro.core.pipeline import restore_timeline, simulate
from repro.core.scheduler import closed_form, solve


# ------------------------------------------------------------- paper claims
def test_mha_compute_speedup_at_least_6x():
    """§3.2: C_RE / C_H >= 6 for MHA, growing with sequence length."""
    cfg = get_arch("llama2-7b")
    prev = 0.0
    for n in (512, 2048, 8192, 32768):
        c = layer_costs(cfg, n)[0]
        ratio = c.c_token / c.c_hidden
        assert ratio >= 6.0, f"n={n}: {ratio}"
        assert ratio >= prev
        prev = ratio


def test_mha_io_exactly_half():
    """§3.2: hidden-state bytes are half the KV bytes for MHA."""
    for name in ("llama2-7b", "llama2-13b", "opt-30b"):
        c = layer_costs(get_arch(name), 1024)[0]
        assert c.io_hidden * 2 == c.io_kv


def test_gqa_inverts_io_ratio():
    """GQA (kv=4): KV is *smaller* than hidden states — the §7 caveat."""
    c = layer_costs(get_arch("qwen2-7b"), 1024)[0]
    assert c.io_kv < c.io_hidden


def test_table3_7b_schedule():
    """Table 3: llama2-7b on A100+4SSD uses H for ~31/32 layers with a
    small KV remainder (we get 30H+2KV with our GEMM-efficiency guess)."""
    s = solve(get_arch("llama2-7b"), 1024, PAPER_A100)
    counts = s.counts
    assert counts["hidden"] >= 29
    assert counts["recompute"] == 0
    assert s.bubble < 0.10


def test_table3_30b_schedule_uses_recompute():
    """Table 3: OPT-30B with 1 SSD/GPU is IO-poor -> recompute fills in."""
    hw = dataclasses.replace(PAPER_A100, storage_bw=6.9 * GB)
    s = solve(get_arch("opt-30b"), 1024, hw)
    assert s.counts["recompute"] >= 4
    assert s.counts["hidden"] >= 36


def test_storage_ratio_band():
    """Table 3: HCache stores 1.92-2.40x less than KV offload (MHA)."""
    for name in ("llama2-7b", "llama2-13b"):
        cfg = get_arch(name)
        s = solve(cfg, 1024, PAPER_A100)
        ratio = (storage_per_token(cfg, ["kv"] * cfg.n_layers)
                 / storage_per_token(cfg, s.methods))
        assert 1.5 <= ratio <= 2.6, ratio


def test_ttft_speedup_bands():
    """§6: HCache vs KV offload 1.3-2.7x; vs recompute >= 2.3x."""
    cfg = get_arch("llama2-7b")
    for n in (1024, 4096, 16384):
        th = restoration_time(cfg, n, PAPER_A100, "hcache")
        tkv = restoration_time(cfg, n, PAPER_A100, "kv_offload")
        tre = restoration_time(cfg, n, PAPER_A100, "recompute")
        assert 1.3 <= tkv / th <= 2.7
        assert tre / th >= 2.3


# ------------------------------------------------------ hypothesis properties
hw_strategy = st.builds(
    HardwareProfile,
    name=st.just("synth"),
    flops=st.floats(1e12, 1e15),
    hbm_bw=st.just(819e9),
    interconnect_bw=st.just(50e9),
    host_link_bw=st.floats(1e9, 1e11),
    storage_bw=st.floats(1e8, 1e11),
    hbm_capacity=st.just(16e9),
)


@settings(max_examples=40, deadline=None)
@given(hw=hw_strategy, n_tokens=st.sampled_from([256, 1024, 8192]))
def test_solver_never_worse_than_pure_methods(hw, n_tokens):
    """The min-max schedule's makespan <= every single-method scheme."""
    cfg = get_arch("llama2-7b")
    s = solve(cfg, n_tokens, hw)
    t = restore_timeline(cfg, n_tokens, hw, s.methods)
    for method, scheme in (("hidden", ["hidden"]), ("kv", ["kv"]),
                           ("recompute", ["recompute"])):
        tm = restore_timeline(cfg, n_tokens, hw,
                              scheme * cfg.n_layers)
        assert t.makespan <= tm.makespan * 1.0001


@settings(max_examples=40, deadline=None)
@given(hw=hw_strategy)
def test_closed_form_near_optimal(hw):
    """Paper's closed form is within one layer of the exact solver when
    restricted to the same two methods."""
    cfg = get_arch("llama2-7b")
    t = method_times(layer_costs(cfg, 1024)[0], hw)
    l_h, l_o = closed_form(cfg.n_layers, t)
    if t.c_h > t.io_h:
        exact = solve(cfg, 1024, hw, allow_recompute=False)
    else:
        exact = solve(cfg, 1024, hw, allow_kv=False)
    assert abs(exact.counts["hidden"] - l_h) <= 2


@settings(max_examples=30, deadline=None)
@given(hw=hw_strategy, n_tokens=st.sampled_from([512, 4096]))
def test_simulated_timeline_consistent(hw, n_tokens):
    """Event simulation: makespan >= both stream busy times; the solver's
    predicted compute/io totals match the simulation's busy times."""
    cfg = get_arch("llama2-13b")
    s = solve(cfg, n_tokens, hw)
    t = restore_timeline(cfg, n_tokens, hw, s.methods)
    assert t.makespan >= t.io_busy - 1e-12
    assert t.makespan >= t.compute_busy - 1e-12
    assert t.io_busy == pytest.approx(s.io_time, rel=1e-6)
    assert t.compute_busy == pytest.approx(s.compute_time, rel=1e-6)


def test_hybrid_schedule_offloads_ssm_states():
    """zamba2: mamba layers should pick state offload ('kv' slot, near-free
    IO) rather than hidden-state rescan, attention layers follow the paper."""
    from repro.config.arch import BlockKind
    cfg = get_arch("zamba2-2.7b")
    s = solve(cfg, 4096, PAPER_A100, allow_recompute=False)
    kinds = cfg.block_kinds()
    mamba_methods = {m for m, k in zip(s.methods, kinds)
                     if k != BlockKind.ATTENTION}
    assert mamba_methods == {"kv"}
