"""Minimal stand-in for `hypothesis` when it is not installed.

The container image does not ship hypothesis, and tier-1 must still
collect and run every module. The shim keeps the property tests
meaningful by drawing a fixed number of pseudo-random examples per test
(seeded, so failures reproduce) instead of hypothesis' guided search.

Usage in a test module:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_compat import given, settings, strategies as st
"""
from __future__ import annotations

import functools
import inspect
import random

_DEFAULT_EXAMPLES = 25


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


class strategies:  # noqa: N801 — mirrors the `hypothesis.strategies` module
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value, max_value, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def sampled_from(options):
        opts = list(options)
        return _Strategy(lambda rng: rng.choice(opts))

    @staticmethod
    def just(value):
        return _Strategy(lambda rng: value)

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements.example(rng) for _ in range(n)]
        return _Strategy(draw)

    @staticmethod
    def builds(target, *args, **kwargs):
        def draw(rng):
            a = [s.example(rng) for s in args]
            kw = {k: s.example(rng) for k, s in kwargs.items()}
            return target(*a, **kw)
        return _Strategy(draw)


def settings(max_examples: int = _DEFAULT_EXAMPLES, **_kw):
    def wrap(fn):
        fn._max_examples = max_examples
        return fn
    return wrap


def given(*arg_strats, **kw_strats):
    def wrap(fn):
        inner = fn
        sig = inspect.signature(inner)
        params = list(sig.parameters.values())
        # hypothesis maps positional strategies onto the RIGHTMOST
        # parameters; the rest (minus kw-strategy names) are pytest
        # fixtures and must stay visible in the test signature.
        covered = {p.name for p in params[len(params) - len(arg_strats):]}
        covered |= set(kw_strats)
        fixture_params = [p for p in params if p.name not in covered]

        @functools.wraps(inner)
        def runner(*fixture_args, **fixture_kw):
            n = getattr(runner, "_max_examples", _DEFAULT_EXAMPLES)
            rng = random.Random(0)
            for i in range(n):
                a = [s.example(rng) for s in arg_strats]
                kw = {k: s.example(rng) for k, s in kw_strats.items()}
                try:
                    inner(*fixture_args, *a, **fixture_kw, **kw)
                except Exception:
                    print(f"falsifying example (shim, draw {i}): "
                          f"args={a} kwargs={kw}")
                    raise
        del runner.__wrapped__              # keep pytest off inner's sig
        runner.__signature__ = sig.replace(parameters=fixture_params)
        return runner
    return wrap


__all__ = ["given", "settings", "strategies"]
