"""Sharding rules: head padding invariants (hypothesis), spec dedup,
vocab padding."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis - seeded shim
    from _hypothesis_compat import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import default_rules, pad_heads
from repro.launch.mesh import make_mesh
from repro.models.layers.embedding import padded_vocab


@settings(max_examples=200, deadline=None)
@given(
    kv=st.sampled_from([1, 2, 4, 8, 16, 32]),
    ratio=st.integers(1, 16),
    axis=st.sampled_from([1, 2, 4, 8, 16]),
)
def test_pad_heads_properties(kv, ratio, axis):
    n_heads = kv * ratio
    padded, group = pad_heads(n_heads, kv, axis)
    assert padded % axis == 0                 # shardable
    assert padded == kv * group               # GQA grouping preserved
    assert padded >= n_heads                  # never shrinks
    assert padded - n_heads < axis * kv       # bounded waste


def test_pad_heads_assigned_archs():
    from repro.configs import ASSIGNED
    for cfg in ASSIGNED.values():
        if cfg.n_heads == 0:
            continue
        padded, group = pad_heads(cfg.n_heads, cfg.n_kv_heads, 16)
        assert padded % 16 == 0
        waste = padded / cfg.n_heads
        assert waste <= 1.25, f"{cfg.name}: {waste}"


def test_spec_dedup_never_reuses_axis():
    mesh = make_mesh((1, 1), ("data", "model"))
    rules = default_rules(mesh).with_rules(a=("data", "model"),
                                           b=("data",))
    spec = rules.spec(("a", "b"))
    flat = []
    for part in spec:
        if part is None:
            continue
        flat.extend([part] if isinstance(part, str) else list(part))
    assert len(flat) == len(set(flat))


def test_long_context_rules_replicate_batch():
    mesh = make_mesh((1, 1), ("data", "model"))
    rules = default_rules(mesh, long_context=True)
    assert rules.spec(("batch",)) == P(None)
    kv = rules.spec(("kv_seq",))
    assert kv != P(None)


def test_padded_vocab():
    assert padded_vocab(49155) == 49280
    assert padded_vocab(152064) == 152064
    assert padded_vocab(51865) % 128 == 0
    for v in (49155, 51865, 92553):
        assert padded_vocab(v) % 16 == 0
