"""Capacity-driven session lifecycle: mid-stream eviction equivalence,
admission/eviction policies, host-budget demotion ladder, tier demotion."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.arch import reduced_for_smoke
from repro.config.hardware import PAPER_A100
from repro.configs import get_arch
from repro.core.capacity import (CapacityManager, FIFOAdmission,
                                 LRUEviction, PriorityAdmission,
                                 RestoreCostAwareAdmission,
                                 RestoreCostAwareEviction,
                                 restore_makespan, session_restore_cost)
from repro.core.hcache import HCacheManager
from repro.models import Model
from repro.models.module import split
from repro.serving import InferenceEngine, Request
from repro.storage import ChunkStore, make_array


@pytest.fixture(scope="module")
def setup():
    from repro.distributed.sharding import default_rules
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = reduced_for_smoke(get_arch("llama2-7b"))
    model = Model(cfg, rules=default_rules(mesh), model_axis=1,
                  dtype=jnp.float32, remat="none")
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def fresh_engine(setup, cold=False, budget=None, **kw):
    cfg, model, params = setup
    store = ChunkStore(make_array("dram", 4), chunk_tokens=16,
                       cold_devices=make_array("dram", 4) if cold else None)
    # store_dtype follows the model dtype (fp32) so pause/restore cycles
    # are lossless and greedy equivalence is bit-exact, not borderline
    mgr = HCacheManager(model, store, hw=PAPER_A100,
                        schedule_override="hidden",
                        store_dtype=np.float32)
    capacity = (CapacityManager(mgr, host_budget_bytes=budget)
                if budget is not None else None)
    defaults = dict(max_batch=2, max_seq=128, prefill_chunk=8,
                    capacity=capacity)
    defaults.update(kw)
    return InferenceEngine(model, params, mgr, **defaults), mgr


def _prompts(cfg, n, seed=7):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, int(k)).astype(np.int32)
            for k in rng.integers(6, 24, size=n)]


# --------------------------------------------------- mid-stream eviction
@pytest.mark.parametrize("eviction", [LRUEviction(),
                                      RestoreCostAwareEviction()],
                         ids=["lru", "restore_cost"])
def test_preemption_equivalence_8_sessions_2_slots(setup, eviction):
    """The acceptance workload: 8 interleaved sessions over 2 slots run
    to completion via mid-stream eviction + pipelined restoration, with
    byte-for-byte greedy equivalence to the unconstrained (8-slot) run."""
    cfg, model, params = setup
    prompts = _prompts(cfg, 8)

    ref, _ = fresh_engine(setup, max_batch=8)
    for i, p in enumerate(prompts):
        ref.submit(Request(f"s{i}", p, max_new_tokens=5))
    ref.run()
    want = {f"s{i}": ref.result(f"s{i}") for i in range(8)}
    ref.close()

    eng, _ = fresh_engine(setup, max_batch=2, preempt_quantum=3,
                          eviction=eviction)
    for i, p in enumerate(prompts):
        eng.submit(Request(f"s{i}", p, max_new_tokens=5))
    eng.run()
    got = {f"s{i}": eng.result(f"s{i}") for i in range(8)}
    assert eng.metrics.preemptions > 0            # eviction actually ran
    assert len(eng.metrics.restore_sim_all) == eng.metrics.preemptions
    assert all(s.phase.value == "done" for s in eng.sessions.values())
    assert got == want
    eng.close()


def test_paused_session_survives_multiple_evictions(setup):
    """A session paused more than once still matches the straight run."""
    cfg, model, params = setup
    p = _prompts(cfg, 3, seed=11)

    ref, _ = fresh_engine(setup, max_batch=3)
    for i, pr in enumerate(p):
        ref.submit(Request(f"m{i}", pr, max_new_tokens=8))
    ref.run()
    want = ref.result("m2")
    ref.close()

    eng, _ = fresh_engine(setup, max_batch=1, preempt_quantum=2)
    for i, pr in enumerate(p):
        eng.submit(Request(f"m{i}", pr, max_new_tokens=8))
    eng.run()
    assert max(s.pauses for s in eng.sessions.values()) >= 2
    assert eng.result("m2") == want
    eng.close()


def test_finish_at_prefill_does_not_corrupt_hidden_stream(setup):
    """Regression: a session that hits max_new_tokens at prefill
    completion sits in its slot (DECODE phase, finished) for one decode
    batch before _retire; the decode step's hidden save must skip it, or
    its masked-out scratch step overwrites the last real hidden row and
    the next round restores corrupted KV. (Surfaced by resume prefills,
    which commonly finish sessions; reachable before via
    max_new_tokens=1.)"""
    cfg, model, params = setup
    eng, mgr = fresh_engine(setup, max_batch=2)
    rng = np.random.default_rng(5)
    p1 = rng.integers(0, cfg.vocab_size, 10).astype(np.int32)
    pg = rng.integers(0, cfg.vocab_size, 10).astype(np.int32)
    eng.submit(Request("f", p1, max_new_tokens=1))
    eng.submit(Request("g", pg, max_new_tokens=6))   # keeps decode running
    eng.run()
    p2 = rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
    eng.submit(Request("f", p2, max_new_tokens=3))
    eng.run()
    g2 = eng.result("f")

    full = np.concatenate([p1, p2])    # round-1 output's KV never existed
    pre = model.prefill(params, {"tokens": jnp.asarray(full)[None]})
    n = len(full)
    k = jnp.pad(pre["kv"][0], ((0, 0), (0, 0), (0, 128 - n), (0, 0), (0, 0)))
    v = jnp.pad(pre["kv"][1], ((0, 0), (0, 0), (0, 128 - n), (0, 0), (0, 0)))
    cache = {"k": k, "v": v, "lengths": jnp.asarray([n], jnp.int32)}
    nt = jnp.argmax(pre["logits"][:, -1], -1).astype(jnp.int32)[:, None]
    want = []
    for _ in range(3):
        want.append(int(nt[0, 0]))
        lg, cache = model.decode_step(params, cache, nt)
        nt = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]
    assert g2 == want
    eng.close()


# ------------------------------------------------------------- policies
def test_priority_admission_order(setup):
    cfg, model, params = setup
    eng, _ = fresh_engine(setup, max_batch=1,
                          admission=PriorityAdmission())
    rng = np.random.default_rng(0)
    pr = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    eng.submit(Request("low", pr, max_new_tokens=2, priority=0))
    eng.submit(Request("high", pr.copy(), max_new_tokens=2, priority=5))
    eng.run()
    low, high = eng.sessions["low"], eng.sessions["high"]
    assert high.first_token_step < low.first_token_step
    eng.close()


def test_restore_cost_aware_selects_cheapest(setup):
    """Both the admission and eviction cost-aware policies rank by the
    restoration task-graph makespan, which grows with history length."""
    cfg, model, params = setup
    eng, mgr = fresh_engine(setup)
    short = restore_makespan(mgr, 64)
    long = restore_makespan(mgr, 4096)
    assert 0 < short < long

    mgr.store.put_manifest("small", {"n_tokens": 64,
                                     "methods": ["hidden"] * cfg.n_layers})
    mgr.store.put_manifest("big", {"n_tokens": 4096,
                                   "methods": ["hidden"] * cfg.n_layers})
    assert (session_restore_cost(mgr, "small")
            < session_restore_cost(mgr, "big"))

    class Seq:                                       # engine duck type
        def __init__(self, sid, total, rid, step):
            self.total_len = total
            self.admit_step = step

            class R:
                session_id = sid
                request_id = rid
            self.request = R()

    a, b = Seq("small", 65, 0, 5), Seq("big", 4097, 1, 2)
    assert RestoreCostAwareEviction().select_victim([a, b], eng) is a
    assert LRUEviction().select_victim([a, b], eng) is b   # older admit
    eng.close()


def test_admission_aging_prevents_starvation(setup):
    """Pure SJF starves a long-history session behind a stream of cheap
    ones; the aging credit makes its effective cost fall with queue time
    until it must win (the ROADMAP fairness item)."""
    cfg, model, params = setup
    eng, mgr = fresh_engine(setup)
    mgr.store.put_manifest("cheap", {"n_tokens": 64,
                                     "methods": ["hidden"] * cfg.n_layers})
    mgr.store.put_manifest("costly", {"n_tokens": 4096,
                                      "methods": ["hidden"] * cfg.n_layers})

    class Seq:                                       # engine duck type
        def __init__(self, sid, rid, enqueue_step):
            self.enqueue_step = enqueue_step

            class R:
                session_id = sid
                request_id = rid
            self.request = R()

    gap = (session_restore_cost(mgr, "costly")
           - session_restore_cost(mgr, "cheap"))
    assert gap > 0
    old = Seq("costly", 0, enqueue_step=0)
    eng.step_count = 100
    # a fresh cheap competitor arrives every selection round: SJF picks
    # it forever, no matter how long "costly" has waited
    sjf = RestoreCostAwareAdmission()
    assert sjf.select((old, Seq("cheap", 1, 100)), eng).request.session_id \
        == "cheap"
    # aging: after enough queued steps the credit covers the cost gap
    aging = RestoreCostAwareAdmission(aging=gap / 50)
    assert aging.select((old, Seq("cheap", 2, 100)),
                        eng).request.session_id == "costly"
    # but a newly queued costly session still loses to the cheap one
    assert aging.select((Seq("costly", 3, 100), Seq("cheap", 4, 100)),
                        eng).request.session_id == "cheap"
    eng.close()


def test_fifo_admission_default(setup):
    eng, _ = fresh_engine(setup)
    assert isinstance(eng.admission, FIFOAdmission)
    assert isinstance(RestoreCostAwareAdmission(), object)
    eng.close()


# ------------------------------------------------- host budget / ladder
def _save_sessions(setup, mgr, n=4, n_tokens=32):
    cfg, model, params = setup
    rng = np.random.default_rng(0)
    outs = {}
    for i in range(n):
        toks = rng.integers(0, cfg.vocab_size, n_tokens).astype(np.int32)
        out = model.prefill(params, {"tokens": jnp.asarray(toks)[None]},
                            capture_hidden=True)
        mgr.save_prefill(f"s{i}", toks, out)
        outs[f"s{i}"] = out
    return outs


def test_host_budget_keeps_bytes_under_budget(setup):
    """The satellite acceptance: host-budget eviction keeps
    ChunkStore.bytes_used under budget_bytes, stepping down the ladder
    (cold tier first, then int8, recompute, drop)."""
    cfg, model, params = setup
    store = ChunkStore(make_array("dram", 4), chunk_tokens=16,
                       cold_devices=make_array("dram", 4))
    mgr = HCacheManager(model, store, hw=PAPER_A100,
                        schedule_override="hidden", store_dtype=np.float32)
    outs = _save_sessions(setup, mgr)
    full = store.bytes_used
    budget = int(full * 0.3)
    cap = CapacityManager(mgr, host_budget_bytes=budget)
    assert cap.ensure_host_budget() > 0
    assert store.bytes_used <= budget
    assert store.bytes_cold > 0
    assert ("cold", "s0") in cap.actions
    # demoted sessions remain restorable at full fidelity (cold tier is
    # a transparent move, not a re-encode)
    for sid, out in outs.items():
        res = mgr.restore(params, sid)
        assert res.n_tokens == 32
        np.testing.assert_allclose(np.asarray(res.cache["k"]),
                                   np.asarray(out["kv"][0]), atol=2e-3)
    mgr.saver.close()


def test_int8_after_cold_never_raises_budgeted_bytes(setup):
    """ROADMAP regression: ``demote_hidden_int8`` on a cold-demoted
    session used to re-append the re-encoded 'h'/'hs' streams through
    the HOT tier (``append_tokens`` always writes hot), so the ladder's
    int8 stage could *increase* the budgeted bytes. The re-encode must
    land back in the tier the chunks came from."""
    cfg, model, params = setup
    store = ChunkStore(make_array("dram", 4), chunk_tokens=16,
                       cold_devices=make_array("dram", 4))
    mgr = HCacheManager(model, store, hw=PAPER_A100,
                        schedule_override="hidden", store_dtype=np.float32)
    outs = _save_sessions(setup, mgr, n=1)
    assert store.demote_session_to_cold("s0") > 0
    hot_before = store.bytes_used                  # 0: everything cold
    total_before = store.bytes_for("s0")
    assert mgr.demote_hidden_int8("s0")
    assert store.bytes_used <= hot_before          # hot tier never grows
    assert store.bytes_for("s0") < total_before    # int8 shrinks the total
    assert store.bytes_for("s0", "h", include_cold=False) == 0
    assert store.stream_in_cold("s0", "h") and store.stream_in_cold(
        "s0", "hs")
    # still restorable (int8-level error) through the cold fallback
    res = mgr.restore(params, "s0")
    assert res.n_tokens == 32
    err = np.abs(np.asarray(res.cache["k"])
                 - np.asarray(outs["s0"]["kv"][0])).max()
    assert err < 0.05
    mgr.saver.close()


def test_budget_ladder_without_cold_tier_degrades_representation(setup):
    """No cold tier: the ladder re-encodes to int8, then drops streams
    for restore-by-recompute, then drops sessions outright."""
    cfg, model, params = setup
    store = ChunkStore(make_array("dram", 4), chunk_tokens=16)
    mgr = HCacheManager(model, store, hw=PAPER_A100,
                        schedule_override="hidden")
    _save_sessions(setup, mgr)
    budget = int(store.bytes_used * 0.05)       # forces deep degradation
    cap = CapacityManager(mgr, host_budget_bytes=budget)
    cap.ensure_host_budget(protected=[])
    assert store.bytes_used <= budget
    stages = {s for s, _ in cap.actions}
    assert "int8" in stages and "recompute" in stages
    # recompute-degraded sessions restore exactly (token recompute)
    degraded = [sid for sid in store.sessions()
                if all(m == "recompute"
                       for m in store.get_manifest(sid)["methods"])]
    for sid in degraded[:1]:
        res = mgr.restore(params, sid)
        assert res.n_tokens == 32
    mgr.saver.close()


def test_int8_demotion_roundtrip_and_appends(setup):
    """fp16 -> int8 demotion halves the 'h' stream; later appends follow
    the session codec (manifest-synced), and restoration dequantizes."""
    cfg, model, params = setup
    store = ChunkStore(make_array("dram", 4), chunk_tokens=16)
    mgr = HCacheManager(model, store, hw=PAPER_A100,
                        schedule_override="hidden")
    outs = _save_sessions(setup, mgr, n=1)
    before = store.bytes_for("s0", "h")
    assert mgr.demote_hidden_int8("s0")
    assert not mgr.demote_hidden_int8("s0")       # idempotent
    assert store.bytes_for("s0", "h") * 2 <= before + 64
    assert store.get_manifest("s0")["compress"] == "int8"
    res = mgr.restore(params, "s0")
    err = np.abs(np.asarray(res.cache["k"])
                 - np.asarray(outs["s0"]["kv"][0])).max()
    assert err < 0.05                              # quantization-level
    mgr.saver.close()


def test_promote_hidden_fp16_roundtrip(setup):
    """int8 -> fp16 re-promotion: scales dropped, manifest codec back to
    'none', the 'h' stream ~doubles, and the session stays restorable
    (at the int8-level error already paid — promotion stops further
    loss, it cannot undo past loss)."""
    cfg, model, params = setup
    store = ChunkStore(make_array("dram", 4), chunk_tokens=16)
    mgr = HCacheManager(model, store, hw=PAPER_A100,
                        schedule_override="hidden")
    outs = _save_sessions(setup, mgr, n=1)
    assert not mgr.promote_hidden_fp16("s0")       # not demoted yet
    assert mgr.demote_hidden_int8("s0")
    int8_bytes = store.bytes_for("s0", "h")
    assert mgr.promote_hidden_fp16("s0")
    assert not mgr.promote_hidden_fp16("s0")       # idempotent
    man = store.get_manifest("s0")
    assert man["compress"] == "none"
    assert store.bytes_for("s0", "hs") == 0        # scales dropped
    assert store.bytes_for("s0", "h") >= int8_bytes * 2 - 64
    res = mgr.restore(params, "s0")
    err = np.abs(np.asarray(res.cache["k"])
                 - np.asarray(outs["s0"]["kv"][0])).max()
    assert err < 0.05                              # quantization-level
    mgr.saver.close()


def test_capacity_promotes_demoted_session_on_save(setup):
    """The anti-entropy satellite end to end: a session demoted to int8
    is re-promoted to fp16 on its next save once the budget has
    headroom (the engine's _after_save hook)."""
    cfg, model, params = setup
    eng, mgr = fresh_engine(setup, budget=10_000_000)   # ample headroom
    cap = eng.capacity
    rng = np.random.default_rng(9)
    p1 = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
    eng.submit(Request("promo", p1, max_new_tokens=3))
    eng.run()
    assert mgr.demote_hidden_int8("promo")
    assert mgr.store.get_manifest("promo")["compress"] == "int8"
    p2 = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
    eng.submit(Request("promo", p2, max_new_tokens=2))  # next save cycle
    eng.run()
    assert ("promote", "promo") in cap.actions
    assert mgr.store.get_manifest("promo")["compress"] == "none"
    # no headroom -> no promotion
    assert mgr.demote_hidden_int8("promo")
    cap.host_budget_bytes = mgr.store.bytes_used + 10
    assert not cap.consider_promotion("promo")
    assert mgr.store.get_manifest("promo")["compress"] == "int8"
    eng.close()


def test_storage_array_pressure_callback_fires(setup):
    """Writing past the StorageArray budget triggers reclaim without an
    engine in the loop (the store-driven wiring)."""
    cfg, model, params = setup
    array = make_array("dram", 4)
    store = ChunkStore(array, chunk_tokens=16,
                       cold_devices=make_array("dram", 4))
    mgr = HCacheManager(model, store, hw=PAPER_A100,
                        schedule_override="hidden")
    _save_sessions(setup, mgr, n=1)
    budget = store.bytes_used + 100
    cap = CapacityManager(mgr, host_budget_bytes=budget)
    assert array.budget_bytes == budget
    _save_sessions(setup, mgr, n=2)      # blows the budget mid-save
    assert len(cap.actions) > 0
    assert store.bytes_used <= budget
    mgr.saver.close()


def test_engine_with_budget_serves_under_pressure(setup):
    """End to end: slot pressure AND storage pressure at once — all
    sessions complete, hot tier ends within budget."""
    cfg, model, params = setup
    eng, mgr = fresh_engine(setup, cold=True, budget=20_000,
                            max_batch=2, preempt_quantum=3)
    prompts = _prompts(cfg, 6, seed=3)
    for i, p in enumerate(prompts):
        eng.submit(Request(f"b{i}", p, max_new_tokens=4))
    eng.run()
    assert all(len(eng.result(f"b{i}")) == 4 for i in range(6))
    assert eng.capacity.actions                    # ladder engaged
    assert mgr.store.bytes_used <= 20_000
    eng.close()


def test_engine_close_stops_saver_threads(setup):
    eng, mgr = fresh_engine(setup)
    threads = list(mgr.saver._threads)
    assert all(t.is_alive() for t in threads)
    eng.close()
    assert all(not t.is_alive() for t in threads)


def test_sweep_promotions_recovers_idle_session(setup):
    """Anti-entropy sweep (the background half of the promotion
    satellite): an int8-demoted session that went IDLE — no further
    saves — is re-encoded to fp16 by ``sweep_promotions`` under budget
    headroom, without waiting for its next save."""
    cfg, model, params = setup
    store = ChunkStore(make_array("dram", 4), chunk_tokens=16)
    mgr = HCacheManager(model, store, hw=PAPER_A100,
                        schedule_override="hidden")
    _save_sessions(setup, mgr, n=2)
    cap = CapacityManager(mgr, host_budget_bytes=10_000_000)
    assert mgr.demote_hidden_int8("s0")
    assert mgr.demote_hidden_int8("s1")
    assert cap.sweep_promotions(limit=1) == 1      # bounded per call
    assert cap.sweep_promotions(limit=2) == 1      # the remaining one
    assert store.get_manifest("s0")["compress"] == "none"
    assert store.get_manifest("s1")["compress"] == "none"
    assert [a for a in cap.actions if a[0] == "promote"] != []
    mgr.saver.close()


def test_sweep_promotions_no_headroom_noop(setup):
    """No headroom → the sweep takes no action and touches no stream
    (the no-op acceptance case)."""
    cfg, model, params = setup
    store = ChunkStore(make_array("dram", 4), chunk_tokens=16)
    mgr = HCacheManager(model, store, hw=PAPER_A100,
                        schedule_override="hidden")
    _save_sessions(setup, mgr, n=1)
    assert mgr.demote_hidden_int8("s0")
    h_bytes = store.bytes_for("s0", "h")
    cap = CapacityManager(mgr, host_budget_bytes=store.bytes_used + 16)
    assert cap.sweep_promotions() == 0
    assert store.get_manifest("s0")["compress"] == "int8"
    assert store.bytes_for("s0", "h") == h_bytes
    # and without any budget at all the sweep is inert by definition
    cap2 = CapacityManager(mgr)
    assert cap2.sweep_promotions() == 0
    mgr.saver.close()


def test_engine_idle_step_runs_sweep(setup):
    """The engine wiring: once the queue drains and slots idle, the
    engine's idle steps promote a demoted stored session."""
    cfg, model, params = setup
    eng, mgr = fresh_engine(setup, budget=10_000_000)
    rng = np.random.default_rng(13)
    p = rng.integers(0, cfg.vocab_size, 10).astype(np.int32)
    eng.submit(Request("idle", p, max_new_tokens=3))
    eng.run()
    assert mgr.demote_hidden_int8("idle")
    # a busy engine wouldn't sweep; with nothing queued every step is
    # idle — one manual step stands in for the serving loop's idle tick
    eng.step()
    assert ("promote", "idle") in eng.capacity.actions
    assert mgr.store.get_manifest("idle")["compress"] == "none"
    eng.close()
