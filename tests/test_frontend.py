"""Serving front door (DESIGN.md §14): tokenizer/template stability,
session router steering, engine token-callback seam, pump threading,
OpenAI-compatible API, and the stdlib HTTP binding."""
import asyncio
import json
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.arch import reduced_for_smoke
from repro.config.hardware import PAPER_A100
from repro.configs import get_arch
from repro.core.hcache import HCacheManager
from repro.frontend import (ByteTokenizer, ChatTemplate, EnginePump,
                            FrontDoor, HttpFrontDoor, Overloaded,
                            RouterBusy, SessionRouter)
from repro.models import Model
from repro.models.module import split
from repro.serving import InferenceEngine, Request
from repro.storage import ChunkStore, make_array


@pytest.fixture(scope="module")
def setup():
    from repro.distributed.sharding import default_rules
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    rules = default_rules(mesh)
    cfg = reduced_for_smoke(get_arch("llama2-7b"))
    model = Model(cfg, rules=rules, model_axis=1, dtype=jnp.float32,
                  remat="none")
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def fresh_engine(setup, **kw):
    cfg, model, params = setup
    store = ChunkStore(make_array("dram", 4), chunk_tokens=16)
    mgr = HCacheManager(model, store, hw=PAPER_A100,
                        schedule_override="hidden",
                        store_dtype=np.float32)
    defaults = dict(max_batch=2, max_seq=128, prefill_chunk=8)
    defaults.update(kw)
    return InferenceEngine(model, params, mgr, **defaults)


# ------------------------------------------------------------- tokenizer
def test_tokenizer_roundtrip():
    tok = ByteTokenizer(256)
    ids = [0, 1, 17, 255, 42]
    assert list(tok.encode(tok.decode(ids))) == ids
    # ordinary text maps through UTF-8 bytes mod vocab
    assert list(tok.encode("ab")) == [ord("a"), ord("b")]


def test_chat_template_prefix_stable():
    """The rendered history must be a strict token prefix of the next
    round's render — that is what makes similarity routing exact."""
    tok = ByteTokenizer(256)
    tpl = ChatTemplate(tok)
    msgs = [{"role": "system", "content": "be brief"},
            {"role": "user", "content": "hello"}]
    r1 = tpl.render(msgs)
    reply = tok.decode([5, 9, 250])
    msgs2 = msgs + [{"role": "assistant", "content": reply},
                    {"role": "user", "content": "more"}]
    r2 = tpl.render(msgs2)
    hist = tpl.render(msgs, add_assistant_header=True)
    # round 1 render (prompt + assistant header) prefixes round 2 once
    # the assistant reply continues exactly where generation started
    gen = tok.encode(reply)
    assert np.array_equal(r2[:len(r1)], r1)
    assert np.array_equal(r2[len(r1):len(r1) + len(gen)], gen)
    assert len(r2) > len(hist)


def test_chat_template_token_list_content():
    tpl = ChatTemplate(ByteTokenizer(256))
    a = tpl.render([{"role": "user", "content": [1, 2, 300]}])
    b = tpl.render([{"role": "user", "content": [1, 2, 300 % 256]}])
    assert np.array_equal(a, b)


# ---------------------------------------------------------------- router
def _chain_router(**kw):
    defaults = dict(n_slots=2, block_size=4, reuse_threshold=0.5,
                    max_stored=4)
    defaults.update(kw)
    return SessionRouter(None, **defaults)


def test_router_fresh_then_exact_then_similarity():
    r = _chain_router()
    p1 = np.arange(10, dtype=np.int32)
    d1 = r.route(p1, "conv-a")
    assert d1.kind == "fresh" and len(d1.prompt) == 10
    r.complete(d1, [90, 91, 92])            # history = p1 + [90, 91]
    hist = np.concatenate([p1, [90, 91]]).astype(np.int32)

    p2 = np.concatenate([hist, [92, 7, 8]]).astype(np.int32)
    d2 = r.route(p2, "conv-a")              # same conversation id
    assert d2.kind == "exact"
    assert d2.matched_tokens == len(hist)
    assert list(d2.prompt) == [92, 7, 8]
    r.complete(d2, [93, 94])

    hist2 = np.concatenate([p2, [93]]).astype(np.int32)
    p3 = np.concatenate([hist2, [94, 1]]).astype(np.int32)
    d3 = r.route(p3, None)                  # transcript only, no id
    assert d3.kind == "restore"
    assert d3.matched_tokens == len(hist2)
    assert d3.session_id == d1.session_id
    st = r.stats()
    assert st["exact_hits"] == 1 and st["similarity_hits"] == 1


def test_router_reuse_threshold_rejects_short_match():
    r = _chain_router(reuse_threshold=0.9)
    d1 = r.route(np.arange(8, dtype=np.int32), None)
    r.complete(d1, [50, 51])
    # match covers 9 of 20 tokens < 0.9 -> fresh, not restore
    long = np.concatenate([np.arange(8), [50], np.arange(11)])
    d2 = r.route(long.astype(np.int32), None)
    assert d2.kind == "fresh"


def test_router_blind_never_steers():
    r = _chain_router(steer=False)
    p = np.arange(12, dtype=np.int32)
    d1 = r.route(p, "conv-a")
    r.complete(d1, [1, 2])
    d2 = r.route(np.concatenate([p, [1, 9]]).astype(np.int32), "conv-a")
    assert d1.kind == d2.kind == "fresh"
    assert d2.session_id != d1.session_id
    assert r.stats()["hit_rate"] == 0.0


def test_router_busy_conflict_and_cancel():
    r = _chain_router()
    p = np.arange(10, dtype=np.int32)
    d1 = r.route(p, "conv-a")
    with pytest.raises(RouterBusy):
        r.route(np.concatenate([p, [5]]).astype(np.int32), "conv-a")
    r.cancel(d1)                            # failed submit releases it
    r.complete(d1, [1, 2])
    d2 = r.route(np.concatenate([p, [1, 9]]).astype(np.int32), "conv-a")
    assert d2.kind == "exact"


def test_router_displacement_to_stored_registry():
    """Overwritten slots keep their session restorable via the stored
    registry (save-to-store precedes overwrite by construction)."""
    r = _chain_router(n_slots=1)
    p1 = np.arange(8, dtype=np.int32)
    d1 = r.route(p1, "conv-a")
    r.complete(d1, [70, 71])
    hist = np.concatenate([p1, [70]]).astype(np.int32)
    d2 = r.route(np.arange(100, 112, dtype=np.int32), "conv-b")
    assert d2.kind == "fresh"               # displaced conv-a's slot
    r.complete(d2, [1, 2])
    assert r.stats()["overwrites"] >= 1
    assert d1.session_id in r.stored
    # conv-a returns with its transcript: found in the stored registry
    d3 = r.route(np.concatenate([hist, [71, 3]]).astype(np.int32), None)
    assert d3.kind == "restore"
    assert d3.session_id == d1.session_id
    assert d1.session_id not in r.stored    # back in a live slot


def test_router_fork_on_shared_prefix():
    class FakeEngine:
        prefix_sharing = True

        def __init__(self):
            self.forked = []

        def fork_session(self, src, new):
            self.forked.append((src, new))

    eng = FakeEngine()
    r = SessionRouter(eng, n_slots=4, block_size=4)
    p = np.arange(12, dtype=np.int32)
    d1 = r.route(p, "conv-a")
    r.complete(d1, [40, 41])
    hist = np.concatenate([p, [40]]).astype(np.int32)
    # a DIFFERENT conversation continues from conv-a's checkpoint while
    # conv-a still owns the slot -> fork, not steal
    d2 = r.route(np.concatenate([hist, [41, 9]]).astype(np.int32),
                 "conv-b")
    assert d2.kind == "fork"
    assert d2.forked_from == d1.session_id
    assert eng.forked == [(d1.session_id, d2.session_id)]
    assert list(d2.prompt) == [41, 9]
    # with sharing off the same route falls back to a fresh session
    eng.prefix_sharing = False
    d3 = r.route(np.concatenate([hist, [41, 8]]).astype(np.int32),
                 "conv-c")
    assert d3.kind == "fresh"


def test_router_rewritten_history_falls_back():
    r = _chain_router()
    p = np.arange(10, dtype=np.int32)
    d1 = r.route(p, "conv-a")
    r.complete(d1, [5, 6])
    # client edited its transcript: cached state no longer prefixes it
    d2 = r.route(np.arange(50, 64, dtype=np.int32), "conv-a")
    assert d2.kind == "fresh"
    assert d2.session_id != d1.session_id


# --------------------------------------------------- engine callback seam
def test_engine_token_callbacks_exactly_once(setup):
    cfg, _, _ = setup
    engine = fresh_engine(setup)
    tokens, finishes = [], []
    engine.on_token = lambda seq, tok: tokens.append(
        (seq.request.session_id, int(tok)))
    engine.on_finish = lambda seq, reason: finishes.append(
        (seq.request.session_id, reason))
    rng = np.random.default_rng(0)
    engine.submit(Request("a", rng.integers(0, cfg.vocab_size, 12)
                          .astype(np.int32), max_new_tokens=5))
    engine.submit(Request("b", rng.integers(0, cfg.vocab_size, 7)
                          .astype(np.int32), max_new_tokens=3))
    engine.run()
    for sid in ("a", "b"):
        assert [t for s, t in tokens if s == sid] == engine.result(sid)
    assert sorted(finishes) == [("a", "length"), ("b", "length")]
    engine.close()


def test_engine_callbacks_through_pause_resume(setup):
    """Mid-stream eviction: on_pause fires, and the resumed stream emits
    each token exactly once (the resume feed replays the last sampled
    token without re-firing it)."""
    cfg, _, _ = setup
    engine = fresh_engine(setup, max_batch=1, preempt_quantum=2)
    tokens, pauses = [], []
    engine.on_token = lambda seq, tok: tokens.append(
        (seq.request.session_id, int(tok)))
    engine.on_pause = lambda seq: pauses.append(seq.request.session_id)
    rng = np.random.default_rng(1)
    engine.submit(Request("a", rng.integers(0, cfg.vocab_size, 10)
                          .astype(np.int32), max_new_tokens=6))
    engine.submit(Request("b", rng.integers(0, cfg.vocab_size, 10)
                          .astype(np.int32), max_new_tokens=6))
    engine.run()
    assert engine.metrics.preemptions > 0 and pauses
    for sid in ("a", "b"):
        assert [t for s, t in tokens if s == sid] == engine.result(sid)
    engine.close()


def test_engine_callbacks_on_restored_round(setup):
    """Round 2 restores the stored history; only NEW tokens fire."""
    cfg, _, _ = setup
    engine = fresh_engine(setup)
    tokens = []
    engine.on_token = lambda seq, tok: tokens.append(int(tok))
    rng = np.random.default_rng(2)
    engine.submit(Request("a", rng.integers(0, cfg.vocab_size, 14)
                          .astype(np.int32), max_new_tokens=4))
    engine.run()
    r1 = list(tokens)
    assert r1 == engine.result("a")
    tokens.clear()
    engine.submit(Request("a", rng.integers(0, cfg.vocab_size, 6)
                          .astype(np.int32), max_new_tokens=3))
    engine.run()
    assert engine.metrics.restored_tokens > 0
    assert tokens == engine.result("a")     # round-2 tokens only
    engine.close()


def test_recoverable_sessions(setup):
    cfg, _, _ = setup
    engine = fresh_engine(setup)
    rng = np.random.default_rng(3)
    assert engine.recoverable_sessions() == []
    for sid in ("u1", "u2"):
        engine.submit(Request(sid, rng.integers(0, cfg.vocab_size, 9)
                              .astype(np.int32), max_new_tokens=3))
    engine.run()
    assert sorted(engine.recoverable_sessions()) == ["u1", "u2"]
    engine.close()


def test_request_arrival_stamping(setup):
    cfg, _, _ = setup
    engine = fresh_engine(setup)
    rng = np.random.default_rng(4)
    p = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    r1 = Request("a", p, max_new_tokens=1)
    engine.submit(r1)
    assert r1.arrival_time > 0.0 and r1.arrival_step >= 0
    # a caller that pre-stamped (the front door at ingress) is respected
    r2 = Request("b", p, max_new_tokens=1, priority=2)
    r2.arrival_time = 123.0
    r2.arrival_step = 7
    engine.submit(r2)
    assert r2.arrival_time == 123.0 and r2.arrival_step == 7
    assert r2.priority == 2
    engine.run()
    engine.close()


def test_metrics_to_dict_json_serializable(setup):
    cfg, _, _ = setup
    engine = fresh_engine(setup)
    rng = np.random.default_rng(5)
    engine.submit(Request("a", rng.integers(0, cfg.vocab_size, 8)
                          .astype(np.int32), max_new_tokens=2))
    engine.run()
    d = engine.metrics.to_dict()
    blob = json.loads(json.dumps(d))
    assert blob["decode_steps"] == engine.metrics.decode_steps
    assert blob["ttft_wall"]["n"] == 1
    engine.close()


# ------------------------------------------------------------------ pump
def test_pump_stream_and_backpressure(setup):
    cfg, _, _ = setup
    engine = fresh_engine(setup)
    pump = EnginePump(engine, max_pending=1)
    rng = np.random.default_rng(6)
    p = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    # pump not started: submissions queue deterministically
    sub = pump.submit(Request("a", p, max_new_tokens=3))
    with pytest.raises(Overloaded):
        pump.submit(Request("b", p, max_new_tokens=3))
    pump.start()
    assert sub.wait(60.0)
    assert sub.finish_reason == "length"
    assert sub.tokens == engine.result("a")
    assert len(sub.token_times) == 3 and sub.ttft > 0
    pump.close()
    assert pump.closed
    pump.close()                            # idempotent


def test_pump_call_runs_on_pump_thread(setup):
    engine = fresh_engine(setup)
    pump = EnginePump(engine)
    # not started -> executes inline
    assert pump.call(lambda: threading.current_thread().name).result() \
        == threading.current_thread().name
    pump.start()
    name = pump.call(lambda: threading.current_thread().name).result(30.0)
    assert name == "engine-pump"
    pump.close()


# ------------------------------------------------------------------- api
def _mk_api(setup, **pump_kw):
    engine = fresh_engine(setup)
    pump = EnginePump(engine, **pump_kw).start()
    api = FrontDoor(pump, SessionRouter(engine, block_size=16))
    return engine, pump, api


def test_api_chat_rounds_restore_and_match_reference(setup):
    """Round 2 via conversation_id (exact), round 3 via transcript only
    (similarity); outputs byte-identical to a one-shot full-history
    completion on a fresh session."""
    engine, pump, api = _mk_api(setup)

    async def main():
        msgs = [{"role": "system", "content": "sys"},
                {"role": "user", "content": "hello"}]
        st, r1 = await api.handle("POST", "/v1/chat/completions",
                                  {"messages": msgs, "max_tokens": 4})
        assert st == 200 and r1["hcache"]["route"] == "fresh"
        conv = r1["conversation_id"]
        c1 = r1["choices"][0]["message"]["content"]
        assert r1["choices"][0]["finish_reason"] == "length"

        msgs2 = msgs + [{"role": "assistant", "content": c1},
                        {"role": "user", "content": "again"}]
        st, r2 = await api.handle("POST", "/v1/chat/completions",
                                  {"messages": msgs2, "max_tokens": 4,
                                   "conversation_id": conv})
        assert st == 200 and r2["hcache"]["route"] == "exact"
        assert engine.metrics.restored_tokens > 0
        c2 = r2["choices"][0]["message"]["content"]

        msgs3 = msgs2 + [{"role": "assistant", "content": c2},
                         {"role": "user", "content": "more"}]
        st, r3 = await api.handle("POST", "/v1/chat/completions",
                                  {"messages": msgs3, "max_tokens": 4})
        assert st == 200 and r3["hcache"]["route"] == "restore"
        assert r3["hcache"]["matched_tokens"] > 0

        full = api.template.render(msgs3)
        st, ref = await api.handle("POST", "/v1/completions",
                                   {"prompt": [int(t) for t in full],
                                    "max_tokens": 4})
        assert st == 200 and ref["hcache"]["route"] == "fresh"
        got = list(api.tokenizer.encode(
            r3["choices"][0]["message"]["content"]))
        assert got == ref["choices"][0]["tokens"]
        assert api.router.hit_rate > 0

    asyncio.run(main())
    pump.close()


def test_api_streaming_delivers_incrementally(setup):
    engine, pump, api = _mk_api(setup)

    async def main():
        st, agen = await api.handle(
            "POST", "/v1/chat/completions",
            {"messages": [{"role": "user", "content": "stream me"}],
             "max_tokens": 6, "stream": True})
        assert st == 200
        # park the pump thread between steps: generation provably can't
        # complete until we release it, so receiving the first chunk now
        # proves streaming delivery, not post-hoc buffering
        gate = threading.Event()
        pump.call(gate.wait)
        it = agen.__aiter__()
        seen = [(time.perf_counter(), await it.__anext__())]
        assert pump.pending() > 0           # still mid-generation
        gate.set()
        async for chunk in it:
            seen.append((time.perf_counter(), chunk))
        assert seen[-1][1] == "data: [DONE]\n\n"
        bodies = [json.loads(c[len("data: "):])
                  for _, c in seen[:-1]]
        contents = [b["choices"][0]["delta"].get("content")
                    for b in bodies if "delta" in b["choices"][0]]
        assert sum(1 for c in contents if c) == 6   # one chunk per token
        assert bodies[-1]["choices"][0]["finish_reason"] == "length"
        assert bodies[-1]["hcache"]["route"] == "fresh"
        assert seen[0][0] < seen[-1][0]

    asyncio.run(main())
    pump.close()


def test_api_backpressure_and_busy_statuses(setup):
    engine = fresh_engine(setup)
    pump = EnginePump(engine, max_pending=1)    # NOT started: no progress
    api = FrontDoor(pump, SessionRouter(engine, block_size=16))

    async def main():
        st, _ = await api.handle(
            "POST", "/v1/chat/completions",
            {"messages": [{"role": "user", "content": "one"}],
             "max_tokens": 2, "stream": True,
             "conversation_id": "conv-x"})
        assert st == 200
        # same conversation again while in flight -> 409
        st, err = await api.handle(
            "POST", "/v1/chat/completions",
            {"messages": [{"role": "user", "content": "one two"}],
             "max_tokens": 2, "conversation_id": "conv-x"})
        assert st == 409 and err["error"]["type"] == "conversation_busy"
        # different conversation -> queue-depth cap -> 429, and the
        # router slot it grabbed is released for a retry
        st, err = await api.handle(
            "POST", "/v1/chat/completions",
            {"messages": [{"role": "user", "content": "other"}],
             "max_tokens": 2, "conversation_id": "conv-y"})
        assert st == 429 and err["error"]["type"] == "overloaded"
        assert not any(s.busy and s.conversation_id == "conv-y"
                       for s in api.router.slots)
        st, _ = await api.handle("GET", "/healthz", None)
        assert st == 200

    asyncio.run(main())
    pump.close(force=True)


def test_api_validation_and_metrics_endpoint(setup):
    engine, pump, api = _mk_api(setup)

    async def main():
        st, err = await api.handle("POST", "/v1/chat/completions",
                                   {"messages": []})
        assert st == 400
        st, err = await api.handle("POST", "/v1/completions", {})
        assert st == 400
        st, _ = await api.handle("GET", "/nope", None)
        assert st == 404
        st, models = await api.handle("GET", "/v1/models", None)
        assert st == 200 and models["data"][0]["id"] == api.model_name
        st, m = await api.handle("GET", "/metrics", None)
        assert st == 200
        json.dumps(m)                       # whole document serializes
        assert "engine" in m and "router" in m and "pump" in m

    asyncio.run(main())
    pump.close()


# ------------------------------------------------------------------ http
def test_http_binding_smoke(setup):
    """Satellite (f): ephemeral-port HTTP server, one streaming + one
    non-streaming request over real sockets, clean shutdown with
    ``engine.close()`` reached and no leaked threads."""
    before = set(threading.enumerate())
    engine = fresh_engine(setup)
    pump = EnginePump(engine).start()
    api = FrontDoor(pump)

    async def request(port, body, stream):
        reader, writer = await asyncio.open_connection("127.0.0.1", port)
        doc = json.dumps(body).encode()
        writer.write(
            b"POST /v1/chat/completions HTTP/1.1\r\n"
            b"Host: localhost\r\nContent-Type: application/json\r\n"
            + f"Content-Length: {len(doc)}\r\n\r\n".encode() + doc)
        await writer.drain()
        status = (await reader.readline()).decode()
        while (await reader.readline()).strip():
            pass                            # headers
        raw = (await reader.read()).decode()   # Connection: close -> EOF
        writer.close()
        await writer.wait_closed()
        return status, raw

    async def main():
        srv = await HttpFrontDoor(api, port=0).start()
        assert srv.port != 0
        st, raw = await request(
            srv.port, {"messages": [{"role": "user", "content": "hi"}],
                       "max_tokens": 3}, stream=False)
        assert "200" in st
        doc = json.loads(raw)
        assert len(doc["choices"][0]["message"]["content"]) == 3
        st, raw = await request(
            srv.port, {"messages": [{"role": "user", "content": "hi2"}],
                       "max_tokens": 3, "stream": True}, stream=True)
        assert "200" in st
        events = [e for e in raw.split("\n\n") if e.startswith("data: ")]
        assert events[-1] == "data: [DONE]"
        deltas = [json.loads(e[len("data: "):]) for e in events[:-1]]
        assert sum(1 for d in deltas
                   if d["choices"][0]["delta"].get("content")) == 3
        await srv.close()

    asyncio.run(main())
    pump.close()
    assert pump.closed
    # engine.close() was reached: the saver's daemon threads are joined
    assert not any(t.is_alive() for t in engine.mgr.saver._threads)
    leaked = [t for t in threading.enumerate()
              if t not in before and t.is_alive()]
    assert not leaked, leaked
