"""Communication compression + error-feedback optimizer."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis - seeded shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.distributed.compression import (dequantize_int8, quantize_int8,
                                           tree_cast_bf16)
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_int8_quantization_bounded_error(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(64,)) * rng.uniform(0.1, 100))
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s)
    assert float(jnp.abs(back - x).max()) <= float(s) * 0.5 + 1e-9


def test_tree_cast_bf16_preserves_ints():
    tree = {"w": jnp.ones((3,), jnp.float32), "i": jnp.ones((2,), jnp.int32)}
    out = tree_cast_bf16(tree)
    assert out["w"].dtype == jnp.bfloat16
    assert out["i"].dtype == jnp.int32


def test_error_feedback_recovers_bf16_loss():
    """With error feedback, repeated tiny gradients are not lost to bf16
    rounding (they accumulate in the feedback buffer)."""
    params = {"w": jnp.ones((4,), jnp.float32)}
    cfg = AdamWConfig(lr=1e-2, weight_decay=0.0, error_feedback=True)
    state = init_opt_state(params, error_feedback=True)
    g = {"w": jnp.full((4,), 1e-3, jnp.float32)}
    p = params
    for _ in range(5):
        p, state, _ = adamw_update(p, tree_cast_bf16(g), state, cfg)
    assert float(p["w"][0]) < 1.0           # updates actually applied
    assert "ef" in state
