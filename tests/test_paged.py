"""Paged KV-cache backend: greedy equivalence vs contiguous (restore,
mid-stream pause/resume, retire), allocator edge cases (exhaustion ->
queue backpressure, page reuse after eviction), occupancy gauges."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.arch import reduced_for_smoke
from repro.config.hardware import PAPER_A100
from repro.configs import get_arch
from repro.core.hcache import HCacheManager
from repro.models import Model
from repro.serving import InferenceEngine, Request
from repro.serving.kv_cache import (BlockAllocator, ContiguousBackend,
                                    PagedBackend, make_backend)
from repro.storage import ChunkStore, make_array


@pytest.fixture(scope="module")
def setup():
    from repro.distributed.sharding import default_rules
    from repro.launch.mesh import make_mesh
    from repro.models.module import split
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = reduced_for_smoke(get_arch("llama2-7b"))
    model = Model(cfg, rules=default_rules(mesh), model_axis=1,
                  dtype=jnp.float32, remat="none")
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def fresh_engine(setup, **kw):
    cfg, model, params = setup
    store = ChunkStore(make_array("dram", 4), chunk_tokens=16)
    # store_dtype matches the model dtype so pause/restore cycles are
    # lossless and cross-backend equivalence is bit-exact
    mgr = HCacheManager(model, store, hw=PAPER_A100,
                        schedule_override="hidden", store_dtype=np.float32)
    defaults = dict(max_batch=2, max_seq=128, prefill_chunk=8)
    defaults.update(kw)
    return InferenceEngine(model, params, mgr, **defaults), mgr


def _prompts(cfg, n, seed=7, lo=6, hi=24):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, int(k)).astype(np.int32)
            for k in rng.integers(lo, hi, size=n)]


# ----------------------------------------------------------- allocator
def test_block_allocator_edges():
    a = BlockAllocator(4)
    got = a.alloc(3)
    assert len(got) == 3 and a.free_count == 1
    assert a.alloc(2) is None                 # exhaustion: no partial grant
    assert a.free_count == 1
    last = a.alloc(1)
    assert a.alloc(1) is None and a.free_count == 0
    a.free(got)
    assert a.free_count == 3
    # LIFO reuse: the next alloc hands back the just-freed pages
    assert a.alloc(3) == got
    a.free(last)
    assert a.free_count == 1


def test_paged_backend_rejects_non_lm():
    from repro.distributed.sharding import default_rules
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = reduced_for_smoke(get_arch("falcon-mamba-7b"))
    ssm = Model(cfg, rules=default_rules(mesh), model_axis=1,
                dtype=jnp.float32, remat="none")
    with pytest.raises(NotImplementedError):
        make_backend("paged", ssm, 2, 128)


# --------------------------------------------------------- equivalence
def test_paged_equivalence_restore_pause_retire(setup):
    """The acceptance workload: 8 sessions over 2 slots with mid-stream
    eviction — every session retires, pauses, and restores through the
    paged layout with byte-identical greedy output to contiguous."""
    cfg, model, params = setup
    prompts = _prompts(cfg, 8)
    results, metrics = {}, {}
    for backend in ("contiguous", "paged"):
        eng, _ = fresh_engine(setup, max_batch=2, preempt_quantum=3,
                              backend=backend)
        for i, p in enumerate(prompts):
            eng.submit(Request(f"s{i}", p, max_new_tokens=5))
        eng.run()
        results[backend] = {f"s{i}": eng.result(f"s{i}") for i in range(8)}
        metrics[backend] = eng.metrics
        eng.close()
    assert metrics["paged"].preemptions > 0        # pause/resume exercised
    assert metrics["paged"].restored_tokens > 0    # restore wrote pages
    assert results["paged"] == results["contiguous"]
    # same memory (2 slots worth): paged reserves per-session need only
    assert (metrics["paged"].reserved_tokens_peak
            < metrics["contiguous"].reserved_tokens_peak)
    assert (metrics["paged"].occupancy_mean
            > metrics["contiguous"].occupancy_mean)


def test_paged_multi_round_restoration_matches_ground_truth(setup):
    """Round-2 generation after retire + paged restoration == a single
    prefill over the whole history (same idiom as the contiguous test in
    test_serving.py — here the restored KV lands in scattered pages)."""
    cfg, model, params = setup
    eng, _ = fresh_engine(setup, backend="paged")
    rng = np.random.default_rng(1)
    p1 = rng.integers(0, cfg.vocab_size, 18).astype(np.int32)
    eng.submit(Request("alice", p1, max_new_tokens=5))
    eng.run()
    g1 = eng.result("alice")
    p2 = rng.integers(0, cfg.vocab_size, 7).astype(np.int32)
    eng.submit(Request("alice", p2, max_new_tokens=4))
    eng.run()
    g2 = eng.result("alice")
    eng.close()

    full = np.concatenate([p1, np.asarray(g1[:-1], np.int32), p2])
    pre = model.prefill(params, {"tokens": jnp.asarray(full)[None]})
    n = len(full)
    k = jnp.pad(pre["kv"][0], ((0, 0), (0, 0), (0, 128 - n), (0, 0), (0, 0)))
    v = jnp.pad(pre["kv"][1], ((0, 0), (0, 0), (0, 128 - n), (0, 0), (0, 0)))
    cache = {"k": k, "v": v, "lengths": jnp.asarray([n], jnp.int32)}
    nt = jnp.argmax(pre["logits"][:, -1], -1).astype(jnp.int32)[:, None]
    want = []
    for _ in range(4):
        want.append(int(nt[0, 0]))
        lg, cache = model.decode_step(params, cache, nt)
        nt = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]
    assert g2 == want


# ------------------------------------------- exhaustion / backpressure
def test_pool_exhaustion_backpressures_queue_and_reuses_pages(setup):
    """A 4-page pool (64 tokens) under 4 slots and 6 two-page sessions:
    admission stalls on the allocator (free slots exist, pages don't),
    sessions run anyway as pages recycle, and after drain every page is
    back in the free list."""
    cfg, model, params = setup
    eng, _ = fresh_engine(setup, max_batch=4, backend="paged",
                          cache_blocks=4)
    prompts = _prompts(cfg, 6, seed=3, lo=16, hi=24)
    for i, p in enumerate(prompts):
        eng.submit(Request(f"b{i}", p, max_new_tokens=3))
    eng.run()
    assert all(len(eng.result(f"b{i}")) == 3 for i in range(6))
    m = eng.metrics
    assert m.alloc_stalls > 0                      # pool gated admission
    assert m.concurrent_peak < 4                   # slots alone didn't
    assert eng.kv.allocator.free_count == 4        # page reuse: all back
    assert all(not blks for blks in eng.kv.slot_blocks)
    eng.close()


def test_reserve_is_all_or_nothing(setup):
    cfg, model, params = setup
    b = PagedBackend(model, max_batch=2, max_seq=64, block_size=16,
                     num_blocks=3)
    assert b.reserve(0, 40)                        # 3 pages
    assert b.allocator.free_count == 0
    assert not b.can_reserve(1)
    assert not b.reserve(1, 1)                     # exhausted: no grant
    assert b.allocator.free_count == 0             # and nothing leaked
    b.free_slot(0)
    assert b.allocator.free_count == 3
    assert b.reserve(1, 1)                         # freed pages reusable


def test_reserve_clamps_overlong_sessions_to_table_row(setup):
    """A worst-case need past max_seq (or the pool) clamps to one full
    table row instead of crashing the table write or wedging admission —
    matching contiguous, where overflow decode writes silently drop."""
    cfg, model, params = setup
    b = PagedBackend(model, max_batch=2, max_seq=64, block_size=16)
    assert b.can_reserve(100_000)
    assert b.reserve(0, 100_000)
    assert len(b.slot_blocks[0]) == 4              # blocks_per_seq, not 6250
    assert b.allocator.free_count == 4

    tiny = PagedBackend(model, max_batch=2, max_seq=64, block_size=16,
                        num_blocks=2)              # pool < one full row
    assert tiny.reserve(0, 100_000)
    assert len(tiny.slot_blocks[0]) == 2


def test_preemption_fires_on_pool_exhaustion_with_free_slots(setup):
    """The page pool is the second admission gate: when free slots exist
    but the pool is hogged by a resident session, the preemption quantum
    must still bound the queue's wait (victim paused, pages recycled)."""
    cfg, model, params = setup
    eng, _ = fresh_engine(setup, max_batch=4, backend="paged",
                          cache_blocks=4, preempt_quantum=2)
    rng = np.random.default_rng(2)
    pa = rng.integers(0, cfg.vocab_size, 30).astype(np.int32)  # 3 pages
    pb = rng.integers(0, cfg.vocab_size, 18).astype(np.int32)  # 2 pages
    eng.submit(Request("hog", pa, max_new_tokens=8))
    eng.submit(Request("small", pb, max_new_tokens=3))
    eng.run()
    assert len(eng.result("hog")) == 8
    assert len(eng.result("small")) == 3
    m = eng.metrics
    assert m.alloc_stalls > 0              # pool (not slots) blocked "small"
    assert m.preemptions > 0               # quantum still bounded its wait
    assert eng.kv.allocator.free_count == 4
    eng.close()


# ------------------------------------------------------------- gauges
def test_occupancy_gauges_track_reservations(setup):
    cfg, model, params = setup
    b = ContiguousBackend(model, max_batch=2, max_seq=128)
    b.reserve(0, 20)
    b.set_length(0, 20)
    occ = b.occupancy()
    assert occ.reserved_tokens == 128              # whole slot regardless
    assert occ.live_tokens == 20
    assert occ.free_blocks == 1                    # slots, for contiguous
    assert 0.0 < occ.utilization < 0.2

    p = PagedBackend(model, max_batch=2, max_seq=128, block_size=16)
    p.reserve(0, 20)
    p.set_length(0, 20)
    occ = p.occupancy()
    assert occ.reserved_tokens == 32               # 2 pages, not max_seq
    assert occ.live_tokens == 20
    assert occ.capacity_tokens == 2 * 128
    assert occ.free_blocks == 16 - 2
    assert occ.utilization == pytest.approx(20 / 32)
    assert occ.fragmentation == pytest.approx(1 - 20 / 32)
