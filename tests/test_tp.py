"""Device-sharded KV page pool + mesh-parallel restoration compute
(DESIGN.md §16): byte-identity across tp ∈ {1, 2, 4} through restore,
pause/resume, prefix-sharing CoW and the distributed async store path;
hybrid restoration through the sharded projection pack; planning under
sharding (auto group-size argmin shift, mesh-keyed plan cache, zero
projection recompiles within a bucket); and per-device engine gauges.

``tests/conftest.py`` forces 4 host devices via
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` before jax
imports, so the SPMD path runs on CPU-only CI."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.arch import reduced_for_smoke
from repro.config.hardware import PAPER_A100
from repro.configs import get_arch
from repro.core.cost_model import layer_costs, method_times
from repro.core.hcache import HCacheManager
from repro.core.restoration import (choose_group_size, compile_tasks,
                                    projection_trace_count, replay,
                                    s_bucket)
from repro.distributed import tp as tp_lib
from repro.models import Model
from repro.models.module import split
from repro.serving import InferenceEngine, Request
from repro.serving.kv_cache import (PagedBackend, ShardedPagedBackend,
                                    make_backend)
from repro.storage import (AsyncIOEngine, ChunkStore, make_array,
                           make_shards)


@pytest.fixture(scope="module")
def setup():
    from repro.distributed.sharding import default_rules
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = reduced_for_smoke(get_arch("llama2-7b"))
    model = Model(cfg, rules=default_rules(mesh), model_axis=1,
                  dtype=jnp.float32, remat="none")
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def fresh_engine(setup, store=None, **kw):
    cfg, model, params = setup
    if store is None:
        store = ChunkStore(make_array("dram", 4), chunk_tokens=16)
    # fp32 storage → pause/restore cycles are lossless and cross-tp
    # equivalence is bit-exact (same convention as test_paged)
    mgr = HCacheManager(model, store, hw=PAPER_A100,
                        schedule_override="hidden", store_dtype=np.float32)
    defaults = dict(max_batch=2, max_seq=128, prefill_chunk=8)
    defaults.update(kw)
    return InferenceEngine(model, params, mgr, **defaults), mgr


def _prompts(cfg, n, seed=7, lo=6, hi=24):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, int(k)).astype(np.int32)
            for k in rng.integers(lo, hi, size=n)]


# ------------------------------------------------------------ TPContext
def test_tp_context_identity_when_single_device():
    one = tp_lib.TPContext(1)
    assert not one.spmd
    x = jnp.arange(8.0).reshape(2, 4)
    assert one.shard_kv(x, 1) is x                 # placement is identity
    assert one.replicate(x) is x
    assert one.unshard(x) is x
    assert one.kv_sharding(2, 1) is None
    one.validate_heads(3)                          # never raises when off


def test_tp_context_spmd_shardings():
    assert len(jax.devices()) >= 4                 # conftest forced devices
    tp = tp_lib.TPContext(4)
    assert tp.spmd and tp.key() == (4, True)
    with pytest.raises(ValueError, match="n_kv_heads"):
        tp.validate_heads(6)
    tp.validate_heads(8)
    pool = jnp.zeros((2, 8, 16, 4, 8))             # (L, NB, bs, Kv, hd)
    sharded = tp.shard_kv(pool, 3)
    assert len(sharded.sharding.device_set) == 4
    # each device holds a 1-KV-head slice: 1/4 of the bytes
    assert all(s.data.shape == (2, 8, 16, 1, 8)
               for s in sharded.addressable_shards)
    rep = tp.replicate(jnp.arange(4))
    assert len(rep.sharding.device_set) == 4
    back = tp.unshard(sharded)
    assert len(back.sharding.device_set) == 1


def test_seams_are_identity_without_active_context():
    x = jnp.ones((2, 3, 4))
    assert tp_lib.kv_seam(x, 2) is x
    assert tp_lib.logits_seam(x) is x
    with tp_lib.tp_seam(tp_lib.TPContext(1)):      # tp=1 never activates
        assert tp_lib.active() is None


# ------------------------------------------------- sharded backend state
def test_sharded_backend_pool_layout_and_views(setup):
    cfg, model, params = setup
    tp = tp_lib.TPContext(4)
    b = make_backend("paged", model, 2, 128, tp=tp)
    assert isinstance(b, ShardedPagedBackend)
    # pool sharded over KV heads; page structure replicated
    assert len(b.cache["k_pool"].sharding.device_set) == 4
    assert len(b.cache["block_table"].sharding.device_set) == 4
    total = b.cache["k_pool"].nbytes + b.cache["v_pool"].nbytes
    views = b.device_views()
    assert len(views) == 4
    # every device view sees the same page structure, 1/4 of the bytes
    assert all(v.pool_bytes() == total // 4 for v in views)
    assert all(v.free_count == b.allocator.free_count for v in views)
    rows = b.device_occupancy()
    assert [r["device"] for r in rows] == [0, 1, 2, 3]
    assert all(r["free_pages"] == b.allocator.free_count for r in rows)

    # tp=1 spec degrades to the plain backend with one gauge row
    b1 = make_backend("paged", model, 2, 128, tp=tp_lib.TPContext(1))
    assert type(b1) is PagedBackend
    assert len(b1.device_occupancy()) == 1


def test_sharded_backend_requires_divisible_heads(setup):
    cfg, model, params = setup
    assert cfg.n_kv_heads % 4 == 0                 # the smoke config works
    bad = tp_lib.TPContext(3)
    with pytest.raises(ValueError, match="n_kv_heads"):
        make_backend("paged-tp", model, 2, 128, tp=bad)


# ----------------------------------------------- engine byte-identity
def _run_workload(setup, prompts, tp, **kw):
    eng, _ = fresh_engine(setup, tp=tp, **kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(f"s{i}", p, max_new_tokens=5))
    eng.run()
    out = {f"s{i}": eng.result(f"s{i}") for i in range(len(prompts))}
    met = eng.metrics
    eng.close()
    return out, met


def test_acceptance_workload_byte_identity_across_tp(setup):
    """The paged acceptance workload (8 sessions over 2 slots with
    mid-stream eviction) must produce byte-identical greedy output at
    tp ∈ {1, 2, 4}: every restored token flows through the SPMD grouped
    projection into shard-local pages, every decode through the sharded
    attention with its single logits-seam all-gather."""
    cfg, model, params = setup
    prompts = _prompts(cfg, 8)
    results, metrics = {}, {}
    for tp in (1, 2, 4):
        results[tp], metrics[tp] = _run_workload(
            setup, prompts, tp, max_batch=2, preempt_quantum=3,
            backend="paged")
    assert metrics[4].preemptions > 0              # pause/resume exercised
    assert metrics[4].restored_tokens > 0          # restore wrote pages
    assert results[2] == results[1]
    assert results[4] == results[1]
    # per-device gauges: one row per shard, populated by the run
    assert len(metrics[4].device_gauges) == 4
    assert len(metrics[1].device_gauges) == 1
    assert {r["device"] for r in metrics[4].device_gauges} == {0, 1, 2, 3}


def test_prefix_sharing_cow_byte_identity_under_tp(setup):
    """Cross-session prefix sharing over the sharded pool: adopted
    pages, CoW copies and aliased host chunks are all shard-local ops —
    outputs match the tp=1 sharing run bit for bit."""
    cfg, model, params = setup
    rng = np.random.default_rng(11)
    sys_p = rng.integers(0, cfg.vocab_size, 48).astype(np.int32)
    prompts = [np.concatenate([sys_p, rng.integers(
        0, cfg.vocab_size, 6).astype(np.int32)]) for _ in range(4)]
    results, mets = {}, {}
    for tp in (1, 4):
        results[tp], mets[tp] = _run_workload(
            setup, prompts, tp, backend="paged", prefix_sharing=True)
    assert results[4] == results[1]
    assert mets[4].prefix_hits >= 2                # sharing actually fired
    assert mets[4].dedup_host_bytes > 0


def test_distributed_async_store_byte_identity_under_tp(setup):
    """The full stack at once: striped host shards + async IO engine
    feeding the SPMD projection feeding shard-local pages. Output must
    match the single-device, single-shard DRAM run."""
    cfg, model, params = setup
    prompts = _prompts(cfg, 5, seed=13)

    def sharded_store():
        s = ChunkStore(shards=make_shards(2, 2, "ssd"), chunk_tokens=16)
        s.attach_io_engine(AsyncIOEngine(2))
        return s

    base, bmet = _run_workload(setup, prompts, 1, max_batch=2,
                               preempt_quantum=3, backend="paged")
    got, gmet = _run_workload(setup, prompts, 4, max_batch=2,
                              preempt_quantum=3, backend="paged",
                              store=sharded_store())
    assert gmet.restored_tokens > 0
    assert got == base


# -------------------------------------------------- hybrid + enc-dec
def test_hybrid_restore_byte_identity_under_tp(rules):
    """A hybrid (attention + SSM) session restored through the sharded
    projection pack: attention KV projects SPMD over the mesh, SSM blobs
    bypass it, and the assembled contiguous cache is byte-identical to
    the unsharded restore."""
    cfg = reduced_for_smoke(get_arch("zamba2-2.7b"))
    cfg = dataclasses.replace(cfg, n_heads=4, n_kv_heads=4)
    model = Model(cfg, rules=rules, model_axis=1, dtype=jnp.float32,
                  remat="none")
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 40), 0,
                              cfg.vocab_size)
    pre = model.prefill(params, {"tokens": toks}, capture_hidden=True)
    caches = {}
    for tp in (1, 4):
        store = ChunkStore(make_array("dram", 4), chunk_tokens=16)
        mgr = HCacheManager(model, store, hw=PAPER_A100,
                            schedule_override="hidden",
                            store_dtype=np.float32)
        mgr.set_tp(tp_lib.TPContext(tp))
        mgr.save_prefill("sess", np.asarray(toks[0]), pre)
        caches[tp] = mgr.restore(params, "sess").cache
        mgr.saver.close()
    assert set(caches[1]) == set(caches[4])
    for key in caches[1]:
        np.testing.assert_array_equal(np.asarray(caches[1][key]),
                                      np.asarray(caches[4][key]), err_msg=key)


def test_encdec_paged_backend_matches_contiguous():
    """Satellite: whisper decoder self-KV through the page pool (cross
    context stays a whole per-slot object) — greedy output identical to
    the contiguous enc-dec backend, including a retire→restore round."""
    from repro.distributed.sharding import default_rules
    from repro.launch.mesh import make_mesh
    from repro.serving.kv_cache import PagedEncDecBackend
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = reduced_for_smoke(get_arch("whisper-medium"))
    model = Model(cfg, rules=default_rules(mesh), model_axis=1,
                  dtype=jnp.float32, remat="none")
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    rng = np.random.default_rng(9)
    jobs = [(rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32),
             (rng.standard_normal((16, cfg.d_model)) * 0.1)
             .astype(np.float32)) for n in (7, 11, 9)]
    results = {}
    for backend in ("encdec", "paged"):
        store = ChunkStore(make_array("dram", 4), chunk_tokens=16)
        mgr = HCacheManager(model, store, hw=PAPER_A100,
                            schedule_override="hidden",
                            store_dtype=np.float32)
        eng = InferenceEngine(model, params, mgr, max_batch=2, max_seq=96,
                              prefill_chunk=8, backend=backend)
        if backend == "paged":
            assert isinstance(eng.kv, PagedEncDecBackend)
        for i, (p, f) in enumerate(jobs):
            eng.submit(Request(f"w{i}", p, max_new_tokens=5, frames=f))
        eng.run()
        # round 2 on a retired session: self-KV restores into pages
        eng.submit(Request("w0", np.asarray([3], np.int32),
                           max_new_tokens=3))
        eng.run()
        results[backend] = ([eng.result(f"w{i}") for i in range(3)],
                            eng.result("w0"))
        eng.close()
    assert results["paged"] == results["encdec"]


# ---------------------------------------------------- planning under tp
def test_with_mesh_identity_and_pricing():
    assert PAPER_A100.with_mesh(1) is PAPER_A100   # tp=1 changes nothing
    hw4 = PAPER_A100.with_mesh(4)
    assert hw4.mesh_devices == 4
    assert hw4.name.endswith("-tp4")
    cfg = get_arch("llama2-13b")
    t1 = method_times(layer_costs(cfg, 2048)[0], PAPER_A100)
    t4 = method_times(layer_costs(cfg, 2048)[0], hw4)
    # projection compute is divided across the mesh; IO terms are not
    assert t4.c_h == pytest.approx(t1.c_h / 4)
    assert t4.io_h == pytest.approx(t1.io_h)


def test_choose_group_size_argmin_shift_under_mesh():
    """The auto knob re-prices under sharding: with projection compute
    divided 4-ways the dispatch overhead stops being amortizable against
    it, and the replay argmin shifts — the chosen width at tp=4 must
    equal the mesh-priced replay's own argmin, not tp=1's choice."""
    cfg = get_arch("llama2-13b")
    methods = ["hidden"] * cfg.n_layers
    n = 2048

    def span(hw, g):
        times = [method_times(c, hw) for c in layer_costs(cfg, n)]
        ovh = getattr(hw, "dispatch_overhead", 0.0)
        return replay(compile_tasks(tuple(methods), group_size=g), times,
                      dispatch_overhead=ovh).makespan

    cands = (1, 2, 4, 8, cfg.n_layers)
    hw1 = dataclasses.replace(PAPER_A100, dispatch_overhead=2e-3)
    hw4 = hw1.with_mesh(4)
    got1 = choose_group_size(cfg, hw1, n, methods)
    got4 = choose_group_size(cfg, hw4, n, methods)
    assert got1 == min(cands, key=lambda g: (span(hw1, g), -g))
    assert got4 == min(cands, key=lambda g: (span(hw4, g), -g))
    # the regression: mesh pricing must actually reach the argmin — a
    # planner that ignored mesh_devices would return got1 here
    assert got4 != got1


def test_plan_cache_key_includes_mesh(rules):
    """set_tp re-prices the manager and flips the plan-cache key, so
    plans memoized at tp=1 can never leak into the tp=4 pricing."""
    cfg, model, params = _small_lm(rules)
    store = ChunkStore(make_array("dram", 4), chunk_tokens=16)
    mgr = HCacheManager(model, store, hw=PAPER_A100,
                        schedule_override="hidden",
                        store_dtype=np.float32)
    key1 = mgr._price_key()
    mgr.set_tp(tp_lib.TPContext(4))
    assert mgr.hw.mesh_devices == 4
    assert mgr._price_key() != key1
    mgr.set_tp(tp_lib.TPContext(1))
    assert mgr.hw == PAPER_A100                    # with_mesh(1) identity
    assert mgr._price_key() == key1
    mgr.saver.close()


def _small_lm(rules):
    cfg = reduced_for_smoke(get_arch("llama2-7b"))
    model = Model(cfg, rules=rules, model_axis=1, dtype=jnp.float32,
                  remat="none")
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def test_zero_projection_recompiles_within_bucket_under_tp(rules):
    """DESIGN.md §10's zero-recompile guarantee survives sharding: the
    NamedSharding is a static jit arg, so two same-bucket sessions at
    tp=4 share one compiled SPMD projection."""
    cfg, model, params = _small_lm(rules)
    store = ChunkStore(make_array("dram", 4), chunk_tokens=16)
    mgr = HCacheManager(model, store, hw=PAPER_A100,
                        schedule_override="hidden",
                        store_dtype=np.float32)
    mgr.set_tp(tp_lib.TPContext(4))
    for sid, key, n in (("a", 1, 20), ("b", 2, 28)):
        toks = jax.random.randint(jax.random.PRNGKey(key), (1, n), 0,
                                  cfg.vocab_size)
        pre = model.prefill(params, {"tokens": toks}, capture_hidden=True)
        mgr.save_prefill(sid, np.asarray(toks[0]), pre)
    assert s_bucket(20) == s_bucket(28)
    mgr.restore(params, "a")                 # may trace (fresh bucket+mesh)
    before = projection_trace_count()
    mgr.restore(params, "b")
    assert projection_trace_count() == before, \
        "same-bucket session recompiled the sharded projection"
    mgr.saver.close()


# ----------------------------------------------------------- telemetry
def test_device_gauges_serialize(setup):
    cfg, model, params = setup
    eng, _ = fresh_engine(setup, tp=4, backend="paged")
    eng.submit(Request("g0", _prompts(cfg, 1, seed=21)[0],
                       max_new_tokens=3))
    eng.run()
    m = eng.metrics
    assert len(m.device_gauges) == 4
    for row in m.device_gauges:
        assert {"device", "free_pages", "occupancy_pct",
                "util_pct", "proj_util_pct"} <= set(row)
    blob = json.dumps(m.to_dict())                 # serializable end-to-end
    assert json.loads(blob)["device_gauges"] == m.device_gauges
    eng.close()
