import jax
import pytest

from repro.distributed.sharding import default_rules
from repro.launch.mesh import make_mesh


@pytest.fixture(scope="session")
def mesh():
    return make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="session")
def rules(mesh):
    return default_rules(mesh)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
