import os

# The tensor-parallel tests (test_tp.py) shard over multiple devices;
# forcing 4 host-platform devices BEFORE jax imports lets the whole
# suite — sharded and unsharded — run on any CPU box (DESIGN.md §16).
if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=4")

import jax
import pytest

from repro.distributed.sharding import default_rules
from repro.launch.mesh import make_mesh


@pytest.fixture(scope="module", autouse=True)
def _drop_jax_caches():
    # Each module builds its own smoke model; the compiled executables
    # are dead weight once the module finishes.  Left to accumulate,
    # the process-wide JIT code footprint grows with every module added
    # to the suite and eventually segfaults XLA's CPU compiler
    # mid-suite, so release them at module teardown.
    yield
    jax.clear_caches()


@pytest.fixture(scope="session")
def mesh():
    return make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="session")
def rules(mesh):
    return default_rules(mesh)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
