"""Distributed ChunkStore: cross-host striped restoration, the async IO
engine, per-link contention pricing, and the storage-layer regression
guards that rode along (reclaim lock, read-only DRAM views, FileBackend
size memoization)."""
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.arch import reduced_for_smoke
from repro.config.hardware import PAPER_A100
from repro.configs import get_arch
from repro.core.cost_model import (LinkLoad, layer_costs,
                                   link_priced_times, method_times)
from repro.core.hcache import HCacheManager
from repro.core.restoration import (CacheAssembler, RestorationExecutor,
                                    compile_tasks, fetch_aligned_partition,
                                    replay, task_links)
from repro.core.scheduler import solve
from repro.models import Model
from repro.models.module import split
from repro.serving import InferenceEngine, Request
from repro.storage import (AsyncIOEngine, ChunkStore, DRAMBackend,
                           FileBackend, ShardTopology, StorageArray,
                           make_array, make_shards)


# ------------------------------------------------------------ store level
def _fill(store, n_layers=3, n_tokens=40, width=8, seed=0):
    rng = np.random.default_rng(seed)
    ref = {}
    for layer in range(n_layers):
        data = rng.standard_normal((n_tokens, width)).astype(np.float32)
        store.append_tokens("s", "h", layer, 0, data)
        ref[layer] = data
    store.flush("s")
    return ref


@pytest.mark.parametrize("placement", ["layer", "chunk"])
@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_sharded_reads_byte_identical(placement, n_shards):
    """Restored bytes are invariant to shard count and placement, for
    both the inline and the async-engine read paths."""
    base = ChunkStore(make_array("dram", 2), chunk_tokens=16)
    ref = _fill(base)
    store = ChunkStore(shards=make_shards(n_shards, 2, "ssd"),
                       chunk_tokens=16, placement=placement)
    _fill(store)
    for layer in range(3):
        np.testing.assert_array_equal(
            store.read_layer("s", "h", layer, 40), ref[layer])
    store.attach_io_engine(AsyncIOEngine(n_shards))
    try:
        reads = [store.submit_layer_read("s", "h", layer, 40)
                 for layer in range(3)]
        for layer, lr in enumerate(reads):
            np.testing.assert_array_equal(lr.wait().data, ref[layer])
    finally:
        store.close()


def test_restore_skip_through_sharded_reads():
    """``start_token`` skips whole stripes: only the covering chunks are
    read and the payload starts at the skip offset — across shards."""
    store = ChunkStore(shards=make_shards(4, 2, "ssd"), chunk_tokens=16,
                      placement="layer")
    ref = _fill(store)
    lr = store.submit_layer_read("s", "h", 1, 40, start_token=16)
    np.testing.assert_array_equal(lr.wait().data, ref[1][16:])
    # the skipped chunk's stripe is not even submitted
    assert sum(len(t.keys) for t in lr.tickets) == 2


def test_layer_read_links_and_owner_map():
    """Layer placement: a layer read occupies exactly its owning link;
    chunk placement fans over all of them. The manifest persists the
    owner map so a reopened store can locate stripes."""
    store = ChunkStore(shards=make_shards(4, 1, "ssd"), chunk_tokens=16,
                       placement="layer")
    _fill(store)
    store.put_manifest("s", {"n_tokens": 40})
    man = store.get_manifest("s")
    assert man["shards"] == {"n_shards": 4, "placement": "layer"}
    assert store.submit_layer_read("s", "h", 2, 40).links == (2,)
    chunked = ChunkStore(shards=make_shards(2, 1, "ssd"), chunk_tokens=16,
                         placement="chunk")
    _fill(chunked)
    assert chunked.submit_layer_read("s", "h", 0, 40).links == (0, 1)


def test_reopen_with_different_shard_count_finds_chunks():
    """A store reopened over the same files with a different shard count
    still reads every chunk (placement-fallback search)."""
    shards = make_shards(2, 1, "dram", nic_bw=None)
    store = ChunkStore(shards=shards, chunk_tokens=16, placement="layer")
    ref = _fill(store)
    # reopen: same flat device list regrouped as 1 shard of 2 devices
    devs = [d for s in shards for d in s.devices]
    from repro.storage import HostShard
    reopened = ChunkStore(shards=[HostShard(0, devs)], chunk_tokens=16,
                          placement="layer")
    for layer in range(3):
        np.testing.assert_array_equal(
            reopened.read_layer("s", "h", layer, 40), ref[layer])


# --------------------------------------------------------- async engine
def test_async_engine_error_surfaces_at_wait():
    eng = AsyncIOEngine(1)
    try:
        def boom():
            raise RuntimeError("device gone")
        t = eng.submit(0, ["k"], [(boom, None)])
        with pytest.raises(RuntimeError, match="device gone"):
            t.wait(timeout=5.0)
    finally:
        eng.close()


def test_async_engine_overlaps_shards():
    """Reads on distinct shards proceed in parallel; reads within one
    shard stay serial (one queue per link)."""
    eng = AsyncIOEngine(2)
    try:
        gate = threading.Barrier(2, timeout=5.0)

        def read():
            gate.wait()         # deadlocks unless both shards run at once
            return np.zeros(1), 0.0
        t0 = eng.submit(0, ["a"], [(read, None)])
        t1 = eng.submit(1, ["b"], [(read, None)])
        t0.wait(timeout=5.0)
        t1.wait(timeout=5.0)
    finally:
        eng.close()


# ------------------------------------------------------ per-link pricing
def test_link_priced_times_layer_placement():
    cfg = get_arch("llama2-7b")
    costs = layer_costs(cfg, 2048)
    topo = ShardTopology(4, "layer")
    load = LinkLoad({0: 3})           # link 0 congested, others idle
    times, layer_links = link_priced_times(costs, PAPER_A100,
                                           topology=topo, link_load=load)
    assert layer_links == {li: li % 4 for li in range(cfg.n_layers)}
    base = method_times(costs[1], PAPER_A100)
    # layer 0 pays 3x on its congested link; layer 1's link is idle
    assert times[0].io_h == pytest.approx(3 * base.io_h)
    assert times[1].io_h == pytest.approx(base.io_h)
    assert times[0].c_h == pytest.approx(base.c_h)   # compute unstretched


def test_link_priced_times_chunk_placement_aggregates():
    cfg = get_arch("llama2-7b")
    costs = layer_costs(cfg, 2048)
    topo = ShardTopology(4, "chunk")
    times, layer_links = link_priced_times(
        costs, PAPER_A100, topology=topo, link_load=LinkLoad({2: 2}))
    assert layer_links is None        # no per-layer link parallelism left
    base = method_times(costs[0], PAPER_A100)
    # 4 links' bandwidth, but the max-loaded link gates the stripe (2x)
    assert times[0].io_h == pytest.approx(2 * base.io_h / 4)


def test_link_priced_times_without_topology_is_legacy():
    cfg = get_arch("llama2-7b")
    costs = layer_costs(cfg, 1024)
    times, links = link_priced_times(costs, PAPER_A100, io_streams=3)
    assert links is None
    for t, c in zip(times, costs):
        assert t == method_times(c, PAPER_A100, io_streams=3)


def test_replay_per_link_overlap():
    """Layer-striped IO on 2 links finishes in about half the serial
    time — the IO stream runs one queue per link."""
    cfg = get_arch("llama2-7b")
    methods = ["hidden"] * cfg.n_layers
    tasks = compile_tasks(methods)
    times = [method_times(c, PAPER_A100)
             for c in layer_costs(cfg, 8192)]
    links = task_links(tasks, {li: li % 2 for li in range(cfg.n_layers)})
    serial = replay(tasks, times)
    striped = replay(tasks, times, links=links)
    assert striped.io_finish == pytest.approx(serial.io_finish / 2,
                                              rel=0.05)
    assert striped.makespan <= serial.makespan


def test_fetch_partition_with_links_still_covers():
    cfg = get_arch("llama2-7b")
    methods = ["hidden"] * cfg.n_layers
    times = [method_times(c, PAPER_A100)
             for c in layer_costs(cfg, 4096)]
    links = {li: li % 4 for li in range(cfg.n_layers)}
    part = fetch_aligned_partition(methods, times, links=links)
    assert sum(part) == cfg.n_layers
    assert all(w >= 1 for w in part)


def test_solve_with_link_load_shifts_congested_layers():
    """Layers on a congested link price IO higher, so the solver moves
    them off IO methods first; idle-link layers keep the IO split."""
    cfg = get_arch("llama2-7b")
    topo = ShardTopology(2, "layer")
    hot = solve(cfg, 4096, PAPER_A100, topology=topo,
                link_load=LinkLoad({0: 8}))
    cold = solve(cfg, 4096, PAPER_A100, topology=topo,
                 link_load=LinkLoad({}))
    hot_io = [li for li, m in enumerate(hot.methods) if m != "recompute"]
    # congestion strictly reduces (or holds) the IO-method share
    assert len(hot_io) <= sum(1 for m in cold.methods if m != "recompute")
    assert hot.makespan >= cold.makespan


# ------------------------------------------------- storage-layer guards
def test_maybe_reclaim_single_flight_under_concurrency():
    """Concurrent writers hitting the budget run the reclaim ladder one
    at a time (regression: ``_reclaiming`` was an unguarded bool)."""
    arr = StorageArray([DRAMBackend()], budget_bytes=1)
    arr[0].write("k", np.zeros(1024, np.uint8))
    active = []
    overlaps = []

    def cb(a):
        active.append(1)
        if len(active) > 1:
            overlaps.append(1)
        time.sleep(0.01)
        active.pop()
    arr.on_pressure(cb)
    threads = [threading.Thread(target=arr.maybe_reclaim)
               for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not overlaps


def test_reclaim_callback_does_not_recurse():
    arr = StorageArray([DRAMBackend()], budget_bytes=1)
    arr[0].write("k", np.zeros(64, np.uint8))
    calls = []

    def cb(a):
        calls.append(1)
        a.maybe_reclaim()        # same-thread re-entry must be a no-op
    arr.on_pressure(cb)
    arr.maybe_reclaim()
    assert len(calls) == 1


def test_dram_read_views_are_readonly():
    """DRAMBackend.read returns an unwriteable view of the stored bytes;
    callers that mutate must copy (regression: a consumer scribbling on
    the view silently corrupted the store)."""
    d = DRAMBackend()
    src = np.arange(8, dtype=np.float32)
    d.write("k", src)
    got = d.read("k")
    assert not got.flags.writeable
    with pytest.raises((ValueError, RuntimeError)):
        got[0] = 99.0
    src[0] = -1.0                # writer's array is decoupled too
    np.testing.assert_array_equal(d.read("k"),
                                  np.arange(8, dtype=np.float32))


def test_filebackend_size_cache(tmp_path):
    """bytes_used/nbytes come from the memoized size map — consistent
    across write, overwrite and delete without per-call stat storms."""
    d = FileBackend(str(tmp_path / "dev0"))
    d.write("a", np.zeros(16, np.float32))
    d.write("b", np.zeros(4, np.float32))
    total = d.bytes_used
    assert total == d.nbytes("a") + d.nbytes("b")
    d.write("a", np.zeros(32, np.float32))      # overwrite re-sizes
    assert d.bytes_used > total
    d.delete("b")
    assert d.bytes_used == d.nbytes("a")
    # a reopened backend primes the cache from the directory listing
    d2 = FileBackend(str(tmp_path / "dev0"))
    assert d2.bytes_used == d.bytes_used


# ----------------------------------------------------- executor + engine
@pytest.fixture(scope="module")
def setup():
    from repro.distributed.sharding import default_rules
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    rules = default_rules(mesh)
    cfg = reduced_for_smoke(get_arch("llama2-7b"))
    model = Model(cfg, rules=rules, model_axis=1, dtype=jnp.float32,
                  remat="none")
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 40), 0,
                              cfg.vocab_size)
    pre = model.prefill(params, {"tokens": toks}, capture_hidden=True)
    return cfg, model, params, toks, pre


def _restore(setup, store, use_engine=False):
    cfg, model, params, toks, pre = setup
    mgr = HCacheManager(model, store, hw=PAPER_A100,
                        schedule_override="hidden")
    mgr.save_prefill("s", np.asarray(toks[0]), pre)
    if use_engine:
        store.attach_io_engine(
            AsyncIOEngine(len(store.shards) if store.shards else 1))
    sink = CacheAssembler(model)
    ex = RestorationExecutor(mgr, params, "s", sink=sink)
    while not ex.step(max_tasks=2):
        pass
    store.close()
    return ex, sink


@pytest.mark.parametrize("placement", ["layer", "chunk"])
@pytest.mark.parametrize("use_engine", [False, True])
def test_executor_restore_identical_across_shards(setup, placement,
                                                  use_engine):
    """Full executor restore over 4 shards (sync and async) produces the
    same cache as the one-host store — and its timeline equals the
    per-link replay of the graph it ran."""
    ex0, sink0 = _restore(
        setup, ChunkStore(make_array("dram", 2), chunk_tokens=16))
    store = ChunkStore(shards=make_shards(4, 2, "ssd"), chunk_tokens=16,
                       placement=placement)
    ex, sink = _restore(setup, store, use_engine=use_engine)
    np.testing.assert_array_equal(np.asarray(sink.cache["k"]),
                                  np.asarray(sink0.cache["k"]))
    np.testing.assert_array_equal(np.asarray(sink.cache["v"]),
                                  np.asarray(sink0.cache["v"]))
    tl = ex.timeline()
    assert tl == replay(ex.tasks, ex.times, ex.executed,
                        dispatch_overhead=ex.dispatch_overhead,
                        cross_times=ex.cross_times, links=ex._task_links)
    if placement == "layer":
        assert set(ex.links_touched()) <= {0, 1, 2, 3}


def test_engine_reports_link_load(setup):
    """The serving engine folds restoring executors' touched links into
    the manager's LinkLoad; plans are keyed by it."""
    cfg, model, params, toks, pre = setup
    store = ChunkStore(shards=make_shards(2, 2, "ssd"), chunk_tokens=16,
                       placement="layer")
    mgr = HCacheManager(model, store, hw=PAPER_A100,
                        schedule_override="hidden")
    assert mgr.shard_topology().n_shards == 2
    engine = InferenceEngine(model, params, mgr, max_batch=2, max_seq=128,
                             prefill_chunk=8)
    try:
        prompt = np.asarray(toks[0])[:20]
        for rnd in range(2):
            engine.submit(Request("u0", prompt, max_new_tokens=3))
            engine.run()
        assert mgr.link_load is not None
        assert isinstance(mgr.link_load, LinkLoad)
        # the price key distinguishes per-link load states
        mgr.set_link_load(LinkLoad({0: 2}))
        k_loaded = mgr._price_key()
        mgr.set_link_load(LinkLoad({}))
        assert mgr._price_key() != k_loaded
    finally:
        engine.close()
        store.close()
