"""Chunk store + two-stage saver: roundtrips, striping, resume, hypothesis."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis - seeded shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.storage import (ChunkStore, DirectSaver, SimulatedSSD,
                           SnapshotTask, TwoStageSaver, make_array)


def make_store(n_dev=4, chunk=16, kind="dram"):
    return ChunkStore(make_array(kind, n_dev), chunk_tokens=chunk)


def test_roundtrip_layer_before_token_to_token_before_layer():
    """The core layout mismatch (§4.2): save layer-by-layer in token
    increments, read back whole layers."""
    store = make_store()
    data = {li: np.arange(40 * 8, dtype=np.float32).reshape(40, 8) + li
            for li in range(3)}
    for step in range(0, 40, 5):             # autoregressive growth
        for li in range(3):
            store.append_tokens("s", "h", li, step, data[li][step:step + 5])
    store.flush("s")
    for li in range(3):
        got = store.read_layer("s", "h", li, 40)
        np.testing.assert_array_equal(got, data[li])


def test_chunks_striped_round_robin():
    store = make_store(n_dev=4, chunk=8)
    store.append_tokens("s", "h", 0, 0, np.ones((64, 4), np.float16))
    store.flush("s")
    used = [d.bytes_used for d in store.devices]
    assert all(b > 0 for b in used), used     # all devices hold chunks


def test_resume_mid_chunk():
    """Multi-round sessions append at arbitrary offsets; previously-flushed
    partial chunks must be recovered, not zero-padded."""
    store = make_store(chunk=16)
    a = np.arange(10 * 4, dtype=np.float32).reshape(10, 4)
    b = np.arange(10 * 4, 22 * 4, dtype=np.float32).reshape(12, 4)
    store.append_tokens("s", "h", 0, 0, a)
    store.flush("s")
    store.append_tokens("s", "h", 0, 10, b)   # resumes inside chunk 0
    store.flush("s")
    got = store.read_layer("s", "h", 0, 22)
    np.testing.assert_array_equal(got, np.concatenate([a, b]))


def test_manifest_and_recovery_listing():
    store = make_store()
    store.put_manifest("alice", {"n_tokens": 7, "methods": ["hidden"]})
    store.put_manifest("bob", {"n_tokens": 3, "methods": ["kv"]})
    assert store.sessions() == ["alice", "bob"]
    assert store.get_manifest("alice")["n_tokens"] == 7
    store.drop_session("alice")
    assert store.sessions() == ["bob"]
    assert store.get_manifest("alice") is None


def test_file_backend_survives_reopen(tmp_path):
    store = ChunkStore(make_array("file", 2, root=str(tmp_path)),
                       chunk_tokens=8)
    store.append_tokens("s", "h", 0, 0, np.ones((8, 2), np.float16))
    store.put_manifest("s", {"n_tokens": 8, "methods": []})
    store2 = ChunkStore(make_array("file", 2, root=str(tmp_path)),
                        chunk_tokens=8)
    assert store2.sessions() == ["s"]
    np.testing.assert_array_equal(store2.read_layer("s", "h", 0, 8),
                                  np.ones((8, 2), np.float16))


@settings(max_examples=25, deadline=None)
@given(
    chunk=st.sampled_from([4, 16, 64]),
    pieces=st.lists(st.integers(1, 30), min_size=1, max_size=12),
    width=st.integers(1, 8),
)
def test_append_roundtrip_property(chunk, pieces, width):
    """Any partition of a token stream into appends reads back intact."""
    store = make_store(chunk=chunk)
    total = sum(pieces)
    data = np.random.default_rng(0).normal(
        size=(total, width)).astype(np.float32)
    off = 0
    for n in pieces:
        store.append_tokens("s", "h", 0, off, data[off:off + n])
        off += n
    store.flush("s")
    np.testing.assert_array_equal(store.read_layer("s", "h", 0, total), data)


def test_session_id_with_slash():
    """Session ids containing '/' (tenant/user) must not collide with the
    key separator: listing, reading and dropping all work."""
    store = make_store(chunk=8)
    sid = "tenant/alice/chat-1"
    store.append_tokens(sid, "h", 0, 0, np.ones((8, 2), np.float32))
    store.flush(sid)
    store.put_manifest(sid, {"n_tokens": 8, "methods": ["hidden"]})
    store.put_manifest("bob", {"n_tokens": 1, "methods": []})
    assert store.sessions() == ["bob", sid]
    np.testing.assert_array_equal(store.read_layer(sid, "h", 0, 8),
                                  np.ones((8, 2), np.float32))
    store.drop_session(sid)
    assert store.sessions() == ["bob"]
    assert store.get_manifest(sid) is None


def test_file_backend_session_id_with_double_underscore(tmp_path):
    """Regression: the old filename scheme mapped '/' -> '__' and keys()
    mapped '__' -> '/', mangling session ids that legitimately contain
    '__'. The percent-encoding is injective: list/read/drop round-trip."""
    store = ChunkStore(make_array("file", 2, root=str(tmp_path)),
                       chunk_tokens=8)
    sid = "tenant__alice__chat%1"
    store.append_tokens(sid, "h", 0, 0, np.ones((8, 2), np.float32))
    store.flush(sid)
    store.put_manifest(sid, {"n_tokens": 8, "methods": ["hidden"]})
    store2 = ChunkStore(make_array("file", 2, root=str(tmp_path)),
                        chunk_tokens=8)
    assert store2.sessions() == [sid]
    np.testing.assert_array_equal(store2.read_layer(sid, "h", 0, 8),
                                  np.ones((8, 2), np.float32))
    store2.drop_session(sid)
    assert store2.sessions() == []
    assert store2.get_manifest(sid) is None


def test_two_stage_saver_reraises_daemon_exception():
    """A stage-2 write failure must not be lost in the daemon thread:
    drain() re-raises the first captured exception (and the daemon
    thread survives to process later tasks)."""
    store = make_store()
    saver = TwoStageSaver(store, n_threads=1)
    bad = SnapshotTask(["s", "t"], "h", 0, [0],   # missing start for "t"
                       np.ones((2, 8, 4), np.float16))
    saver.snapshot(bad)                         # daemon IndexErrors on b=1
    with pytest.raises(IndexError):
        saver.drain()
    saver.snapshot(SnapshotTask(["s"], "h", 0, [0],
                                np.ones((1, 8, 4), np.float16)))
    saver.drain()                               # exception was cleared
    saver.close()


def test_chunk_store_cold_tier_demotion():
    """demote_session_to_cold moves a session's bytes out of the hot
    (budgeted) tier; reads fall back transparently, drops cover both."""
    cold = make_array("dram", 4)
    store = ChunkStore(make_array("dram", 4), chunk_tokens=8,
                       cold_devices=cold)
    data = np.arange(24 * 4, dtype=np.float32).reshape(24, 4)
    store.append_tokens("s", "h", 0, 0, data)
    store.flush("s")
    store.put_manifest("s", {"n_tokens": 24, "methods": ["hidden"]})
    hot_before = store.bytes_used
    moved = store.demote_session_to_cold("s")
    assert moved == hot_before > 0
    assert store.bytes_used == 0 and store.bytes_cold == moved
    np.testing.assert_array_equal(store.read_layer("s", "h", 0, 24), data)
    assert store.get_manifest("s")["n_tokens"] == 24
    assert store.sessions() == ["s"]
    assert store.demote_session_to_cold("s") == 0     # nothing hot left
    store.drop_session("s")
    assert store.sessions() == [] and store.bytes_cold == 0


def test_bytes_for_per_session_per_stream():
    store = make_store(chunk=8)
    store.append_tokens("a", "h", 0, 0, np.ones((8, 4), np.float32))
    store.append_tokens("a", "kvk", 0, 0, np.ones((8, 2), np.float32))
    store.append_tokens("b", "h", 0, 0, np.ones((8, 4), np.float32))
    store.flush("a")
    store.flush("b")
    assert store.bytes_for("a", "h") == 8 * 4 * 4
    assert store.bytes_for("a", "kvk") == 8 * 2 * 4
    assert store.bytes_for("a") == 8 * 6 * 4
    assert store.bytes_for("b") == 8 * 4 * 4


def test_layer_available_checks_covering_chunks():
    """layer_available must check the chunks covering the queried range,
    not only chunk 0 (a crash mid-save leaves a prefix of chunks)."""
    store = make_store(chunk=8)
    store.append_tokens("s", "h", 0, 0, np.ones((12, 2), np.float32))
    store.flush("s")                       # chunks 0 (full) + 1 (partial)
    assert store.layer_available("s", "h", 0)
    assert store.layer_available("s", "h", 0, n_tokens=12)
    # range ends inside the flushed short chunk: NOT available
    assert not store.layer_available("s", "h", 0, n_tokens=16)
    assert not store.layer_available("s", "h", 0, n_tokens=20)
    assert not store.layer_available("s", "h", 1)
    # unflushed partial covering the range counts too
    store.append_tokens("s", "h", 1, 0, np.ones((5, 2), np.float32))
    assert store.layer_available("s", "h", 1, n_tokens=5)


def test_read_layer_async_completions():
    """The batched async read reports per-device completion times that
    aggregate striped bandwidth."""
    store = make_store(n_dev=4, chunk=16, kind="ssd")
    store.append_tokens("s", "h", 0, 0, np.ones((64, 32), np.float16))
    store.flush("s")
    store.sync_clocks(0.0)
    r = store.read_layer_async("s", "h", 0, 64)
    assert r.data.shape == (64, 32)
    assert len(r.device_completions) == 4
    assert r.completion == max(r.device_completions) > 0


def test_simulated_ssd_bandwidth_aggregation():
    """Reading a layer striped over 4 SSDs completes ~4x faster than on 1."""
    total = 64 * 16

    def read_time(n_dev):
        store = make_store(n_dev=n_dev, chunk=64, kind="ssd")
        store.append_tokens("s", "h", 0, 0,
                            np.ones((total, 256), np.float16))
        store.flush("s")
        store.sync_clocks(0.0)
        store.read_layer("s", "h", 0, total)
        return store.read_completion()

    t1, t4 = read_time(1), read_time(4)
    # same total bytes in both cases => ideal 4x; latency eats a little
    assert t1 / t4 > 2.5


def test_two_stage_saver_offloads_critical_path():
    store = make_store(kind="ssd")
    saver = TwoStageSaver(store, ring_slots=64)
    direct = DirectSaver(make_store(kind="ssd"))

    def task(i):
        return SnapshotTask(["s"], "h", 0, [i * 8],
                            np.ones((1, 8, 64), np.float16))

    ts_cost = sum(saver.snapshot(task(i)) for i in range(20))
    d_cost = sum(direct.snapshot(task(i)) for i in range(20))
    saver.drain()
    assert ts_cost < d_cost       # stage-1 copy < synchronous SSD write
    store.flush("s")
    got = store.read_layer("s", "h", 0, 160)
    assert got.shape == (160, 64)
    saver.close()
