"""Training substrate: convergence, checkpoint/restart, determinism,
fault supervision, ZeRO axes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.arch import reduced_for_smoke
from repro.configs import get_arch
from repro.distributed.fault import (FailureInjector, InjectedFailure,
                                     run_supervised)
from repro.models import Model
from repro.training import (AdamWConfig, CheckpointManager, DataConfig,
                            Trainer, batch_at)
from repro.training.optimizer import opt_axes


@pytest.fixture(scope="module")
def trainer_setup():
    from repro.distributed.sharding import default_rules
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    rules = default_rules(mesh)
    cfg = reduced_for_smoke(get_arch("qwen2-7b"))
    model = Model(cfg, rules=rules, model_axis=1, dtype=jnp.float32,
                  remat="full")
    trainer = Trainer(model, rules, AdamWConfig(lr=1e-3), loss_chunks=4)
    return cfg, model, trainer


def test_loss_decreases(trainer_setup):
    cfg, model, trainer = trainer_setup
    state, _ = trainer.init_state(jax.random.PRNGKey(0))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    step = jax.jit(trainer.train_step)
    batch = batch_at(dc, 0)
    first = last = None
    for i in range(6):
        state, m = step(state, batch)
        first = first if first is not None else float(m["loss"])
        last = float(m["loss"])
    assert last < first - 0.5


def test_data_deterministic_and_resumable():
    dc = DataConfig(vocab_size=1000, seq_len=16, global_batch=2, seed=7)
    a = batch_at(dc, 41)
    b = batch_at(dc, 41)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = batch_at(dc, 42)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # next-token targets
    full_a = np.concatenate([np.asarray(a["tokens"]),
                             np.asarray(a["targets"])[:, -1:]], axis=1)
    np.testing.assert_array_equal(full_a[:, 1:], np.asarray(a["targets"]))


def test_checkpoint_roundtrip(tmp_path, trainer_setup):
    cfg, model, trainer = trainer_setup
    state, _ = trainer.init_state(jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(3, state, wait=True)
    mgr.save(7, state, wait=True)
    mgr.save(11, state, wait=True)
    assert mgr.all_steps() == [7, 11]          # retention
    step, restored = mgr.restore(state)
    assert step == 11
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_supervised_restart_reproduces_uninterrupted_run(tmp_path,
                                                         trainer_setup):
    """Training with an injected failure at step 7 must land on the same
    final params as an uninterrupted run (deterministic data + restore)."""
    cfg, model, trainer = trainer_setup
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=2)
    step_jit = jax.jit(trainer.train_step)

    def run(ckdir, fail_at):
        state, _ = trainer.init_state(jax.random.PRNGKey(0))
        live = {"state": state}
        injector = FailureInjector(fail_at=fail_at)

        def one(step):
            injector.check(step)
            live["state"], m = step_jit(live["state"], batch_at(dc, step))
            return m

        report = run_supervised(
            one, ckpt=CheckpointManager(str(ckdir)),
            save_state=lambda: live["state"],
            load_state=lambda s, st: live.update(state=st),
            n_steps=12, ckpt_every=3)
        return live["state"], report

    clean, r0 = run(tmp_path / "clean", ())
    faulty, r1 = run(tmp_path / "faulty", (7,))
    assert r1.restarts == 1 and r0.restarts == 0
    for a, b in zip(jax.tree.leaves(clean["params"]),
                    jax.tree.leaves(faulty["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_supervisor_gives_up_after_max_restarts(tmp_path):
    def one(step):
        raise InjectedFailure("always")

    with pytest.raises(InjectedFailure):
        run_supervised(one, ckpt=CheckpointManager(str(tmp_path)),
                       save_state=lambda: {"x": jnp.zeros(())},
                       load_state=lambda s, st: None,
                       n_steps=5, max_restarts=2)


def test_elastic_restore_reshards(tmp_path, trainer_setup):
    """Checkpoints restore under a different mesh via device_put."""
    cfg, model, trainer = trainer_setup
    state, _ = trainer.init_state(jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(0, state, wait=True)
    shardings = jax.tree.map(
        lambda x: jax.sharding.SingleDeviceSharding(jax.devices()[0]), state)
    step, restored = mgr.restore(state, shardings=shardings)
    assert step == 0
    assert all(x.sharding == jax.sharding.SingleDeviceSharding(
        jax.devices()[0]) for x in jax.tree.leaves(restored))


def test_opt_axes_zero1():
    assert opt_axes(("vocab", None), (1024, 512), 16) == ("vocab",
                                                          "opt_fsdp")
    assert opt_axes((None, "d_ff"), (333, 512), 16) == (None, "d_ff")
    assert opt_axes((None, None), (64, 128), 16) == (None, "opt_fsdp")
