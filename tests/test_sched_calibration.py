"""Self-calibrating bubble-free scheduler (DESIGN.md §13): online
profiler fit/persistence, measured-rate substitution in the cost model,
(L_H, L_KV, L_RE) convergence under a skewed synthetic clock, contention
pricing monotonicity, fetch-aligned non-uniform restore groups
(byte-identity on both cache backends incl. restore-skip), and
plan-cache invalidation (the stale-plan regression)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.arch import reduced_for_smoke
from repro.config.hardware import PAPER_A100
from repro.configs import get_arch
from repro.core.capacity import restore_makespan
from repro.core.cost_model import MethodTimes, layer_costs, method_times
from repro.core.hcache import HCacheManager
from repro.core.profiler import MeasuredProfile
from repro.core.restoration import (CacheAssembler, compile_tasks,
                                    fetch_aligned_partition, group_widths,
                                    replay, s_bucket)
from repro.core.scheduler import solve
from repro.models import Model
from repro.models.module import split
from repro.serving import InferenceEngine, Request
from repro.serving.kv_cache import ContiguousBackend, PagedBackend, ViewSink
from repro.storage import ChunkStore, make_array

B, S = 1, 40


def build(arch, rules):
    cfg = reduced_for_smoke(get_arch(arch))
    model = Model(cfg, rules=rules, model_axis=1, dtype=jnp.float32,
                  remat="none")
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def manager(model, *, group_size=1, profile=None, device="dram",
            schedule_override="hidden"):
    store = ChunkStore(make_array(device, 4), chunk_tokens=16)
    return HCacheManager(model, store, hw=PAPER_A100,
                         schedule_override=schedule_override,
                         store_dtype=np.float32,
                         restore_group_size=group_size, profile=profile)


def save_session(cfg, model, params, mgr, sid="sess", n_tokens=S, key=1):
    toks = jax.random.randint(jax.random.PRNGKey(key), (B, n_tokens), 0,
                              cfg.vocab_size)
    pre = model.prefill(params, {"tokens": toks}, capture_hidden=True)
    mgr.save_prefill(sid, np.asarray(toks[0]), pre)
    return toks, pre


# ---------------------------------------------------------------- profiler
def test_profiler_fit_recovers_overhead_and_rate():
    """Two buckets on an exact line seconds = a + b·work recover the
    intercept (dispatch overhead) and slope (marginal rate)."""
    a, b = 1e-4, 9e-10
    p = MeasuredProfile()
    for work in (1e6, 2e6, 4e6):
        p.record("project", s_bucket(int(work)), work, a + b * work)
    assert p.rate("project") == pytest.approx(b, rel=1e-6)
    assert p.overhead("project") == pytest.approx(a, rel=1e-6)
    assert p.dispatch_overhead() == pytest.approx(a, rel=1e-6)
    assert p.predict("project", 3e6) == pytest.approx(a + b * 3e6, rel=1e-6)
    # unmeasured kinds stay unknown (static model keeps pricing them)
    assert p.rate("io_kv") is None
    assert p.dispatch_overhead() is not None and p.overhead("io_h") is None


def test_profiler_single_bucket_through_origin():
    """One bucket cannot separate overhead from rate: degrade to a
    through-origin rate instead of extrapolating a fake intercept."""
    p = MeasuredProfile()
    p.record("io_h", 64, 1e6, 2e-3)
    assert p.rate("io_h") == pytest.approx(2e-9)
    assert p.overhead("io_h") == 0.0


def test_profiler_roundtrip_and_epoch(tmp_path):
    """JSON persistence preserves the fit and the epoch; the epoch stops
    bumping once observations stop drifting (converged profile)."""
    p = MeasuredProfile()
    for i in range(3):
        p.record("io_h", 1024, 1e6, 1e-3)
        p.record("io_h", 2048, 2e6, 2e-3)
    early = p.epoch
    for i in range(10):
        p.record("io_h", 1024, 1e6, 1e-3)
        p.record("io_h", 2048, 2e6, 2e-3)
    assert p.epoch == early, "identical samples kept bumping the epoch"
    path = str(tmp_path / "hw.json")
    p.save(path)
    q = MeasuredProfile.load(path)
    assert q.epoch == p.epoch
    assert q.rate("io_h") == pytest.approx(p.rate("io_h"))
    assert q.sample_counts() == p.sample_counts()
    # a genuinely different machine drifts the reloaded profile
    for i in range(4):
        q.record("io_h", 1024, 1e6, 5e-3)
    assert q.epoch > p.epoch


# -------------------------------------------------- cost model substitution
def test_method_times_measured_rates_replace_datasheet():
    cfg = get_arch("llama2-13b")
    cost = layer_costs(cfg, 2048)[0]
    p = MeasuredProfile()
    r_io, r_proj = 3e-10, 2e-14
    p.record("io_h", 2048, 1e6, 1e6 * r_io)
    p.record("project", 2048, 1e9, 1e9 * r_proj)
    static = method_times(cost, PAPER_A100)
    cal = method_times(cost, PAPER_A100, profile=p)
    assert cal.io_h == pytest.approx(cost.io_hidden * r_io)
    assert cal.c_h == pytest.approx(cost.c_hidden * r_proj)
    # kinds without samples keep the static model
    assert cal.io_kv == static.io_kv
    assert cal.c_token == static.c_token


def test_method_times_contention_scales_io_only():
    """N-way restore multiplicity stretches the shared-link IO legs
    N-fold; per-chip compute legs are unaffected."""
    cfg = get_arch("llama2-13b")
    cost = layer_costs(cfg, 2048)[0]
    t1 = method_times(cost, PAPER_A100, io_streams=1)
    t4 = method_times(cost, PAPER_A100, io_streams=4)
    assert t4.io_h == pytest.approx(4 * t1.io_h)
    assert t4.io_kv == pytest.approx(4 * t1.io_kv)
    assert t4.c_h == t1.c_h and t4.c_token == t1.c_token


def test_solve_converges_under_skewed_clock():
    """Skewed synthetic clock: the machine's storage is 12.5x slower
    than the datasheet. Feeding two rounds of observations priced under
    the TRUE hardware makes solve() under the WRONG static profile
    produce the true machine's split — calibration converges within a
    few restores."""
    cfg = get_arch("llama2-13b")
    guess = PAPER_A100
    true_hw = PAPER_A100.derated(storage=0.08)
    n = 2048
    sched_static = solve(cfg, n, guess)
    sched_true = solve(cfg, n, true_hw)
    assert sched_static.counts != sched_true.counts, \
        "skew too small to matter — test would be vacuous"
    p = MeasuredProfile()
    for _ in range(2):                       # "a few restores"
        for bucket in (1024, 2048):
            c = layer_costs(cfg, bucket)[0]
            t = method_times(c, true_hw)
            p.record("io_h", bucket, c.io_hidden, t.io_h)
            p.record("io_kv", bucket, c.io_kv, t.io_kv)
            p.record("project", bucket, c.c_hidden, t.c_h)
            p.record("recompute", bucket, c.c_token, t.c_token)
    sched_cal = solve(cfg, n, guess, profile=p)
    assert sched_cal.counts == sched_true.counts
    assert sched_cal.makespan == pytest.approx(sched_true.makespan,
                                               rel=1e-3)


def test_solve_contention_shifts_split_from_io():
    """Under 4-way contention the IO legs stretch and the split moves
    layers off the IO methods (toward recompute), never onto them."""
    cfg = get_arch("llama2-13b")
    s1 = solve(cfg, 2048, PAPER_A100, io_streams=1)
    s4 = solve(cfg, 2048, PAPER_A100, io_streams=4)
    io1 = s1.counts["hidden"] + s1.counts["kv"]
    io4 = s4.counts["hidden"] + s4.counts["kv"]
    assert io4 <= io1
    assert s4.makespan > s1.makespan


# ------------------------------------------------------ contention pricing
def test_restore_makespan_monotonic_in_io_streams(rules):
    """Admission/eviction pricing: the same session costs strictly more
    to restore while other sessions share the host link."""
    cfg, model, params = build("llama2-7b", rules)
    mgr = manager(model)
    save_session(cfg, model, params, mgr)
    spans = []
    for m in (1, 2, 4):
        mgr.set_io_streams(m)
        spans.append(restore_makespan(mgr, S))
    assert spans[0] < spans[1] < spans[2]
    mgr.saver.close()


# ----------------------------------------------- plan-cache invalidation
def test_hw_swap_invalidates_plan_cache(rules):
    """The stale-plan regression: re-pointing ``mgr.hw`` at different
    hardware must flush the memoized schedule/group plans — before the
    fix the old argmin survived the swap and every later restore ran a
    plan priced for the wrong machine."""
    cfg, model, params = build("llama2-7b", rules)
    store = ChunkStore(make_array("dram", 4), chunk_tokens=16)
    mgr = HCacheManager(model, store, hw=PAPER_A100,
                        store_dtype=np.float32, restore_group_size="auto")
    plan_fast = mgr.plan(S)
    mgr.resolve_group_size(S, plan_fast.methods)
    assert mgr._plans and mgr._group_plans
    # a machine whose GEMMs are ~10^6x slower: recompute becomes the
    # worst method and the replan must flip the split to pure IO
    mgr.hw = PAPER_A100.derated(flops=1e-6)
    assert not mgr._plans and not mgr._group_plans, \
        "hw swap left stale plans memoized"
    plan_slow = mgr.plan(S)
    assert plan_slow.counts != plan_fast.counts
    assert plan_slow.counts["recompute"] == 0
    mgr.saver.close()


def test_profile_epoch_keys_plan_cache(rules):
    """An epoch bump (fit drift) re-plans without an explicit flush:
    the price state is part of the memo key."""
    cfg, model, params = build("llama2-7b", rules)
    p = MeasuredProfile()
    mgr = manager(model, group_size="auto", profile=p)
    methods = ("hidden",) * cfg.n_layers
    mgr.resolve_group_size(S, methods)
    n0 = len(mgr._group_plans)
    mgr.resolve_group_size(S, methods)
    assert len(mgr._group_plans) == n0          # memoized, no churn
    for i in range(3):                          # drift the io_h fit
        p.record("io_h", s_bucket(S), 1e6, 1e-3 * (i + 1))
    mgr.resolve_group_size(S, methods)
    assert len(mgr._group_plans) == n0 + 1, \
        "profile drift did not re-key the group plan"
    # multiplicity is also part of the key
    mgr.set_io_streams(4)
    mgr.resolve_group_size(S, methods)
    assert len(mgr._group_plans) == n0 + 2
    mgr.saver.close()


# -------------------------------------------- fetch-aligned partitioning
def test_group_widths_normalization():
    assert group_widths(4, 10) == (4, 4, 2)
    assert group_widths(1, 3) == (1, 1, 1)
    assert group_widths((2, 3), 10) == (2, 3, 3, 2)   # extend with last
    assert group_widths((8, 8), 10) == (8, 2)          # clamp + truncate
    assert group_widths(5, 0) == ()


def test_fetch_partition_covers_and_is_optimal():
    """The DP partition covers every hidden layer exactly once and its
    replayed makespan is never worse than ANY uniform width (uniform
    partitions are a subset of its search space)."""
    methods = ["recompute"] * 2 + ["hidden"] * 10
    times = [MethodTimes(io_h=1.0, io_kv=0.5, c_h=0.9, c_token=0.4)
             for _ in methods]
    ovh = 0.3
    part = fetch_aligned_partition(methods, times, dispatch_overhead=ovh)
    assert sum(part) == 10 and all(w >= 1 for w in part)

    def makespan(g):
        return replay(compile_tasks(tuple(methods), group_size=g),
                      times, dispatch_overhead=ovh).makespan

    best_uniform = min(makespan(g) for g in (1, 2, 4, 8, 10))
    assert makespan(part) <= best_uniform + 1e-12
    # with per-group overhead against a fetch ramp the optimum is
    # genuinely non-uniform: strictly beats every uniform width
    assert len(set(part)) > 1
    assert makespan(part) < best_uniform


def test_fetch_partition_compiles_to_matching_groups():
    methods = ["hidden"] * 7 + ["kv"]
    tasks = compile_tasks(tuple(methods), group_size=(1, 2, 4))
    projects = [t.members for t in tasks if t.kind == "project"]
    assert projects == [(0,), (1, 2), (3, 4, 5, 6)]


@pytest.mark.parametrize("start", [0, 16])
def test_nonuniform_groups_byte_identical_both_backends(start, rules):
    """Uniform and non-uniform group plans land byte-identical KV on the
    contiguous slot and the paged pool — including the restore-skip
    path, where only the suffix [start, S) ships."""
    cfg, model, params = build("llama2-7b", rules)
    views = {}
    for plan in (1, (1, 2, 1), "fetch"):
        mgr = manager(model, group_size=plan)
        save_session(cfg, model, params, mgr)
        for backend in (ContiguousBackend(model, 2, 64),
                        PagedBackend(model, 2, 64, block_size=8)):
            assert backend.reserve(1, S)
            view = backend.view(1)
            ex = mgr.begin_restore(params, "sess", sink=ViewSink(view),
                                   start_token=start)
            ex.run()
            k, v = view.gather_hist(S)
            views[(str(plan), backend.name)] = (np.asarray(k),
                                                np.asarray(v))
        mgr.saver.close()
    ref = views[("1", "contiguous")]
    for key, (k, v) in views.items():
        np.testing.assert_array_equal(k, ref[0], err_msg=str(key))
        np.testing.assert_array_equal(v, ref[1], err_msg=str(key))


def test_nonuniform_groups_zero_recompile_same_bucket(rules):
    """Non-uniform plans pad every group to the widest width: two
    same-bucket sessions under a tuple plan share one compiled
    projection (the DESIGN.md §10 guarantee survives §13)."""
    from repro.core.restoration import projection_trace_count
    cfg, model, params = build("llama2-7b", rules)
    mgr = manager(model, group_size=(1, 2, 1))
    save_session(cfg, model, params, mgr, sid="a", n_tokens=20, key=1)
    save_session(cfg, model, params, mgr, sid="b", n_tokens=28, key=2)
    ex = mgr.begin_restore(params, "a", sink=CacheAssembler(model))
    ex.run()
    before = projection_trace_count()
    ex = mgr.begin_restore(params, "b", sink=CacheAssembler(model))
    ex.run()
    assert projection_trace_count() == before, \
        "non-uniform groups reintroduced per-session recompiles"
    mgr.saver.close()


# ------------------------------------------------- executor / engine loop
def test_executor_records_profile_on_ssd_store(rules):
    """A real restore over the simulated-SSD store feeds the profiler:
    observed task durations, a measured timeline, and a predicted
    makespan to compare against."""
    cfg, model, params = build("llama2-7b", rules)
    p = MeasuredProfile()
    mgr = manager(model, profile=p, device="ssd")
    save_session(cfg, model, params, mgr)
    ex = mgr.begin_restore(params, "sess", sink=CacheAssembler(model))
    ex.run()
    assert ex.observed, "profiled executor recorded no task durations"
    assert p.samples("io_h") > 0
    assert ex.predicted_makespan > 0
    tl = ex.measured_timeline()
    assert tl.makespan > 0
    mgr.saver.close()


def test_engine_calibration_gauges(rules):
    """Round-2 restore through the engine populates the calibration
    gauges: observed bubble fraction, predicted-vs-measured makespan
    error, peak restore concurrency, and profiler sample counts."""
    cfg, model, params = build("llama2-7b", rules)
    p = MeasuredProfile()
    store = ChunkStore(make_array("ssd", 4), chunk_tokens=16)
    mgr = HCacheManager(model, store, hw=PAPER_A100,
                        schedule_override="hidden",
                        store_dtype=np.float32, profile=p)
    eng = InferenceEngine(model, params, mgr, max_batch=2, max_seq=128,
                          prefill_chunk=8)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, 18).astype(np.int32)
    eng.submit(Request("alice", prompt, max_new_tokens=4))
    eng.run()
    eng.submit(Request("alice",
                       rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
                       max_new_tokens=4))
    eng.run()
    m = eng.metrics
    assert m.restore_bubble_n >= 1
    assert 0.0 <= m.restore_bubble_mean <= 1.0
    assert m.makespan_err_mean >= 0.0
    assert m.io_streams_peak >= 1
    assert m.profiler_samples and sum(m.profiler_samples.values()) > 0
    assert p.samples() > 0
    eng.close()
