"""Enc-dec (whisper) through the continuous-batching engine via the
FamilyAdapter seam + paired self/cross EncDecBackend (DESIGN.md §11):
byte-identical greedy outputs vs the direct Model.prefill/decode_step
path, including save→evict→restore rounds and pause→resume over
constrained slots; per-slot enc_len batching; cross restoration task
modeling; the adapter seam's no-branching acceptance criterion; and the
hybrid unchunked-prefill regression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.arch import reduced_for_smoke
from repro.config.hardware import PAPER_A100
from repro.configs import get_arch
from repro.core.capacity import restore_makespan, session_restore_cost
from repro.core.hcache import HCacheManager
from repro.models import Model
from repro.models.module import split
from repro.serving import (EncDecBackend, InferenceEngine, Request,
                           make_backend)
from repro.storage import ChunkStore, make_array


@pytest.fixture(scope="module")
def setup():
    from repro.distributed.sharding import default_rules
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = reduced_for_smoke(get_arch("whisper-medium"))
    model = Model(cfg, rules=default_rules(mesh), model_axis=1,
                  dtype=jnp.float32, remat="none")
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def fresh_engine(setup, **kw):
    cfg, model, params = setup
    store = ChunkStore(make_array("dram", 4), chunk_tokens=16)
    # fp32 storage → pause/restore cycles are lossless and greedy
    # equivalence is bit-exact (same convention as test_capacity)
    mgr = HCacheManager(model, store, hw=PAPER_A100,
                        schedule_override="hidden",
                        store_dtype=np.float32)
    defaults = dict(max_batch=2, max_seq=96, prefill_chunk=8)
    defaults.update(kw)
    return InferenceEngine(model, params, mgr, **defaults), mgr


def _frames(cfg, n, seed):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal((n, cfg.d_model)) * 0.1).astype(np.float32)


def _prompts(cfg, n, seed=7):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, int(k)).astype(np.int32)
            for k in rng.integers(6, 20, size=n)]


def direct_greedy(model, params, frames, prompt, n_new, ctx=96):
    """Ground truth: Model.prefill + decode_step, greedy (the path
    test_models::test_decode_matches_forward validates)."""
    batch = {"tokens": jnp.asarray(prompt)[None],
             "frames": jnp.asarray(frames)[None]}
    pre = model.prefill(params, batch)
    S = len(prompt)

    def padkv(x):
        return jnp.pad(x, ((0, 0), (0, 0), (0, ctx - x.shape[2]),
                           (0, 0), (0, 0)))

    ck, cv = pre["cross_kv"]
    cache = {"self_k": padkv(pre["kv"][0]), "self_v": padkv(pre["kv"][1]),
             "cross_k": ck, "cross_v": cv,
             "enc_len": jnp.asarray(ck.shape[2], jnp.int32),
             "lengths": jnp.asarray([S], jnp.int32)}
    out = [int(jnp.argmax(pre["logits"][0, -1]))]
    for _ in range(n_new - 1):
        tok = jnp.asarray([[out[-1]]], jnp.int32)
        lg, cache = model.decode_step(params, cache, tok)
        out.append(int(jnp.argmax(lg[0, -1])))
    return out


# --------------------------------------------------------- basic serving
def test_engine_matches_direct_greedy_mixed_enc_lens(setup):
    """Two whisper sessions with different encoder AND decoder lengths
    batch together; each matches the direct path byte-for-byte (the
    per-slot enc_len the seed's scalar cache could not express)."""
    cfg, model, params = setup
    jobs = [(np.arange(7, dtype=np.int32) % cfg.vocab_size,
             _frames(cfg, 16, seed=3)),
            (np.arange(11, dtype=np.int32)[::-1] % cfg.vocab_size,
             _frames(cfg, 24, seed=4))]
    eng, _ = fresh_engine(setup)
    assert isinstance(eng.kv, EncDecBackend)
    for i, (p, f) in enumerate(jobs):
        eng.submit(Request(f"w{i}", p, max_new_tokens=6, frames=f))
    eng.run()
    for i, (p, f) in enumerate(jobs):
        want = direct_greedy(model, params, f, p, 6)
        assert eng.result(f"w{i}") == want, f"w{i}"
    assert [int(x) for x in eng.kv.enc_len_np] == [0, 0]  # freed on retire
    eng.close()


def test_first_residency_requires_frames(setup):
    eng, _ = fresh_engine(setup)
    eng.submit(Request("nof", np.arange(5, dtype=np.int32),
                       max_new_tokens=2))
    with pytest.raises(ValueError, match="frames"):
        eng.run()
    eng.close()


# ------------------------------------------------- save → evict → restore
def test_round2_after_retire_restores_and_matches_direct(setup):
    """Round 2 on a retired whisper session: self-KV restores through
    the grouped hidden→KV projection, the cross context through the
    encoder blob, and generation matches a never-evicted direct run."""
    cfg, model, params = setup
    p1 = np.arange(9, dtype=np.int32) % cfg.vocab_size
    frames = _frames(cfg, 20, seed=5)
    eng, mgr = fresh_engine(setup)
    eng.submit(Request("alice", p1, max_new_tokens=5, frames=frames))
    eng.run()
    g1 = eng.result("alice")
    man = mgr.store.get_manifest("alice")
    assert int(man["enc_len"]) == 20

    p2 = (np.arange(6, dtype=np.int32) + 3) % cfg.vocab_size
    eng.submit(Request("alice", p2, max_new_tokens=4))   # no frames: restore
    eng.run()
    g2 = eng.result("alice")
    assert eng.metrics.restored_tokens > 0

    # ground truth: one decoder prefill over the whole history (the last
    # round-1 token's KV was never computed — see test_serving's
    # multi-round convention), greedy from there
    full = np.concatenate([p1, np.asarray(g1[:-1], np.int32), p2])
    want = direct_greedy(model, params, frames, full, 4)
    assert g2 == want
    eng.close()


# ------------------------------------------------------- pause → resume
@pytest.mark.parametrize("quantum", [3])
def test_preemption_equivalence_8_sessions_2_slots(setup, quantum):
    """The capacity acceptance workload on whisper: 8 interleaved
    enc-dec sessions over 2 slots, mid-stream eviction + pipelined
    restoration, byte-for-byte equal to the unconstrained 8-slot run."""
    cfg, model, params = setup
    prompts = _prompts(cfg, 8)
    frames = [_frames(cfg, 12 + 2 * i, seed=20 + i) for i in range(8)]

    ref, _ = fresh_engine(setup, max_batch=8)
    for i, p in enumerate(prompts):
        ref.submit(Request(f"s{i}", p, max_new_tokens=5, frames=frames[i]))
    ref.run()
    want = {f"s{i}": ref.result(f"s{i}") for i in range(8)}
    ref.close()

    eng, _ = fresh_engine(setup, max_batch=2, preempt_quantum=quantum)
    for i, p in enumerate(prompts):
        eng.submit(Request(f"s{i}", p, max_new_tokens=5, frames=frames[i]))
    eng.run()
    got = {f"s{i}": eng.result(f"s{i}") for i in range(8)}
    assert eng.metrics.preemptions > 0
    assert all(s.phase.value == "done" for s in eng.sessions.values())
    assert got == want
    eng.close()


# ------------------------------------------------ restoration cost model
def test_cross_restore_tasks_modeled(setup):
    """The executor's graph carries the io_enc/project_cross pair; the
    replayed makespan charges the encoder blob read and the 1→2L cross
    projection (no longer a zero-cost blob), and the admission policy's
    session_restore_cost sees it through the manifest's enc_len."""
    cfg, model, params = setup
    eng, mgr = fresh_engine(setup)
    p = np.arange(8, dtype=np.int32)
    eng.submit(Request("c", p, max_new_tokens=3, frames=_frames(cfg, 24, 1)))
    eng.run()
    eng.close()
    ex = mgr.begin_restore(params, "c")
    kinds = [t.kind for t in ex.tasks]
    assert kinds.count("io_enc") == 1 and kinds.count("project_cross") == 1
    assert ex.cross_times is not None and ex.cross_times.compute > 0
    n = ex.n_tokens
    with_cross = restore_makespan(mgr, n, ex.methods, enc_len=24)
    without = restore_makespan(mgr, n, ex.methods, enc_len=0)
    assert with_cross > without
    assert session_restore_cost(mgr, "c") == pytest.approx(with_cross)


def test_engine_restore_timeline_includes_cross(setup):
    """Serving-path restore of an enc-dec session reports a makespan ≥
    the cross-only lower bound (the engine's restore_sim and the
    analytic replay share one task graph)."""
    cfg, model, params = setup
    eng, mgr = fresh_engine(setup)
    p = np.arange(10, dtype=np.int32)
    eng.submit(Request("t", p, max_new_tokens=3, frames=_frames(cfg, 16, 9)))
    eng.run()
    eng.submit(Request("t", np.arange(4, dtype=np.int32), max_new_tokens=2))
    eng.run()
    seq = eng.sessions["t"]
    assert seq.restored
    from repro.core.restoration import cross_restore_times
    ct = cross_restore_times(mgr, 16)
    assert seq.restore_sim >= ct.compute
    eng.close()


# --------------------------------------------------------- adapter seam
def test_engine_has_no_family_branches():
    """Acceptance criterion: all family dispatch goes through the
    FamilyAdapter — the engine contains no ``model.kind`` branching."""
    import inspect
    import repro.serving.engine as engine_mod
    src = inspect.getsource(engine_mod)
    assert "model.kind" not in src
    assert 'kind ==' not in src


@pytest.mark.parametrize("arch,expect", [
    ("llama2-7b", ("chunkable", "supports_resume", "supports_paged",
                   "supports_recompute")),
    ("falcon-mamba-7b", ()),
    ("zamba2-2.7b", ()),
    ("whisper-medium", ("chunkable", "supports_resume", "supports_paged")),
])
def test_adapter_capability_matrix(arch, expect, rules):
    cfg = reduced_for_smoke(get_arch(arch))
    model = Model(cfg, rules=rules, model_axis=1, dtype=jnp.float32,
                  remat="none")
    flags = ("chunkable", "supports_resume", "supports_paged",
             "supports_recompute")
    got = tuple(f for f in flags if getattr(model.adapter, f))
    assert got == expect


# ------------------------------------------- hybrid unchunked regression
def test_encdec_chunked_prefill_matches_whole_prompt(setup):
    """Chunked decoder-prompt prefill (DESIGN.md §13 satellite): the
    encoder runs once on the FIRST chunk, later chunks attend to the
    already-resident cross context with the right position offset —
    greedy output is byte-identical to a single-chunk prefill and to
    the direct path."""
    cfg, model, params = setup
    prompt = (np.arange(19, dtype=np.int32) * 3) % cfg.vocab_size
    frames = _frames(cfg, 16, seed=11)
    outs = {}
    for chunk in (4, 64):                 # 19 tokens: 5 chunks vs 1
        eng, _ = fresh_engine(setup, prefill_chunk=chunk)
        eng.submit(Request("c", prompt, max_new_tokens=6, frames=frames))
        eng.run()
        outs[chunk] = eng.result("c")
        eng.close()
    assert outs[4] == outs[64]
    assert outs[4] == direct_greedy(model, params, frames, prompt, 6)


def test_hybrid_prefill_ignores_chunk_knob(rules):
    """Hybrid prefill must stay unchunked (recurrent conv/ssm states are
    computed in one scan with no carry-in): with prefill_chunk smaller
    than the prompt the engine still takes the whole prompt in one step
    and matches the direct path byte-for-byte."""
    cfg = reduced_for_smoke(get_arch("zamba2-2.7b"))
    model = Model(cfg, rules=rules, model_axis=1, dtype=jnp.float32,
                  remat="none")
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    store = ChunkStore(make_array("dram", 4), chunk_tokens=16)
    mgr = HCacheManager(model, store, hw=PAPER_A100,
                        schedule_override="hidden", store_dtype=np.float32)
    eng = InferenceEngine(model, params, mgr, max_batch=1, max_seq=64,
                          prefill_chunk=4)
    prompt = (np.arange(17, dtype=np.int32) * 5) % cfg.vocab_size
    eng.submit(Request("h", prompt, max_new_tokens=5))
    eng.run()
    got = eng.result("h")
    # one engine step consumed the whole 17-token prompt
    assert eng.sessions["h"].prefill_done == len(prompt)

    pre = model.prefill(params, {"tokens": jnp.asarray(prompt)[None]})
    conv, ssm = pre["mamba_states"]

    def padkv(x):
        return jnp.pad(x, ((0, 0), (0, 0), (0, 64 - x.shape[2]),
                           (0, 0), (0, 0)))

    cache = {"attn_k": padkv(pre["kv"][0]), "attn_v": padkv(pre["kv"][1]),
             "conv": conv, "ssm": ssm,
             "lengths": jnp.asarray([len(prompt)], jnp.int32)}
    want = [int(jnp.argmax(pre["logits"][0, -1]))]
    for _ in range(4):
        tok = jnp.asarray([[want[-1]]], jnp.int32)
        lg, cache = model.decode_step(params, cache, tok)
        want.append(int(jnp.argmax(lg[0, -1])))
    assert got == want
    eng.close()


def test_eviction_prices_cross_side(setup):
    """RestoreCostAwareEviction must see the enc-dec cross restoration
    cost (from the manifest's enc_len), exactly like admission does: of
    two sessions with equal decoder history, the one with the SMALL
    encoder context is the cheaper victim — without the enc_len plumb
    the makespans tie and the request_id tie-break would pick 'big'."""
    from types import SimpleNamespace

    from repro.core.capacity import RestoreCostAwareEviction

    cfg, model, params = setup
    eng, mgr = fresh_engine(setup)
    prompt = np.arange(6, dtype=np.int32)
    for sid, n_enc, seed in (("big", 48, 1), ("small", 8, 2)):
        eng.submit(Request(sid, prompt, max_new_tokens=3,
                           frames=_frames(cfg, n_enc, seed)))
    eng.run()
    seqs = [SimpleNamespace(total_len=9,
                            request=SimpleNamespace(session_id="big",
                                                    request_id=0)),
            SimpleNamespace(total_len=9,
                            request=SimpleNamespace(session_id="small",
                                                    request_id=1))]
    victim = RestoreCostAwareEviction().select_victim(seqs, eng)
    assert victim.request.session_id == "small"
    eng.close()


def test_enc_seq_capacity_overflow_fails_loudly(setup):
    """An encoder context larger than the backend's enc_seq must raise
    an actionable error naming the knob, not an opaque shape error."""
    cfg, model, params = setup
    eng, _ = fresh_engine(setup, enc_seq=8)
    eng.submit(Request("o", np.arange(4, dtype=np.int32), max_new_tokens=2,
                       frames=_frames(cfg, 16, seed=1)))
    with pytest.raises(ValueError, match="enc_seq"):
        eng.run()
    eng.close()
