"""Per-arch smoke tests: reduced same-family config, one forward/train step
on CPU, asserting output shapes + no NaNs — required for every assigned
architecture."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.arch import reduced_for_smoke
from repro.configs import ASSIGNED, get_arch
from repro.models import Model
from repro.models.module import split
from repro.training import AdamWConfig, Trainer

B, S = 2, 16


def _batch(cfg, model, key=1):
    toks = jax.random.randint(jax.random.PRNGKey(key), (B, S), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "targets": toks}
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, S, cfg.d_model)) * 0.1
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, 4, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_smoke_forward(arch, rules):
    cfg = reduced_for_smoke(get_arch(arch))
    model = Model(cfg, rules=rules, model_axis=1, dtype=jnp.float32,
                  remat="none")
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    out = model.forward(params, _batch(cfg, model))
    lg = out["logits"]
    assert lg.shape == (B, S, lg.shape[-1])
    assert lg.shape[-1] >= cfg.vocab_size          # padded vocab
    assert not bool(jnp.isnan(lg).any()), f"{arch}: NaNs in logits"


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_smoke_train_step(arch, rules):
    cfg = reduced_for_smoke(get_arch(arch))
    model = Model(cfg, rules=rules, model_axis=1, dtype=jnp.float32,
                  remat="full")
    trainer = Trainer(model, rules, AdamWConfig(lr=1e-3), loss_chunks=2)
    state, _ = trainer.init_state(jax.random.PRNGKey(0))
    state, metrics = jax.jit(trainer.train_step)(state,
                                                 _batch(cfg, model))
    assert np.isfinite(float(metrics["loss"])), f"{arch}: loss not finite"
    assert float(metrics["grad_norm"]) > 0


@pytest.mark.parametrize("arch", ["qwen2-7b", "gemma2-9b", "zamba2-2.7b",
                                  "falcon-mamba-7b", "whisper-medium"])
def test_decode_matches_forward(arch, rules):
    """Prefill + single decode step == full forward at the same position."""
    cfg = reduced_for_smoke(get_arch(arch))
    model = Model(cfg, rules=rules, model_axis=1, dtype=jnp.float32,
                  remat="none")
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, 24, cfg.d_model)) * 0.1
    full = model.forward(params, dict(batch, tokens=toks))["logits"]
    pre = model.prefill(params, dict(batch, tokens=toks[:, :S]))
    cache = _cache_from_prefill(model, cfg, pre, ctx=32)
    lg, _ = model.decode_step(params, cache, toks[:, S:S + 1])
    err = float(jnp.abs(lg[:, 0] - full[:, S]).max())
    assert err < 5e-4, f"{arch}: decode mismatch {err}"


def _cache_from_prefill(model, cfg, pre, ctx):
    def padkv(x):
        return jnp.pad(x, ((0, 0), (0, 0), (0, ctx - x.shape[2]),
                           (0, 0), (0, 0)))

    lengths = jnp.full((B,), S, jnp.int32)
    if model.kind == "lm":
        return {"k": padkv(pre["kv"][0]), "v": padkv(pre["kv"][1]),
                "lengths": lengths}
    if model.kind == "ssm":
        conv, ssm = pre["states"]
        return {"conv": conv, "ssm": ssm, "lengths": lengths}
    if model.kind == "hybrid":
        conv, ssm = pre["mamba_states"]
        return {"attn_k": padkv(pre["kv"][0]), "attn_v": padkv(pre["kv"][1]),
                "conv": conv, "ssm": ssm, "lengths": lengths}
    ck, cv = pre["cross_kv"]
    return {"self_k": padkv(pre["kv"][0]), "self_v": padkv(pre["kv"][1]),
            "cross_k": ck, "cross_v": cv,
            "enc_len": jnp.asarray(ck.shape[2], jnp.int32),
            "lengths": lengths}


def test_vocab_padding_masked(rules):
    """Padded vocab columns never win argmax."""
    cfg = reduced_for_smoke(get_arch("granite-moe-1b-a400m"))
    cfg = cfg.scaled(vocab_size=130)               # pads to 256
    model = Model(cfg, rules=rules, model_axis=1, dtype=jnp.float32,
                  remat="none")
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, 130)
    lg = model.forward(params, {"tokens": toks})["logits"]
    assert int(jnp.argmax(lg, -1).max()) < 130
