"""Batched restoration data path (DESIGN.md §10): grouped projection
byte-equivalence across group sizes / families / codecs / sink backends,
S-bucketed zero-recompile sharing, grouped task-graph compilation and
replay, dispatch-count reduction, and the layer-stacked decode snapshot."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.arch import reduced_for_smoke
from repro.config.hardware import PAPER_A100
from repro.configs import get_arch
from repro.core.cost_model import layer_costs, method_times
from repro.core.hcache import HCacheManager
from repro.core.restoration import (CacheAssembler, compile_tasks,
                                    project_hidden, projection_trace_count,
                                    replay, s_bucket, subset_blocks)
from repro.models import Model
from repro.models.module import split
from repro.serving.kv_cache import ContiguousBackend, PagedBackend, ViewSink
from repro.storage import ChunkStore, make_array

B, S = 1, 40

KV_KEYS = {"lm": ("k", "v"), "hybrid": ("attn_k", "attn_v"),
           "encdec": ("self_k", "self_v")}


def build(arch, rules, *, compress="none", n_layers=None):
    cfg = reduced_for_smoke(get_arch(arch))
    if n_layers is not None:
        cfg = dataclasses.replace(cfg, n_layers=n_layers)
    model = Model(cfg, rules=rules, model_axis=1, dtype=jnp.float32,
                  remat="none")
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def manager(model, *, group_size, compress="none", store_dtype=np.float32):
    store = ChunkStore(make_array("dram", 4), chunk_tokens=16)
    return HCacheManager(model, store, hw=PAPER_A100,
                         schedule_override="hidden", compress=compress,
                         store_dtype=store_dtype,
                         restore_group_size=group_size)


def save_session(cfg, model, params, mgr, sid="sess", n_tokens=S, key=1):
    toks = jax.random.randint(jax.random.PRNGKey(key), (B, n_tokens), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(jax.random.PRNGKey(2),
                                            (B, 24, cfg.d_model)) * 0.1
    pre = model.prefill(params, batch, capture_hidden=True)
    mgr.save_prefill(sid, np.asarray(toks[0]), pre)
    return toks, pre


# ------------------------------------------------------------ task graph
def test_compile_tasks_groups_projections():
    """group_size coalesces hidden-layer projections into group tasks
    whose deps cover every member's fetch; group_size=1 degenerates to
    the per-layer graph."""
    methods = ["hidden", "kv", "hidden", "hidden", "recompute", "hidden"]
    tasks = compile_tasks(methods, group_size=3)
    projects = [t for t in tasks if t.kind == "project"]
    assert [t.members for t in projects] == [(0, 2, 3), (5,)]
    for t in projects:
        for li, d in zip(t.members, t.all_deps):
            assert tasks[d].kind == "io_h" and tasks[d].layer == li
    per_layer = compile_tasks(methods, group_size=1)
    assert [t.members for t in per_layer if t.kind == "project"] == \
        [(0,), (2,), (3,), (5,)]


def test_replay_group_amortizes_dispatch_overhead():
    """With per-dispatch overhead, grouped graphs finish strictly sooner
    (fewer compute dispatches); with zero overhead the busy time is
    identical — grouping is pure re-batching, not a cost-model change."""
    cfg = get_arch("llama2-13b")
    methods = ["hidden"] * cfg.n_layers
    times = [method_times(c, PAPER_A100) for c in layer_costs(cfg, 2048)]
    base1 = replay(compile_tasks(methods, group_size=1), times)
    base8 = replay(compile_tasks(methods, group_size=8), times)
    assert base1.compute_busy == pytest.approx(base8.compute_busy)
    ovh = 50e-6
    t1 = replay(compile_tasks(methods, group_size=1), times,
                dispatch_overhead=ovh)
    t8 = replay(compile_tasks(methods, group_size=8), times,
                dispatch_overhead=ovh)
    assert t1.compute_busy - t8.compute_busy == pytest.approx(
        ovh * (cfg.n_layers - -(-cfg.n_layers // 8)))
    # the trade-off the knob exposes: grouping always saves busy time
    # (amortized dispatches) but waits for all member fetches (bubble);
    # at a large enough dispatch cost the grouped graph wins makespan
    big = 2e-3
    t1b = replay(compile_tasks(methods, group_size=1), times,
                 dispatch_overhead=big)
    t8b = replay(compile_tasks(methods, group_size=8), times,
                 dispatch_overhead=big)
    assert t8b.makespan < t1b.makespan
    assert t8.compute_bubble > t1.compute_bubble


def test_s_bucket_power_of_two():
    assert s_bucket(1) == 16
    assert s_bucket(16) == 16
    assert s_bucket(17) == 32
    assert s_bucket(40) == 64
    assert s_bucket(129) == 256


# ------------------------------------------------- grouped byte-equivalence
@pytest.mark.parametrize("arch", ["llama2-7b", "qwen2-7b", "zamba2-2.7b",
                                  "whisper-medium"])
def test_grouped_matches_per_layer_bytes(arch, rules):
    """Restored caches are byte-identical across group_size ∈ {1, 4, L}
    for lm (with and without qkv bias), hybrid, and encdec families."""
    cfg, model, params = build(arch, rules)
    kk, vk = KV_KEYS[model.kind]
    caches = {}
    for gs in (1, 4, cfg.n_layers):
        mgr = manager(model, group_size=gs)
        save_session(cfg, model, params, mgr)
        caches[gs] = mgr.restore(params, "sess").cache
        mgr.saver.close()
    for gs in (4, cfg.n_layers):
        np.testing.assert_array_equal(np.asarray(caches[1][kk]),
                                      np.asarray(caches[gs][kk]))
        np.testing.assert_array_equal(np.asarray(caches[1][vk]),
                                      np.asarray(caches[gs][vk]))


def test_grouped_matches_per_layer_bytes_int8(rules):
    """Same contract through the int8 hidden codec (dequantize → group)."""
    cfg, model, params = build("llama2-7b", rules)
    caches = {}
    for gs in (1, 4):
        mgr = manager(model, group_size=gs, compress="int8")
        save_session(cfg, model, params, mgr)
        caches[gs] = mgr.restore(params, "sess").cache
        mgr.saver.close()
    np.testing.assert_array_equal(np.asarray(caches[1]["k"]),
                                  np.asarray(caches[4]["k"]))
    np.testing.assert_array_equal(np.asarray(caches[1]["v"]),
                                  np.asarray(caches[4]["v"]))


def test_grouped_matches_legacy_projection(rules):
    """The grouped device path reproduces the legacy per-layer reference
    (subset_blocks + project_hidden) to float tolerance, and the restored
    cache is exact vs the prefill KV at fp32 storage."""
    cfg, model, params = build("llama2-7b", rules)
    mgr = manager(model, group_size=4)
    toks, pre = save_session(cfg, model, params, mgr)
    res = mgr.restore(params, "sess")
    np.testing.assert_array_equal(np.asarray(res.cache["k"]),
                                  np.asarray(pre["kv"][0]))
    hidden = jnp.stack([jnp.asarray(pre["hidden"][li])
                        for li in range(cfg.n_layers)])
    pos = jnp.arange(S)[None, :]
    sub = subset_blocks(model, params, list(range(cfg.n_layers)))
    k_ref, v_ref = project_hidden(model, sub, hidden, pos)
    np.testing.assert_allclose(np.asarray(res.cache["k"]),
                               np.asarray(k_ref), atol=1e-5)
    mgr.saver.close()


@pytest.mark.parametrize("group_size", [1, 4])
def test_grouped_view_sinks_match_assembler(group_size, rules):
    """ViewSink grouped writes land identically on both backends: the
    contiguous slot and the paged pool hold the same restored KV as the
    standalone CacheAssembler."""
    cfg, model, params = build("llama2-7b", rules)
    mgr = manager(model, group_size=group_size)
    save_session(cfg, model, params, mgr)
    want = mgr.restore(params, "sess").cache

    for backend in (ContiguousBackend(model, 2, 64),
                    PagedBackend(model, 2, 64, block_size=8)):
        slot = 1
        assert backend.reserve(slot, S)
        view = backend.view(slot)
        ex = mgr.begin_restore(params, "sess", sink=ViewSink(view))
        ex.run()
        k, v = view.gather_hist(S)           # (L, 1, S, Kv, hd)
        np.testing.assert_array_equal(
            np.asarray(k), np.asarray(want["k"]), err_msg=backend.name)
        np.testing.assert_array_equal(
            np.asarray(v), np.asarray(want["v"]), err_msg=backend.name)
        assert int(backend.get_lengths()[slot]) == S
    mgr.saver.close()


# --------------------------------------------------- recompiles / dispatches
def test_same_bucket_sessions_share_one_projection_compile(rules):
    """Two sessions with different lengths in the same power-of-two
    bucket reuse one compiled projection — zero recompiles."""
    cfg, model, params = build("llama2-7b", rules)
    mgr = manager(model, group_size=4)
    save_session(cfg, model, params, mgr, sid="a", n_tokens=20, key=1)
    save_session(cfg, model, params, mgr, sid="b", n_tokens=28, key=2)
    assert s_bucket(20) == s_bucket(28)
    mgr.restore(params, "a")                 # may trace (fresh bucket)
    before = projection_trace_count()
    res_b = mgr.restore(params, "b")
    assert projection_trace_count() == before, \
        "same-bucket session recompiled the projection"
    assert res_b.n_tokens == 28
    mgr.saver.close()


def test_group_dispatch_count_reduction(rules):
    """8 hidden layers at group_size=8 issue ≥8x fewer device dispatches
    than per-layer execution (the acceptance criterion's metric)."""
    cfg, model, params = build("llama2-7b", rules, n_layers=8)
    counts = {}
    for gs in (1, 8):
        mgr = manager(model, group_size=gs)
        save_session(cfg, model, params, mgr)
        ex = mgr.begin_restore(params, "sess",
                               sink=CacheAssembler(model))
        ex.run()
        counts[gs] = ex.dispatch_count
        mgr.saver.close()
    assert counts[1] >= 8 * counts[8]


def test_executor_timeline_uses_group_granularity(rules):
    """The executor's reported timeline equals the group-aware replay of
    its compiled graph — simulate and execution cannot drift."""
    cfg, model, params = build("llama2-7b", rules)
    mgr = manager(model, group_size=4)
    save_session(cfg, model, params, mgr)
    ex = mgr.begin_restore(params, "sess", sink=CacheAssembler(model))
    ex.run()
    want = replay(ex.tasks, ex.times)
    assert ex.timeline() == want
    assert sum(1 for t in ex.tasks if t.kind == "project") == \
        -(-cfg.n_layers // 4)
    mgr.saver.close()


# ------------------------------------------------- stacked decode snapshot
def test_save_decode_hidden_stacked_snapshot(rules):
    """One decode step issues ONE layer-stacked snapshot for the plain
    rows (not L), lands byte-identical rows in the store, and charges
    exactly the same stage-1 cost as the per-layer form."""
    from repro.storage.two_stage import SnapshotTask

    cfg, model, params = build("llama2-7b", rules)
    mgr = manager(model, group_size=4)
    submitted = []
    orig = mgr.saver.snapshot

    def spy(task: SnapshotTask):
        submitted.append(task)
        return orig(task)

    mgr.saver.snapshot = spy
    L, Bt, D = cfg.n_layers, 2, cfg.d_model
    rng = np.random.default_rng(3)
    h = rng.normal(size=(L, Bt, 1, D)).astype(np.float32)
    lengths = np.asarray([5, 9])
    cost = mgr.save_decode_hidden(["sa", "sb"], h, lengths)
    mgr.saver.drain()
    assert len(submitted) == 1                 # one task, not L
    assert list(submitted[0].layers) == list(range(L))
    expected_cost = h.astype(mgr.store_dtype).nbytes / mgr.saver.host_bw
    assert cost == pytest.approx(expected_cost)
    # rows landed per (layer, session) at the right offsets
    mgr.store.flush("sa")
    mgr.store.flush("sb")
    for li in range(L):
        for b, sid in enumerate(("sa", "sb")):
            assert mgr.store.layer_available(sid, "h", li,
                                             int(lengths[b]) + 1)
    mgr.saver.close()


def test_save_decode_hidden_stacked_int8_rows(rules):
    """Demoted (int8) rows also collapse to one stacked q + one stacked
    scale snapshot per row, and the stored bytes match the bulk codec."""
    cfg, model, params = build("llama2-7b", rules)
    mgr = manager(model, group_size=4)
    mgr._session_compress["sq"] = "int8"
    L, D = cfg.n_layers, cfg.d_model
    rng = np.random.default_rng(4)
    h = rng.normal(size=(L, 1, 1, D)).astype(np.float32)
    n_before = 7
    cost = mgr.save_decode_hidden(["sq"], h, np.asarray([n_before]))
    mgr.saver.drain()
    mgr.store.flush("sq")
    assert cost > 0
    from repro.core.restoration import quantize_hidden_int8
    for li in range(L):
        q_want, s_want = quantize_hidden_int8(h[li][0].astype(np.float32))
        got_q = np.asarray(mgr.store.read_layer("sq", "h", li, n_before + 1))
        got_s = np.asarray(mgr.store.read_layer("sq", "hs", li,
                                                n_before + 1))
        np.testing.assert_array_equal(got_q[n_before:], q_want)
        np.testing.assert_array_equal(got_s[n_before:], s_want)
    mgr.saver.close()


# ----------------------------------------------------- auto group size
def test_choose_group_size_argmin_of_replay():
    """'auto' picks the restore_makespan argmin over {1, 2, 4, 8, L}
    from the same group-aware replay the executor reports — under heavy
    dispatch overhead the widest group wins; at zero overhead grouping
    only adds fetch-wait bubble, so the per-layer graph wins."""
    from repro.core.restoration import choose_group_size
    cfg = get_arch("llama2-13b")
    methods = ["hidden"] * cfg.n_layers
    n = 2048

    def span(hw, g):
        times = [method_times(c, hw) for c in layer_costs(cfg, n)]
        ovh = getattr(hw, "dispatch_overhead", 0.0)
        return replay(compile_tasks(methods, group_size=g), times,
                      dispatch_overhead=ovh).makespan

    cands = (1, 2, 4, 8, cfg.n_layers)
    heavy = dataclasses.replace(PAPER_A100, dispatch_overhead=2e-3)
    got = choose_group_size(cfg, heavy, n, methods)
    assert got == min(cands, key=lambda g: (span(heavy, g), -g))
    assert got > 1
    free = dataclasses.replace(PAPER_A100, dispatch_overhead=0.0)
    got0 = choose_group_size(cfg, free, n, methods)
    assert got0 == min(cands, key=lambda g: (span(free, g), -g))
    assert got0 == 1


def test_auto_group_size_end_to_end(rules):
    """HCacheManager(restore_group_size='auto'): the executor resolves a
    concrete width per restore, the restored cache is byte-identical to
    a fixed-width restore, and capacity's restore_makespan handles the
    'auto' manager without error."""
    from repro.core.capacity import restore_makespan
    cfg, model, params = build("llama2-7b", rules)
    mgr_auto = manager(model, group_size="auto")
    save_session(cfg, model, params, mgr_auto)
    ex = mgr_auto.begin_restore(params, "sess")
    assert isinstance(ex.group_size, int) and ex.group_size >= 1
    assert mgr_auto._group_plans          # resolution memoized per bucket
    res_auto = mgr_auto.restore(params, "sess")

    mgr_fix = manager(model, group_size=4)
    save_session(cfg, model, params, mgr_fix)
    res_fix = mgr_fix.restore(params, "sess")
    np.testing.assert_array_equal(np.asarray(res_auto.cache["k"]),
                                  np.asarray(res_fix.cache["k"]))
    np.testing.assert_array_equal(np.asarray(res_auto.cache["v"]),
                                  np.asarray(res_fix.cache["v"]))
    assert restore_makespan(mgr_auto, S) > 0
    mgr_auto.saver.close()
    mgr_fix.saver.close()


def test_auto_group_size_stable_within_bucket(rules):
    """'auto' must resolve from the S-bucket, not the exact length:
    same-bucket sessions pick the same width and share one compiled
    projection (the zero-recompile guarantee of DESIGN.md §10 holds
    under the auto knob too)."""
    cfg, model, params = build("llama2-7b", rules)
    mgr = manager(model, group_size="auto")
    save_session(cfg, model, params, mgr, sid="a", n_tokens=20, key=1)
    save_session(cfg, model, params, mgr, sid="b", n_tokens=28, key=2)
    assert s_bucket(20) == s_bucket(28)
    exa = mgr.begin_restore(params, "a")
    exb = mgr.begin_restore(params, "b")
    assert exa.group_size == exb.group_size
    mgr.restore(params, "a")                 # may trace (fresh bucket)
    before = projection_trace_count()
    mgr.restore(params, "b")
    assert projection_trace_count() == before, \
        "auto group size recompiled the projection within a bucket"
    mgr.saver.close()
