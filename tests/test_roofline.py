"""HLO cost parser: loop-aware flops / collective bytes on known programs."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.hlo_cost import analyze_hlo, shape_bytes
from repro.launch.roofline import analyze, model_flops


def test_shape_bytes():
    assert shape_bytes("f32[4,8]") == 128
    assert shape_bytes("bf16[10]{0}") == 20
    assert shape_bytes("(f32[2,2], s32[3])") == 28
    assert shape_bytes("pred[16]") == 16


def test_scan_flops_multiplied():
    def f(x, ws):
        def body(c, w):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((128, 128), "float32"),
        jax.ShapeDtypeStruct((6, 128, 128), "float32")).compile()
    r = analyze_hlo(c.as_text())
    assert r["flops"] == pytest.approx(6 * 2 * 128 ** 3, rel=0.01)


def test_nested_scan_flops():
    def g(x, ws):
        def outer(c, wset):
            def inner(c2, w):
                return c2 @ w, None
            c, _ = jax.lax.scan(inner, c, wset)
            return c, None
        out, _ = jax.lax.scan(outer, x, ws)
        return out

    c = jax.jit(g).lower(
        jax.ShapeDtypeStruct((64, 64), "float32"),
        jax.ShapeDtypeStruct((3, 4, 64, 64), "float32")).compile()
    r = analyze_hlo(c.as_text())
    assert r["flops"] == pytest.approx(12 * 2 * 64 ** 3, rel=0.01)


def test_collective_bytes_from_sharded_contraction():
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device (run under dryrun XLA_FLAGS)")


def test_roofline_terms_and_bottleneck():
    from repro.config.shapes import TRAIN_4K
    from repro.configs import get_arch
    cfg = get_arch("qwen2-7b")
    rep = analyze(cfg, TRAIN_4K, mesh_name="16x16", chips=256,
                  flops_per_device=1e15, bytes_per_device=1e11,
                  coll_breakdown={"all-reduce": 1e9})
    assert rep.compute_s == pytest.approx(1e15 / 197e12)
    assert rep.memory_s == pytest.approx(1e11 / 819e9)
    assert rep.collective_s == pytest.approx(1e9 / 50e9)
    assert rep.bottleneck == "compute"
    assert rep.model_flops == pytest.approx(
        6 * cfg.active_param_count() * 4096 * 256)


def test_model_flops_decode_counts_one_token():
    from repro.config.shapes import DECODE_32K
    from repro.configs import get_arch
    cfg = get_arch("qwen2-7b")
    assert model_flops(cfg, DECODE_32K) == pytest.approx(
        2 * cfg.active_param_count() * 128)
