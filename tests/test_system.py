"""End-to-end system behaviour: the paper's full serving story on one
model — save during prefill+decode, evict, bubble-free restore, continue —
plus the dry-run machinery on a small mesh.

(The heavyweight per-component coverage lives in the sibling test modules;
this file asserts the cross-component contracts.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.arch import reduced_for_smoke
from repro.config.hardware import PAPER_A100, TPU_V5E
from repro.configs import get_arch
from repro.core.hcache import HCacheManager
from repro.core.pipeline import ttft
from repro.core.scheduler import solve
from repro.models import Model
from repro.models.module import split
from repro.serving import InferenceEngine, Request
from repro.storage import ChunkStore, SimulatedSSD, make_array


def test_full_serving_lifecycle(rules):
    """Three-round conversation with eviction between rounds: every round's
    output must equal the never-evicted reference."""
    cfg = reduced_for_smoke(get_arch("llama2-7b"))
    model = Model(cfg, rules=rules, model_axis=1, dtype=jnp.float32,
                  remat="none")
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    store = ChunkStore(make_array("ssd", 4), chunk_tokens=16)
    mgr = HCacheManager(model, store, hw=PAPER_A100,
                        schedule_override="hidden")
    engine = InferenceEngine(model, params, mgr, max_batch=2, max_seq=256,
                             prefill_chunk=8)
    rng = np.random.default_rng(3)
    history = []
    for rnd in range(3):
        prompt = rng.integers(0, cfg.vocab_size, 9 + rnd).astype(np.int32)
        engine.submit(Request("u", prompt, max_new_tokens=4))
        engine.run()
        out = engine.result("u")
        history.append((prompt, out))

    # reference: replay the whole conversation without eviction
    toks = []
    for prompt, out in history[:-1]:
        toks.extend(prompt.tolist())
        toks.extend(out[:-1])
    toks.extend(history[-1][0].tolist())
    full = jnp.asarray(toks, jnp.int32)[None]
    pre = model.prefill(params, {"tokens": full})
    n = full.shape[1]
    k = jnp.pad(pre["kv"][0], ((0, 0), (0, 0), (0, 256 - n), (0, 0), (0, 0)))
    v = jnp.pad(pre["kv"][1], ((0, 0), (0, 0), (0, 256 - n), (0, 0), (0, 0)))
    cache = {"k": k, "v": v, "lengths": jnp.asarray([n], jnp.int32)}
    nt = jnp.argmax(pre["logits"][:, -1], -1).astype(jnp.int32)[:, None]
    want = []
    for _ in range(4):
        want.append(int(nt[0, 0]))
        lg, cache = model.decode_step(params, cache, nt)
        nt = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]
    assert history[-1][1] == want, "restored round diverged from reference"

    # storage actually used the simulated SSD array
    assert store.bytes_used > 0
    assert any(isinstance(d, SimulatedSSD) and d.write_time_total > 0
               for d in store.devices)


def test_ttft_ordering_matches_paper():
    """TTFT(hcache) < TTFT(kv offload) < TTFT(recompute) on the paper's
    testbed for every evaluated model/length."""
    for name in ("llama2-7b", "llama2-13b", "opt-30b"):
        cfg = get_arch(name)
        for n in (2048, 8192):
            sched = solve(cfg, n, PAPER_A100)
            t_h = ttft(cfg, n, 64, PAPER_A100, sched.methods)
            t_kv = ttft(cfg, n, 64, PAPER_A100, ["kv"] * cfg.n_layers)
            t_re = ttft(cfg, n, 64, PAPER_A100,
                        ["recompute"] * cfg.n_layers)
            assert t_h < t_kv < t_re, (name, n)


def test_dryrun_cell_on_small_mesh(rules):
    """The dry-run builder lowers + compiles on the test mesh (1x1); the
    512-device production run is exercised by launch/dryrun.py itself."""
    from repro.config.shapes import InputShape
    from repro.launch.dryrun import build_cell
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = reduced_for_smoke(get_arch("qwen2-7b"))
    shape = InputShape("tiny_train", 32, 2, "train")
    from repro.distributed.sharding import mesh_context
    with mesh_context(mesh):
        fn, args, shardings, donate = build_cell(mesh, cfg, shape, "base")
        compiled = jax.jit(fn, in_shardings=shardings,
                           donate_argnums=donate).lower(*args).compile()
    assert compiled.cost_analysis() is not None


def test_tpu_profile_restoration_beats_offload():
    """On the TPU v5e profile the scheduler still finds a mix that beats
    pure KV offload for the paper's MHA models."""
    cfg = get_arch("llama2-7b")
    s = solve(cfg, 8192, TPU_V5E)
    from repro.core.pipeline import restore_timeline
    t_mix = restore_timeline(cfg, 8192, TPU_V5E, s.methods).makespan
    t_kv = restore_timeline(cfg, 8192, TPU_V5E,
                            ["kv"] * cfg.n_layers).makespan
    assert t_mix < t_kv
