"""Pipelined restoration executor: task-graph compilation, one-source-of-
truth timelines, incremental engine-integrated restoration (restore-
equivalence + decode-isolation), and prefetch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.arch import reduced_for_smoke
from repro.config.hardware import PAPER_A100
from repro.configs import get_arch
from repro.core.cost_model import layer_costs, method_times
from repro.core.hcache import HCacheManager
from repro.core.pipeline import simulate
from repro.core.restoration import (CacheAssembler, RestorationExecutor,
                                    compile_tasks, replay)
from repro.core.scheduler import solve
from repro.models import Model
from repro.models.module import split
from repro.serving import InferenceEngine, Phase, Request
from repro.storage import ChunkStore, make_array


# ------------------------------------------------------------- task graph
def test_compile_tasks_orders_streams():
    """IO: hidden fetches first (layer order), then kv; compute: recompute
    prefix then projections; every projection depends on its fetch."""
    methods = ["recompute", "hidden", "kv", "hidden"]
    tasks = compile_tasks(methods)
    kinds = [(t.kind, t.layer) for t in tasks]
    assert kinds == [("io_h", 1), ("io_h", 3), ("io_kv", 2),
                     ("recompute", 0), ("project", 1), ("project", 3)]
    for t in tasks:
        if t.kind == "project":
            dep = tasks[t.dep]
            assert dep.kind == "io_h" and dep.layer == t.layer


def test_replay_is_simulate():
    """pipeline.simulate IS a replay of the compiled task graph — any
    schedule, any model: one source of truth."""
    cfg = get_arch("llama2-13b")
    for n in (512, 4096):
        sched = solve(cfg, n, PAPER_A100)
        times = [method_times(c, PAPER_A100) for c in layer_costs(cfg, n)]
        for methods in (sched.methods, ["kv"] * cfg.n_layers,
                        ["hidden"] * cfg.n_layers):
            a = simulate(methods, times)
            b = replay(compile_tasks(methods), times)
            assert a == b


def test_replay_order_invariant_per_stream():
    """Interleaving the two streams differently (as incremental execution
    does) never changes the timeline, as long as per-stream order holds."""
    cfg = get_arch("llama2-7b")
    sched = solve(cfg, 2048, PAPER_A100)
    times = [method_times(c, PAPER_A100)
             for c in layer_costs(cfg, 2048)]
    tasks = compile_tasks(sched.methods)
    io = [i for i, t in enumerate(tasks) if t.stream == "io"]
    comp = [i for i, t in enumerate(tasks) if t.stream == "compute"]
    # perfect round-robin interleave of the two streams
    order = []
    while io or comp:
        if io:
            order.append(io.pop(0))
        if comp:
            order.append(comp.pop(0))
    assert replay(tasks, times, order) == replay(tasks, times)


# --------------------------------------------------------------- fixtures
@pytest.fixture(scope="module")
def setup():
    from repro.distributed.sharding import default_rules
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    rules = default_rules(mesh)
    cfg = reduced_for_smoke(get_arch("llama2-7b"))
    model = Model(cfg, rules=rules, model_axis=1, dtype=jnp.float32,
                  remat="none")
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def fresh_engine(setup, **kw):
    cfg, model, params = setup
    store = ChunkStore(make_array("dram", 4), chunk_tokens=16)
    mgr = HCacheManager(model, store, hw=PAPER_A100,
                        schedule_override="hidden")
    defaults = dict(max_batch=2, max_seq=128, prefill_chunk=8)
    defaults.update(kw)
    return InferenceEngine(model, params, mgr, **defaults), mgr


# ------------------------------------------------- incremental execution
def test_executor_incremental_matches_run_to_completion(setup):
    """Stepping the executor 1 task at a time produces the same cache and
    the same timeline as running it in one go."""
    cfg, model, params = setup
    _, mgr = fresh_engine(setup)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 24), 0,
                              cfg.vocab_size)
    pre = model.prefill(params, {"tokens": toks}, capture_hidden=True)
    mgr.save_prefill("s", np.asarray(toks[0]), pre)

    whole = mgr.restore(params, "s")
    sink = CacheAssembler(model)
    ex = RestorationExecutor(mgr, params, "s", sink=sink)
    n_steps = 0
    while not ex.step(max_tasks=1):
        n_steps += 1
    assert n_steps >= len(ex.tasks) - 1          # genuinely incremental
    np.testing.assert_array_equal(np.asarray(sink.cache["k"]),
                                  np.asarray(whole.cache["k"]))
    assert ex.timeline() == whole.timeline


def test_engine_restore_equivalence_logits(setup):
    """(a) A session restored mid-conversation through the incremental
    executor produces decode logits matching an uninterrupted session."""
    cfg, model, params = setup
    engine, _ = fresh_engine(setup)
    rng = np.random.default_rng(7)
    p1 = rng.integers(0, cfg.vocab_size, 20).astype(np.int32)
    engine.submit(Request("eq", p1, max_new_tokens=5))
    engine.run()
    g1 = engine.result("eq")
    p2 = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    engine.submit(Request("eq", p2, max_new_tokens=1))
    engine.run()
    assert engine.sessions["eq"].restored

    # uninterrupted reference: one prefill over the whole history
    full = np.concatenate([p1, np.asarray(g1[:-1], np.int32), p2])
    pre = model.prefill(params, {"tokens": jnp.asarray(full)[None]})
    want = int(jnp.argmax(pre["logits"][:, -1], -1)[0])
    assert engine.result("eq") == [want]


def test_decode_isolation_while_restoring(setup):
    """(b) An actively decoding session emits a token on every engine step
    while another session is in Phase.RESTORING — restoration never
    blocks the decode batch."""
    cfg, model, params = setup
    engine, mgr = fresh_engine(setup, restore_tasks_per_step=1)
    rng = np.random.default_rng(8)
    # store state for "warm" so its admission goes through RESTORING
    p0 = rng.integers(0, cfg.vocab_size, 30).astype(np.int32)
    engine.submit(Request("warm", p0, max_new_tokens=2))
    engine.run()

    engine.submit(Request("active", rng.integers(
        0, cfg.vocab_size, 8).astype(np.int32), max_new_tokens=40))
    for _ in range(3):
        engine.step()                      # "active" reaches DECODE
    active = engine.sessions["active"]
    assert active.phase == Phase.DECODE

    engine.submit(Request("warm", rng.integers(
        0, cfg.vocab_size, 4).astype(np.int32), max_new_tokens=2))
    engine.step()
    warm = engine.sessions["warm"]
    assert warm.phase == Phase.RESTORING   # multi-step phase, 1 task/step
    restoring_steps = 0
    while warm.phase == Phase.RESTORING:
        before = len(active.generated)
        engine.step()
        restoring_steps += 1
        assert len(active.generated) == before + 1, \
            "decode batch stalled during restoration"
    assert restoring_steps >= 2            # restoration really spanned steps


def test_two_sessions_restore_concurrently(setup):
    """≥2 sessions interleave their restorations with an active workload."""
    cfg, model, params = setup
    engine, mgr = fresh_engine(setup, max_batch=3, restore_tasks_per_step=1)
    rng = np.random.default_rng(9)
    prompts = {}
    for sid in ("a", "b"):
        prompts[sid] = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
        engine.submit(Request(sid, prompts[sid], max_new_tokens=2))
    engine.run()
    for sid in ("a", "b"):
        engine.submit(Request(sid, rng.integers(
            0, cfg.vocab_size, 4).astype(np.int32), max_new_tokens=2))
    engine.step()
    phases = {sid: engine.sessions[sid].phase for sid in ("a", "b")}
    assert phases == {"a": Phase.RESTORING, "b": Phase.RESTORING}
    engine.run()
    assert engine.sessions["a"].restored and engine.sessions["b"].restored
    assert len(engine.result("a")) == 2 and len(engine.result("b")) == 2


def test_prefetch_starts_before_slot_frees(setup):
    """A queued session with stored state gets IO prefetched while all
    slots are still busy."""
    cfg, model, params = setup
    engine, mgr = fresh_engine(setup, max_batch=1, restore_tasks_per_step=2)
    rng = np.random.default_rng(10)
    p = rng.integers(0, cfg.vocab_size, 20).astype(np.int32)
    engine.submit(Request("pre", p, max_new_tokens=2))
    engine.run()

    # occupy the only slot, then queue the stored session behind it
    engine.submit(Request("hog", rng.integers(
        0, cfg.vocab_size, 8).astype(np.int32), max_new_tokens=30))
    engine.step()
    engine.submit(Request("pre", rng.integers(
        0, cfg.vocab_size, 4).astype(np.int32), max_new_tokens=2))
    engine.step()
    assert "pre" in engine._prefetch
    warm = engine._prefetch["pre"]
    assert len(warm.executed) >= 1         # layer-0 IO already issued
    assert all(warm.tasks[i].stream == "io" for i in warm.executed)
    engine.run()
    assert engine.sessions["pre"].restored
    assert len(engine.result("pre")) == 2


def test_stale_prefetch_discarded_on_manifest_change(setup):
    """A prefetch executor warmed from an older manifest is discarded at
    admission when the session saved more state in between (e.g. its
    previous turn retired after the prefetch started)."""
    cfg, model, params = setup
    engine, mgr = fresh_engine(setup, max_batch=1, restore_tasks_per_step=4)
    rng = np.random.default_rng(12)
    p1 = rng.integers(0, cfg.vocab_size, 14).astype(np.int32)
    engine.submit(Request("st", p1, max_new_tokens=3))
    engine.run()
    n1 = mgr.store.get_manifest("st")["n_tokens"]

    # warm a (soon-stale) executor from the current manifest by hand
    engine._prefetch["st"] = mgr.begin_restore(params, "st")
    engine._prefetch["st"].prefetch_step(1)

    # the session grows: another turn runs and retires
    p2 = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
    engine.submit(Request("st", p2, max_new_tokens=3))
    engine.run()
    n2 = mgr.store.get_manifest("st")["n_tokens"]
    assert n2 > n1

    p3 = rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
    engine.submit(Request("st", p3, max_new_tokens=2))
    engine.run()
    assert engine.sessions["st"].history_len == n2   # not the stale n1


def test_engine_reports_measured_io_on_ssd(setup):
    """With simulated-SSD devices the executor's striped async reads
    surface a measured completion time in the engine metrics."""
    cfg, model, params = setup
    from repro.config.hardware import PAPER_A100 as hw
    store = ChunkStore(make_array("ssd", 4), chunk_tokens=16)
    mgr = HCacheManager(model, store, hw=hw, schedule_override="hidden")
    engine = InferenceEngine(model, params, mgr, max_batch=2, max_seq=128,
                             prefill_chunk=8)
    rng = np.random.default_rng(13)
    p = rng.integers(0, cfg.vocab_size, 20).astype(np.int32)
    engine.submit(Request("io", p, max_new_tokens=2))
    engine.run()
    engine.submit(Request("io", rng.integers(
        0, cfg.vocab_size, 4).astype(np.int32), max_new_tokens=2))
    engine.run()
    assert engine.metrics.restore_io_measured > 0


def test_metrics_ttft_populations(setup):
    """Simulated TTFT is recorded only for sessions that actually
    restored; cold starts land in their own population."""
    cfg, model, params = setup
    engine, _ = fresh_engine(setup)
    rng = np.random.default_rng(11)
    engine.submit(Request("cold", rng.integers(
        0, cfg.vocab_size, 10).astype(np.int32), max_new_tokens=2))
    engine.run()
    assert engine.metrics.ttft_sim == []
    assert len(engine.metrics.ttft_wall_cold) == 1
    assert engine.metrics.ttft_wall_restored == []

    engine.submit(Request("cold", rng.integers(
        0, cfg.vocab_size, 4).astype(np.int32), max_new_tokens=2))
    engine.run()
    assert len(engine.metrics.ttft_sim) == 1
    assert engine.metrics.ttft_sim[0] > 0
    assert len(engine.metrics.ttft_wall_restored) == 1
    assert len(engine.metrics.ttft_wall_cold) == 1
