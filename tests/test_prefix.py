"""Cross-session prefix sharing (DESIGN.md §12): refcounted CoW pages,
the token-hash prefix index, content-addressed host chunk sharing, and
session forking — greedy outputs must stay byte-identical to runs
without sharing, and no page may leak or be freed while referenced."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis - seeded shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.config.arch import reduced_for_smoke
from repro.config.hardware import PAPER_A100
from repro.configs import get_arch
from repro.core.hcache import HCacheManager
from repro.models import Model
from repro.serving import InferenceEngine, Request
from repro.serving.kv_cache import BlockAllocator, PagedBackend
from repro.serving.prefix_index import PrefixIndex
from repro.serving.request import Phase
from repro.storage import ChunkStore, make_array


@pytest.fixture(scope="module")
def setup():
    from repro.distributed.sharding import default_rules
    from repro.launch.mesh import make_mesh
    from repro.models.module import split
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = reduced_for_smoke(get_arch("llama2-7b"))
    model = Model(cfg, rules=default_rules(mesh), model_axis=1,
                  dtype=jnp.float32, remat="none")
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def fresh_engine(setup, **kw):
    cfg, model, params = setup
    store = ChunkStore(make_array("dram", 4), chunk_tokens=16)
    mgr = HCacheManager(model, store, hw=PAPER_A100,
                        schedule_override="hidden", store_dtype=np.float32)
    defaults = dict(max_batch=2, max_seq=128, prefill_chunk=8)
    defaults.update(kw)
    return InferenceEngine(model, params, mgr, **defaults), mgr


# ------------------------------------------------- allocator refcounts
def test_block_allocator_double_free_raises():
    """Regression: freeing an already-free page used to append it to the
    LIFO free list a second time, letting two sessions be granted the
    same physical page. It must raise instead."""
    a = BlockAllocator(4)
    got = a.alloc(2)
    a.free(got)
    with pytest.raises(RuntimeError, match="double free"):
        a.free(got)
    # and the free list stayed sane: 4 distinct pages, no duplicates
    assert a.free_count == 4
    assert sorted(a.alloc(4)) == [0, 1, 2, 3]


def test_block_allocator_refcounts():
    a = BlockAllocator(2)
    (b,) = a.alloc(1)
    a.incref(b)
    assert a.refcount(b) == 2
    a.free([b])                        # one holder left: page stays out
    assert a.refcount(b) == 1 and a.free_count == 1
    a.free([b])                        # last holder: back on the free list
    assert a.refcount(b) == 0 and a.free_count == 2
    with pytest.raises(RuntimeError, match="incref of unallocated"):
        a.incref(b)


# -------------------------------------------- backend CoW + index unit
def _write_tokens(backend, slot, toks, start):
    """Write each position's token id as its KV value — content checks
    then reduce to comparing gathers against the slot's token array."""
    n = len(toks) - start
    if n <= 0:
        return
    L = backend.cache["k_pool"].shape[0]
    Kv, hd = backend.cache["k_pool"].shape[-2:]
    vals = jnp.broadcast_to(
        jnp.asarray(toks[start:], jnp.float32)[None, None, :, None, None],
        (L, 1, n, Kv, hd))
    backend.view(slot).write_kv(vals, vals, start)


def _slot_content(backend, slot, n):
    k, _ = backend.view(slot).gather_hist(n)
    return np.asarray(k)[0, 0, :, 0, 0]


def test_cow_divergence_preserves_sibling_content(setup):
    """Two slots share a 2-page prefix; slot 1 diverges inside page 0.
    Only that page is copied (one CoW), and slot 0 still reads the
    original bytes."""
    cfg, model, params = setup
    b = PagedBackend(model, max_batch=2, max_seq=64, block_size=16,
                     num_blocks=8)
    idx = PrefixIndex(b)
    b.prefix_index = idx
    toks = np.arange(100, 140)                      # 40 tokens, 2 full pages
    assert b.reserve(0, 40)
    b.set_length(0, 40)
    _write_tokens(b, 0, toks, 0)
    idx.publish(toks, 40, b.slot_blocks[0])
    assert len(idx) == 2

    blocks, m, _ = idx.match(toks)
    assert m == 32
    b.adopt_shared(1, blocks)
    assert b.reserve(1, 40)
    b.set_length(1, 40)
    assert b.slot_blocks[1][:2] == b.slot_blocks[0][:2]   # truly shared
    _write_tokens(b, 1, toks, 32)                   # private tail, no CoW
    assert b.cow_copies == 0

    fork = toks.copy()
    fork[5] = 999
    # diverge slot 1 at position 5 (inside shared page 0)
    vals = jnp.full((b.cache["k_pool"].shape[0], 1, 1,
                     *b.cache["k_pool"].shape[-2:]), 999.0)
    b.view(1).write_kv(vals, vals, 5)
    assert b.cow_copies == 1
    assert b.slot_blocks[1][0] != b.slot_blocks[0][0]     # page privatized
    assert b.slot_blocks[1][1] == b.slot_blocks[0][1]     # page 1 shared
    np.testing.assert_array_equal(_slot_content(b, 0, 40), toks)
    np.testing.assert_array_equal(_slot_content(b, 1, 40), fork)

    b.free_slot(0)
    b.free_slot(1)
    assert idx.clear() == 2
    assert b.allocator.free_count == 8              # nothing leaked


def test_prefix_index_rejects_divergent_tokens(setup):
    cfg, model, params = setup
    b = PagedBackend(model, max_batch=2, max_seq=64, block_size=16,
                     num_blocks=8)
    idx = PrefixIndex(b)
    toks = np.arange(32)
    b.reserve(0, 32)
    idx.publish(toks, 32, b.slot_blocks[0])
    other = toks.copy()
    other[20] = 7                                   # differs in page 1
    _, m, _ = idx.match(other)
    assert m == 16                                  # page 0 only
    _, m0, _ = idx.match(other, limit=15)
    assert m0 == 0                                  # no full page allowed
    b.free_slot(0)
    idx.clear()
    assert b.allocator.free_count == 8


def test_index_pages_spill_under_pool_pressure(setup):
    """Index-held pages are a cache, not a reservation: when the pool
    cannot satisfy a reservation, LRU index entries are released."""
    cfg, model, params = setup
    b = PagedBackend(model, max_batch=2, max_seq=128, block_size=16,
                     num_blocks=4)
    idx = PrefixIndex(b)
    b.prefix_index = idx
    toks = np.arange(32)
    b.reserve(0, 32)
    idx.publish(toks, 32, b.slot_blocks[0])
    b.free_slot(0)                      # only the index holds the 2 pages
    assert b.allocator.free_count == 2
    assert idx.releasable() == 2
    assert b.can_reserve(64)            # 2 free + 2 releasable
    assert b.reserve(1, 64)             # forces the spill
    assert len(idx) == 0
    b.free_slot(1)
    assert b.allocator.free_count == 4


# ------------------------------------------- hypothesis: invariants
def _check_invariants(b, idx, live_toks):
    # refcount of every page == exactly the number of holders mapping it
    holds = [0] * b.num_blocks
    for blks in b.slot_blocks:
        for blk in blks:
            holds[blk] += 1
    for e in idx._entries.values():
        holds[e.block] += 1
    free = set(b.allocator._free)
    assert len(free) == len(b.allocator._free), "duplicate free-list entry"
    for blk in range(b.num_blocks):
        assert b.allocator.refcount(blk) == holds[blk]
        assert (blk in free) == (holds[blk] == 0)
    # every occupied slot still reads exactly its own token stream
    for slot, toks in live_toks.items():
        np.testing.assert_array_equal(
            _slot_content(b, slot, len(toks)), toks)


@settings(max_examples=8, deadline=None)
@given(ops=st.lists(st.integers(0, 4), min_size=4, max_size=20),
       seed=st.integers(0, 2**31 - 1))
def test_refcount_invariants_random_interleavings(setup, ops, seed):
    """Random admit/publish/diverge/retire/release interleavings: no
    page leaks, no page freed while referenced, every slot's content
    byte-identical to what an unshared run would hold."""
    cfg, model, params = setup
    rng = np.random.default_rng(seed)
    b = PagedBackend(model, max_batch=3, max_seq=64, block_size=16,
                     num_blocks=10)
    idx = PrefixIndex(b)
    b.prefix_index = idx
    shared = [rng.integers(0, 1000, 48), rng.integers(0, 1000, 48)]
    live = {}                                     # slot -> token array
    for op in ops:
        if op == 0:                               # admit (maybe via match)
            free = [s for s in range(3) if s not in live]
            if not free:
                continue
            slot = free[0]
            toks = np.concatenate([shared[int(rng.integers(0, 2))],
                                   rng.integers(0, 1000,
                                                int(rng.integers(0, 16)))])
            blocks, m, _ = idx.match(toks)
            if m:
                b.adopt_shared(slot, blocks)
            if not b.reserve(slot, len(toks)):
                b.free_slot(slot)
                continue
            b.set_length(slot, len(toks))
            _write_tokens(b, slot, toks, m)
            live[slot] = toks
        elif op == 1:                             # publish
            if live:
                slot = int(rng.choice(list(live)))
                idx.publish(live[slot], len(live[slot]),
                            b.slot_blocks[slot])
        elif op == 2:                             # diverge one position
            if live:
                slot = int(rng.choice(list(live)))
                pos = int(rng.integers(0, len(live[slot])))
                tok = int(rng.integers(1000, 2000))
                live[slot] = live[slot].copy()
                live[slot][pos] = tok
                _write_tokens(b, slot, live[slot][:pos + 1], pos)
        elif op == 3:                             # retire
            if live:
                slot = int(rng.choice(list(live)))
                b.free_slot(slot)
                del live[slot]
        else:                                     # index pressure release
            idx.release(1)
        _check_invariants(b, idx, live)
    for slot in list(live):
        b.free_slot(slot)
    idx.clear()
    assert b.allocator.free_count == b.num_blocks     # no page leaked


# ------------------------------------------------- host chunk sharing
def _store():
    return ChunkStore(make_array("dram", 2), chunk_tokens=8)


def test_share_session_dedups_and_diverges():
    s = _store()
    data = np.arange(64, dtype=np.float32).reshape(16, 4)
    s.append_tokens("a", "h", 0, 0, data)
    s.flush("a")
    base = s.bytes_for("a")
    n = s.share_session("a", "b")
    assert n == 2                              # two chunks aliased
    # dedup-aware accounting: the alias costs nothing, dedup_bytes
    # reports what a copy would have cost
    assert s.bytes_for("b") == 0
    assert s.dedup_bytes == base
    np.testing.assert_array_equal(s.read_layer("b", "h", 0, 16), data)
    # fork writer diverges: b overwrites its chunk 0, a keeps the bytes
    s.append_tokens("b", "h", 0, 8, data[:8] + 100)
    s.flush("b")
    np.testing.assert_array_equal(s.read_layer("a", "h", 0, 16), data)
    got_b = s.read_layer("b", "h", 0, 16)
    np.testing.assert_array_equal(got_b[:8], data[:8])
    np.testing.assert_array_equal(got_b[8:], data[:8] + 100)
    assert s.bytes_for("b") > 0                # divergent chunk is real now


def test_owner_extension_shadows_shared_chunk():
    """The owner extending a partial chunk that a fork still references
    rewrites that chunk's key in place — the fork must keep reading the
    old bytes (shadow-out, deferred delete)."""
    s = _store()
    head = np.ones((4, 4), np.float32)
    tail = np.full((4, 4), 2.0, np.float32)
    s.append_tokens("a", "h", 0, 0, head)
    s.flush("a")                               # partial chunk 0: 4 rows
    s.share_session("a", "b")
    s.append_tokens("a", "h", 0, 4, tail)      # extends chunk 0 in place
    s.flush("a")
    np.testing.assert_array_equal(s.read_layer("a", "h", 0, 8),
                                  np.concatenate([head, tail]))
    np.testing.assert_array_equal(s.read_layer("b", "h", 0, 4), head)
    # dropping the last referent frees the shadowed bytes
    used = s.bytes_used
    s.drop_session("b")
    assert s.bytes_used < used


def test_shared_chunks_survive_owner_eviction_and_skip_demotion():
    s = ChunkStore(make_array("dram", 2), chunk_tokens=8,
                   cold_devices=make_array("dram", 2))
    data = np.arange(32, dtype=np.float32).reshape(8, 4)
    s.append_tokens("a", "h", 0, 0, data)
    s.flush("a")
    s.share_session("a", "b")
    # deferred demotion: a shared chunk stays hot until the last referent
    # releases it — a sibling may be restoring from these bytes right now
    assert s.demote_session_to_cold("a") == 0
    assert s.bytes_used > 0 and s.bytes_cold == 0
    # deferred eviction: dropping the owner keeps the shared bytes
    s.drop_session("a")
    np.testing.assert_array_equal(s.read_layer("b", "h", 0, 8), data)
    s.drop_session("b")
    assert s.bytes_used == 0 and s.bytes_cold == 0


def test_pin_chunks_keep_bytes_for_new_aliases():
    s = _store()
    data = np.full((8, 4), 3.0, np.float32)
    s.append_tokens("a", "h", 0, 0, data)
    s.flush("a")
    pins = s.pin_chunks("a", "h", 0, [0])
    s.drop_session("a")
    s.alias_chunk("c", "h", 0, 0, pins[0])     # admission via prefix hit
    s.unpin(pins)
    np.testing.assert_array_equal(s.read_layer("c", "h", 0, 8), data)
    s.drop_session("c")
    assert all(d.bytes_used == 0 for d in s.devices)


# -------------------------------------------------- engine end-to-end
def test_prefix_sharing_outputs_byte_identical(setup):
    """4 sessions over one 48-token system prompt, 2 slots: sharing on
    must produce byte-identical greedy outputs while later sessions skip
    the shared prefill via adopted pages + aliased host chunks."""
    cfg, model, params = setup
    rng = np.random.default_rng(11)
    sys_p = rng.integers(0, cfg.vocab_size, 48).astype(np.int32)
    prompts = [np.concatenate([sys_p, rng.integers(
        0, cfg.vocab_size, 6).astype(np.int32)]) for _ in range(4)]
    results, mets = {}, {}
    for sharing in (False, True):
        eng, _ = fresh_engine(setup, backend="paged",
                              prefix_sharing=sharing)
        for i, p in enumerate(prompts):
            eng.submit(Request(f"p{i}", p, max_new_tokens=4))
        eng.run()
        results[sharing] = {i: eng.result(f"p{i}") for i in range(4)}
        mets[sharing] = eng.metrics
        eng.close()
    assert results[True] == results[False]
    m = mets[True]
    assert m.prefix_hits >= 2                  # late sessions hit
    assert m.restore_skipped_tokens >= 2 * 48  # prefill skipped wholesale
    assert m.dedup_host_bytes > 0              # host streams aliased
    assert mets[False].prefix_hits == 0


@pytest.mark.parametrize("backend", ["contiguous", "paged"])
def test_fork_diverge_evict_restore_roundtrip(setup, backend):
    """fork -> diverge -> evict -> restore on both backends: the fork
    continues from the fork point, both lineages stay independent, and
    everything is byte-identical to the sharing-off (copying) run."""
    cfg, model, params = setup
    rng = np.random.default_rng(5)
    p = rng.integers(0, cfg.vocab_size, 20).astype(np.int32)
    t_fork = int(rng.integers(0, cfg.vocab_size))
    t_src = int(rng.integers(0, cfg.vocab_size))
    results = {}
    for sharing in (False, True):
        eng, _ = fresh_engine(setup, backend=backend,
                              prefix_sharing=sharing)
        eng.submit(Request("src", p, max_new_tokens=6))
        for _ in range(200):
            s = eng.sessions.get("src")
            if (s is not None and s.phase == Phase.DECODE
                    and len(s.generated) >= 3):
                break
            eng.step()
        man = eng.fork_session("src", "fk")
        assert int(man["n_tokens"]) == eng.sessions["src"].total_len - 1
        eng.run()                                  # src retires
        # the fork diverges; src resumes — an evict/restore round trip
        eng.submit(Request("fk", np.asarray([t_fork], np.int32),
                           max_new_tokens=3))
        eng.submit(Request("src", np.asarray([t_src], np.int32),
                           max_new_tokens=3))
        eng.run()
        results[sharing] = (eng.result("src"), eng.result("fk"),
                            eng.metrics.forks)
        if sharing and backend == "paged":
            assert eng.metrics.restore_skipped_tokens > 0
            assert eng.kv.allocator.free_count + len(
                eng.prefix_index._entries) >= 0
        eng.close()
    assert results[True] == results[False]


def test_restore_skip_resumes_round2_identically(setup):
    """Round-2 restoration of a retired session starts at the divergence
    token when its own published pages still sit in the index."""
    cfg, model, params = setup
    rng = np.random.default_rng(9)
    p1 = rng.integers(0, cfg.vocab_size, 40).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
    results = {}
    for sharing in (False, True):
        eng, _ = fresh_engine(setup, backend="paged",
                              prefix_sharing=sharing)
        eng.submit(Request("s", p1, max_new_tokens=4))
        eng.run()
        g1 = eng.result("s")
        eng.submit(Request("s", p2, max_new_tokens=4))
        eng.run()
        results[sharing] = (g1, eng.result("s"))
        if sharing:
            # 43 saved tokens -> 2 full pages adopted, restore starts at 32
            assert eng.metrics.restore_skipped_tokens >= 32
            assert eng.metrics.restored_tokens < 43
        eng.close()
    assert results[True] == results[False]
