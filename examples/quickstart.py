"""Quickstart: the HCache lifecycle in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a small llama-family model, prefills a prompt while saving hidden
states, evicts the KV cache, restores it from host storage via the
bubble-free scheduler, and shows the restored decode path produces exactly
the same tokens as the never-evicted one.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config.arch import reduced_for_smoke
from repro.config.hardware import PAPER_A100
from repro.configs import get_arch
from repro.core.hcache import HCacheManager
from repro.distributed.sharding import default_rules
from repro.launch.mesh import make_mesh
from repro.models import Model
from repro.models.module import split
from repro.storage import ChunkStore, make_array

mesh = make_mesh((1, 1), ("data", "model"))
rules = default_rules(mesh)
cfg = reduced_for_smoke(get_arch("llama2-7b"))
model = Model(cfg, rules=rules, dtype=jnp.float32, remat="none")
params, _ = split(model.init(jax.random.PRNGKey(0)))

# --- 1. prefill, capturing per-layer hidden states (the HCache save path)
prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 48), 0,
                            cfg.vocab_size)
out = model.prefill(params, {"tokens": prompt}, capture_hidden=True)
print(f"prefilled {prompt.shape[1]} tokens; hidden states: "
      f"{out['hidden'].shape} ({out['hidden'].nbytes / 1e6:.2f} MB)")

# --- 2. persist to (simulated-SSD) host storage & evict
# (schedule_override pins the hidden-state path for the demo — on this
# toy-sized model the bubble-free scheduler would correctly prefer pure
# recompute, which is free at 4 layers x 64 dims)
store = ChunkStore(make_array("ssd", 4), chunk_tokens=16)
mgr = HCacheManager(model, store, hw=PAPER_A100,
                    schedule_override="hidden")
mgr.save_prefill("demo", np.asarray(prompt[0]), out)
sched = mgr.plan(48)
print(f"bubble-free schedule: {sched.summary()}")
print(f"stored {store.bytes_used / 1e6:.2f} MB across "
      f"{len(store.devices)} simulated SSDs")

# --- 3. restore (recompute-prefix + H-projection + KV reads, pipelined)
res = mgr.restore(params, "demo")
print(f"restored {res.n_tokens} tokens; simulated restoration "
      f"{res.timeline.makespan * 1e3:.3f} ms "
      f"(io busy {res.timeline.io_busy * 1e3:.3f} / compute "
      f"{res.timeline.compute_busy * 1e3:.3f})")

# --- 4. decode from the restored cache vs the never-evicted cache
def pad(x, ctx=64):
    return jnp.pad(x, ((0, 0), (0, 0), (0, ctx - x.shape[2]), (0, 0),
                       (0, 0)))

restored = {"k": pad(res.cache["k"]), "v": pad(res.cache["v"]),
            "lengths": res.cache["lengths"]}
reference = {"k": pad(out["kv"][0]), "v": pad(out["kv"][1]),
             "lengths": jnp.asarray([48], jnp.int32)}
tok = jnp.argmax(out["logits"][:, -1], -1).astype(jnp.int32)[:, None]
seq_r, seq_g = [], []
tr, tg = tok, tok
for _ in range(8):
    seq_r.append(int(tr[0, 0]))
    seq_g.append(int(tg[0, 0]))
    lr, restored = model.decode_step(params, restored, tr)
    lg, reference = model.decode_step(params, reference, tg)
    tr = jnp.argmax(lr[:, -1], -1).astype(jnp.int32)[:, None]
    tg = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]
print("restored :", seq_r)
print("reference:", seq_g)
print("MATCH" if seq_r == seq_g else "MISMATCH")
