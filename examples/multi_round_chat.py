"""Multi-round conversation serving (paper §6.1.1, ShareGPT-like).

    PYTHONPATH=src python examples/multi_round_chat.py

Drives the continuous-batching engine with a small synthetic conversation
trace. Sessions are evicted after every round (as in the paper's setup) and
restored through HCache when the user returns; TTFT decomposition and
storage use are reported per round.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config.arch import reduced_for_smoke
from repro.config.hardware import PAPER_A100
from repro.configs import get_arch
from repro.core.hcache import HCacheManager
from repro.distributed.sharding import default_rules
from repro.launch.mesh import make_mesh
from repro.models import Model
from repro.models.module import split
from repro.serving import InferenceEngine, Request
from repro.storage import ChunkStore, make_array
from repro.training.data import sharegpt_trace

mesh = make_mesh((1, 1), ("data", "model"))
rules = default_rules(mesh)
cfg = reduced_for_smoke(get_arch("llama2-7b"))
model = Model(cfg, rules=rules, dtype=jnp.float32, remat="none")
params, _ = split(model.init(jax.random.PRNGKey(0)))
store = ChunkStore(make_array("ssd", 4), chunk_tokens=16)
mgr = HCacheManager(model, store, hw=PAPER_A100)
engine = InferenceEngine(model, params, mgr, max_batch=4, max_seq=512,
                         prefill_chunk=16)

rng = np.random.default_rng(0)
trace = sharegpt_trace(3, rounds_per_session=3, seed=0)
for r in trace:
    n_in = min(r.input_len, 24)                 # CPU-friendly sizes
    n_out = min(r.output_len, 8)
    prompt = rng.integers(0, cfg.vocab_size, n_in).astype(np.int32)
    engine.submit(Request(r.session_id, prompt, max_new_tokens=n_out))
    engine.run()
    seq = engine.sessions[r.session_id]
    print(f"{r.session_id}: +{n_in} prompt, {len(seq.generated)} generated, "
          f"history {seq.history_len}, restore(sim) "
          f"{seq.restore_sim * 1e3:.3f} ms, TTFT(wall) {seq.ttft_wall:.3f} s")

m = engine.metrics
print(f"\n{len(m.ttft_wall)} requests; {m.restored_tokens} tokens restored; "
      f"{m.decode_steps} decode steps; store {store.bytes_used / 1e6:.1f} MB")
print(f"recoverable sessions after 'shutdown': "
      f"{engine.recoverable_sessions()}")
