"""Train a small qwen2-family model end-to-end with fault injection.

    PYTHONPATH=src python examples/train_small.py [--steps 60]

Exercises the full training substrate on CPU: AdamW, chunked
vocab-parallel CE, remat, deterministic data, async checkpoints, and a
supervised restart (a failure is injected mid-run; the final params are
identical to an uninterrupted run). For the production-scale path (full
configs, 16x16 mesh) see launch/train.py and launch/dryrun.py.
"""
import argparse
import shutil
import tempfile

import jax
import jax.numpy as jnp

from repro.config.arch import reduced_for_smoke
from repro.configs import get_arch
from repro.distributed.fault import FailureInjector, run_supervised
from repro.distributed.sharding import default_rules
from repro.launch.mesh import make_mesh
from repro.models import Model
from repro.training import (AdamWConfig, CheckpointManager, DataConfig,
                            Trainer, batch_at)

p = argparse.ArgumentParser()
p.add_argument("--steps", type=int, default=60)
args = p.parse_args()

mesh = make_mesh((1, 1), ("data", "model"))
rules = default_rules(mesh)
cfg = reduced_for_smoke(get_arch("qwen2-7b")).scaled(
    n_layers=6, d_model=128, n_heads=8, n_kv_heads=2, head_dim=16,
    d_ff=512, vocab_size=2048)
model = Model(cfg, rules=rules, dtype=jnp.float32, remat="full")
trainer = Trainer(model, rules, AdamWConfig(lr=3e-4), loss_chunks=4)
state, _ = trainer.init_state(jax.random.PRNGKey(0))
n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
print(f"model: {n_params / 1e6:.1f}M params "
      f"({cfg.n_layers}L d={cfg.d_model})")

dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=128, global_batch=8)
step_jit = jax.jit(trainer.train_step)
ckdir = tempfile.mkdtemp(prefix="repro_train_")
injector = FailureInjector(fail_at=(args.steps // 2,))
live = {"state": state}


def one(step):
    injector.check(step)
    live["state"], m = step_jit(live["state"], batch_at(dc, step))
    if step % 10 == 0:
        print(f"  step {step:4d}  loss {float(m['loss']):.4f}  "
              f"gnorm {float(m['grad_norm']):.3f}")
    return m


report = run_supervised(
    one, ckpt=CheckpointManager(ckdir),
    save_state=lambda: live["state"],
    load_state=lambda s, st: live.update(state=st),
    n_steps=args.steps, ckpt_every=10)
print(f"finished: {report.steps_run} steps, {report.restarts} restart(s) "
      f"(failure was injected at step {args.steps // 2} and recovered)")
shutil.rmtree(ckdir, ignore_errors=True)
