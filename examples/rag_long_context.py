"""RAG / long-context serving (paper §6.1.2, L-Eval-like).

    PYTHONPATH=src python examples/rag_long_context.py

RAG contexts are ingested OFFLINE (§3.1: "in RAG applications, hidden
states can be generated and saved offline"): we prefill each document once,
save its HCache state, and then serve user questions against the shared
contexts — each request restores the document state and prefills only the
short question. Reports the TTFT estimate for HCache vs KV offload vs
recompute per request on the paper's A100 testbed constants.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config.arch import reduced_for_smoke
from repro.config.hardware import PAPER_A100
from repro.configs import get_arch
from repro.core.hcache import HCacheManager
from repro.core.pipeline import ttft
from repro.distributed.sharding import default_rules
from repro.launch.mesh import make_mesh
from repro.models import Model
from repro.models.module import split
from repro.serving import InferenceEngine, Request
from repro.storage import ChunkStore, make_array
from repro.training.data import leval_trace

mesh = make_mesh((1, 1), ("data", "model"))
rules = default_rules(mesh)
cfg = reduced_for_smoke(get_arch("llama2-7b"))
model = Model(cfg, rules=rules, dtype=jnp.float32, remat="none")
params, _ = split(model.init(jax.random.PRNGKey(0)))
store = ChunkStore(make_array("ssd", 4), chunk_tokens=16)
mgr = HCacheManager(model, store, hw=PAPER_A100)

# --- offline ingestion of shared contexts -------------------------------
rng = np.random.default_rng(0)
DOC_LEN = 96
docs = {}
for d in range(2):
    doc = rng.integers(0, cfg.vocab_size, DOC_LEN).astype(np.int32)
    out = model.prefill(params, {"tokens": jnp.asarray(doc)[None]},
                        capture_hidden=True)
    mgr.save_prefill(f"doc{d}", doc, out)
    docs[f"doc{d}"] = doc
print(f"ingested {len(docs)} contexts offline "
      f"({store.bytes_used / 1e6:.1f} MB hidden-state cache)")

# --- online Q&A ----------------------------------------------------------
engine = InferenceEngine(model, params, mgr, max_batch=2, max_seq=256,
                         prefill_chunk=16)
full_cfg = get_arch("llama2-7b")      # paper-scale TTFT estimates
for i, r in enumerate(leval_trace(4, seed=1, n_contexts=2)):
    doc_id = f"doc{int(r.session_id[3:]) % 2}"
    q = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
    engine.submit(Request(doc_id, q, max_new_tokens=4))
    engine.run()
    seq = engine.sessions[doc_id]
    n_hist = seq.history_len
    sched = mgr.plan(8192)
    est = {m: ttft(full_cfg, 8192, 64, PAPER_A100, s) for m, s in (
        ("hcache", sched.methods),
        ("kv_offload", ["kv"] * full_cfg.n_layers),
        ("recompute", ["recompute"] * full_cfg.n_layers))}
    print(f"q{i} on {doc_id}: restored {n_hist} tokens, answer "
          f"{seq.generated}; paper-scale TTFT @8k ctx: "
          + " ".join(f"{k}={v * 1e3:.0f}ms" for k, v in est.items()))
    engine.sessions.pop(doc_id)       # evict between questions
