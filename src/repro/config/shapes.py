"""Assigned input shapes.

Each architecture is exercised against all four LM shapes; ``decode_*`` and
``long_*`` lower ``serve_step`` (one new token against a KV cache of
``seq_len``), not ``train_step``.  ``long_500k`` runs only for sub-quadratic
archs (ssm / hybrid) — the skip list lives here so the dry-run, roofline and
docs all agree on it.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.config.arch import ArchConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", 4096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32768, 128, "decode")
LONG_500K = InputShape("long_500k", 524288, 1, "decode")
# extra (not part of the assigned 40-cell grid): the paper's restoration op
# at production scale — 32 sessions × 32k-token histories
RESTORE_32K = InputShape("restore_32k", 32768, 32, "restore")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES + (RESTORE_32K,)}

# Families with a sub-quadratic (state-space / linear-time) sequence path.
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def shape_applicable(cfg: ArchConfig, shape: InputShape) -> Optional[str]:
    """Return None if the (arch, shape) cell runs, else a skip reason."""
    if shape.name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return ("pure full-attention arch: 500k decode has no sub-quadratic "
                "path (skip per assignment; see DESIGN.md)")
    return None


def cells_for(cfg: ArchConfig):
    """All applicable (shape, skip_reason) rows for an arch — 40-cell table."""
    return [(s, shape_applicable(cfg, s)) for s in ALL_SHAPES]
