from repro.config.arch import ArchConfig, AttnKind, BlockKind, reduced_for_smoke
from repro.config.hardware import PROFILES, TPU_V5E, HardwareProfile
from repro.config.shapes import (ALL_SHAPES, DECODE_32K, LONG_500K, PREFILL_32K,
                                 SHAPES_BY_NAME, TRAIN_4K, InputShape,
                                 cells_for, shape_applicable)

__all__ = [
    "ArchConfig", "AttnKind", "BlockKind", "reduced_for_smoke",
    "PROFILES", "TPU_V5E", "HardwareProfile",
    "ALL_SHAPES", "SHAPES_BY_NAME", "InputShape", "TRAIN_4K", "PREFILL_32K",
    "DECODE_32K", "LONG_500K", "cells_for", "shape_applicable",
]
