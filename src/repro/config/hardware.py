"""Hardware profiles.

Two uses:
  1. Roofline analysis of the compiled dry-run (TPU v5e constants).
  2. The HCache cost model / bubble-free scheduler, which needs
     (FLOPS, host-link BW, storage BW) tuples — including the paper's own
     GPU platforms so the analytical replication of the paper's figures uses
     the paper's numbers.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    name: str
    flops: float                 # peak dense FLOP/s (bf16/fp16)
    hbm_bw: float                # bytes/s on-chip HBM
    interconnect_bw: float       # bytes/s per ICI/NVLink link
    host_link_bw: float          # bytes/s accelerator<->host (PCIe / v5e host DMA)
    storage_bw: float            # bytes/s aggregate storage backend read BW
    hbm_capacity: float          # bytes per chip
    chips: int = 1
    # fixed per-device-dispatch cost charged to every compute-stream task
    # in the restoration replay (kernel launch + host-side framework
    # overhead). 0.0 keeps the paper's pure-bandwidth/FLOPs model; the
    # grouped restoration path amortizes this over group_size layers —
    # see benchmarks/bench_restore_batch.py for the knob's measurable
    # effect on makespan.
    dispatch_overhead: float = 0.0
    # tensor-parallel mesh width the restoration compute runs SPMD over
    # (DESIGN.md §16): projection FLOPs divide across the shards, the
    # dispatch overhead is charged once per launch (one XLA program, not
    # one per device). 1 = the classic single-device model.
    mesh_devices: int = 1

    def with_mesh(self, tp: int) -> "HardwareProfile":
        """A copy priced for a ``tp``-wide tensor-parallel mesh. The name
        changes too, so profiles for different meshes never alias in
        caches keyed by profile identity."""
        tp = max(int(tp), 1)
        if tp == self.mesh_devices:
            return self
        base = self.name.split("-tp")[0]
        return dataclasses.replace(
            self, name=base if tp == 1 else f"{base}-tp{tp}",
            mesh_devices=tp)

    def derated(self, *, storage: float = 1.0, host_link: float = 1.0,
                flops: float = 1.0,
                dispatch_overhead: float = None) -> "HardwareProfile":
        """A copy with bandwidths/FLOPs scaled and an optional dispatch
        overhead — the shape real hardware diverges from its datasheet in
        (shared PCIe lanes, filesystem overhead on the SSDs, sustained
        vs peak GEMM throughput). Every profile here is a guess until
        the online profiler (core/profiler.py) measures it; derated
        copies stand in for "what the machine actually does" in
        calibration tests and bench_sched."""
        return dataclasses.replace(
            self, name=self.name + "-derated",
            storage_bw=self.storage_bw * storage,
            host_link_bw=self.host_link_bw * host_link,
            flops=self.flops * flops,
            dispatch_overhead=(self.dispatch_overhead
                               if dispatch_overhead is None
                               else dispatch_overhead))


TB = 1e12
GB = 1e9

# --- TPU target (assignment constants) --------------------------------------
TPU_V5E = HardwareProfile(
    name="tpu_v5e",
    flops=197e12,
    hbm_bw=819 * GB,
    interconnect_bw=50 * GB,
    host_link_bw=32 * GB,
    storage_bw=4 * 6.9 * GB,     # same 4×PM9A3 backend as the paper testbed
    hbm_capacity=16 * GB,
)

# --- paper platforms (Table 2; FP16 FLOPS, PCIe transmission) ----------------
PAPER_A100 = HardwareProfile("a100", 312e12, 2039 * GB, 600 * GB, 32 * GB,
                             4 * 6.9 * GB, 40 * GB)
PAPER_A30 = HardwareProfile("a30", 165e12, 933 * GB, 200 * GB, 32 * GB,
                            4 * 6.9 * GB, 24 * GB)
PAPER_4090 = HardwareProfile("4090", 330e12, 1008 * GB, 64 * GB, 32 * GB,
                             4 * 6.9 * GB, 24 * GB)
PAPER_L20 = HardwareProfile("l20", 120e12, 864 * GB, 64 * GB, 32 * GB,
                            4 * 6.9 * GB, 48 * GB)
PAPER_H800 = HardwareProfile("h800", 990e12, 3350 * GB, 400 * GB, 64 * GB,
                             4 * 6.9 * GB, 80 * GB)

PROFILES = {p.name: p for p in
            (TPU_V5E, PAPER_A100, PAPER_A30, PAPER_4090, PAPER_L20, PAPER_H800)}

# MXU efficiency assumed for the cost model's GEMM estimates (cuBLAS/MXU
# sustained fraction on well-shaped GEMMs).
GEMM_EFFICIENCY = 0.65

# Storage devices for the chunk store simulation (paper's PM9A3).
SSD_READ_BW = 6.9 * GB
SSD_WRITE_BW = 4.0 * GB
DRAM_BW = 80 * GB

# Cross-host NIC link defaults for the distributed chunk store (100 GbE
# per host shard; RTT covers the request round-trip + kernel stack).
NIC_BW = 12.5 * GB
NIC_RTT = 30e-6

# TPU-native chunk size: 128 tokens (lane-aligned), vs the paper's 64.
TPU_CHUNK_TOKENS = 128
