"""Architecture configuration.

One dataclass covers every assigned family:

  dense GQA transformers   (qwen2, qwen2.5, starcoder2, gemma2)
  MoE transformers         (granite-moe, grok-1)
  pure SSM                 (falcon-mamba, Mamba1)
  hybrid SSM+attention     (zamba2, Mamba2 + shared attention blocks)
  encoder-decoder          (whisper, conv frontend stubbed)
  VLM backbone             (internvl2, ViT frontend stubbed)

The config is *static* metadata: model builders read it at trace time, the
cost model reads it for restoration analysis, and the dry-run reads it to
construct input ShapeDtypeStructs.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Optional, Sequence


class BlockKind(str, enum.Enum):
    """Kind of a residual block in the stack."""

    ATTENTION = "attention"
    MAMBA1 = "mamba1"
    MAMBA2 = "mamba2"


class AttnKind(str, enum.Enum):
    GLOBAL = "global"          # full causal attention
    LOCAL = "local"            # sliding-window causal attention
    ENCODER = "encoder"        # bidirectional (whisper encoder)
    CROSS = "cross"            # cross attention (whisper decoder)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """Static description of one architecture."""

    name: str
    family: str                              # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    # --- attention details -------------------------------------------------
    head_dim: Optional[int] = None           # default d_model // n_heads
    qkv_bias: bool = False                   # qwen2 family
    rope_theta: float = 10000.0
    use_rope: bool = True                    # whisper uses learned/sinusoidal positions
    local_window: Optional[int] = None       # gemma2 sliding window
    layer_pattern: Optional[str] = None      # e.g. "LG" repeated (gemma2), None=all global
    logit_softcap: Optional[float] = None    # gemma2 final-logit softcap
    attn_softcap: Optional[float] = None     # gemma2 attention softcap
    # --- FFN ----------------------------------------------------------------
    ffn_activation: str = "silu"             # silu | gelu | relu (glu except whisper)
    ffn_glu: bool = True                     # gated linear unit (llama-style)
    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0                       # 0 => dense FFN
    experts_per_token: int = 0
    moe_shared_ff: int = 0                   # granite has none; reserved
    # --- SSM ----------------------------------------------------------------
    ssm_state: int = 0                       # mamba d_state
    ssm_conv: int = 4                        # causal conv width
    ssm_expand: int = 2                      # mamba inner expansion
    ssm_headdim: int = 64                    # mamba2 head dim
    # --- hybrid (zamba2) ----------------------------------------------------
    hybrid_attn_every: int = 0               # shared attn block every k mamba blocks
    # --- enc-dec (whisper) --------------------------------------------------
    encoder_layers: int = 0                  # whisper: same count as decoder
    is_encoder_decoder: bool = False
    max_source_positions: int = 0            # whisper encoder length after conv
    # --- embeddings / norms --------------------------------------------------
    norm: str = "rmsnorm"                    # rmsnorm | layernorm
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    embedding_scale: bool = False            # gemma multiplies by sqrt(d_model)
    post_attn_norm: bool = False             # gemma2 extra norms
    # --- modality frontend stub ----------------------------------------------
    frontend: Optional[str] = None           # "audio_conv" | "vit_patch" | None
    frontend_dim: int = 0                    # raw feature dim fed to the stub
    # --- source provenance ---------------------------------------------------
    source: str = ""

    # ------------------------------------------------------------------ props
    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // max(self.n_heads, 1)

    @property
    def kv_dim(self) -> int:
        """Per-token, per-layer KV width of ONE of K or V (elements)."""
        return self.n_kv_heads * self.head_dim_

    @property
    def is_attention_free(self) -> bool:
        return self.n_heads == 0

    @property
    def is_mha(self) -> bool:
        return self.n_heads > 0 and self.n_kv_heads == self.n_heads

    def block_kinds(self) -> Sequence[BlockKind]:
        """Kind of each block in the main (decoder) stack, in order."""
        if self.family == "ssm":
            return [BlockKind.MAMBA1] * self.n_layers
        if self.family == "hybrid":
            kinds = []
            for i in range(self.n_layers):
                if self.hybrid_attn_every and (i % self.hybrid_attn_every
                                               == self.hybrid_attn_every - 1):
                    kinds.append(BlockKind.ATTENTION)
                else:
                    kinds.append(BlockKind.MAMBA2)
            return kinds
        return [BlockKind.ATTENTION] * self.n_layers

    def attn_kinds(self) -> Sequence[AttnKind]:
        """For attention blocks only: local/global pattern (gemma2)."""
        if not self.layer_pattern:
            return [AttnKind.GLOBAL] * self.n_layers
        pat = self.layer_pattern
        out = []
        for i in range(self.n_layers):
            out.append(AttnKind.LOCAL if pat[i % len(pat)] == "L" else AttnKind.GLOBAL)
        return out

    # ------------------------------------------------------------- parameters
    def param_count(self) -> int:
        """Total parameter count (embedding included once if tied)."""
        d, hd = self.d_model, self.head_dim_
        n_q = self.n_heads * hd
        n_kv = self.n_kv_heads * hd
        per_layer = 0
        kinds = self.block_kinds()
        attn_kinds = [k for k in kinds if k == BlockKind.ATTENTION]
        for kind in kinds:
            if kind == BlockKind.ATTENTION:
                attn = d * n_q + 2 * d * n_kv + n_q * d
                if self.qkv_bias:
                    attn += n_q + 2 * n_kv
                per_layer += attn + self._ffn_params()
            else:
                per_layer += self._mamba_params(kind)
        total = per_layer
        # encoder stack (whisper): MHA + non-GLU FFN, plus cross-attn in decoder
        if self.is_encoder_decoder:
            enc_attn = 4 * d * d
            enc_ffn = 2 * d * self.d_ff
            total += self.encoder_layers * (enc_attn + enc_ffn)
            total += self.n_layers * (4 * d * d)  # decoder cross-attention
        emb = self.vocab_size * d
        total += emb if self.tie_embeddings else 2 * emb
        return total

    def _ffn_params(self) -> int:
        d, f = self.d_model, self.d_ff
        one_ffn = (3 if self.ffn_glu else 2) * d * f
        if self.n_experts:
            return self.n_experts * one_ffn + d * self.n_experts  # + router
        return one_ffn

    def _mamba_params(self, kind: BlockKind) -> int:
        d = self.d_model
        inner = self.ssm_expand * d
        if kind == BlockKind.MAMBA2:
            n_heads = inner // self.ssm_headdim
            in_proj = d * (2 * inner + 2 * self.ssm_state + n_heads)
            return in_proj + inner * self.ssm_conv + n_heads + inner * d
        # mamba1
        dt_rank = max(d // 16, 1)
        in_proj = d * 2 * inner
        x_proj = inner * (dt_rank + 2 * self.ssm_state)
        dt_proj = dt_rank * inner + inner
        out_proj = inner * d
        conv = inner * self.ssm_conv
        return in_proj + x_proj + dt_proj + out_proj + conv + inner * self.ssm_state + inner

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if not self.n_experts:
            return self.param_count()
        dense_ffn = (3 if self.ffn_glu else 2) * self.d_model * self.d_ff
        inactive = (self.n_experts - self.experts_per_token) * dense_ffn
        return self.param_count() - self.n_layers * inactive

    # ------------------------------------------------------- HCache geometry
    def hidden_bytes_per_token_layer(self, dtype_bytes: int = 2) -> int:
        return self.d_model * dtype_bytes

    def kv_bytes_per_token_layer(self, dtype_bytes: int = 2) -> int:
        return 2 * self.kv_dim * dtype_bytes

    def scaled(self, **overrides) -> "ArchConfig":
        """Reduced config of the same family (for smoke tests)."""
        return dataclasses.replace(self, **overrides)


def reduced_for_smoke(cfg: ArchConfig) -> ArchConfig:
    """Tiny same-family config: few layers, narrow width, small vocab."""
    n_heads = min(cfg.n_heads, 4) if cfg.n_heads else 0
    ratio = max(cfg.n_heads // max(cfg.n_kv_heads, 1), 1) if cfg.n_heads else 1
    n_kv = max(n_heads // min(ratio, n_heads), 1) if n_heads else 0
    head_dim = 16
    d_model = max(n_heads, 2) * head_dim if n_heads else 64
    layers = 4
    if cfg.family == "hybrid":
        layers = 2 * max(cfg.hybrid_attn_every, 2)
    over = dict(
        n_layers=layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim if n_heads else None,
        d_ff=4 * d_model if not cfg.n_experts else 32,
        vocab_size=256,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_headdim=16 if cfg.family in ("hybrid",) else cfg.ssm_headdim,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        experts_per_token=min(cfg.experts_per_token, 2) if cfg.n_experts else 0,
        encoder_layers=2 if cfg.is_encoder_decoder else 0,
        max_source_positions=64 if cfg.is_encoder_decoder else 0,
        local_window=16 if cfg.local_window else None,
        frontend_dim=8 if cfg.frontend else 0,
    )
    return cfg.scaled(**over)
