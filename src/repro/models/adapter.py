"""FamilyAdapter — the per-family seam between models and serving.

Every model family (lm / ssm / hybrid / encdec) differs in the same few
places: how a prefill chunk is built and absorbed into the KV cache, how
a batched decode step is invoked, which cache keys hold the stacked
attention KV, and which pieces of a prefill output are persisted by the
HCache save path. Before this module those differences lived as
``model.kind == ...`` switches scattered through ``serving/engine.py``,
``models/model.py`` and ``core/hcache.py``; they now live here, one
class per family, so the engine and the manager are family-agnostic
(DESIGN.md §11).

The adapter deliberately does NOT import ``repro.serving``: the serving
seam methods are duck-typed over the engine's ``SequenceState`` and the
backend's ``CacheView`` handle (same convention as ``core/capacity.py``),
so models stay importable without the serving stack.

Capability flags
----------------
``chunkable``           the prompt may be split into SplitFuse chunks
                        (attention-history models only: a chunk attends
                        over the already-written prefix via ``hist_kv``;
                        ssm/hybrid compute their recurrent states in one
                        scan and have no state carry-in, so their prefill
                        must stay unchunked — see the regression test in
                        tests/test_encdec_engine.py);
``supports_resume``     a paused/stored session can resume by prefilling
                        new tokens on top of restored state (lm: prefill
                        with ``hist_kv``; encdec: decoder prefill with
                        restored self-KV history + cross state from the
                        view). ssm/hybrid resume would restart recurrent
                        states from zero, so they are not preemptable;
``supports_paged``      the block-table paged KV backend applies;
``supports_recompute``  the restoration scheduler may assign recompute-
                        from-tokens (undefined for interleaved-recurrent
                        and enc-dec stacks);
``kv_names``            (k, v) cache keys of the stacked attention KV;
``n_state_blobs``       whole-object state blobs in the restore graph;
``has_cross``           restoration includes the encoder-side tasks
                        (``io_enc`` read + ``project_cross`` compute).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np


class FamilyAdapter:
    kind: str = "?"
    chunkable: bool = False
    supports_resume: bool = False
    supports_paged: bool = False
    supports_recompute: bool = False
    kv_names: Optional[Tuple[str, str]] = None
    n_state_blobs: int = 0
    has_cross: bool = False

    def __init__(self, model):
        self.model = model

    # ------------------------------------------------------- model compute
    def init(self, rng):
        raise NotImplementedError

    def forward(self, params, batch, *, skip_logits=False):
        raise NotImplementedError

    def prefill(self, params, batch, *, capture_hidden=False,
                hist_kv=None, hist_len=None):
        raise NotImplementedError

    def decode_step_full(self, params, cache, tokens):
        """(logits, new cache, per-layer hidden states)."""
        raise NotImplementedError

    def decode_step_paged(self, params, cache, tokens):
        raise NotImplementedError(
            f"paged decode requires an lm-family model; "
            f"{self.model.cfg.name} is {self.kind!r}")

    def restore_kv_from_hidden(self, params, hidden, *, positions):
        raise ValueError(f"{self.model.cfg.name}: attention-free arch; use "
                         "restore_ssm_states (ssm-rescan)")

    def restore_ssm_states(self, params, hidden):
        raise ValueError(f"{self.model.cfg.name}: no SSM states")

    # -------------------------------------------------- serving: prefill
    def prefill_chunk(self, params, seq, chunk, hist, *, capture_hidden):
        """Run one prefill chunk for a resident sequence. ``chunk`` is a
        1-D token array, ``hist`` the tokens already in the sequence's
        ``CacheView`` (restored history + earlier chunks)."""
        raise NotImplementedError

    def absorb_prefill(self, view, out, n, hist) -> None:
        """Map a prefill output's cache pieces to ``CacheView`` writes
        (``n`` chunk tokens landing at offset ``hist``). The caller owns
        ``view.set_length``."""
        raise NotImplementedError

    def decode_hidden(self, hidden):
        """The (L, B, 1, D) hidden stack to persist from a decode step's
        raw hidden output."""
        return hidden

    # ------------------------------------------------ serving: save naming
    def kv_row(self, li: int) -> int:
        """Stacked-KV row of global layer ``li`` (the row order sinks,
        snapshots and prefill outputs share)."""
        return li

    def prefill_hidden(self, out, li: int) -> np.ndarray:
        """Layer ``li``'s saved hidden states (S, D) from a B=1 prefill
        output."""
        return np.asarray(out["hidden"][li][0])

    def prefill_kv(self, out, li: int):
        """Layer ``li``'s (k, v) from a B=1 prefill output, (S, Kv, hd)."""
        idx = self.kv_row(li)
        return (np.asarray(out["kv"][0][idx][0]),
                np.asarray(out["kv"][1][idx][0]))


# ------------------------------------------------------------------- lm
class LMAdapter(FamilyAdapter):
    kind = "lm"
    chunkable = True
    supports_resume = True
    supports_paged = True
    supports_recompute = True
    kv_names = ("k", "v")

    def init(self, rng):
        from repro.models import transformer as tfm
        return tfm.init_lm(rng, self.model.h)

    def forward(self, params, batch, *, skip_logits=False):
        from repro.models import transformer as tfm
        return tfm.lm_forward(params, batch["tokens"], self.model.h,
                              patch_embeds=batch.get("patches"),
                              skip_logits=skip_logits)

    def prefill(self, params, batch, *, capture_hidden=False,
                hist_kv=None, hist_len=None):
        from repro.models import transformer as tfm
        return tfm.lm_forward(params, batch["tokens"], self.model.h,
                              patch_embeds=batch.get("patches"),
                              hist_kv=hist_kv, hist_len=hist_len,
                              capture_hidden=capture_hidden, emit_kv=True,
                              final_logits_only=True)

    def decode_step_full(self, params, cache, tokens):
        from repro.models import transformer as tfm
        return tfm.lm_decode_step(params, cache, tokens, self.model.h)

    def decode_step_paged(self, params, cache, tokens):
        from repro.models import transformer as tfm
        return tfm.lm_decode_step_paged(params, cache, tokens, self.model.h)

    def restore_kv_from_hidden(self, params, hidden, *, positions):
        from repro.models import transformer as tfm
        return tfm.lm_restore_kv(params, hidden, self.model.h,
                                 positions=positions)

    def prefill_chunk(self, params, seq, chunk, hist, *, capture_hidden):
        hist_kv = seq.view.gather_hist(hist) if hist else None
        batch = {"tokens": jnp.asarray(chunk, jnp.int32)[None]}
        return self.prefill(params, batch, capture_hidden=capture_hidden,
                            hist_kv=hist_kv,
                            hist_len=hist if hist_kv is not None else None)

    def absorb_prefill(self, view, out, n, hist):
        k, v = out["kv"]
        view.write_kv(k, v, hist)

    def kv_row(self, li):
        from repro.config.arch import BlockKind
        return [i for i, bk in enumerate(self.model.cfg.block_kinds())
                if bk == BlockKind.ATTENTION].index(li)


# ------------------------------------------------------------------ ssm
class SSMAdapter(FamilyAdapter):
    kind = "ssm"
    n_state_blobs = 1
    kv_names = None

    def init(self, rng):
        from repro.models import ssm as ssm_mod
        return ssm_mod.init_ssm_lm(rng, self.model.h)

    def forward(self, params, batch, *, skip_logits=False):
        from repro.models import ssm as ssm_mod
        return ssm_mod.ssm_forward(params, batch["tokens"], self.model.h,
                                   skip_logits=skip_logits)

    def prefill(self, params, batch, *, capture_hidden=False,
                hist_kv=None, hist_len=None):
        from repro.models import ssm as ssm_mod
        return ssm_mod.ssm_forward(params, batch["tokens"], self.model.h,
                                   capture_hidden=capture_hidden,
                                   emit_state=True, final_logits_only=True)

    def decode_step_full(self, params, cache, tokens):
        from repro.models import ssm as ssm_mod
        return ssm_mod.ssm_decode_step(params, cache, tokens, self.model.h)

    def restore_ssm_states(self, params, hidden):
        from repro.models import ssm as ssm_mod
        return ssm_mod.ssm_restore_states(params, hidden, self.model.h)

    def prefill_chunk(self, params, seq, chunk, hist, *, capture_hidden):
        return self.prefill(
            params, {"tokens": jnp.asarray(chunk, jnp.int32)[None]},
            capture_hidden=capture_hidden)

    def absorb_prefill(self, view, out, n, hist):
        conv, ssmst = out["states"]
        view.write_states({"conv": conv, "ssm": ssmst})

    def prefill_kv(self, out, li):
        raise ValueError(f"{self.model.cfg.name}: attention-free arch "
                         "has no KV to persist")


# --------------------------------------------------------------- hybrid
class HybridAdapter(FamilyAdapter):
    kind = "hybrid"
    # NOT chunkable: hybrid_forward computes every mamba layer's conv/ssm
    # state in one scan over the full chunk with no state carry-in — a
    # second chunk would restart the recurrence from zero. The whole
    # prompt must prefill in one engine step (regression-tested).
    kv_names = ("attn_k", "attn_v")
    n_state_blobs = 1

    def init(self, rng):
        from repro.models import hybrid
        return hybrid.init_hybrid(rng, self.model.h)

    def forward(self, params, batch, *, skip_logits=False):
        from repro.models import hybrid
        return hybrid.hybrid_forward(params, batch["tokens"], self.model.h,
                                     skip_logits=skip_logits)

    def prefill(self, params, batch, *, capture_hidden=False,
                hist_kv=None, hist_len=None):
        from repro.models import hybrid
        return hybrid.hybrid_forward(params, batch["tokens"], self.model.h,
                                     capture_hidden=capture_hidden,
                                     emit_state=True, final_logits_only=True)

    def decode_step_full(self, params, cache, tokens):
        from repro.models import hybrid
        return hybrid.hybrid_decode_step(params, cache, tokens, self.model.h)

    def restore_kv_from_hidden(self, params, hidden, *, positions):
        from repro.models import hybrid
        return hybrid.hybrid_restore_attn_kv(params, hidden, self.model.h,
                                             positions=positions)

    def restore_ssm_states(self, params, hidden):
        from repro.models import hybrid
        return hybrid.hybrid_restore_mamba_states(params, hidden,
                                                  self.model.h)

    def prefill_chunk(self, params, seq, chunk, hist, *, capture_hidden):
        return self.prefill(
            params, {"tokens": jnp.asarray(chunk, jnp.int32)[None]},
            capture_hidden=capture_hidden)

    def absorb_prefill(self, view, out, n, hist):
        k, v = out["kv"]
        view.write_kv(k, v, hist)
        conv, ssmst = out["mamba_states"]
        view.write_states({"conv": conv, "ssm": ssmst})

    def decode_hidden(self, hidden):
        return hidden[1]                       # (mamba_hidden, attn_hidden)

    def kv_row(self, li):
        return li // self.model.h.k

    def prefill_hidden(self, out, li):
        return np.asarray(out["attn_hidden"][self.kv_row(li)][0])


# --------------------------------------------------------------- encdec
class EncDecAdapter(FamilyAdapter):
    kind = "encdec"
    # chunkable: the encoder pass and the cross-KV projection run once,
    # on the FIRST chunk of a residency (hist == 0); later chunks — and
    # resume / multi-round prefill — attend over the self-KV history and
    # the cross state already sitting in the view (the hist > 0 path
    # below), so a long decoder prompt no longer monopolizes an engine
    # step: it interleaves with the decode batch like the LM family.
    chunkable = True
    supports_resume = True
    # the decoder self-KV region pages like an lm cache (the scatter /
    # block-table gather never touch the cross side); the paged serving
    # backend pairs the pool with whole-object cross state
    supports_paged = True
    kv_names = ("self_k", "self_v")
    has_cross = True

    def init(self, rng):
        from repro.models import encdec
        return encdec.init_encdec(rng, self.model.h)

    def forward(self, params, batch, *, skip_logits=False):
        from repro.models import encdec
        enc_out, _ = encdec.encode(params, batch["frames"], self.model.h)
        return encdec.decode_prefill(params, batch["tokens"], enc_out,
                                     self.model.h, skip_logits=skip_logits)

    def prefill(self, params, batch, *, capture_hidden=False,
                hist_kv=None, hist_len=None):
        from repro.models import encdec
        enc_out, enc_hidden = encdec.encode(params, batch["frames"],
                                            self.model.h,
                                            capture_hidden=capture_hidden)
        out = encdec.decode_prefill(params, batch["tokens"], enc_out,
                                    self.model.h,
                                    capture_hidden=capture_hidden,
                                    emit_kv=True, final_logits_only=True)
        out["enc_out"] = enc_out
        out["enc_hidden"] = enc_hidden
        return out

    def decode_step_full(self, params, cache, tokens):
        from repro.models import encdec
        return encdec.decode_step(params, cache, tokens, self.model.h)

    def decode_step_paged(self, params, cache, tokens):
        from repro.models import encdec
        return encdec.decode_step_paged(params, cache, tokens,
                                        self.model.h)

    def restore_kv_from_hidden(self, params, hidden, *, positions):
        from repro.models import encdec
        return encdec.restore_self_kv(params, hidden, self.model.h,
                                      positions=positions)

    def prefill_chunk(self, params, seq, chunk, hist, *, capture_hidden):
        from repro.models import encdec
        toks = jnp.asarray(chunk, jnp.int32)[None]
        if hist:
            # resume / round-N prefill: no encoder pass — self-attention
            # history and the cross state come from the slot's view
            hk, hv = seq.view.gather_hist(hist)
            ck, cv, _ = seq.view.cross_state()
            return encdec.decode_prefill(
                params, toks, None, self.model.h,
                capture_hidden=capture_hidden, emit_kv=True,
                final_logits_only=True, hist_kv=(hk, hv), hist_len=hist,
                cross=(ck, cv), pos_offset=hist)
        frames = seq.request.frames
        if frames is None:
            raise ValueError(
                f"enc-dec session {seq.request.session_id!r} has no stored "
                "state and no Request.frames — a first-residency whisper "
                "request must carry its encoder frame embeddings")
        frames = jnp.asarray(frames)
        if frames.ndim == 2:
            frames = frames[None]
        return self.prefill(params, {"tokens": toks, "frames": frames},
                            capture_hidden=capture_hidden)

    def absorb_prefill(self, view, out, n, hist):
        k, v = out["kv"]
        view.write_kv(k, v, hist)
        if hist == 0:
            # first residency: the cross context lands whole; on resume
            # it is already in the view (restored or never evicted)
            ck, cv = out["cross_kv"]
            view.write_states({"cross_k": ck, "cross_v": cv,
                               "enc_len": int(ck.shape[2])})


ADAPTERS = {"lm": LMAdapter, "ssm": SSMAdapter, "hybrid": HybridAdapter,
            "encdec": EncDecAdapter}


def make_adapter(model) -> FamilyAdapter:
    return ADAPTERS[model.kind](model)
