"""Token embedding + (tied) output head.

The table is vocab-sharded (vocab-parallel logits); lookups use jnp.take —
the SPMD partitioner lowers the sharded-dim gather to a local gather +
all-reduce. See DESIGN.md (hillclimb candidate if the roofline shows the
lookup collective dominating).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import ShardingRules, constrain
from repro.models.module import box, normal_init


VOCAB_PAD = 128   # Megatron-style: physical vocab padded for TP divisibility


def padded_vocab(vocab: int) -> int:
    return (vocab + VOCAB_PAD - 1) // VOCAB_PAD * VOCAB_PAD


def init_embedding(rng, vocab: int, d_model: int, dtype, tie: bool,
                   max_positions: int = 0, learned_positions: bool = False):
    re, ru, rp = jax.random.split(rng, 3)
    vp = padded_vocab(vocab)
    p = {"table": box(normal_init(re, (vp, d_model), dtype, 1.0),
                      "vocab", "d_model")}
    if not tie:
        p["unembed"] = box(
            normal_init(ru, (d_model, vp), dtype, d_model ** -0.5),
            "d_model", "vocab")
    if learned_positions:
        p["positions"] = box(
            normal_init(rp, (max_positions, d_model), dtype, 0.02),
            None, "d_model")
    return p


def embed_tokens(p: dict, ids, rules: ShardingRules, *, scale: bool,
                 d_model: int):
    x = jnp.take(p["table"], ids, axis=0)
    if scale:
        x = x * jnp.asarray(d_model ** 0.5, x.dtype)
    return constrain(x, rules, "batch", "seq", "d_model")


def logits(p: dict, x, rules: ShardingRules, *,
           softcap: Optional[float] = None, true_vocab: Optional[int] = None):
    if "unembed" in p:
        out = jnp.einsum("bsd,dv->bsv", x, p["unembed"])
    else:
        out = jnp.einsum("bsd,vd->bsv", x, p["table"])
    out = constrain(out, rules, "batch", "seq", "vocab")
    if softcap is not None:
        out = (jnp.tanh(out.astype(jnp.float32) / softcap) * softcap)
    vp = out.shape[-1]
    if true_vocab is not None and vp != true_vocab:
        # padded vocab columns must never win softmax/argmax
        mask = jnp.arange(vp) < true_vocab
        out = jnp.where(mask, out, jnp.asarray(-1e30, out.dtype))
    return out


def positional(p: dict, positions):
    """Learned absolute positions (whisper decoder / OPT)."""
    return jnp.take(p["positions"], positions, axis=0)
