"""RMSNorm / LayerNorm (pre-norm transformer style), fp32 internals."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.module import bias_param, scale_param


def init_norm(kind: str, d: int, dtype) -> dict:
    p = {"scale": scale_param(d, dtype, None)}
    if kind == "layernorm":
        p["bias"] = bias_param(d, dtype, None)
    return p


def apply_norm(p: dict, x, kind: str, eps: float):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jnp.reciprocal(jnp.sqrt(var + eps))
        out = out * p["scale"].astype(jnp.float32)
    elif kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        out = (xf - mu) * jnp.reciprocal(jnp.sqrt(var + eps))
        out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        raise ValueError(kind)
    return out.astype(x.dtype)
