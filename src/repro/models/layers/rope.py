"""Rotary position embeddings (llama-style half-split rotation) and
sinusoidal absolute positions (whisper encoder)."""
from __future__ import annotations

import jax.numpy as jnp


def rope_angles(positions, head_dim: int, theta: float):
    """positions (..., S) -> cos, sin of shape (..., S, head_dim//2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, head_dim); positions: (B, S) token positions."""
    head_dim = x.shape[-1]
    cos, sin = rope_angles(positions, head_dim, theta)   # (B, S, hd/2)
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n_pos: int, d: int, dtype=jnp.float32):
    """Whisper-style sinusoidal table (n_pos, d)."""
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (jnp.log(10000.0) / max(half - 1, 1)))
    ang = jnp.arange(n_pos, dtype=jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)
