"""Feed-forward: GLU (llama/gemma style) and plain 2-layer (whisper/opt/
starcoder2) variants, TP-sharded on d_ff."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import ShardingRules, constrain
from repro.models.module import dense_param

ACTS = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
}


def init_mlp(rng, d_model: int, d_ff: int, glu: bool, dtype) -> dict:
    r1, r2, r3 = jax.random.split(rng, 3)
    p = {
        "w_up": dense_param(r1, d_model, d_ff, dtype, "d_model", "d_ff"),
        "w_down": dense_param(r2, d_ff, d_model, dtype, "d_ff", "d_model"),
    }
    if glu:
        p["w_gate"] = dense_param(r3, d_model, d_ff, dtype, "d_model", "d_ff")
    return p


def apply_mlp(p: dict, x, activation: str, rules: ShardingRules):
    act = ACTS[activation]
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    up = constrain(up, rules, "batch", "seq", "d_ff")
    if "w_gate" in p:
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        up = act(gate) * up
    else:
        up = act(up)
    out = jnp.einsum("bsf,fd->bsd", up, p["w_down"])
    return constrain(out, rules, "batch", "seq", "d_model")
