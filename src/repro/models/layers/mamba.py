"""Mamba1 (falcon-mamba) and Mamba2/SSD (zamba2) blocks.

TPU adaptation notes (DESIGN.md §2):
  * Mamba1's selective scan is evaluated **chunk-wise**: an outer
    ``lax.scan`` carries the (B, I, N) state across chunks while the inner
    per-chunk scan is wrapped in ``jax.checkpoint`` — backward memory is
    O(S/Q) boundary states instead of O(S) per-step states. This replaces
    the CUDA kernel's SRAM streaming.
  * Mamba2 uses the SSD chunked form: within-chunk attention-like matmuls
    (MXU-friendly) + an inter-chunk state recurrence of length S/Q.
  * Decode is a single-token state update (``kernels/ssm_update.py`` is the
    Pallas version; this file holds the jnp path/oracle).

Sharding: the inner dim (I) / SSD heads (H) are TP-sharded over ``model``;
states (B, I, N) / (B, H, P, N) shard the same dims.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import ShardingRules, constrain
from repro.models.layers.norm import apply_norm, init_norm
from repro.models.module import bias_param, box, dense_param, normal_init


# =============================================================== causal conv1d
def causal_conv1d(x, weight, bias, state=None):
    """Depthwise causal conv. x: (B,S,C), weight: (C,W).

    With ``state`` (B, W-1, C) the conv sees the previous inputs (decode /
    chunked prefill continuation). Returns (y, new_state)."""
    B, S, C = x.shape
    W = weight.shape[1]
    if state is None:
        state = jnp.zeros((B, W - 1, C), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)          # (B, S+W-1, C)
    y = sum(xp[:, w:w + S, :] * weight[:, w] for w in range(W))
    y = y + bias
    new_state = xp[:, S:, :] if W > 1 else state
    return y, new_state


# ==================================================================== Mamba 1
@dataclasses.dataclass(frozen=True)
class Mamba1Hyper:
    d_model: int
    d_state: int
    d_conv: int = 4
    expand: int = 2
    chunk: int = 128          # scan chunk (remat boundary)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return max(self.d_model // 16, 1)


def init_mamba1(rng, h: Mamba1Hyper, dtype) -> dict:
    r = jax.random.split(rng, 6)
    I, N, R = h.d_inner, h.d_state, h.dt_rank
    a_init = jnp.log(jnp.broadcast_to(
        jnp.arange(1, N + 1, dtype=jnp.float32), (I, N)))
    return {
        "in_proj": dense_param(r[0], h.d_model, 2 * I, dtype, "d_model",
                               "ssm_inner"),
        "conv_w": box(normal_init(r[1], (I, h.d_conv), dtype, h.d_conv ** -0.5),
                      "ssm_inner", "conv_w"),
        "conv_b": bias_param(I, dtype, "ssm_inner"),
        "x_proj": dense_param(r[2], I, R + 2 * N, dtype, "ssm_inner", None),
        "dt_proj": dense_param(r[3], R, I, dtype, "dt_rank", "ssm_inner",
                               R ** -0.5),
        "dt_bias": box(jnp.log(jnp.expm1(
            jnp.full((I,), 0.01, jnp.float32))).astype(dtype), "ssm_inner"),
        "a_log": box(a_init.astype(jnp.float32), "ssm_inner", "ssm_state"),
        "d_skip": box(jnp.ones((I,), dtype), "ssm_inner"),
        "out_proj": dense_param(r[4], I, h.d_model, dtype, "ssm_inner",
                                "d_model"),
    }


def _mamba1_scan_chunk(h_state, inputs):
    """One remat chunk: sequential scan over Q steps.

    h_state: (B, I, N) fp32. inputs: (dA, dBx, C) with shapes
    (B,Q,I,N), (B,Q,I,N), (B,Q,N)."""
    dA, dBx, Cm = inputs

    def step(hs, xs):
        da, dbx, c = xs                                   # (B,I,N),(B,I,N),(B,N)
        hs = da * hs + dbx
        y = jnp.einsum("bin,bn->bi", hs, c)
        return hs, y

    h_state, ys = jax.lax.scan(
        step, h_state,
        (dA.transpose(1, 0, 2, 3), dBx.transpose(1, 0, 2, 3),
         Cm.transpose(1, 0, 2)))
    return h_state, ys.transpose(1, 0, 2)                 # (B,Q,I)


def apply_mamba1(p: dict, x, h: Mamba1Hyper, rules: ShardingRules, *,
                 init_state=None, conv_state=None, remat_chunks: bool = True):
    """x: (B,S,D) -> (y (B,S,D), (conv_state, ssm_state))."""
    B, S, D = x.shape
    I, N, R = h.d_inner, h.d_state, h.dt_rank
    xz = jnp.einsum("bsd,di->bsi", x, p["in_proj"])
    xz = constrain(xz, rules, "batch", "seq", "ssm_inner")
    xi, z = jnp.split(xz, 2, axis=-1)
    xc, new_conv = causal_conv1d(xi, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc)
    proj = jnp.einsum("bsi,ir->bsr", xc, p["x_proj"])
    dt_low, Bm, Cm = jnp.split(proj, [R, R + N], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_low, p["dt_proj"])
        + p["dt_bias"]).astype(jnp.float32)               # (B,S,I)
    A = -jnp.exp(p["a_log"])                              # (I,N) fp32
    dA = jnp.exp(dt[..., None] * A)                       # (B,S,I,N)
    dBx = (dt * xc.astype(jnp.float32))[..., None] * Bm[..., None, :].astype(
        jnp.float32)                                      # (B,S,I,N)

    Q = min(h.chunk, S)
    n_chunks = (S + Q - 1) // Q
    padS = n_chunks * Q - S
    if padS:
        dA = jnp.pad(dA, ((0, 0), (0, padS), (0, 0), (0, 0)),
                     constant_values=1.0)
        dBx = jnp.pad(dBx, ((0, 0), (0, padS), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, padS), (0, 0)))
    h0 = (jnp.zeros((B, I, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    chunk_fn = (jax.checkpoint(_mamba1_scan_chunk) if remat_chunks
                else _mamba1_scan_chunk)

    def outer(hs, xs):
        return chunk_fn(hs, xs)

    reshaped = (
        dA.reshape(B, n_chunks, Q, I, N).transpose(1, 0, 2, 3, 4),
        dBx.reshape(B, n_chunks, Q, I, N).transpose(1, 0, 2, 3, 4),
        Cm.astype(jnp.float32).reshape(B, n_chunks, Q, N).transpose(1, 0, 2, 3),
    )
    h_final, ys = jax.lax.scan(outer, h0, reshaped)
    y = ys.transpose(1, 0, 2, 3).reshape(B, n_chunks * Q, I)[:, :S]
    y = y + xc.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = constrain(y.astype(x.dtype), rules, "batch", "seq", "ssm_inner")
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    return constrain(out, rules, "batch", "seq", "d_model"), (new_conv, h_final)


def decode_mamba1_step(p: dict, x, h: Mamba1Hyper, rules: ShardingRules, *,
                       conv_state, ssm_state):
    """Single-token decode. x: (B,1,D). States as returned by apply_mamba1."""
    out, (ncs, nss) = apply_mamba1(p, x, h, rules, init_state=ssm_state,
                                   conv_state=conv_state, remat_chunks=False)
    return out, (ncs, nss)


# ==================================================================== Mamba 2
@dataclasses.dataclass(frozen=True)
class Mamba2Hyper:
    d_model: int
    d_state: int
    head_dim: int = 64
    d_conv: int = 4
    expand: int = 2
    n_groups: int = 1
    chunk: int = 128

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def init_mamba2(rng, h: Mamba2Hyper, dtype) -> dict:
    r = jax.random.split(rng, 5)
    I, N, H, G = h.d_inner, h.d_state, h.n_heads, h.n_groups
    conv_ch = I + 2 * G * N
    return {
        "in_proj": dense_param(r[0], h.d_model, 2 * I + 2 * G * N + H, dtype,
                               "d_model", "ssm_inner"),
        "conv_w": box(normal_init(r[1], (conv_ch, h.d_conv), dtype,
                                  h.d_conv ** -0.5), "ssm_inner", "conv_w"),
        "conv_b": bias_param(conv_ch, dtype, "ssm_inner"),
        "dt_bias": box(jnp.log(jnp.expm1(
            jnp.full((H,), 0.01, jnp.float32))).astype(jnp.float32),
            "ssm_heads"),
        "a_log": box(jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
                     "ssm_heads"),
        "d_skip": box(jnp.ones((H,), jnp.float32), "ssm_heads"),
        "gate_norm": init_norm("rmsnorm", I, dtype)["scale"],
        "out_proj": dense_param(r[3], I, h.d_model, dtype, "ssm_inner",
                                "d_model"),
    }


def _ssd_chunk_tensors(xh, dt, A, Bm, Cm, Q):
    """Reshape (B,S,...) into per-chunk tensors for the SSD algorithm."""
    B, S = dt.shape[:2]
    nc = S // Q
    xh = xh.reshape(B, nc, Q, *xh.shape[2:])
    dt = dt.reshape(B, nc, Q, -1)
    Bm = Bm.reshape(B, nc, Q, *Bm.shape[2:])
    Cm = Cm.reshape(B, nc, Q, *Cm.shape[2:])
    return xh, dt, Bm, Cm, nc


def apply_mamba2(p: dict, x, h: Mamba2Hyper, rules: ShardingRules, *,
                 init_state=None, conv_state=None):
    """SSD chunked forward. x: (B,S,D) -> (y, (conv_state, ssm_state)).

    ssm_state: (B, H, P, N) fp32."""
    B, S, D = x.shape
    I, N, H, P, G = h.d_inner, h.d_state, h.n_heads, h.head_dim, h.n_groups
    proj = jnp.einsum("bsd,di->bsi", x, p["in_proj"])
    proj = constrain(proj, rules, "batch", "seq", "ssm_inner")
    z, xBC, dt_raw = jnp.split(proj, [I, 2 * I + 2 * G * N], axis=-1)
    xBC, new_conv = causal_conv1d(xBC, p["conv_w"], p["conv_b"], conv_state)
    xBC = jax.nn.silu(xBC)
    xi, Bm, Cm = jnp.split(xBC, [I, I + G * N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["a_log"])                                         # (H,)

    Q = min(h.chunk, S)
    padS = (Q - S % Q) % Q
    if padS:
        xi = jnp.pad(xi, ((0, 0), (0, padS), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, padS), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, padS), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, padS), (0, 0)))
    Sp = S + padS
    xh = xi.reshape(B, Sp, H, P)
    Bg = Bm.reshape(B, Sp, G, N).astype(jnp.float32)
    Cg = Cm.reshape(B, Sp, G, N).astype(jnp.float32)
    xh_c, dt_c, B_c, C_c, nc = _ssd_chunk_tensors(xh, dt, A, Bg, Cg, Q)

    a = dt_c * A                                           # (B,nc,Q,H) (<=0)
    a_cs = jnp.cumsum(a, axis=2)                           # within-chunk cumsum
    a_total = a_cs[:, :, -1, :]                            # (B,nc,H)

    # --- intra-chunk (attention-like) -------------------------------------
    # L[i,j] = exp(a_cs[i] - a_cs[j]) for i >= j
    seg = a_cs[:, :, :, None, :] - a_cs[:, :, None, :, :]  # (B,nc,Q,Q,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcqgn,bckgn->bcqkg", C_c, B_c)    # (B,nc,Q,Q,G)
    # broadcast groups over heads (G divides H)
    hpg = H // G
    dx = (dt_c[..., None] * xh_c.astype(jnp.float32))      # (B,nc,Q,H,P)
    scores_h = jnp.repeat(scores, hpg, axis=-1)            # (B,nc,Q,Q,H)
    M = scores_h * L.transpose(0, 1, 2, 3, 4)              # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", M, dx)

    # --- chunk states + inter-chunk recurrence -----------------------------
    decay_to_end = jnp.exp(a_total[:, :, None, :] - a_cs)  # (B,nc,Q,H)
    state_c = jnp.einsum("bcqgn,bcqhp->bchpn", B_c,
                         dx * decay_to_end[..., None])     # (B,nc,H,P,N)

    h0 = (jnp.zeros((B, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def chunk_rec(hs, xs):
        st, atot = xs                                      # (B,H,P,N), (B,H)
        prev = hs
        hs = jnp.exp(atot)[:, :, None, None] * hs + st
        return hs, prev

    h_final, h_prev = jax.lax.scan(
        chunk_rec, h0,
        (state_c.transpose(1, 0, 2, 3, 4), a_total.transpose(1, 0, 2)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)               # (B,nc,H,P,N)

    decay_from_start = jnp.exp(a_cs)                       # (B,nc,Q,H)
    # y_inter[q] = C[q] · (decay_from_start[q] * h_prev)
    y_inter = jnp.einsum("bcqgn,bchpn->bcqhp",
                         C_c, h_prev) * decay_from_start[..., None]

    y = (y_intra + y_inter).reshape(B, Sp, H, P)[:, :S]
    y = y + xh[:, :S].astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(B, S, I)
    # gated RMSNorm (mamba2 norm-before-gate)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jnp.reciprocal(jnp.sqrt(var + 1e-6)) * p["gate_norm"].astype(
        jnp.float32)
    y = constrain(y.astype(x.dtype), rules, "batch", "seq", "ssm_inner")
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    return constrain(out, rules, "batch", "seq", "d_model"), (new_conv, h_final)


def decode_mamba2_step(p: dict, x, h: Mamba2Hyper, rules: ShardingRules, *,
                       conv_state, ssm_state):
    return apply_mamba2(p, x, h, rules, init_state=ssm_state,
                        conv_state=conv_state)
