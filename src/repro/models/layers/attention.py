"""Attention: GQA / MHA, causal + sliding-window + bidirectional + cross,
attention-logit softcap (gemma2/grok), chunked "flash"-style jnp path for
long sequences, and a direct path for decode (KV-sequence-sharded).

TP layout: q heads are padded to a multiple of the model axis
(``sharding.pad_heads``) and sharded over ``model``; K/V stay at their true
GQA width (replicated across model for prefill; decode shards the *cached
sequence* dimension instead — flash-decoding style, GSPMD inserts the
softmax all-reduces).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import ShardingRules, constrain
from repro.models.layers.rope import apply_rope
from repro.models.module import bias_param, box, dense_param, normal_init

NEG_INF = -2.0e38


@dataclasses.dataclass(frozen=True)
class AttnHyper:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    padded_heads: int            # multiple of the model axis (>= n_heads)
    qkv_bias: bool = False
    use_rope: bool = True
    rope_theta: float = 10000.0
    attn_softcap: Optional[float] = None
    causal: bool = True
    chunk: int = 1024            # kv chunk for the flash path

    @property
    def group(self) -> int:
        return self.padded_heads // self.n_kv_heads


def init_attention(rng, d_model: int, h: AttnHyper, dtype) -> dict:
    rq, rk, rv, ro = jax.random.split(rng, 4)
    qd = h.padded_heads * h.head_dim
    kvd = h.n_kv_heads * h.head_dim
    scale = d_model ** -0.5
    wq = normal_init(rq, (d_model, qd), dtype, scale)
    wo = normal_init(ro, (qd, d_model), dtype, (qd) ** -0.5)
    if h.padded_heads != h.n_heads:
        # zero the padded head slices so padding never changes the output
        real = h.n_heads * h.head_dim
        live = (jnp.arange(qd) % (h.group * h.head_dim)
                < (h.n_heads // h.n_kv_heads) * h.head_dim)
        del real
        wq = wq * live[None, :].astype(dtype)
        wo = wo * live[:, None].astype(dtype)
    p = {
        "wq": box(wq, "d_model", "qkv_out"),
        "wk": dense_param(rk, d_model, kvd, dtype, "d_model", "kv_out", scale),
        "wv": dense_param(rv, d_model, kvd, dtype, "d_model", "kv_out", scale),
        "wo": box(wo, "o_in", "d_model"),
    }
    if h.qkv_bias:
        p["bq"] = bias_param(qd, dtype, "qkv_out")
        p["bk"] = bias_param(kvd, dtype, "kv_out")
        p["bv"] = bias_param(kvd, dtype, "kv_out")
    return p


def project_qkv(p: dict, x, h: AttnHyper, rules: ShardingRules, positions):
    """x (B,S,D) -> q (B,S,Hp,hd), k/v (B,S,Kv,hd); RoPE applied."""
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if h.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, h.padded_heads, h.head_dim)
    k = k.reshape(B, S, h.n_kv_heads, h.head_dim)
    v = v.reshape(B, S, h.n_kv_heads, h.head_dim)
    if h.use_rope:
        q = apply_rope(q, positions, h.rope_theta)
        k = apply_rope(k, positions, h.rope_theta)
    q = constrain(q, rules, "batch", "seq", "heads", "head_dim")
    k = constrain(k, rules, "batch", "seq", "kv_heads", "head_dim")
    v = constrain(v, rules, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def restore_kv(wk, wv, bk, bv, hidden, h: AttnHyper, positions):
    """The HCache restoration op: per-layer K,V from saved hidden states.

    hidden: (B, S, D) layer-input hidden states (post input-norm NOT applied —
    callers pass the normed input, matching what project_qkv consumed).
    """
    B, S, _ = hidden.shape
    k = jnp.einsum("bsd,dh->bsh", hidden, wk)
    v = jnp.einsum("bsd,dh->bsh", hidden, wv)
    if bk is not None:
        k, v = k + bk, v + bv
    k = k.reshape(B, S, h.n_kv_heads, h.head_dim)
    v = v.reshape(B, S, h.n_kv_heads, h.head_dim)
    if h.use_rope:
        k = apply_rope(k, positions, h.rope_theta)
    return k, v


def _mask_bias(q_pos, kv_pos, *, causal: bool, window: Optional[int],
               kv_len=None):
    """Additive bias (B,1,1,Sq,Skv): 0 where attendable, NEG_INF elsewhere.

    q_pos: (B, Sq) absolute positions of the queries.
    kv_pos: (Skv,) absolute positions of this KV chunk.
    kv_len: None, scalar, or (B,) live length of the KV buffer.
    """
    qp = q_pos[:, :, None]                     # (B, Sq, 1)
    kp = kv_pos[None, None, :]                 # (1, 1, Skv)
    ok = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), dtype=bool)
    if causal:
        ok &= kp <= qp
    if window is not None:
        ok &= kp > qp - window
    if kv_len is not None:
        kl = jnp.broadcast_to(jnp.asarray(kv_len), (q_pos.shape[0],))
        ok &= kp < kl[:, None, None]
    bias = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)
    return bias[:, None, None, :, :]           # (B,1,1,Sq,Skv)


def _scores(q, k, softcap):
    """q (B,Sq,Kv,g,hd), k (B,C,Kv,hd) -> (B,Kv,g,Sq,C) fp32."""
    s = jnp.einsum("bqkgh,bckh->bkgqc", q, k,
                   preferred_element_type=jnp.float32)
    s *= q.shape[-1] ** -0.5
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    return s


def flash_attention_jnp(q, k, v, h: AttnHyper, *, q_positions, kv_start: int = 0,
                        causal: bool, window: Optional[int] = None,
                        kv_len=None):
    """Chunked online-softmax attention (pure jnp; oracle for the Pallas
    kernel and the dry-run lowering path).

    q: (B,Sq,Hp,hd), k/v: (B,Skv,Kv,hd). Returns (B,Sq,Hp,hd).
    """
    B, Sq, Hp, hd = q.shape
    Skv = k.shape[1]
    Kv = h.n_kv_heads
    g = Hp // Kv
    qg = q.reshape(B, Sq, Kv, g, hd)
    C = min(h.chunk, Skv)
    n_chunks = (Skv + C - 1) // C
    pad = n_chunks * C - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if kv_len is None:
            kv_len = Skv          # mask the padded tail
    kc = k.reshape(B, n_chunks, C, Kv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, C, Kv, hd).transpose(1, 0, 2, 3, 4)

    m0 = jnp.full((B, Kv, g, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Kv, g, Sq), jnp.float32)
    acc0 = jnp.zeros((B, Kv, g, Sq, hd), jnp.float32)

    def body(carry, xs):
        m, l, acc = carry
        idx, kci, vci = xs
        s = _scores(qg, kci, h.attn_softcap)              # (B,Kv,g,Sq,C)
        kv_pos = kv_start + idx * C + jnp.arange(C)
        bias = _mask_bias(q_positions, kv_pos,
                          causal=causal, window=window, kv_len=kv_len)
        s = s + bias
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgqc,bckh->bkgqh", p.astype(v.dtype), vci,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hp, hd)
    return out.astype(q.dtype)


def flash_attention_triangular(q, k, v, h: AttnHyper, *, q_positions,
                               causal: bool = True,
                               window: Optional[int] = None,
                               q_block: int = 4096):
    """§Perf variant: process q in static blocks, each attending only
    kv[: block_end] — removes the ~2× causal-masking compute the single
    rectangular sweep pays (the jnp analog of the Pallas kernel's masked-
    block skipping). Self-attention only (q and kv positions aligned)."""
    B, Sq, Hp, hd = q.shape
    if Sq <= q_block or not causal:
        return flash_attention_jnp(q, k, v, h, q_positions=q_positions,
                                   causal=causal, window=window)
    outs = []
    for start in range(0, Sq, q_block):
        end = min(start + q_block, Sq)
        outs.append(flash_attention_jnp(
            q[:, start:end], k[:, :end], v[:, :end], h,
            q_positions=q_positions[:, start:end], causal=True,
            window=window))
    return jnp.concatenate(outs, axis=1)


def decode_attention_jnp(q, k_cache, v_cache, h: AttnHyper, *, kv_len,
                         window: Optional[int] = None):
    """Single-step decode attention against a (possibly kv_seq-sharded)
    cache. q: (B,1,Hp,hd); caches: (B,Smax,Kv,hd); kv_len: current length
    (scalar, includes the token being written this step)."""
    B, _, Hp, hd = q.shape
    Kv = h.n_kv_heads
    g = Hp // Kv
    qg = q.reshape(B, 1, Kv, g, hd)
    s = _scores(qg, k_cache, h.attn_softcap)               # (B,Kv,g,1,Smax)
    kv_pos = jnp.arange(k_cache.shape[1])
    kl = jnp.broadcast_to(jnp.asarray(kv_len), (B,))
    qpos = (kl - 1)[:, None]                               # (B, 1)
    bias = _mask_bias(qpos, kv_pos, causal=True, window=window, kv_len=kl)
    s = s + bias
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqc,bckh->bkgqh", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, 1, Hp, hd)
    return out.astype(q.dtype)


def attn_output(p: dict, attn, rules: ShardingRules):
    """attn (B,S,Hp,hd) -> (B,S,D) via the output projection."""
    B, S, Hp, hd = attn.shape
    out = jnp.einsum("bsh,hd->bsd", attn.reshape(B, S, Hp * hd), p["wo"])
    return constrain(out, rules, "batch", "seq", "d_model")
