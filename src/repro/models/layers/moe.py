"""Top-k routed mixture-of-experts FFN.

Dispatch strategy (TPU/GSPMD-native, see DESIGN.md §4):
  * routing, sorting and capacity-dropping happen **per batch row**, so every
    op is batched over the data-sharded batch dim and GSPMD keeps all
    dispatch work local (no cross-device sort).
  * expert FFN weights are TP-sharded on the per-expert d_ff dim (the mesh
    pins axes to (data, model); grok's 8 experts don't divide model=16, so
    expert-parallelism proper is not expressible — recorded as an adaptation).
  * capacity = ceil(S·top_k/E · capacity_factor); overflow tokens are dropped
    (their FFN output is 0, residual passes through) — standard GShard-style
    dropping.

FLOPs scale with *active* parameters (top-k · capacity_factor), which is what
the roofline MODEL_FLOPS ratio checks.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import ShardingRules, constrain
from repro.models.layers.mlp import ACTS
from repro.models.module import box, normal_init


@dataclasses.dataclass(frozen=True)
class MoEHyper:
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int
    activation: str = "silu"
    glu: bool = True
    capacity_factor: float = 1.25
    # §Perf variant: defer the model-axis reduction of the expert outputs
    # until AFTER the scatter back to token positions — the all-reduce then
    # moves (B,S,D) instead of (B,E,C,D) = top_k·capacity_factor× less bytes.
    # GSPMD refuses to defer (measured, see EXPERIMENTS.md §Perf), so the
    # late combine is forced with shard_map + explicit psum.
    late_combine: bool = False


def init_moe(rng, h: MoEHyper, dtype) -> dict:
    r1, r2, r3, r4 = jax.random.split(rng, 4)
    E, D, F = h.n_experts, h.d_model, h.d_ff
    p = {
        "router": box(normal_init(r1, (D, E), dtype, D ** -0.5),
                      "d_model", "experts"),
        "w_up": box(normal_init(r2, (E, D, F), dtype, D ** -0.5),
                    "experts", "d_model", "d_ff"),
        "w_down": box(normal_init(r3, (E, F, D), dtype, F ** -0.5),
                      "experts", "d_ff", "d_model"),
    }
    if h.glu:
        p["w_gate"] = box(normal_init(r4, (E, D, F), dtype, D ** -0.5),
                          "experts", "d_model", "d_ff")
    return p


def apply_moe(p: dict, x, h: MoEHyper, rules: ShardingRules):
    """x: (B, S, D) -> (B, S, D).  Per-row capacity-dropping dispatch."""
    if h.late_combine:
        from repro.distributed.sharding import current_mesh
        mesh = current_mesh()
        if not mesh.empty and "model" in mesh.axis_names \
                and mesh.shape["model"] > 1 \
                and rules.rules.get("d_ff") == "model":
            return _apply_moe_shard_map(p, x, h, rules, mesh)
    return _apply_moe_gspmd(p, x, h, rules)


def _apply_moe_shard_map(p, x, h: MoEHyper, rules: ShardingRules, mesh):
    """shard_map MoE: dispatch runs per data-shard; expert FFNs use the
    local d_ff slice; ONE psum over `model` AFTER the token scatter."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import ShardingRules as SR

    batch_spec = rules.spec(("batch", None, None))
    local_rules = SR({})                      # constraints no-op inside

    def body(xl, pl):
        out, probs = _apply_moe_gspmd(pl, xl, h, local_rules,
                                      skip_pin=True)
        out = jax.lax.psum(out, "model")
        return out, probs

    w_spec = P(None, None, "model")
    p_specs = {"router": P(None, None), "w_up": w_spec,
               "w_down": P(None, "model", None)}
    if "w_gate" in p:
        p_specs["w_gate"] = w_spec
    out, probs = shard_map(
        body, mesh=mesh, in_specs=(batch_spec, p_specs),
        out_specs=(batch_spec, rules.spec(("batch", None, None))),
        check_rep=False)(x, dict(p))
    return out, probs


def _apply_moe_gspmd(p: dict, x, h: MoEHyper, rules: ShardingRules,
                     skip_pin: bool = False):
    # pin 2D-sharded expert weights (grok: fsdp->data) to their layout HERE,
    # inside the layer-scan body — stops XLA hoisting a full-stack all-gather
    # (+f32 upcast) out of the loop (64×8×6144×2048 f32 = 24 GiB/device)
    if not skip_pin:
        p = dict(p)
        p["w_up"] = constrain(p["w_up"], rules, "experts", "fsdp", "d_ff")
        p["w_down"] = constrain(p["w_down"], rules, "experts", "d_ff",
                                "fsdp")
        if "w_gate" in p:
            p["w_gate"] = constrain(p["w_gate"], rules, "experts", "fsdp",
                                    "d_ff")
    B, S, D = x.shape
    E, K = h.n_experts, h.top_k
    C = math.ceil(S * K / E * h.capacity_factor) if S * K >= E else S * K
    C = max(min(C, S), 1)
    act = ACTS[h.activation]

    logits = jnp.einsum("bsd,de->bse", x, p["router"],
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                    # (B, S, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # --- per-row stable sort by expert id ---------------------------------
    flat_e = top_e.reshape(B, S * K)                          # (B, T)
    flat_t = jnp.broadcast_to(jnp.arange(S)[:, None], (S, K)).reshape(S * K)
    flat_p = top_p.reshape(B, S * K)
    order = jnp.argsort(flat_e, axis=-1, stable=True)         # (B, T)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    sorted_t = flat_t[order]                                  # (B, T) token ids
    sorted_p = jnp.take_along_axis(flat_p, order, axis=-1)

    # position of each entry within its expert group
    group_start = jnp.cumsum(
        jax.nn.one_hot(sorted_e, E, dtype=jnp.int32).sum(axis=1), axis=-1)  # (B,E)
    starts = jnp.concatenate(
        [jnp.zeros((B, 1), jnp.int32), group_start[:, :-1]], axis=-1)
    pos_in_e = jnp.arange(S * K)[None, :] - jnp.take_along_axis(
        starts, sorted_e, axis=-1)                            # (B, T)
    keep = pos_in_e < C
    slot = jnp.where(keep, sorted_e * C + pos_in_e, E * C)    # dropped -> sentinel

    # scatter token ids / weights into (B, E*C) slot buffers
    def row_scatter(slots, vals, fill):
        buf = jnp.full((E * C + 1,), fill, vals.dtype)
        return buf.at[slots].set(vals)[:-1]

    tok_buf = jax.vmap(lambda s, t: row_scatter(s, t, jnp.int32(-1)))(
        slot, sorted_t.astype(jnp.int32))                     # (B, E*C)
    w_buf = jax.vmap(lambda s, w: row_scatter(s, w, jnp.float32(0)))(
        slot, sorted_p.astype(jnp.float32))

    gathered = jnp.take_along_axis(
        x, jnp.maximum(tok_buf, 0)[..., None], axis=1)        # (B, E*C, D)
    gathered = gathered * (tok_buf >= 0)[..., None].astype(x.dtype)
    ge = gathered.reshape(B, E, C, D)
    ge = constrain(ge, rules, "batch", "experts", None, "d_model")

    up = jnp.einsum("becd,edf->becf", ge, p["w_up"])
    up = constrain(up, rules, "batch", "experts", None, "d_ff")
    if "w_gate" in p:
        gate = jnp.einsum("becd,edf->becf", ge, p["w_gate"])
        up = act(gate) * up
    else:
        up = act(up)
    out_e = jnp.einsum("becf,efd->becd", up, p["w_down"])
    if not h.late_combine:
        # baseline: reduce partial sums over the model axis here (the
        # paper-faithful naive TP layout; see EXPERIMENTS.md §Perf)
        out_e = constrain(out_e, rules, "batch", "experts", None, "d_model")
    out_e = out_e.reshape(B, E * C, D) * w_buf[..., None].astype(x.dtype)

    # scatter-add back to token positions
    def row_combine(tok, vals):
        return jnp.zeros((S, D), vals.dtype).at[
            jnp.maximum(tok, 0)].add(vals * (tok >= 0)[:, None].astype(vals.dtype))

    out = jax.vmap(row_combine)(tok_buf, out_e)
    return constrain(out, rules, "batch", "seq", "d_model"), probs
