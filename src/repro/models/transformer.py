"""Decoder-only LM stack (dense / GQA / MoE / VLM) with scan-over-layers.

Entry points (all pure functions over boxed-param values):

  init_lm           -> boxed params
  lm_forward        -> full-sequence forward (train / prefill), optionally
                       capturing per-layer hidden states (HCache save path)
                       and emitting stacked KV caches (prefill)
  lm_decode_step    -> single-token continuous-batching decode step
  lm_restore_kv     -> THE PAPER'S OP: stacked per-layer K,V from stacked
                       saved hidden states (norm + projection + RoPE only)

HCache definition: the saved "hidden state" of layer *i* is the residual
stream INPUT to layer *i* (`H_L` in the paper). Restoration recomputes
`K = W_k·RMSNorm(H)` — the norm is part of the (cheap) restoration compute,
keeping restore == original bitwise (§3.1; the paper folds the norm into ε).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.arch import ArchConfig
from repro.distributed import tp as tp_lib
from repro.distributed.sharding import ShardingRules, constrain, pad_heads
from repro.models.layers import attention as attn_lib
from repro.models.layers.attention import AttnHyper
from repro.models.layers.embedding import (embed_tokens, init_embedding,
                                           logits as embed_logits, positional)
from repro.models.layers.mlp import apply_mlp, init_mlp
from repro.models.layers.moe import MoEHyper, apply_moe, init_moe
from repro.models.layers.norm import apply_norm, init_norm
from repro.models.module import stacked_init, split

BIG_WINDOW = 1 << 30


@dataclasses.dataclass(frozen=True)
class LMHyper:
    cfg: ArchConfig
    rules: ShardingRules
    model_axis: int = 1
    dtype: Any = jnp.float32
    attn_chunk: int = 1024
    remat: str = "full"              # none | full | dots
    max_positions: int = 8192        # learned-pos archs only
    n_vis: int = 0                   # VLM: patch positions at sequence head
    tri_prefill: bool = False        # §Perf: triangular prefill schedule
    moe_late_combine: bool = False   # §Perf: see layers/moe.py

    @functools.cached_property
    def attn(self) -> AttnHyper:
        c = self.cfg
        padded, _ = pad_heads(c.n_heads, c.n_kv_heads, self.model_axis)
        return AttnHyper(
            n_heads=c.n_heads, n_kv_heads=c.n_kv_heads, head_dim=c.head_dim_,
            padded_heads=padded, qkv_bias=c.qkv_bias, use_rope=c.use_rope,
            rope_theta=c.rope_theta, attn_softcap=c.attn_softcap,
            chunk=self.attn_chunk)

    @functools.cached_property
    def moe(self) -> Optional[MoEHyper]:
        c = self.cfg
        if not c.n_experts:
            return None
        return MoEHyper(n_experts=c.n_experts, top_k=c.experts_per_token,
                        d_model=c.d_model, d_ff=c.d_ff,
                        activation=c.ffn_activation, glu=c.ffn_glu,
                        late_combine=self.moe_late_combine)


# ------------------------------------------------------------------- params
def init_block(rng, h: LMHyper) -> dict:
    c = h.cfg
    r1, r2, r3 = jax.random.split(rng, 3)
    p = {
        "ln1": init_norm(c.norm, c.d_model, h.dtype),
        "attn": attn_lib.init_attention(r1, c.d_model, h.attn, h.dtype),
        "ln2": init_norm(c.norm, c.d_model, h.dtype),
    }
    if h.moe is not None:
        p["moe"] = init_moe(r2, h.moe, h.dtype)
    else:
        p["mlp"] = init_mlp(r3, c.d_model, c.d_ff, c.ffn_glu, h.dtype)
    if c.post_attn_norm:
        p["post_ln1"] = init_norm(c.norm, c.d_model, h.dtype)
        p["post_ln2"] = init_norm(c.norm, c.d_model, h.dtype)
    return p


def init_lm(rng, h: LMHyper) -> dict:
    c = h.cfg
    re, rb = jax.random.split(rng)
    learned_pos = not c.use_rope
    params = {
        "embed": init_embedding(re, c.vocab_size, c.d_model, h.dtype,
                                c.tie_embeddings, h.max_positions,
                                learned_pos),
        "blocks": stacked_init(lambda r: init_block(r, h), c.n_layers, rb),
        "final_norm": init_norm(c.norm, c.d_model, h.dtype),
    }
    return params


def layer_windows(h: LMHyper) -> Optional[jnp.ndarray]:
    """Per-layer attention window (gemma2 local/global); None if uniform."""
    c = h.cfg
    if not c.local_window:
        return None
    from repro.config.arch import AttnKind
    kinds = c.attn_kinds()
    return jnp.asarray([c.local_window if k == AttnKind.LOCAL else BIG_WINDOW
                        for k in kinds], jnp.int32)


# ----------------------------------------------------------------- block fns
def _ffn(p, x, h: LMHyper):
    if h.moe is not None:
        out, probs = apply_moe(p["moe"], x, h.moe, h.rules)
        # GShard load-balance aux: E * sum_e f_e * P_e
        E = h.moe.n_experts
        top1 = jnp.argmax(probs, axis=-1)
        f = jnp.mean(jax.nn.one_hot(top1, E, dtype=jnp.float32), axis=(0, 1))
        P = jnp.mean(probs, axis=(0, 1))
        aux = E * jnp.sum(f * P)
        return out, aux
    return apply_mlp(p["mlp"], x, h.cfg.ffn_activation, h.rules), 0.0


def block_forward(p, x, h: LMHyper, *, positions, window,
                  hist_kv=None, hist_len=None, emit_kv: bool):
    """Full-sequence block. x: (B,S,D). Optional restored history KV
    (B,Sh,Kv,hd) pair prepended to the attention context (HCache prefill).

    Returns (x_out, aux, (k, v) or None, hidden_in)."""
    c = h.cfg
    hidden_in = x
    normed = apply_norm(p["ln1"], x, c.norm, c.norm_eps)
    q, k, v = attn_lib.project_qkv(p["attn"], normed, h.attn, h.rules,
                                   positions)
    if hist_kv is not None:
        hk, hv = hist_kv
        k_all = jnp.concatenate([hk, k], axis=1)
        v_all = jnp.concatenate([hv, v], axis=1)
        kv_len = None if hist_len is None else hist_len + x.shape[1]
    else:
        k_all, v_all, kv_len = k, v, None
    w = None
    if window is not None:
        w = window if not isinstance(window, int) else jnp.asarray(window)
    if h.tri_prefill and hist_kv is None and w is None:
        attn_out = attn_lib.flash_attention_triangular(
            q, k_all, v_all, h.attn, q_positions=positions, causal=True)
    else:
        attn_out = attn_lib.flash_attention_jnp(
            q, k_all, v_all, h.attn, q_positions=positions, causal=True,
            window=w, kv_len=kv_len)
    attn_out = attn_lib.attn_output(p["attn"], attn_out, h.rules)
    if c.post_attn_norm:
        attn_out = apply_norm(p["post_ln1"], attn_out, c.norm, c.norm_eps)
    x = x + attn_out
    normed2 = apply_norm(p["ln2"], x, c.norm, c.norm_eps)
    ff, aux = _ffn(p, normed2, h)
    if c.post_attn_norm:
        ff = apply_norm(p["post_ln2"], ff, c.norm, c.norm_eps)
    x = x + ff
    kv = (k, v) if emit_kv else None
    return x, aux, kv, hidden_in


def block_decode(p, x, h: LMHyper, *, k_cache, v_cache, lengths, window):
    """Single-token block. x: (B,1,D); caches (B,Smax,Kv,hd); lengths (B,)
    count tokens ALREADY in the cache (the new token is written at
    ``lengths``). Returns (x_out, new_k_cache, new_v_cache, hidden_in)."""
    c = h.cfg
    hidden_in = x
    positions = lengths[:, None]                       # (B,1)
    normed = apply_norm(p["ln1"], x, c.norm, c.norm_eps)
    q, k, v = attn_lib.project_qkv(p["attn"], normed, h.attn, h.rules,
                                   positions)
    B = x.shape[0]
    bidx = jnp.arange(B)
    k_cache = k_cache.at[bidx, lengths].set(k[:, 0], mode="drop")
    v_cache = v_cache.at[bidx, lengths].set(v[:, 0], mode="drop")
    k_cache = constrain(k_cache, h.rules, "batch", "kv_seq", "kv_heads",
                        "head_dim")
    v_cache = constrain(v_cache, h.rules, "batch", "kv_seq", "kv_heads",
                        "head_dim")
    w = None
    if window is not None:
        w = window if not isinstance(window, int) else jnp.asarray(window)
    attn_out = attn_lib.decode_attention_jnp(
        q, k_cache, v_cache, h.attn, kv_len=lengths + 1, window=w)
    attn_out = attn_lib.attn_output(p["attn"], attn_out, h.rules)
    if c.post_attn_norm:
        attn_out = apply_norm(p["post_ln1"], attn_out, c.norm, c.norm_eps)
    x = x + attn_out
    normed2 = apply_norm(p["ln2"], x, c.norm, c.norm_eps)
    ff, _ = _ffn(p, normed2, h)
    if c.post_attn_norm:
        ff = apply_norm(p["post_ln2"], ff, c.norm, c.norm_eps)
    x = x + ff
    return x, k_cache, v_cache, hidden_in


def block_decode_paged(p, x, h: LMHyper, *, k_pool, v_pool, block_table,
                       blk, off, lengths, window):
    """Single-token block over a paged KV cache.

    x: (B,1,D); pools (NB, bs, Kv, hd) physical pages; block_table
    (B, MB) logical→physical page map (entries >= NB are unallocated
    sentinels); blk/off (B,) precomputed write address of the new token.
    The new KV is scattered into its page (sentinel writes drop), then
    attention runs over the block-table gather of the logical layout —
    identical math to ``block_decode``: masked positions contribute
    exactly-zero probability, so gathered junk past the live length
    cannot perturb the output."""
    c = h.cfg
    hidden_in = x
    positions = lengths[:, None]                       # (B,1)
    normed = apply_norm(p["ln1"], x, c.norm, c.norm_eps)
    q, k, v = attn_lib.project_qkv(p["attn"], normed, h.attn, h.rules,
                                   positions)
    k_pool = k_pool.at[blk, off].set(k[:, 0], mode="drop")
    v_pool = v_pool.at[blk, off].set(v[:, 0], mode="drop")
    k_pool = constrain(k_pool, h.rules, None, None, "kv_heads", "head_dim")
    v_pool = constrain(v_pool, h.rules, None, None, "kv_heads", "head_dim")
    # tensor-parallel seam (DESIGN.md §16): under an active TPContext the
    # pools stay sharded over KV heads — the new-token scatter and the
    # block-table gather below never index the head axis, so both are
    # shard-local by construction
    k_pool = tp_lib.kv_seam(k_pool, 2)
    v_pool = tp_lib.kv_seam(v_pool, 2)
    B, MB = block_table.shape
    NB, bs = k_pool.shape[0], k_pool.shape[1]
    table = jnp.minimum(block_table, NB - 1)           # clamp sentinels
    k_cache = k_pool[table].reshape(B, MB * bs, *k_pool.shape[2:])
    v_cache = v_pool[table].reshape(B, MB * bs, *v_pool.shape[2:])
    w = None
    if window is not None:
        w = window if not isinstance(window, int) else jnp.asarray(window)
    attn_out = attn_lib.decode_attention_jnp(
        q, k_cache, v_cache, h.attn, kv_len=lengths + 1, window=w)
    # the ONE collective of the sharded decode path: replicate the
    # per-head attention output before the wo contraction so the output
    # projection (and the logits) run the exact single-device program —
    # a head-sharded wo would partial-sum across devices and break
    # bitwise identity with tp=1
    attn_out = tp_lib.logits_seam(attn_out)
    attn_out = attn_lib.attn_output(p["attn"], attn_out, h.rules)
    if c.post_attn_norm:
        attn_out = apply_norm(p["post_ln1"], attn_out, c.norm, c.norm_eps)
    x = x + attn_out
    normed2 = apply_norm(p["ln2"], x, c.norm, c.norm_eps)
    ff, _ = _ffn(p, normed2, h)
    if c.post_attn_norm:
        ff = apply_norm(p["post_ln2"], ff, c.norm, c.norm_eps)
    x = x + ff
    return x, k_pool, v_pool, hidden_in


def _remat_wrap(fn, h: LMHyper):
    if h.remat == "none":
        return fn
    if h.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


# ------------------------------------------------------------ full forward
def _embed_input(params, h: LMHyper, tokens, positions, patch_embeds=None):
    c = h.cfg
    x = embed_tokens(params["embed"], tokens, h.rules,
                     scale=c.embedding_scale, d_model=c.d_model)
    if not c.use_rope and "positions" in params["embed"]:
        x = x + positional(params["embed"], positions).astype(x.dtype)
    if patch_embeds is not None:
        n_vis = patch_embeds.shape[1]
        x = jnp.concatenate(
            [patch_embeds.astype(x.dtype), x[:, n_vis:]], axis=1)
    return x.astype(h.dtype)


def lm_forward(params, tokens, h: LMHyper, *, positions=None,
               patch_embeds=None, hist_kv=None, hist_len=None,
               capture_hidden: bool = False, emit_kv: bool = False,
               final_logits_only: bool = False, skip_logits: bool = False):
    """Train / prefill forward.

    tokens: (B,S) int32. hist_kv: optional restored-history KV caches,
    stacked (L,B,Sh,Kv,hd) pair — the HCache prefill path.
    Returns dict(logits, kv, hidden, aux)."""
    c = h.cfg
    B, S = tokens.shape
    if positions is None:
        base = 0 if hist_len is None else hist_len
        positions = base + jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = _embed_input(params, h, tokens, positions, patch_embeds)
    x = constrain(x, h.rules, "batch", "seq", "d_model")
    windows = layer_windows(h)

    def body(carry, xs):
        x, aux = carry
        (bp, win, hkv) = xs
        x, a, kv, hidden = block_forward(
            bp, x, h, positions=positions, window=win,
            hist_kv=hkv, hist_len=hist_len, emit_kv=emit_kv)
        if kv is not None:
            kv = tuple(constrain(t, h.rules, "batch", "kv_seq", "kv_heads",
                                 "head_dim") for t in kv)
        ys = (kv, hidden if capture_hidden else None)
        return (x, aux + a), ys

    body = _remat_wrap(body, h)
    xs = (params["blocks"], windows, hist_kv)
    (x, aux), ys = jax.lax.scan(body, (x, 0.0), xs)
    kv_stack, hidden_stack = ys
    x = apply_norm(params["final_norm"], x, c.norm, c.norm_eps)
    if final_logits_only:
        x = x[:, -1:]
    if skip_logits:     # training path: chunked vocab-parallel CE downstream
        return {"final_x": x, "kv": kv_stack, "hidden": hidden_stack,
                "aux": aux}
    lg = embed_logits(params["embed"], x, h.rules, softcap=c.logit_softcap,
                      true_vocab=c.vocab_size)
    return {"logits": lg, "kv": kv_stack, "hidden": hidden_stack, "aux": aux}


def lm_decode_step(params, cache, tokens, h: LMHyper):
    """One continuous-batching decode step.

    cache: dict(k (L,B,Smax,Kv,hd), v, lengths (B,)). tokens: (B,1).
    Returns (logits (B,1,V), new cache)."""
    c = h.cfg
    lengths = cache["lengths"]
    x = _embed_input(params, h, tokens, lengths[:, None])
    x = constrain(x, h.rules, "batch", None, "d_model")
    windows = layer_windows(h)

    def body(x, xs):
        bp, win, kc, vc = xs
        x, nk, nv, hidden = block_decode(bp, x, h, k_cache=kc, v_cache=vc,
                                         lengths=lengths, window=win)
        return x, (nk, nv, hidden)

    xs = (params["blocks"], windows, cache["k"], cache["v"])
    x, (nk, nv, hidden) = jax.lax.scan(body, x, xs)
    x = apply_norm(params["final_norm"], x, c.norm, c.norm_eps)
    lg = embed_logits(params["embed"], x, h.rules, softcap=c.logit_softcap,
                      true_vocab=c.vocab_size)
    new_cache = {"k": nk, "v": nv, "lengths": lengths + 1}
    return lg, new_cache, hidden


def lm_decode_step_paged(params, cache, tokens, h: LMHyper):
    """One continuous-batching decode step over a paged KV cache.

    cache: dict(k_pool/v_pool (L, NB, bs, Kv, hd), block_table (B, MB)
    int32, lengths (B,)). tokens: (B,1). Returns (logits, new cache,
    per-layer hidden) — same contract as ``lm_decode_step``; with every
    live position mapped by the block table this is byte-identical to
    the contiguous step at logical width MB·bs == Smax."""
    c = h.cfg
    lengths = cache["lengths"]
    bt = cache["block_table"]
    bs = cache["k_pool"].shape[2]
    x = _embed_input(params, h, tokens, lengths[:, None])
    x = constrain(x, h.rules, "batch", None, "d_model")
    windows = layer_windows(h)
    B = tokens.shape[0]
    MB = bt.shape[1]
    NB = cache["k_pool"].shape[1]
    bidx = jnp.arange(B)
    li = lengths // bs
    # a logical page past the table (slot exactly full) must become a
    # dropped sentinel write, not clamp into the slot's last live page
    blk = jnp.where(li < MB, bt[bidx, jnp.minimum(li, MB - 1)], NB)
    off = lengths % bs

    def body(x, xs):
        bp, win, kp, vp = xs
        x, nk, nv, hidden = block_decode_paged(
            bp, x, h, k_pool=kp, v_pool=vp, block_table=bt, blk=blk,
            off=off, lengths=lengths, window=win)
        return x, (nk, nv, hidden)

    xs = (params["blocks"], windows, cache["k_pool"], cache["v_pool"])
    x, (nk, nv, hidden) = jax.lax.scan(body, x, xs)
    x = apply_norm(params["final_norm"], x, c.norm, c.norm_eps)
    lg = embed_logits(params["embed"], x, h.rules, softcap=c.logit_softcap,
                      true_vocab=c.vocab_size)
    new_cache = {"k_pool": nk, "v_pool": nv, "block_table": bt,
                 "lengths": lengths + 1}
    return lg, new_cache, hidden


# -------------------------------------------------------------- HCache op
def lm_restore_kv(params, hidden, h: LMHyper, *, positions):
    """Restore stacked KV caches from stacked saved hidden states.

    hidden: (L, B, S, D) residual-stream inputs per layer (bf16 on the wire).
    positions: (B, S). Returns (k, v): (L, B, S, Kv, hd) each — exactly what
    the prefill with emit_kv=True would have produced for these layers."""
    c = h.cfg

    def one_layer(bp, hl):
        normed = apply_norm(bp["ln1"], hl.astype(h.dtype), c.norm, c.norm_eps)
        return attn_lib.restore_kv(
            bp["attn"]["wk"], bp["attn"]["wv"],
            bp["attn"].get("bk"), bp["attn"].get("bv"),
            normed, h.attn, positions)

    return jax.vmap(one_layer)(params["blocks"], hidden)
