"""Unified model facade.

One `Model` object per architecture dispatches to the family implementation
(transformer / ssm / hybrid / encdec) through its ``FamilyAdapter``
(models/adapter.py) behind a uniform API used by the serving engine, the
trainer, and the multi-pod dry-run:

    init(rng)                          -> boxed params
    forward(params, batch)             -> train-path logits dict
    prefill(params, batch, ...)        -> logits + cache pieces (+ hidden)
    decode_step(params, cache, tokens) -> (logits, new cache)
    restore_cache(params, saved, ...)  -> HCache restoration (per family)
    *_inputs(shape)                    -> ShapeDtypeStruct trees + logical
                                          sharding specs for the dry-run

The compute methods are thin delegations to ``self.adapter`` — per-family
branching lives there (one class per family), not in ``if kind`` chains
here or in the serving engine (DESIGN.md §11). The dry-run shape/sharding
declarations below stay inline: they are static specs, not dispatch.

Whisper uses a fixed decoder prompt length (DEC_PROMPT) / training target
length (DEC_TRAIN); InternVL2 reserves the first ``n_vis`` positions of the
sequence for stubbed patch embeddings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.arch import ArchConfig
from repro.config.shapes import InputShape
from repro.distributed.sharding import ShardingRules
from repro.models import encdec, hybrid, ssm as ssm_mod, transformer as tfm
from repro.models.adapter import make_adapter
from repro.models.module import split

DEC_PROMPT = 128      # whisper decoder prompt length in prefill cells
DEC_TRAIN = 448       # whisper decoder target length in train cells
DEC_BUF = 1024        # whisper decoder self-KV buffer for decode cells
N_VIS = 256           # internvl2 patch positions


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    rules: ShardingRules
    model_axis: int = 1
    dtype: Any = jnp.float32
    remat: str = "full"
    attn_chunk: int = 1024
    tri_prefill: bool = False        # §Perf variants (see layers)
    moe_late_combine: bool = False

    def __post_init__(self):
        c = self.cfg
        if c.is_encoder_decoder:
            self.h = encdec.EncDecHyper(
                cfg=c, rules=self.rules, model_axis=self.model_axis,
                dtype=self.dtype, attn_chunk=self.attn_chunk,
                remat=self.remat)
            self.kind = "encdec"
        elif c.family == "ssm":
            self.h = ssm_mod.SSMHyper(cfg=c, rules=self.rules,
                                      model_axis=self.model_axis,
                                      dtype=self.dtype, remat=self.remat)
            self.kind = "ssm"
        elif c.family == "hybrid":
            self.h = hybrid.HybridHyper(
                cfg=c, rules=self.rules, model_axis=self.model_axis,
                dtype=self.dtype, attn_chunk=self.attn_chunk,
                remat=self.remat)
            self.kind = "hybrid"
        else:
            self.h = tfm.LMHyper(
                cfg=c, rules=self.rules, model_axis=self.model_axis,
                dtype=self.dtype, attn_chunk=self.attn_chunk,
                remat=self.remat, n_vis=N_VIS if c.family == "vlm" else 0,
                tri_prefill=self.tri_prefill,
                moe_late_combine=self.moe_late_combine)
            self.kind = "lm"
        self.adapter = make_adapter(self)

    # ----------------------------------------------------------------- init
    def init(self, rng):
        return self.adapter.init(rng)

    def abstract_params(self, rng=None):
        """(ShapeDtypeStruct values tree, logical axes tree) — no alloc."""
        rng = jax.random.PRNGKey(0) if rng is None else rng
        boxed = jax.eval_shape(self.init, rng)
        return split(boxed)

    # -------------------------------------------------------------- forward
    def forward(self, params, batch: Dict[str, Any], *,
                skip_logits: bool = False) -> Dict[str, Any]:
        """Training-path forward -> dict with 'logits' (B,S,V) + 'aux'
        (or 'final_x' (B,S,D) when skip_logits — chunked-CE training)."""
        return self.adapter.forward(params, batch, skip_logits=skip_logits)

    # -------------------------------------------------------------- prefill
    def prefill(self, params, batch, *, capture_hidden=False,
                hist_kv=None, hist_len=None):
        return self.adapter.prefill(params, batch,
                                    capture_hidden=capture_hidden,
                                    hist_kv=hist_kv, hist_len=hist_len)

    # --------------------------------------------------------------- decode
    def decode_step(self, params, cache, tokens):
        lg, cache, _ = self.decode_step_full(params, cache, tokens)
        return lg, cache

    def decode_step_full(self, params, cache, tokens):
        """(logits, cache, per-layer hidden states) — HCache save path."""
        return self.adapter.decode_step_full(params, cache, tokens)

    def decode_step_paged(self, params, cache, tokens):
        """Decode step over a block-table paged cache (serving engine's
        'paged' KVCacheBackend; see serving/kv_cache.py)."""
        return self.adapter.decode_step_paged(params, cache, tokens)

    # ------------------------------------------------------------ HCache op
    def restore_kv_from_hidden(self, params, hidden, *, positions):
        """The paper's restoration GEMM (families with attention)."""
        return self.adapter.restore_kv_from_hidden(params, hidden,
                                                   positions=positions)

    def restore_ssm_states(self, params, hidden):
        return self.adapter.restore_ssm_states(params, hidden)

    # ====================================================== dry-run input specs
    def _tok(self, b, s):
        return _sds((b, s), jnp.int32)

    def train_batch_spec(self, shape: InputShape):
        c = self.cfg
        B, S = shape.global_batch, shape.seq_len
        if self.kind == "encdec":
            return {"frames": _sds((B, S, c.d_model), self.dtype),
                    "tokens": self._tok(B, DEC_TRAIN),
                    "targets": self._tok(B, DEC_TRAIN)}
        batch = {"tokens": self._tok(B, S), "targets": self._tok(B, S)}
        if c.family == "vlm":
            batch["patches"] = _sds((B, N_VIS, c.d_model), self.dtype)
        return batch

    def train_batch_sharding(self):
        r = self.rules
        out = {"tokens": r.spec(("batch", "seq")),
               "targets": r.spec(("batch", "seq"))}
        if self.kind == "encdec":
            out["frames"] = r.spec(("batch", "seq", "d_model"))
            del out["targets"]
            out["targets"] = r.spec(("batch", None))
            out["tokens"] = r.spec(("batch", None))
        if self.cfg.family == "vlm":
            out["patches"] = r.spec(("batch", None, "d_model"))
        return out

    def prefill_batch_spec(self, shape: InputShape):
        c = self.cfg
        B, S = shape.global_batch, shape.seq_len
        if self.kind == "encdec":
            return {"frames": _sds((B, S, c.d_model), self.dtype),
                    "tokens": self._tok(B, DEC_PROMPT)}
        batch = {"tokens": self._tok(B, S)}
        if c.family == "vlm":
            batch["patches"] = _sds((B, N_VIS, c.d_model), self.dtype)
        return batch

    def prefill_batch_sharding(self):
        out = self.train_batch_sharding()
        out.pop("targets", None)
        return out

    def cache_spec(self, batch: int, ctx_len: int):
        """Decode-cell cache ShapeDtypeStructs (fully-populated context)."""
        c = self.cfg
        hd = c.head_dim_
        L = c.n_layers
        lengths = _sds((batch,), jnp.int32)
        if self.kind == "lm":
            kv = _sds((L, batch, ctx_len, c.n_kv_heads, hd), self.dtype)
            return {"k": kv, "v": kv, "lengths": lengths}
        if self.kind == "ssm":
            hyper = self.h.mamba
            return {
                "conv": _sds((L, batch, hyper.d_conv - 1, hyper.d_inner),
                             self.dtype),
                "ssm": _sds((L, batch, hyper.d_inner, hyper.d_state),
                            jnp.float32),
                "lengths": lengths}
        if self.kind == "hybrid":
            hh = self.h
            m = hh.mamba
            conv_ch = m.d_inner + 2 * m.n_groups * m.d_state
            kv = _sds((hh.n_super, batch, ctx_len, c.n_kv_heads, hd),
                      self.dtype)
            return {
                "attn_k": kv, "attn_v": kv,
                "conv": _sds((hh.n_super, hh.k - 1, batch, m.d_conv - 1,
                              conv_ch), self.dtype),
                "ssm": _sds((hh.n_super, hh.k - 1, batch, m.n_heads,
                             m.head_dim, m.d_state), jnp.float32),
                "lengths": lengths}
        # encdec: 32k/500k context is the *cross* (encoder) side
        kv_self = _sds((L, batch, DEC_BUF, c.n_heads, hd), self.dtype)
        kv_cross = _sds((L, batch, ctx_len, c.n_heads, hd), self.dtype)
        return {"self_k": kv_self, "self_v": kv_self,
                "cross_k": kv_cross, "cross_v": kv_cross,
                "enc_len": _sds((), jnp.int32), "lengths": lengths}

    def cache_sharding(self):
        r = self.rules
        if self.kind == "lm":
            kv = r.spec(("layers", "batch", "kv_seq", "kv_heads", "head_dim"))
            return {"k": kv, "v": kv, "lengths": r.spec(("batch",))}
        if self.kind == "ssm":
            return {
                "conv": r.spec(("layers", "batch", "conv_w", "ssm_inner")),
                "ssm": r.spec(("layers", "batch", "ssm_inner", "ssm_state")),
                "lengths": r.spec(("batch",))}
        if self.kind == "hybrid":
            kv = r.spec(("layers", "batch", "kv_seq", "kv_heads", "head_dim"))
            return {
                "attn_k": kv, "attn_v": kv,
                "conv": r.spec(("layers", None, "batch", "conv_w",
                                "ssm_inner")),
                "ssm": r.spec(("layers", None, "batch", "ssm_heads",
                               None, "ssm_state")),
                "lengths": r.spec(("batch",))}
        kv_self = r.spec(("layers", "batch", None, "kv_heads", "head_dim"))
        kv_cross = r.spec(("layers", "batch", "kv_seq", "kv_heads",
                           "head_dim"))
        return {"self_k": kv_self, "self_v": kv_self,
                "cross_k": kv_cross, "cross_v": kv_cross,
                "enc_len": jax.sharding.PartitionSpec(),
                "lengths": r.spec(("batch",))}

    def init_cache(self, batch: int, ctx_len: int, *, enc_len: int = 0):
        """Concrete zero-initialized cache (serving engine)."""
        spec = self.cache_spec(batch, ctx_len)
        cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)
        cache["lengths"] = jnp.zeros((batch,), jnp.int32)
        if "enc_len" in cache:
            cache["enc_len"] = jnp.asarray(enc_len, jnp.int32)
        return cache

    def init_paged_cache(self, batch: int, num_blocks: int,
                         block_size: int, max_blocks_per_seq: int):
        """Zero-initialized block-table paged decode cache (lm family).

        k_pool/v_pool: (L, num_blocks, block_size, Kv, hd) physical
        pages; block_table: (batch, max_blocks_per_seq) int32 with
        ``num_blocks`` as the unallocated sentinel; lengths: (batch,)."""
        if not self.adapter.supports_paged:
            raise NotImplementedError(
                f"paged KV cache requires an lm-family model; "
                f"{self.cfg.name} is {self.kind!r}")
        c = self.cfg
        kv = jnp.zeros((c.n_layers, num_blocks, block_size, c.n_kv_heads,
                        c.head_dim_), self.dtype)
        return {"k_pool": kv, "v_pool": jnp.zeros_like(kv),
                "block_table": jnp.full((batch, max_blocks_per_seq),
                                        num_blocks, jnp.int32),
                "lengths": jnp.zeros((batch,), jnp.int32)}

    def param_shardings(self, mesh):
        _, axes = self.abstract_params()
        return self.rules.tree_shardings(mesh, axes)
