"""Pure Mamba1 LM (falcon-mamba-7b). Attention-free.

HCache applicability: no KV cache exists; restoration uses ``ssm-rescan``
(per-layer state recompute from that layer's saved input hidden states) —
layer-parallel and linear-time, see DESIGN.md §3.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.config.arch import ArchConfig
from repro.distributed.sharding import ShardingRules, constrain
from repro.models.layers.embedding import (embed_tokens, init_embedding,
                                           logits as embed_logits)
from repro.models.layers.mamba import Mamba1Hyper, apply_mamba1, init_mamba1
from repro.models.layers.norm import apply_norm, init_norm
from repro.models.module import stacked_init
from repro.models import transformer as tfm


@dataclasses.dataclass(frozen=True)
class SSMHyper:
    cfg: ArchConfig
    rules: ShardingRules
    model_axis: int = 1
    dtype: Any = jnp.float32
    remat: str = "full"

    @functools.cached_property
    def mamba(self) -> Mamba1Hyper:
        c = self.cfg
        return Mamba1Hyper(d_model=c.d_model, d_state=c.ssm_state,
                           d_conv=c.ssm_conv, expand=c.ssm_expand)

    @functools.cached_property
    def lm(self) -> tfm.LMHyper:
        return tfm.LMHyper(cfg=self.cfg, rules=self.rules,
                           model_axis=self.model_axis, dtype=self.dtype,
                           remat=self.remat)


def _init_block(rng, h: SSMHyper) -> dict:
    return {"ln": init_norm(h.cfg.norm, h.cfg.d_model, h.dtype),
            "m": init_mamba1(rng, h.mamba, h.dtype)}


def init_ssm_lm(rng, h: SSMHyper) -> dict:
    c = h.cfg
    re, rb = jax.random.split(rng)
    return {
        "embed": init_embedding(re, c.vocab_size, c.d_model, h.dtype,
                                c.tie_embeddings),
        "blocks": stacked_init(lambda r: _init_block(r, h), c.n_layers, rb),
        "final_norm": init_norm(c.norm, c.d_model, h.dtype),
    }


def ssm_forward(params, tokens, h: SSMHyper, *, capture_hidden: bool = False,
                emit_state: bool = False, final_logits_only: bool = False,
                skip_logits: bool = False):
    c = h.cfg
    B, S = tokens.shape
    x = embed_tokens(params["embed"], tokens, h.rules, scale=False,
                     d_model=c.d_model).astype(h.dtype)
    x = constrain(x, h.rules, "batch", "seq", "d_model")

    def body(x, bp):
        hidden = x
        normed = apply_norm(bp["ln"], x, c.norm, c.norm_eps)
        out, (ncs, nss) = apply_mamba1(bp["m"], normed, h.mamba, h.rules)
        x = x + out
        return x, (hidden if capture_hidden else None,
                   (ncs, nss) if emit_state else None)

    body = tfm._remat_wrap(body, h.lm)
    x, (hidden, states) = jax.lax.scan(body, x, params["blocks"])
    x = apply_norm(params["final_norm"], x, c.norm, c.norm_eps)
    if final_logits_only:
        x = x[:, -1:]
    if skip_logits:
        return {"final_x": x, "hidden": hidden, "states": states, "aux": 0.0}
    lg = embed_logits(params["embed"], x, h.rules, true_vocab=c.vocab_size)
    return {"logits": lg, "hidden": hidden, "states": states, "aux": 0.0}


def ssm_decode_step(params, cache, tokens, h: SSMHyper):
    """cache: dict(conv (L,B,W-1,I), ssm (L,B,I,N), lengths (B,))."""
    c = h.cfg
    x = embed_tokens(params["embed"], tokens, h.rules, scale=False,
                     d_model=c.d_model).astype(h.dtype)

    def body(x, xs):
        bp, cs, ss = xs
        hidden = x
        normed = apply_norm(bp["ln"], x, c.norm, c.norm_eps)
        out, (ncs, nss) = apply_mamba1(bp["m"], normed, h.mamba, h.rules,
                                       conv_state=cs, init_state=ss,
                                       remat_chunks=False)
        return x + out, (ncs, nss, hidden)

    x, (nconv, nssm, hidden) = jax.lax.scan(body, x,
                                            (params["blocks"], cache["conv"],
                                             cache["ssm"]))
    x = apply_norm(params["final_norm"], x, c.norm, c.norm_eps)
    lg = embed_logits(params["embed"], x, h.rules, true_vocab=c.vocab_size)
    return lg, {"conv": nconv, "ssm": nssm,
                "lengths": cache["lengths"] + 1}, hidden


def ssm_restore_states(params, hidden, h: SSMHyper):
    """ssm-rescan restoration: (L,B,S,D) hidden -> per-layer final states."""
    def one(bp, hl):
        normed = apply_norm(bp["ln"], hl.astype(h.dtype), h.cfg.norm,
                            h.cfg.norm_eps)
        _, (ncs, nss) = apply_mamba1(bp["m"], normed, h.mamba, h.rules,
                                     remat_chunks=False)
        return ncs, nss

    return jax.vmap(one)(params["blocks"], hidden)
