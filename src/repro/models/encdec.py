"""Encoder-decoder stack (whisper-medium).

The audio conv frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, S_enc, d_model); the encoder adds
sinusoidal positions and runs bidirectional attention blocks. The decoder
uses learned positions, causal self-attention and cross-attention over the
encoder output.

HCache for enc-dec (DESIGN.md §3): decoder self-KV restores from decoder
hidden states (paper op); cross-KV for *all* decoder layers restores from
the single saved encoder output — a stronger-than-paper compression ratio
(1 tensor -> 2·L tensors).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.config.arch import ArchConfig
from repro.distributed import tp as tp_lib
from repro.distributed.sharding import ShardingRules, constrain
from repro.models.layers import attention as attn_lib
from repro.models.layers.attention import AttnHyper
from repro.models.layers.embedding import (embed_tokens, init_embedding,
                                           logits as embed_logits, positional)
from repro.models.layers.mlp import apply_mlp, init_mlp
from repro.models.layers.norm import apply_norm, init_norm
from repro.models.layers.rope import sinusoidal_positions
from repro.models.module import stacked_init
from repro.models import transformer as tfm


@dataclasses.dataclass(frozen=True)
class EncDecHyper:
    cfg: ArchConfig
    rules: ShardingRules
    model_axis: int = 1
    dtype: Any = jnp.float32
    attn_chunk: int = 1024
    remat: str = "full"
    max_positions: int = 8192        # decoder learned-position table

    @functools.cached_property
    def attn(self) -> AttnHyper:
        c = self.cfg
        from repro.distributed.sharding import pad_heads
        padded, _ = pad_heads(c.n_heads, c.n_kv_heads, self.model_axis)
        return AttnHyper(n_heads=c.n_heads, n_kv_heads=c.n_kv_heads,
                         head_dim=c.head_dim_, padded_heads=padded,
                         use_rope=False, chunk=self.attn_chunk)


def _init_enc_block(rng, h: EncDecHyper) -> dict:
    c = h.cfg
    r1, r2 = jax.random.split(rng)
    return {
        "ln1": init_norm(c.norm, c.d_model, h.dtype),
        "attn": attn_lib.init_attention(r1, c.d_model, h.attn, h.dtype),
        "ln2": init_norm(c.norm, c.d_model, h.dtype),
        "mlp": init_mlp(r2, c.d_model, c.d_ff, c.ffn_glu, h.dtype),
    }


def _init_dec_block(rng, h: EncDecHyper) -> dict:
    c = h.cfg
    r1, r2, r3 = jax.random.split(rng, 3)
    return {
        "ln1": init_norm(c.norm, c.d_model, h.dtype),
        "self_attn": attn_lib.init_attention(r1, c.d_model, h.attn, h.dtype),
        "ln_x": init_norm(c.norm, c.d_model, h.dtype),
        "cross_attn": attn_lib.init_attention(r2, c.d_model, h.attn, h.dtype),
        "ln2": init_norm(c.norm, c.d_model, h.dtype),
        "mlp": init_mlp(r3, c.d_model, c.d_ff, c.ffn_glu, h.dtype),
    }


def init_encdec(rng, h: EncDecHyper) -> dict:
    c = h.cfg
    re, renc, rdec = jax.random.split(rng, 3)
    return {
        "embed": init_embedding(re, c.vocab_size, c.d_model, h.dtype,
                                c.tie_embeddings, h.max_positions, True),
        "enc_blocks": stacked_init(lambda r: _init_enc_block(r, h),
                                   c.encoder_layers, renc),
        "enc_norm": init_norm(c.norm, c.d_model, h.dtype),
        "dec_blocks": stacked_init(lambda r: _init_dec_block(r, h),
                                   c.n_layers, rdec),
        "final_norm": init_norm(c.norm, c.d_model, h.dtype),
    }


# ------------------------------------------------------------------ encoder
def encode(params, frames, h: EncDecHyper, *, capture_hidden: bool = False):
    """frames: (B, S_enc, D) stubbed frame embeddings -> enc_out (B,S_enc,D).
    Also returns per-layer hidden states when capturing (HCache save)."""
    c = h.cfg
    B, S, _ = frames.shape
    pos = sinusoidal_positions(S, c.d_model, h.dtype)
    x = frames.astype(h.dtype) + pos[None]
    x = constrain(x, h.rules, "batch", "seq", "d_model")
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    def body(x, bp):
        hidden = x
        normed = apply_norm(bp["ln1"], x, c.norm, c.norm_eps)
        q, k, v = attn_lib.project_qkv(bp["attn"], normed, h.attn, h.rules,
                                       positions)
        a = attn_lib.flash_attention_jnp(q, k, v, h.attn,
                                         q_positions=positions, causal=False)
        x = x + attn_lib.attn_output(bp["attn"], a, h.rules)
        normed2 = apply_norm(bp["ln2"], x, c.norm, c.norm_eps)
        x = x + apply_mlp(bp["mlp"], normed2, c.ffn_activation, h.rules)
        return x, hidden if capture_hidden else None

    body = tfm._remat_wrap(body, _lm_view(h))
    x, hidden = jax.lax.scan(body, x, params["enc_blocks"])
    return apply_norm(params["enc_norm"], x, c.norm, c.norm_eps), hidden


def _lm_view(h: EncDecHyper):
    return tfm.LMHyper(cfg=h.cfg, rules=h.rules, model_axis=h.model_axis,
                       dtype=h.dtype, attn_chunk=h.attn_chunk, remat=h.remat)


def cross_kv(params, enc_out, h: EncDecHyper):
    """Project encoder output into stacked cross-attention KV for all
    decoder layers: (L, B, S_enc, H, hd) ×2 — also the HCache restore op
    for the cross context."""
    def one(bp):
        return attn_lib.restore_kv(
            bp["cross_attn"]["wk"], bp["cross_attn"]["wv"], None, None,
            enc_out, h.attn, positions=None)

    return jax.vmap(one)(params["dec_blocks"])


# ------------------------------------------------------------------ decoder
def _dec_block(bp, x, h: EncDecHyper, *, positions, ck, cv, enc_len,
               self_kv_mode, k_cache=None, v_cache=None, lengths=None,
               emit_kv=False, hist_k=None, hist_v=None, hist_len=None,
               block_table=None, blk=None, off=None):
    """One decoder block; self_kv_mode in {"full", "step", "paged"}.

    ``hist_k``/``hist_v`` (B, hist_len, H, hd): restored self-attention
    history prepended to the chunk's KV — resume / round-N prefill after
    an HCache restoration (``positions`` must then be absolute, offset by
    ``hist_len``).

    ``paged`` mode routes the decoder self-KV through a physical page
    pool: ``k_cache``/``v_cache`` are (NB, bs, H, hd) pools, ``blk``/
    ``off`` the new token's page address, ``block_table`` (B, MB) the
    logical→physical map — same scatter-then-gather contract as
    ``transformer.block_decode_paged``; the cross-attention side is
    untouched (cross-KV stays a whole object per slot)."""
    c = h.cfg
    hidden_in = x
    normed = apply_norm(bp["ln1"], x, c.norm, c.norm_eps)
    q, k, v = attn_lib.project_qkv(bp["self_attn"], normed, h.attn, h.rules,
                                   positions)
    if self_kv_mode == "full":
        if hist_k is not None:
            k_all = jnp.concatenate([hist_k.astype(k.dtype), k], axis=1)
            v_all = jnp.concatenate([hist_v.astype(v.dtype), v], axis=1)
            kv_len = hist_len + x.shape[1]
        else:
            k_all, v_all, kv_len = k, v, None
        a = attn_lib.flash_attention_jnp(q, k_all, v_all, h.attn,
                                         q_positions=positions, causal=True,
                                         kv_len=kv_len)
        new_k, new_v = k, v
    elif self_kv_mode == "paged":
        k_cache = k_cache.at[blk, off].set(k[:, 0], mode="drop")
        v_cache = v_cache.at[blk, off].set(v[:, 0], mode="drop")
        # tensor-parallel seam: pools stay sharded over heads; scatter
        # and block-table gather never index the head axis
        k_cache = tp_lib.kv_seam(k_cache, 2)
        v_cache = tp_lib.kv_seam(v_cache, 2)
        B, MB = block_table.shape
        NB, bs = k_cache.shape[0], k_cache.shape[1]
        table = jnp.minimum(block_table, NB - 1)       # clamp sentinels
        kg = k_cache[table].reshape(B, MB * bs, *k_cache.shape[2:])
        vg = v_cache[table].reshape(B, MB * bs, *v_cache.shape[2:])
        a = attn_lib.decode_attention_jnp(q, kg, vg, h.attn,
                                          kv_len=lengths + 1)
        new_k, new_v = k_cache, v_cache
    else:
        B = x.shape[0]
        bidx = jnp.arange(B)
        k_cache = k_cache.at[bidx, lengths].set(k[:, 0], mode="drop")
        v_cache = v_cache.at[bidx, lengths].set(v[:, 0], mode="drop")
        a = attn_lib.decode_attention_jnp(q, k_cache, v_cache, h.attn,
                                          kv_len=lengths + 1)
        new_k, new_v = k_cache, v_cache
    # single all-gather at the output-projection seam (no-op off-mesh)
    a = tp_lib.logits_seam(a) if self_kv_mode == "paged" else a
    x = x + attn_lib.attn_output(bp["self_attn"], a, h.rules)

    normed_x = apply_norm(bp["ln_x"], x, c.norm, c.norm_eps)
    qx = jnp.einsum("bsd,dh->bsh", normed_x, bp["cross_attn"]["wq"])
    B, Sq = x.shape[:2]
    qx = qx.reshape(B, Sq, h.attn.padded_heads, h.attn.head_dim)
    ca = attn_lib.flash_attention_jnp(
        qx, ck, cv, h.attn,
        q_positions=jnp.zeros((B, Sq), jnp.int32), causal=False,
        kv_len=enc_len)
    x = x + attn_lib.attn_output(bp["cross_attn"], ca, h.rules)

    normed2 = apply_norm(bp["ln2"], x, c.norm, c.norm_eps)
    x = x + apply_mlp(bp["mlp"], normed2, c.ffn_activation, h.rules)
    return x, ((new_k, new_v) if (emit_kv or self_kv_mode
                                  in ("step", "paged")) else None), hidden_in


def decode_prefill(params, tokens, enc_out, h: EncDecHyper, *,
                   capture_hidden: bool = False, emit_kv: bool = False,
                   final_logits_only: bool = False,
                   skip_logits: bool = False,
                   hist_kv=None, hist_len=None, cross=None,
                   pos_offset: int = 0):
    """Teacher-forced / prefill decoder pass over (B, S_dec) tokens.

    Resume path (HCache, serving engine): ``hist_kv`` — stacked restored
    self-KV history (L, B, hist_len, H, hd) ×2 the chunk attends over;
    ``cross`` — precomputed stacked cross KV (L, B, S_enc, H, hd) ×2 from
    the slot's view, replacing the ``enc_out`` projection (``enc_out``
    may then be None); ``pos_offset`` — the chunk's absolute start
    position (= hist_len), so learned positions and the causal mask line
    up with the restored prefix."""
    c = h.cfg
    B, S = tokens.shape
    positions = jnp.broadcast_to(pos_offset + jnp.arange(S)[None, :], (B, S))
    x = embed_tokens(params["embed"], tokens, h.rules, scale=False,
                     d_model=c.d_model)
    x = x + positional(params["embed"], positions).astype(x.dtype)
    x = x.astype(h.dtype)
    ckv = cross if cross is not None else cross_kv(params, enc_out, h)

    if hist_kv is not None:
        def body(x, xs):
            bp, (ck, cv), hk, hv = xs
            x, kv, hidden = _dec_block(bp, x, h, positions=positions, ck=ck,
                                       cv=cv, enc_len=None,
                                       self_kv_mode="full", emit_kv=emit_kv,
                                       hist_k=hk, hist_v=hv,
                                       hist_len=hist_len)
            return x, (kv, hidden if capture_hidden else None)

        xs = (params["dec_blocks"], ckv, hist_kv[0], hist_kv[1])
    else:
        def body(x, xs):
            bp, (ck, cv) = xs
            x, kv, hidden = _dec_block(bp, x, h, positions=positions, ck=ck,
                                       cv=cv, enc_len=None,
                                       self_kv_mode="full", emit_kv=emit_kv)
            return x, (kv, hidden if capture_hidden else None)

        xs = (params["dec_blocks"], ckv)

    body = tfm._remat_wrap(body, _lm_view(h))
    x, (kv, hidden) = jax.lax.scan(body, x, xs)
    x = apply_norm(params["final_norm"], x, c.norm, c.norm_eps)
    if final_logits_only:
        x = x[:, -1:]
    if skip_logits:
        return {"final_x": x, "kv": kv, "hidden": hidden, "cross_kv": ckv,
                "aux": 0.0}
    lg = embed_logits(params["embed"], x, h.rules, true_vocab=c.vocab_size)
    return {"logits": lg, "kv": kv, "hidden": hidden, "cross_kv": ckv,
            "aux": 0.0}


def decode_step(params, cache, tokens, h: EncDecHyper):
    """cache: dict(self_k/self_v (L,B,Sd,H,hd), cross_k/cross_v
    (L,B,Senc,H,hd), enc_len scalar or (B,), lengths (B,))."""
    c = h.cfg
    lengths = cache["lengths"]
    B = tokens.shape[0]
    positions = lengths[:, None]
    x = embed_tokens(params["embed"], tokens, h.rules, scale=False,
                     d_model=c.d_model)
    x = x + positional(params["embed"], positions).astype(x.dtype)
    x = x.astype(h.dtype)

    def body(x, xs):
        bp, kc, vc, ck, cv = xs
        x, (nk, nv), hidden = _dec_block(bp, x, h, positions=positions,
                                         ck=ck, cv=cv,
                                         enc_len=cache.get("enc_len"),
                                         self_kv_mode="step", k_cache=kc,
                                         v_cache=vc, lengths=lengths)
        return x, (nk, nv, hidden)

    xs = (params["dec_blocks"], cache["self_k"], cache["self_v"],
          cache["cross_k"], cache["cross_v"])
    x, (nk, nv, hidden) = jax.lax.scan(body, x, xs)
    x = apply_norm(params["final_norm"], x, c.norm, c.norm_eps)
    lg = embed_logits(params["embed"], x, h.rules, true_vocab=c.vocab_size)
    new_cache = dict(cache, self_k=nk, self_v=nv, lengths=lengths + 1)
    return lg, new_cache, hidden


def decode_step_paged(params, cache, tokens, h: EncDecHyper):
    """Paged-self-KV decode step (serving 'paged' backend for enc-dec).

    cache: dict(k_pool/v_pool (L, NB, bs, H, hd) physical pages,
    block_table (B, MB) int32 with NB as the unallocated sentinel,
    cross_k/cross_v (L, B, S_enc, H, hd) whole-object per slot,
    enc_len (B,), lengths (B,)). Same contract as ``decode_step`` —
    with every live position mapped by the block table the gathered
    logical layout is byte-identical to the contiguous self-KV region
    (masked positions contribute exactly-zero probability), so paged
    and contiguous enc-dec decode agree bitwise. Only the decoder
    self-KV pages; the cross context keeps the paired whole-object
    layout (there is no block-table analog for it)."""
    c = h.cfg
    lengths = cache["lengths"]
    bt = cache["block_table"]
    bs = cache["k_pool"].shape[2]
    B = tokens.shape[0]
    MB = bt.shape[1]
    NB = cache["k_pool"].shape[1]
    positions = lengths[:, None]
    x = embed_tokens(params["embed"], tokens, h.rules, scale=False,
                     d_model=c.d_model)
    x = x + positional(params["embed"], positions).astype(x.dtype)
    x = x.astype(h.dtype)
    bidx = jnp.arange(B)
    li = lengths // bs
    # a logical page past the table (slot exactly full) must become a
    # dropped sentinel write, not clamp into the slot's last live page
    blk = jnp.where(li < MB, bt[bidx, jnp.minimum(li, MB - 1)], NB)
    off = lengths % bs

    def body(x, xs):
        bp, kp, vp, ck, cv = xs
        x, (nk, nv), hidden = _dec_block(bp, x, h, positions=positions,
                                         ck=ck, cv=cv,
                                         enc_len=cache.get("enc_len"),
                                         self_kv_mode="paged", k_cache=kp,
                                         v_cache=vp, lengths=lengths,
                                         block_table=bt, blk=blk, off=off)
        return x, (nk, nv, hidden)

    xs = (params["dec_blocks"], cache["k_pool"], cache["v_pool"],
          cache["cross_k"], cache["cross_v"])
    x, (nk, nv, hidden) = jax.lax.scan(body, x, xs)
    x = apply_norm(params["final_norm"], x, c.norm, c.norm_eps)
    lg = embed_logits(params["embed"], x, h.rules, true_vocab=c.vocab_size)
    new_cache = dict(cache, k_pool=nk, v_pool=nv, lengths=lengths + 1)
    return lg, new_cache, hidden


def restore_self_kv(params, hidden, h: EncDecHyper, *, positions):
    """HCache paper op for the decoder self-attention KV."""
    c = h.cfg

    def one(bp, hl):
        normed = apply_norm(bp["ln1"], hl.astype(h.dtype), c.norm, c.norm_eps)
        return attn_lib.restore_kv(bp["self_attn"]["wk"],
                                   bp["self_attn"]["wv"], None, None,
                                   normed, h.attn, positions)

    return jax.vmap(one)(params["dec_blocks"], hidden)
