"""Hybrid Mamba2 + attention stack (zamba2-style).

The stack is a scan over *super-blocks*: each super-block is (k-1) Mamba2
blocks followed by one full transformer (attention+MLP) block, where
k = cfg.hybrid_attn_every. zamba2-2.7b: 54 layers = 9 super-blocks of
(5 mamba + 1 attn).

HCache applicability (DESIGN.md §3): attention blocks restore KV from their
saved hidden states exactly as the paper; Mamba2 blocks use ``ssm-rescan``
— the layer's final recurrent state is recomputed from that layer's saved
input, which only needs the state recurrence (no intra-chunk attention
matrices, no output projection): cheaper than a forward pass and fully
layer-parallel.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.config.arch import ArchConfig
from repro.distributed.sharding import ShardingRules, constrain
from repro.models.layers import attention as attn_lib
from repro.models.layers.mamba import (Mamba2Hyper, apply_mamba2,
                                       init_mamba2)
from repro.models.layers.norm import apply_norm, init_norm
from repro.models.layers.embedding import init_embedding, embed_tokens, logits as embed_logits
from repro.models.module import stacked_init
from repro.models import transformer as tfm


@dataclasses.dataclass(frozen=True)
class HybridHyper:
    cfg: ArchConfig
    rules: ShardingRules
    model_axis: int = 1
    dtype: Any = jnp.float32
    attn_chunk: int = 1024
    remat: str = "full"

    @property
    def k(self) -> int:
        return self.cfg.hybrid_attn_every

    @property
    def n_super(self) -> int:
        return self.cfg.n_layers // self.k

    @functools.cached_property
    def mamba(self) -> Mamba2Hyper:
        c = self.cfg
        return Mamba2Hyper(d_model=c.d_model, d_state=c.ssm_state,
                           head_dim=c.ssm_headdim, d_conv=c.ssm_conv,
                           expand=c.ssm_expand)

    @functools.cached_property
    def lm(self) -> tfm.LMHyper:
        """LMHyper view used for the attention blocks."""
        return tfm.LMHyper(cfg=self.cfg, rules=self.rules,
                           model_axis=self.model_axis, dtype=self.dtype,
                           attn_chunk=self.attn_chunk, remat=self.remat)


def _init_mamba_block(rng, h: HybridHyper) -> dict:
    r1, r2 = jax.random.split(rng)
    return {"ln": init_norm(h.cfg.norm, h.cfg.d_model, h.dtype),
            "m": init_mamba2(r2, h.mamba, h.dtype)}


def init_hybrid(rng, h: HybridHyper) -> dict:
    re, rm, ra = jax.random.split(rng, 3)
    c = h.cfg
    return {
        "embed": init_embedding(re, c.vocab_size, c.d_model, h.dtype,
                                c.tie_embeddings),
        "mamba": stacked_init(
            lambda r: stacked_init(lambda r2: _init_mamba_block(r2, h),
                                   h.k - 1, r),
            h.n_super, rm),
        "attn": stacked_init(lambda r: tfm.init_block(r, h.lm), h.n_super, ra),
        "final_norm": init_norm(c.norm, c.d_model, h.dtype),
    }


def _mamba_fwd(mp, x, h: HybridHyper, conv_state=None, ssm_state=None):
    c = h.cfg
    hidden_in = x
    normed = apply_norm(mp["ln"], x, c.norm, c.norm_eps)
    out, (ncs, nss) = apply_mamba2(mp["m"], normed, h.mamba, h.rules,
                                   conv_state=conv_state, init_state=ssm_state)
    return x + out, hidden_in, (ncs, nss)


def hybrid_forward(params, tokens, h: HybridHyper, *, positions=None,
                   capture_hidden: bool = False, emit_state: bool = False,
                   final_logits_only: bool = False,
                   skip_logits: bool = False):
    """Full-sequence forward (train / prefill).

    Returns dict(logits, aux, and when emit_state: attn kv
    (n_super,B,S,Kv,hd), mamba conv/ssm states; when capture_hidden:
    mamba_hidden (n_super,k-1,B,S,D), attn_hidden (n_super,B,S,D))."""
    c = h.cfg
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = embed_tokens(params["embed"], tokens, h.rules, scale=False,
                     d_model=c.d_model).astype(h.dtype)
    x = constrain(x, h.rules, "batch", "seq", "d_model")

    def super_body(carry, xs):
        x, aux = carry
        mp_stack, ap = xs

        def inner(xc, mp):
            xc, hidden, (ncs, nss) = _mamba_fwd(mp, xc, h)
            return xc, (hidden if capture_hidden else None,
                        (ncs, nss) if emit_state else None)

        x, (m_hidden, m_states) = jax.lax.scan(inner, x, mp_stack)
        x, a, kv, a_hidden = tfm.block_forward(
            ap, x, h.lm, positions=positions, window=None,
            emit_kv=emit_state)
        if kv is not None:
            kv = tuple(constrain(t, h.rules, "batch", "kv_seq", "kv_heads",
                                 "head_dim") for t in kv)
        ys = (m_hidden, m_states, kv,
              a_hidden if capture_hidden else None)
        return (x, aux + a), ys

    body = tfm._remat_wrap(super_body, h.lm)
    (x, aux), ys = jax.lax.scan(body, (x, 0.0), (params["mamba"],
                                                 params["attn"]))
    m_hidden, m_states, kv, a_hidden = ys
    x = apply_norm(params["final_norm"], x, c.norm, c.norm_eps)
    if final_logits_only:
        x = x[:, -1:]
    if skip_logits:
        return {"final_x": x, "aux": aux, "kv": kv,
                "mamba_states": m_states, "mamba_hidden": m_hidden,
                "attn_hidden": a_hidden}
    lg = embed_logits(params["embed"], x, h.rules, softcap=c.logit_softcap,
                      true_vocab=c.vocab_size)
    return {"logits": lg, "aux": aux, "kv": kv, "mamba_states": m_states,
            "mamba_hidden": m_hidden, "attn_hidden": a_hidden}


def hybrid_decode_step(params, cache, tokens, h: HybridHyper):
    """cache: dict(attn_k/attn_v (n_super,B,Smax,Kv,hd), conv
    (n_super,k-1,B,W-1,C), ssm (n_super,k-1,B,H,P,N), lengths (B,))."""
    c = h.cfg
    lengths = cache["lengths"]
    x = embed_tokens(params["embed"], tokens, h.rules, scale=False,
                     d_model=c.d_model).astype(h.dtype)

    def super_body(x, xs):
        mp_stack, ap, conv, ssm, kc, vc = xs

        def inner(xc, mxs):
            mp, cs, ss = mxs
            xc, hidden, (ncs, nss) = _mamba_fwd(mp, xc, h, conv_state=cs,
                                                ssm_state=ss)
            return xc, (ncs, nss, hidden)

        x, (nconv, nssm, m_hidden) = jax.lax.scan(inner, x,
                                                  (mp_stack, conv, ssm))
        a_hidden = x
        x, nk, nv, _ = tfm.block_decode(ap, x, h.lm, k_cache=kc, v_cache=vc,
                                        lengths=lengths, window=None)
        return x, (nconv, nssm, nk, nv, m_hidden, a_hidden)

    xs = (params["mamba"], params["attn"], cache["conv"], cache["ssm"],
          cache["attn_k"], cache["attn_v"])
    x, (nconv, nssm, nk, nv, m_hidden, a_hidden) = jax.lax.scan(
        super_body, x, xs)
    x = apply_norm(params["final_norm"], x, c.norm, c.norm_eps)
    lg = embed_logits(params["embed"], x, h.rules, softcap=c.logit_softcap,
                      true_vocab=c.vocab_size)
    new_cache = {"attn_k": nk, "attn_v": nv, "conv": nconv, "ssm": nssm,
                 "lengths": lengths + 1}
    return lg, new_cache, (m_hidden, a_hidden)


# ---------------------------------------------------------------- HCache ops
def hybrid_restore_attn_kv(params, attn_hidden, h: HybridHyper, *, positions):
    """Restore attention-block KV from saved hidden states (paper op)."""
    c = h.cfg

    def one(ap, hl):
        normed = apply_norm(ap["ln1"], hl.astype(h.dtype), c.norm, c.norm_eps)
        return attn_lib.restore_kv(
            ap["attn"]["wk"], ap["attn"]["wv"], ap["attn"].get("bk"),
            ap["attn"].get("bv"), normed, h.lm.attn, positions)

    return jax.vmap(one)(params["attn"], attn_hidden)


def hybrid_restore_mamba_states(params, mamba_hidden, h: HybridHyper):
    """ssm-rescan: recompute each mamba layer's (conv, ssm) final state from
    that layer's saved input hidden states. Layer-parallel (double vmap)."""
    def one(mp, hl):
        normed = apply_norm(mp["ln"], hl.astype(h.dtype), h.cfg.norm,
                            h.cfg.norm_eps)
        _, (ncs, nss) = apply_mamba2(mp["m"], normed, h.mamba, h.rules)
        return ncs, nss

    return jax.vmap(jax.vmap(one))(params["mamba"], mamba_hidden)
