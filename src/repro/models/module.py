"""Minimal pure-JAX module system.

No flax in the container, so parameters are plain pytrees (nested dicts of
arrays). Every parameter is created *boxed* with its logical sharding axes;
``split`` separates the value tree from the axes tree so apply-functions see
plain arrays while the launcher can resolve NamedShardings.

Design rules:
  * init functions are pure (rng -> boxed tree) and vmap-able, so stacked
    (scan-over-layers) parameters are built with ``stacked_init``.
  * logical axes are strings resolved by ``repro.distributed.sharding``.
    A stacked parameter gets a leading "layers" axis automatically.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

Axes = Tuple[Optional[str], ...]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Boxed:
    """A parameter value carrying its logical sharding axes."""

    value: Any
    axes: Axes

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, axes, children):
        return cls(children[0], axes)


def is_boxed(x) -> bool:
    return isinstance(x, Boxed)


def box(value, *axes: Optional[str]) -> Boxed:
    if value.ndim != len(axes):
        raise ValueError(f"axes {axes} do not match shape {value.shape}")
    return Boxed(value, tuple(axes))


def split(tree):
    """Boxed tree -> (values tree, axes tree)."""
    values = jax.tree.map(lambda b: b.value, tree, is_leaf=is_boxed)
    axes = jax.tree.map(lambda b: b.axes, tree, is_leaf=is_boxed)
    return values, axes


def merge(values, axes):
    return jax.tree.map(Boxed, values, axes,
                        is_leaf=lambda x: not isinstance(x, dict))


# ----------------------------------------------------------------- initializers
def normal_init(rng, shape, dtype, scale: float):
    return (jax.random.normal(rng, shape, jnp.float32) * scale).astype(dtype)


def dense_param(rng, d_in: int, d_out: int, dtype, in_axis: Optional[str],
                out_axis: Optional[str], scale: Optional[float] = None) -> Boxed:
    scale = scale if scale is not None else d_in ** -0.5
    return box(normal_init(rng, (d_in, d_out), dtype, scale), in_axis, out_axis)


def bias_param(d: int, dtype, axis: Optional[str]) -> Boxed:
    return box(jnp.zeros((d,), dtype), axis)


def scale_param(d: int, dtype, axis: Optional[str], value: float = 1.0) -> Boxed:
    return box(jnp.full((d,), value, dtype), axis)


def stacked_init(per_layer_init: Callable, n: int, rng) -> Any:
    """vmap a per-layer init over ``n`` layers; prepend the "layers" axis."""
    rngs = jax.random.split(rng, n)
    stacked = jax.vmap(per_layer_init)(rngs)
    return jax.tree.map(
        lambda b: Boxed(b.value, ("layers",) + b.axes), stacked, is_leaf=is_boxed)


def count_params(values_tree) -> int:
    return sum(x.size for x in jax.tree.leaves(values_tree))


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree)
