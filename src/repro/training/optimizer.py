"""AdamW in pure JAX with ZeRO-1 sharding metadata.

The container has no optax; this is a complete, production-shaped AdamW:
global-norm clipping, decoupled weight decay, bias correction, and an
optional bf16 error-feedback compensation buffer (gradient "compression":
the backward all-reduces run in the bf16 compute dtype — half the DP
collective bytes — and the feedback buffer folds the quantization error
into the next step, 1-bit-Adam style but at 16 bits).

ZeRO-1: optimizer moments (and the fp32 master params) are sharded over the
data axis on top of the model-parallel sharding — `opt_axes` rewrites each
parameter's logical axes so the largest divisible unsharded dim maps to
"opt_fsdp" (resolved to the data axis by the sharding rules). Required for
grok-1-314b: 12 bytes/param of optimizer state fits 256 chips only when
data-sharded.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    error_feedback: bool = False


def init_opt_state(params, *, error_feedback: bool = False) -> dict:
    zeros = lambda t: jax.tree.map(jnp.zeros_like, t)
    state = {"m": zeros(params), "v": zeros(params),
             "step": jnp.zeros((), jnp.int32)}
    if error_feedback:
        state["ef"] = zeros(params)
    return state


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, cfg: AdamWConfig
                 ) -> Tuple[Any, dict, dict]:
    """params/grads fp32 trees -> (new_params, new_state, metrics)."""
    step = state["step"] + 1
    if cfg.error_feedback:
        grads = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e,
                             grads, state["ef"])
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g * scale, grads)

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        new_p = p - cfg.lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                              + cfg.weight_decay * p)
        return new_p, m, v

    flat_p, tree = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = tree.unflatten([o[0] for o in out])
    new_state = {"m": tree.unflatten([o[1] for o in out]),
                 "v": tree.unflatten([o[2] for o in out]),
                 "step": step}
    if cfg.error_feedback:
        # error feedback vs the bf16-quantized gradient actually applied
        def ef(g):
            return (g - g.astype(jnp.bfloat16).astype(jnp.float32))
        new_state["ef"] = jax.tree.map(ef, grads)
    return new_params, new_state, {"grad_norm": gnorm}


# ------------------------------------------------------------ ZeRO-1 sharding
def opt_axes(axes: Tuple[Optional[str], ...], shape: Tuple[int, ...],
             data_size: int) -> Tuple[Optional[str], ...]:
    """Rewrite a param's logical axes for optimizer/master storage: the
    largest unsharded, divisible dim becomes "opt_fsdp" (ZeRO-1)."""
    best, best_dim = None, 0
    for i, (ax, d) in enumerate(zip(axes, shape)):
        if ax is None and d % data_size == 0 and d > best_dim:
            best, best_dim = i, d
    if best is None:
        return axes
    new = list(axes)
    new[best] = "opt_fsdp"
    return tuple(new)


def opt_axes_tree(axes_tree, shapes_tree, data_size: int):
    return jax.tree.map(
        lambda a, s: opt_axes(a, s.shape, data_size), axes_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
