from repro.training.checkpoint import CheckpointManager
from repro.training.data import DataConfig, batch_at, leval_trace, sharegpt_trace
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state
from repro.training.train_step import Trainer, chunked_ce_loss
