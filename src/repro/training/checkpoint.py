"""Sharded, asynchronous, atomically-committed checkpoints.

Layout:
    <root>/step_000042.tmp/      (written)
    <root>/step_000042/          (atomic rename = commit)
        manifest.json            tree structure, shapes, dtypes
        leaf_00000.npy …         one file per pytree leaf

On a real multi-host pod each process writes only its addressable shards
(per-leaf files keyed by shard index) — the single-process container writes
the whole array, and the format keeps the per-leaf split so the multi-host
extension only changes the writer loop.

Elastic restore: leaves are `jax.device_put` against the *target* sharding
tree, which may come from a different mesh shape than the one that saved —
restarting 512-chip jobs on 256 chips (or vice versa) is a reshard on load,
no file rewrite.

Async: `save` snapshots to host (np.asarray) synchronously — the fast part
— and writes files on a background thread; `wait` joins before the next
save (single outstanding checkpoint, bounded memory).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, List, Optional, Tuple

import jax
import numpy as np


def _tree_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


class CheckpointManager:
    def __init__(self, root: str, *, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state, *, wait: bool = False) -> None:
        self.wait()
        host = [(k, np.asarray(v)) for k, v in _tree_paths(state)]
        treedef = jax.tree.structure(state)
        manifest = {
            "step": step,
            "keys": [k for k, _ in host],
            "treedef": str(treedef),
        }

        def _write():
            tmp = os.path.join(self.root, f"step_{step:09d}.tmp")
            final = os.path.join(self.root, f"step_{step:09d}")
            os.makedirs(tmp, exist_ok=True)
            for i, (_, arr) in enumerate(host):
                np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)                     # atomic commit
            self._retain()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
        if wait:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _retain(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.root, f"step_{s:09d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.root):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like, *, step: Optional[int] = None,
                shardings=None) -> Tuple[int, Any]:
        """Load into the structure of ``like``; device_put against
        ``shardings`` (tree or None) — elastic resharding happens here."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = os.path.join(self.root, f"step_{step:09d}")
        leaves = []
        i = 0
        while os.path.exists(os.path.join(d, f"leaf_{i:05d}.npy")):
            leaves.append(np.load(os.path.join(d, f"leaf_{i:05d}.npy")))
            i += 1
        treedef = jax.tree.structure(like)
        state = jax.tree.unflatten(treedef, leaves)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        return step, state
