"""Deterministic, resumable data pipeline.

Training batches are a *stateless* function of (seed, step): restart after
a failure at step N reproduces exactly the batches a continuous run would
have seen — checkpoint/restart never perturbs the data order, and elastic
re-scaling only needs the step counter. A skip-ahead is O(1).

Also generates the serving traces the paper evaluates on, matching the
published statistics: ShareGPT4-like multi-round conversations (§2.3,
Fig 3: ~66.8 input / ~358.8 output tokens per round, history CDF median
≈2.5k) and L-Eval-like long-context tasks (Table 1).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0


def batch_at(cfg: DataConfig, step: int, *, targets: bool = True) -> dict:
    """The (seed, step)-deterministic batch."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    tokens = jax.random.randint(
        key, (cfg.global_batch, cfg.seq_len + 1), 0, cfg.vocab_size,
        dtype=jnp.int32)
    out = {"tokens": tokens[:, :-1]}
    if targets:
        out["targets"] = tokens[:, 1:]
    return out


def stream(cfg: DataConfig, start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield batch_at(cfg, step)
        step += 1


# ---------------------------------------------------------- serving traces
@dataclasses.dataclass
class Round:
    session_id: str
    input_len: int
    output_len: int
    arrival: float           # seconds


def sharegpt_trace(n_sessions: int, rounds_per_session: int = 5, *,
                   rate: float = 1.0, round_interval: float = 30.0,
                   seed: int = 0) -> List[Round]:
    """ShareGPT4-like trace (paper Fig 3): Poisson session arrivals,
    per-round lognormal input ~66.8 / output ~358.8 tokens."""
    rng = np.random.default_rng(seed)
    rounds: List[Round] = []
    t = 0.0
    for s in range(n_sessions):
        t += rng.exponential(1.0 / rate)
        rt = t
        for r in range(rounds_per_session):
            inp = max(int(rng.lognormal(np.log(50.0), 0.8)), 4)
            out = max(int(rng.lognormal(np.log(250.0), 0.9)), 8)
            rounds.append(Round(f"s{s}", inp, out, rt))
            rt += round_interval
    rounds.sort(key=lambda r: r.arrival)
    return rounds


def leval_trace(n_requests: int, *, seed: int = 0,
                zipf_alpha: Optional[float] = None,
                n_contexts: int = 20) -> List[Round]:
    """L-Eval-like trace (paper Table 1): bimodal — long shared contexts
    (mean ≈16k tokens), short instructions/outputs (<100). With
    ``zipf_alpha`` the context popularity is Zipfian (paper Fig 15)."""
    rng = np.random.default_rng(seed)
    ctx_lens = np.clip(rng.lognormal(np.log(9000.0), 0.7, n_contexts),
                       4000, 16384).astype(int)
    rounds = []
    t = 0.0
    for i in range(n_requests):
        if zipf_alpha is None:
            ctx = int(rng.integers(n_contexts))
        else:
            ranks = np.arange(1, n_contexts + 1, dtype=np.float64)
            p = ranks ** -zipf_alpha
            ctx = int(rng.choice(n_contexts, p=p / p.sum()))
        t += rng.exponential(2.0)
        rounds.append(Round(f"ctx{ctx}", int(rng.integers(16, 100)),
                            int(rng.integers(4, 64)), t))
    return rounds
