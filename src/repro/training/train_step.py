"""Train step: mixed precision, chunked vocab-parallel cross-entropy,
ZeRO-1 resharding, remat — the function the dry-run lowers for train_4k.

Structure (GSPMD handles every collective):

  master params: fp32, sharded (model × data) via ``opt_axes``   [ZeRO-1/3]
  fwd/bwd:       bf16 cast + constraint to model-only specs      [all-gather]
  grads:         flow back onto the master sharding              [reduce-scatter]
  loss:          scan over sequence chunks; per-chunk logits are
                 vocab-sharded and never materialized for the full sequence
                 (gemma2: 1M tokens × 256k vocab would be 0.5 TB).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import ShardingRules, constrain
from repro.models.layers.embedding import logits as embed_logits
from repro.models.model import Model
from repro.training.optimizer import (AdamWConfig, adamw_update,
                                      init_opt_state, opt_axes_tree)


def chunked_ce_loss(embed_params, final_x, targets, rules: ShardingRules, *,
                    softcap: Optional[float], true_vocab: Optional[int] = None,
                    n_chunks: int = 8):
    """Mean CE over (B,S) targets from final hidden states (B,S,D).

    The per-chunk function is rematerialized: backward recomputes each
    chunk's logits instead of keeping (B, S, V) alive."""
    B, S, D = final_x.shape
    while S % n_chunks:
        n_chunks -= 1
    C = S // n_chunks
    xc = final_x.reshape(B, n_chunks, C, D).transpose(1, 0, 2, 3)
    tc = targets.reshape(B, n_chunks, C).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_loss(carry, xs):
        x, t = xs
        lg = embed_logits(embed_params, x, rules, softcap=softcap,
                          true_vocab=true_vocab)
        lg = lg.astype(jnp.float32)
        m = jax.lax.stop_gradient(lg.max(axis=-1, keepdims=True))
        lse = jnp.log(jnp.sum(jnp.exp(lg - m), axis=-1)) + m[..., 0]
        label_lg = jnp.take_along_axis(lg, t[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(lse - label_lg), None

    total, _ = jax.lax.scan(chunk_loss, jnp.zeros((), jnp.float32), (xc, tc))
    return total / (B * S)


@dataclasses.dataclass
class Trainer:
    """Builds the jit-able train_step for one model + mesh."""

    model: Model
    rules: ShardingRules
    opt: AdamWConfig = AdamWConfig()
    loss_chunks: int = 8
    aux_weight: float = 0.01          # MoE load-balance loss weight

    def init_state(self, rng) -> Tuple[dict, Any]:
        """Returns (state, logical axes tree for sharding resolution)."""
        from repro.models.module import split
        boxed = self.model.init(rng)
        values, axes = split(boxed)
        params = jax.tree.map(lambda x: x.astype(jnp.float32), values)
        state = {"params": params,
                 "opt": init_opt_state(params,
                                       error_feedback=self.opt.error_feedback)}
        return state, axes

    def state_axes(self, axes, state, data_size: int = 1):
        """Logical axes for every leaf of the train state (ZeRO-1)."""
        shapes = state["params"]
        p_axes = opt_axes_tree(axes, shapes, data_size)
        opt_state_axes = {"m": p_axes, "v": p_axes, "step": ()}
        if "ef" in state["opt"]:
            opt_state_axes["ef"] = p_axes
        return {"params": p_axes, "opt": opt_state_axes}

    def loss_fn(self, params_f32, batch) -> Tuple[jnp.ndarray, Dict]:
        compute = jax.tree.map(
            lambda x: x.astype(self.model.dtype)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, params_f32)
        out = self.model.forward(compute, batch, skip_logits=True)
        loss = chunked_ce_loss(
            compute["embed"], out["final_x"], batch["targets"], self.rules,
            softcap=self.model.cfg.logit_softcap,
            true_vocab=self.model.cfg.vocab_size, n_chunks=self.loss_chunks)
        aux = out.get("aux", 0.0)
        total = loss + self.aux_weight * aux
        return total, {"ce": loss, "aux": aux}

    def train_step(self, state: dict, batch: dict) -> Tuple[dict, Dict]:
        (loss, parts), grads = jax.value_and_grad(
            self.loss_fn, has_aux=True)(state["params"], batch)
        new_params, new_opt, om = adamw_update(state["params"], grads,
                                               state["opt"], self.opt)
        metrics = {"loss": loss, **parts, **om}
        return {"params": new_params, "opt": new_opt}, metrics
