"""Pipelined restoration executor (paper §4.1, DESIGN.md §5, §10, §11).

One source of truth for restoration: a ``Schedule`` compiles into an
ordered task graph (``compile_tasks``) of per-layer steps — striped
chunk-store IO reads, hidden→KV projections, recompute-prefix segments,
SSM state blob loads, and for enc-dec sessions the ``io_enc``
encoder-blob read + ``project_cross`` cross-KV projection pair (both
charged via ``CrossTimes``, so the cross side is costed, not a zero-time
blob). The same graph serves three consumers:

  * ``replay``                — virtual two-stream replay of a task order
                                under a hardware profile → ``Timeline``.
                                ``core.pipeline.simulate`` is exactly
                                ``replay(compile_tasks(methods), times)``.
  * ``RestorationExecutor``   — executes the graph *incrementally*
                                (``step(max_tasks)``), interleaving the IO
                                and compute streams event-driven, writing
                                each finished layer straight into a
                                ``RestoreSink`` (the serving engine's batch
                                slot — no intermediate B=1 cache).
  * prefetch                  — an executor without a sink may run IO
                                tasks early (queued sessions warm their
                                layer-0 reads before a slot frees).

The executor records the order tasks actually executed in; its reported
``Timeline`` is ``replay`` over that executed order, so the engine's
numbers and the analytic simulation can never drift apart.

Batched data path (DESIGN.md §10): projection tasks are compiled into
*groups* of ``group_size`` layers. A group executes as ONE stacked
device call — hidden states for all members land in a single
host→device upload, weights come from a once-per-``(model, params)``
``RestoreParamPack`` (device-stacked wk/wv/bk/bv/ln1 + precomputed RoPE
tables; no per-task param re-gather), and the result flows to the sink
through ``put_kv_group`` (one scatter for the whole group). Projection
shapes are bucketed to powers of two over the token dimension with
zero-padded tails, so every session in a bucket reuses one compiled
projection — zero recompiles across a serving run. ``replay`` models
groups as single compute tasks charged ``dispatch_overhead`` once, so
group size is a measurable bubbles-vs-dispatch trade-off.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.arch import BlockKind
from repro.core.cost_model import (MethodTimes, layer_costs,
                                   link_priced_times, method_times)
from repro.core.scheduler import Schedule
from repro.kernels import ops
from repro.models.layers.norm import apply_norm
from repro.models.layers.rope import rope_angles
from repro.models.layers import attention as attn_lib

# Task kinds. IO-stream: io_h (hidden fetch), io_kv (raw KV fetch),
# io_enc (enc-dec: the saved encoder-output blob, sized in S_enc), blob
# (SSM-state/token whole-object reads — O(1) in tokens, charged zero
# virtual time as in the paper's model). Compute-stream: recompute (one
# prefix layer from tokens), project (hidden → K,V GEMM for a GROUP of
# layers — one device dispatch per group), project_cross (enc-dec: the
# single encoder output → cross-KV for ALL decoder layers).
IO_KINDS = ("io_h", "io_kv", "io_enc", "blob")
COMPUTE_KINDS = ("recompute", "project", "project_cross")


@dataclasses.dataclass(frozen=True)
class Task:
    kind: str                 # io_h|io_kv|io_enc|blob|recompute|project|
    #                           project_cross
    layer: int                # global layer index (-1 for blob/enc tasks;
    #                           first member for project groups)
    dep: Optional[int] = None  # task-list index that must execute first
    layers: Optional[Tuple[int, ...]] = None   # project group members
    deps: Optional[Tuple[int, ...]] = None     # all fetches a group needs

    @property
    def stream(self) -> str:
        return "io" if self.kind in IO_KINDS else "compute"

    @property
    def members(self) -> Tuple[int, ...]:
        return self.layers if self.layers is not None else (self.layer,)

    @property
    def all_deps(self) -> Tuple[int, ...]:
        if self.deps is not None:
            return self.deps
        return () if self.dep is None else (self.dep,)


@dataclasses.dataclass(frozen=True)
class CrossTimes:
    """Virtual durations of the enc-dec cross-restoration pair: the
    encoder-blob read (one (S_enc, D) tensor) and the cross-KV
    projection (K,V GEMMs for every decoder layer from that one blob —
    the 1 → 2·L expansion DESIGN.md §3 describes)."""

    io: float
    compute: float


def group_widths(group_size, n_hidden: int) -> Tuple[int, ...]:
    """Normalize a group plan — a uniform width (int) or an explicit
    partition (sequence of widths, the fetch-aligned form) — into the
    tuple of group widths that covers ``n_hidden`` hidden layers
    exactly. A short partition is extended with its last width; a long
    one is truncated; widths are clamped positive."""
    if n_hidden <= 0:
        return ()
    if isinstance(group_size, (tuple, list)):
        widths: List[int] = []
        total = 0
        for w in group_size:
            if total >= n_hidden:
                break
            w = max(int(w), 1)
            widths.append(min(w, n_hidden - total))
            total += widths[-1]
        last = widths[-1] if widths else 1
        while total < n_hidden:
            widths.append(min(last, n_hidden - total))
            total += widths[-1]
        return tuple(widths)
    g = max(int(group_size), 1)
    return tuple(min(g, n_hidden - s) for s in range(0, n_hidden, g))


def compile_tasks(methods: Sequence[str], *, n_blobs: int = 0,
                  group_size=1, cross: bool = False) -> List[Task]:
    """Compile a per-layer method assignment into the ordered task graph.

    List order encodes per-stream priority (paper §4.1): the IO stream
    runs hidden fetches first (layer order) so projections can start,
    then the encoder blob (when ``cross`` — its projection gates the
    first cross-attention), then KV fetches fill the IO tail; the
    compute stream runs the recompute prefix from t=0, then projections
    in fetch order, then the cross projection. A projection group
    depends on *all* of its members' fetches; with ``group_size=1`` this
    degenerates exactly to the per-layer graph.

    ``group_size`` is either a uniform width (int) or an explicit
    partition — a tuple of widths, the fetch-aligned non-uniform form
    (small leading groups so projection starts the moment the first
    stripe lands, wide tail groups to amortize dispatch)."""
    tasks: List[Task] = []
    io_of: Dict[int, int] = {}
    hidden_layers = [i for i, m in enumerate(methods) if m == "hidden"]
    for i in hidden_layers:
        io_of[i] = len(tasks)
        tasks.append(Task("io_h", i))
    io_enc = None
    if cross:
        io_enc = len(tasks)
        tasks.append(Task("io_enc", -1))
    for i, m in enumerate(methods):
        if m == "kv":
            tasks.append(Task("io_kv", i))
    for _ in range(n_blobs):
        tasks.append(Task("blob", -1))
    for i, m in enumerate(methods):
        if m == "recompute":
            tasks.append(Task("recompute", i))
    s = 0
    for w in group_widths(group_size, len(hidden_layers)):
        grp = tuple(hidden_layers[s:s + w])
        s += w
        deps = tuple(io_of[i] for i in grp)
        tasks.append(Task("project", grp[0], dep=deps[-1], layers=grp,
                          deps=deps))
    if cross:
        tasks.append(Task("project_cross", -1, dep=io_enc))
    return tasks


def task_duration(task: Task, times: Sequence[MethodTimes],
                  dispatch_overhead: float = 0.0,
                  cross_times: Optional[CrossTimes] = None) -> float:
    """Virtual duration of one task. Compute-stream tasks carry the
    per-dispatch overhead once — a projection group amortizes it over
    all members (the whole point of grouping)."""
    if task.kind == "io_h":
        return times[task.layer].io_h
    if task.kind == "io_kv":
        return times[task.layer].io_kv
    if task.kind == "io_enc":
        return cross_times.io if cross_times else 0.0
    if task.kind == "recompute":
        return times[task.layer].c_token + dispatch_overhead
    if task.kind == "project":
        return (sum(times[li].c_h for li in task.members)
                + dispatch_overhead)
    if task.kind == "project_cross":
        return ((cross_times.compute if cross_times else 0.0)
                + dispatch_overhead)
    return 0.0                                 # blob reads: O(1) in tokens


def task_links(tasks: Sequence[Task],
               layer_links: Optional[Dict[int, int]])\
        -> Optional[Dict[int, int]]:
    """Task-index → NIC-link map for ``replay``: each per-layer IO task
    inherits the link its layer's stripes live on (layer placement only;
    chunk placement has no per-layer link and returns None)."""
    if not layer_links:
        return None
    out = {}
    for i, t in enumerate(tasks):
        if t.stream == "io" and t.layer >= 0:
            link = layer_links.get(t.layer)
            if link is not None:
                out[i] = link
    return out


def replay(tasks: Sequence[Task], times: Sequence[MethodTimes],
           order: Optional[Sequence[int]] = None,
           dispatch_overhead: float = 0.0,
           cross_times: Optional[CrossTimes] = None,
           durations: Optional[Dict[int, float]] = None,
           links: Optional[Dict[int, int]] = None):
    """Two-stream virtual replay of ``tasks`` in ``order`` → Timeline.

    Each stream is serial; a compute task with deps starts no earlier
    than the completion of ALL its deps on the IO stream. ``order``
    defaults to list order (the compiled priority); the executor passes
    the order it actually ran. ``durations`` overrides individual task
    durations (task index → seconds) with *measured* values — the
    executor's observed timeline replays the same graph under what each
    task actually took, so predicted-vs-measured makespan error is a
    like-for-like comparison.

    ``links`` (task index → NIC link, from ``task_links``) splits the IO
    stream into one serial queue PER LINK — the distributed store's
    layer-striped reads genuinely overlap across shards, so the IO
    finish is the max over link clocks, not their sum. Tasks without an
    entry share queue 0 (the one-host degenerate case)."""
    from repro.core.pipeline import Timeline
    if order is None:
        order = range(len(tasks))
    done = [0.0] * len(tasks)
    io_clocks: Dict[int, float] = {}
    comp_t = io_busy = comp_busy = 0.0
    for idx in order:
        t = tasks[idx]
        if durations is not None and idx in durations:
            dur = durations[idx]
        else:
            dur = task_duration(t, times, dispatch_overhead, cross_times)
        if t.stream == "io":
            link = links.get(idx, 0) if links else 0
            io_clocks[link] = io_clocks.get(link, 0.0) + dur
            io_busy += dur
            done[idx] = io_clocks[link]
        else:
            deps = t.all_deps
            start = comp_t if not deps else max(
                comp_t, max(done[d] for d in deps))
            comp_t = start + dur
            comp_busy += dur
            done[idx] = comp_t
    io_t = max(io_clocks.values(), default=0.0)
    return Timeline(max(io_t, comp_t), io_busy, comp_busy, io_t, comp_t)


def _cross_times_at(cfg, hw, dtype_bytes: int, enc_len: int, *,
                    profile=None, io_streams: int = 1)\
        -> Optional[CrossTimes]:
    if not enc_len:
        return None
    tms = [method_times(c, hw, profile=profile, io_streams=io_streams)
           for c in layer_costs(cfg, int(enc_len), dtype_bytes)]
    return CrossTimes(io=tms[0].io_h, compute=sum(t.c_h for t in tms))


def cross_restore_times(mgr, enc_len: int) -> Optional[CrossTimes]:
    """CrossTimes for an enc-dec session with ``enc_len`` stored encoder
    positions (None when unknown/zero — old manifests predate the
    ``enc_len`` field and fall back to the paper's zero-cost blob
    model). IO: one (S_enc, D) blob; compute: the K,V projection of
    that blob for every decoder layer."""
    return _cross_times_at(mgr.cfg, mgr.hw, mgr.dtype_bytes, enc_len,
                           profile=getattr(mgr, "profile", None),
                           io_streams=getattr(mgr, "io_streams", 1))


GROUP_SIZE_CANDIDATES = (1, 2, 4, 8)


def fetch_aligned_partition(methods: Sequence[str],
                            times: Sequence[MethodTimes], *,
                            dispatch_overhead: float = 0.0,
                            links: Optional[Dict[int, int]] = None)\
        -> Tuple[int, ...]:
    """Group boundaries at fetch-completion times (ROADMAP: "non-uniform
    groups aligned to fetch completions — the open half of group-size
    tuning").

    A projection group cannot start before its LAST member's hidden
    fetch lands, so a wide first group leaves the compute stream idle
    for the whole fetch ramp while a width-1 tail pays dispatch overhead
    per layer. The optimal shape is non-uniform: boundaries placed where
    the fetch stream has just caught up — small leading groups, wide
    tail groups. Exact O(n²) DP over the hidden layers: ``f(j)`` =
    earliest compute-stream completion of the first ``j`` projections,
    with fetch ``j`` landing at the io_h prefix sum and the compute
    stream starting busy for the recompute prefix (which replay runs
    before any projection).

    ``links`` (layer → NIC link, distributed store) makes the fetch
    completions per-shard: each link runs its own serial queue, so fetch
    ``j`` lands on its OWN link's running clock — much earlier than the
    one-host prefix sum when layers stripe round-robin. The DP gates a
    group ending at ``j`` on the prefix-max of the completions (the
    group needs ALL members' fetches; per-link clocks are not monotone
    in ``j``), which collapses to the plain prefix sum on one host."""
    hidden = [i for i, m in enumerate(methods) if m == "hidden"]
    n = len(hidden)
    if n <= 1:
        return (1,) * n
    fetch_done = [0.0] * (n + 1)            # per-fetch completion times
    link_clock: Dict[int, float] = {}
    for j, li in enumerate(hidden):
        link = links.get(li, 0) if links else 0
        link_clock[link] = link_clock.get(link, 0.0) + times[li].io_h
        fetch_done[j + 1] = link_clock[link]
    gate = [0.0] * (n + 1)                  # prefix max: all fetches <= j
    for j in range(1, n + 1):
        gate[j] = max(gate[j - 1], fetch_done[j])
    busy0 = sum(times[li].c_token + dispatch_overhead
                for li, m in enumerate(methods) if m == "recompute")
    c_h = [times[li].c_h for li in hidden]
    f = [0.0] * (n + 1)
    parent = [0] * (n + 1)
    f[0] = busy0
    for j in range(1, n + 1):
        best = None
        proj = 0.0
        for i in range(j - 1, -1, -1):      # group = hidden[i:j]
            proj += c_h[i]
            t = max(f[i], gate[j]) + dispatch_overhead + proj
            if best is None or t < best:
                best, parent[j] = t, i
        f[j] = best
    widths: List[int] = []
    j = n
    while j > 0:
        widths.append(j - parent[j])
        j = parent[j]
    return tuple(reversed(widths))


def choose_group_size(cfg, hw, n_tokens: int, methods: Sequence[str], *,
                      dtype_bytes: int = 2, n_blobs: int = 0,
                      cross: bool = False, enc_len: int = 0,
                      profile=None, io_streams: int = 1,
                      fetch_aligned: bool = False,
                      topology=None, link_load=None):
    """Auto group-size planning (ROADMAP "restoration group-size
    tuning", planning half): replay the grouped task graph over the
    hardware profile for g ∈ {1, 2, 4, 8, L} — plus, with
    ``fetch_aligned``, the non-uniform fetch-completion partition — and
    take the makespan argmin. The same group-aware cost model the
    executor's timeline and ``capacity.restore_makespan`` use, so the
    planner and the bake-off metric cannot disagree. Ties prefer fewer
    groups (equal modeled makespan, strictly fewer real device
    dispatches). Returns an int (uniform width) or a tuple of widths
    (non-uniform partition).

    ``profile``/``io_streams`` price the replay with measured rates and
    the current restore multiplicity — the self-calibrating half. The
    choice is computed at the ``s_bucket`` of ``n_tokens`` (and of
    ``enc_len``), NOT the exact lengths: the compiled projection shape
    is ``(G_pad, S_bucket, D)``, so every session in a bucket must pick
    the same plan or the auto knob would reintroduce the per-session
    recompiles the bucketing exists to prevent (DESIGN.md §10)."""
    n_hidden = sum(1 for m in methods if m == "hidden")
    if n_hidden <= 1:
        return 1
    n_bucket = s_bucket(max(int(n_tokens), 1))
    times, layer_links = link_priced_times(
        layer_costs(cfg, n_bucket, dtype_bytes), hw, profile=profile,
        io_streams=io_streams, topology=topology, link_load=link_load)
    cross_times = (_cross_times_at(cfg, hw, dtype_bytes, s_bucket(enc_len),
                                   profile=profile, io_streams=io_streams)
                   if cross and enc_len else None)
    # sharded pricing (DESIGN.md §16): ``times`` already divides the
    # projection compute across hw.mesh_devices (method_times), and the
    # per-launch dispatch overhead is read from the mesh's own profiler
    # cell — an SPMD launch pays it once, so under tp > 1 the compute
    # side of the argmin shrinks and the optimum shifts toward SMALLER
    # groups (less amortization needed per dispatch).
    overhead = getattr(hw, "dispatch_overhead", 0.0)
    if profile is not None:
        measured = profile.dispatch_overhead(
            mesh=getattr(hw, "mesh_devices", 1))
        if measured is not None:
            overhead = measured
    cands = sorted({g for g in GROUP_SIZE_CANDIDATES if g < n_hidden}
                   | {n_hidden})

    def makespan(g):
        tasks = compile_tasks(tuple(methods), n_blobs=n_blobs,
                              group_size=g, cross=cross)
        return replay(tasks, times, dispatch_overhead=overhead,
                      cross_times=cross_times,
                      links=task_links(tasks, layer_links)).makespan

    best = min(cands, key=lambda g: (makespan(g), -g))
    if not fetch_aligned:
        return best
    part = fetch_aligned_partition(methods, times,
                                   dispatch_overhead=overhead,
                                   links=layer_links)
    widths = set(part)
    if len(widths) == 1:                 # degenerate partition is uniform
        part = widths.pop()
        return part if makespan(part) < makespan(best) else best
    # prefer the uniform plan on ties: same modeled makespan, simpler
    return part if makespan(part) < makespan(best) else best


# ----------------------------------------------------- hidden-state codec
def quantize_hidden_int8(x: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Per-token int8 quantization of stored hidden states (save path in
    hcache, dequantized here on restore — one codec for both)."""
    scale = np.abs(x).max(axis=-1, keepdims=True).astype(np.float32) / 127.0
    scale = np.maximum(scale, 1e-8)
    q = np.clip(np.round(x / scale), -127, 127).astype(np.int8)
    return q, scale


def dequantize_hidden_int8(q: np.ndarray, scale: np.ndarray) -> np.ndarray:
    return q.astype(np.float32) * scale.astype(np.float32)


# ------------------------------------------------------------------- sinks
class RestoreSink:
    """Receives restored state one piece at a time, in any order."""

    def put_kv(self, row: int, k, v, start: int = 0) -> None:
        """One attention layer's KV at token offset ``start`` (k, v:
        (1, n, kv_heads, head_dim)); row indexes the stacked-KV buffer.
        ``start > 0`` is the restore-skip path: tokens [0, start) are
        already resident (shared prefix) and the executor only ships the
        suffix."""
        raise NotImplementedError

    def put_kv_group(self, rows: Sequence[int], k, v,
                     start: int = 0) -> None:
        """A whole projection group's KV in one call; rows are the
        stacked-KV buffer rows, k/v: (G, 1, n, kv_heads, head_dim).
        Default: per-row fallback — batching sinks (ViewSink) override
        with a single scatter."""
        for g, row in enumerate(rows):
            self.put_kv(row, k[g], v[g], start)

    def put_states(self, conv, ssm) -> None:
        raise NotImplementedError

    def put_cross(self, ck, cv, enc_len: int) -> None:
        raise NotImplementedError

    def finish(self, n_tokens: int) -> None:
        raise NotImplementedError


class CacheAssembler(RestoreSink):
    """Builds the family-specific B=1 cache dict — the standalone
    ``HCacheManager.restore`` API (tests, offline tools). The serving
    engine does NOT use this: its sink writes batch-slot buffers."""

    def __init__(self, model):
        self.model = model
        self.k_parts: Dict[int, jnp.ndarray] = {}
        self.v_parts: Dict[int, jnp.ndarray] = {}
        self.states = None
        self.cross = None
        self.cache: Optional[dict] = None

    def put_kv(self, row, k, v, start=0):
        if start:
            raise ValueError(
                "CacheAssembler builds a standalone B=1 cache from token "
                "0 — restore-skip (start > 0) is a serving-engine path "
                "(ViewSink over a slot that already holds the prefix)")
        self.k_parts[row] = k
        self.v_parts[row] = v

    def put_states(self, conv, ssm):
        self.states = (conv, ssm)

    def put_cross(self, ck, cv, enc_len):
        self.cross = (ck, cv, enc_len)

    def finish(self, n_tokens):
        model = self.model
        lengths = jnp.asarray([n_tokens], jnp.int32)
        if model.kind == "ssm":
            conv, ssm = self.states
            self.cache = {"conv": conv, "ssm": ssm, "lengths": lengths}
            return
        rows = sorted(self.k_parts)
        k = jnp.stack([self.k_parts[r] for r in rows]).astype(model.dtype)
        v = jnp.stack([self.v_parts[r] for r in rows]).astype(model.dtype)
        if model.kind == "lm":
            self.cache = {"k": k, "v": v, "lengths": lengths}
        elif model.kind == "hybrid":
            conv, ssm = self.states
            self.cache = {"attn_k": k, "attn_v": v, "conv": conv,
                          "ssm": ssm, "lengths": lengths}
        else:                                   # encdec
            ck, cv, enc_len = self.cross
            self.cache = {"self_k": k, "self_v": v, "cross_k": ck,
                          "cross_v": cv,
                          "enc_len": jnp.asarray(enc_len, jnp.int32),
                          "lengths": lengths}


# ---------------------------------------------------------- param packing
def s_bucket(n: int, minimum: int = 16) -> int:
    """Power-of-two token bucket for projection shapes: all sessions in
    a bucket share one compiled projection (zero recompiles across a
    serving run); the padded tail is zeros and its outputs are sliced
    away before the sink."""
    b = max(int(minimum), 1)
    while b < n:
        b <<= 1
    return b


class RestoreParamPack:
    """Device-resident restoration weights for every attention layer,
    built once per ``(model, params)`` and shared by all executors.

    The stacks are (A, …) with A = number of attention layers, row
    order == the stacked-KV row order the sinks use — so a projection
    group gathers ``wk[rows]`` inside its jitted call instead of
    re-running ``jax.tree.map`` over the whole parameter stack per
    task. For lm/hybrid/encdec the per-layer params are already
    layer-stacked device arrays (scan-over-layers init), so building
    the pack is reference-taking, not copying. RoPE cos/sin tables are
    precomputed up to the largest bucket seen and sliced per bucket."""

    def __init__(self, *, ln_scale, ln_bias, wk, wv, bk, bv, norm_kind,
                 norm_eps, head_dim, use_rope, rope_theta, dtype,
                 tp_ctx=None):
        self.ln_scale = ln_scale        # (A, D)
        self.ln_bias = ln_bias          # (A, D) | None (rmsnorm)
        self.wk = wk                    # (A, D, KV)
        self.wv = wv                    # (A, D, KV)
        self.bk = bk                    # (A, KV) | None
        self.bv = bv                    # (A, KV) | None
        self.norm_kind = norm_kind
        self.norm_eps = float(norm_eps)
        self.head_dim = int(head_dim)
        self.use_rope = bool(use_rope)
        self.rope_theta = float(rope_theta)
        self.dtype = dtype
        self.n_rows = int(wk.shape[0])
        # tensor-parallel context the weight stacks are sharded under
        # (DESIGN.md §16): wk/wv/bk/bv live KV-axis-sharded across its
        # mesh (the flattened KV axis is heads-leading, so tp contiguous
        # chunks == head groups), hidden/norm/RoPE inputs replicate, and
        # the projection outputs carry the KV-head sharding straight into
        # the shard-local page-pool scatter. None = single device.
        self.tp_ctx = tp_ctx
        self._spmd = tp_ctx is not None and tp_ctx.spmd
        self._cos = None
        self._sin = None
        self._slices: Dict[int, Tuple[jnp.ndarray, jnp.ndarray]] = {}

    @property
    def out_sharding(self):
        """NamedSharding of the projection outputs (G, S, KV) — KV-axis
        sharded over the mesh — or None on a single device."""
        if not self._spmd:
            return None
        return self.tp_ctx.kv_sharding(3, 2)

    def place_hidden(self, stack):
        """Commit one group's hidden stack to the device(s): replicated
        across the mesh under SPMD (every device projects its own heads
        from the full stack), a plain single upload otherwise."""
        if not self._spmd:
            return jnp.asarray(stack)
        return self.tp_ctx.replicate(jnp.asarray(stack))

    def rope_tables(self, n_pos: int,
                    start: int = 0) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """cos/sin (n_pos, head_dim//2) for absolute positions
        [start, start + n_pos); the backing table grows by powers of two
        and per-(start, bucket) slices are cached so repeated restores
        reuse the same device arrays. ``start > 0`` serves restore-skip:
        a suffix restore applies RoPE at its true absolute positions."""
        got = self._slices.get((start, n_pos))
        if got is not None:
            return got
        end = start + n_pos
        if self._cos is None or self._cos.shape[0] < end:
            cap = s_bucket(end, minimum=128)
            cos, sin = rope_angles(jnp.arange(cap), self.head_dim,
                                   self.rope_theta)
            self._cos, self._sin = cos, sin
            self._slices.clear()
        sl = (self._cos[start:end], self._sin[start:end])
        if self._spmd:
            # replicated commit: the sliced tables feed an SPMD launch
            # whose weight inputs span the mesh
            sl = (self.tp_ctx.replicate(sl[0]),
                  self.tp_ctx.replicate(sl[1]))
        self._slices[(start, n_pos)] = sl
        return sl


def build_param_pack(model, params, tp_ctx=None)\
        -> Optional[RestoreParamPack]:
    """Pack the attention-restoration weights of ``params``. None for
    attention-free (ssm) stacks.

    With a live ``tp_ctx`` (distributed/tp.py) the weight stacks are
    committed sharded on the flattened KV output axis — tp contiguous
    chunks of (A, D, KV) == KV-head groups since the flatten is
    heads-leading — so ``_project_group_jit`` compiles to one SPMD
    program in which each device projects only its own heads, and the
    outputs land already sharded for the shard-local pool scatter."""
    kind = model.kind
    if kind == "ssm":
        return None
    if kind == "lm":
        blocks, attn_key, attn_h = params["blocks"], "attn", model.h.attn
    elif kind == "hybrid":
        blocks, attn_key, attn_h = params["attn"], "attn", model.h.lm.attn
    else:                                       # encdec (decoder self-attn)
        blocks, attn_key, attn_h = (params["dec_blocks"], "self_attn",
                                    model.h.attn)
    ap = blocks[attn_key]
    ln = blocks["ln1"]
    wk, wv = ap["wk"], ap["wv"]
    bk, bv = ap.get("bk"), ap.get("bv")
    ln_scale, ln_bias = ln["scale"], ln.get("bias")
    if tp_ctx is not None and tp_ctx.spmd:
        tp_ctx.validate_heads(wk.shape[-1] // attn_h.head_dim)
        wk = tp_ctx.shard_kv(wk, 2)
        wv = tp_ctx.shard_kv(wv, 2)
        bk = tp_ctx.shard_kv(bk, 1) if bk is not None else None
        bv = tp_ctx.shard_kv(bv, 1) if bv is not None else None
        ln_scale = tp_ctx.replicate(ln_scale)
        ln_bias = (tp_ctx.replicate(ln_bias)
                   if ln_bias is not None else None)
    return RestoreParamPack(
        ln_scale=ln_scale, ln_bias=ln_bias,
        wk=wk, wv=wv, bk=bk, bv=bv,
        norm_kind=model.cfg.norm, norm_eps=model.cfg.norm_eps,
        head_dim=attn_h.head_dim, use_rope=attn_h.use_rope,
        rope_theta=attn_h.rope_theta, dtype=model.dtype,
        tp_ctx=tp_ctx)


# number of times the grouped projection has been TRACED (== compiled):
# the body below runs once per compilation, so this is the recompile
# counter the bucketing regression test and bench_restore_batch read.
_PROJECTION_TRACES = [0]


def projection_trace_count() -> int:
    return _PROJECTION_TRACES[0]


@functools.partial(jax.jit, static_argnames=(
    "norm_kind", "eps", "head_dim", "use_rope", "dtype", "use_pallas",
    "interpret", "kv_sharding"))
def _project_group_jit(hidden, rows, ln_scale, ln_bias, wk, wv, bk, bv,
                       cos, sin, *, norm_kind, eps, head_dim, use_rope,
                       dtype, use_pallas, interpret, kv_sharding=None):
    """ONE device dispatch for a whole projection group.

    hidden (G, S_bucket, D) stored-dtype upload; rows (G,) pack-row ids
    (traced, so group membership never retraces); weight stacks are the
    full pack — the gather fuses into the compiled program. Returns
    (k, v): (G, S_bucket, Kv, hd) in the model dtype.

    ``kv_sharding`` (a NamedSharding, static — hashable, so each mesh
    width compiles exactly once per bucket and the zero-recompile
    invariant holds per (bucket, tp)) pins the outputs sharded on the
    flattened-KV axis: with the weight stacks committed the same way the
    whole call is one SPMD launch where each device projects only its
    heads and no gather ever crosses devices (DESIGN.md §16)."""
    _PROJECTION_TRACES[0] += 1
    h = hidden.astype(dtype)
    # the model's own norm, with per-group-row params broadcast over S —
    # restore must stay byte-equal to what project_qkv consumed
    ln = {"scale": ln_scale[rows][:, None, :]}
    if ln_bias is not None:
        ln["bias"] = ln_bias[rows][:, None, :]
    normed = apply_norm(ln, h, norm_kind, eps)
    k, v = ops.restore_kv_grouped(
        normed, wk[rows], wv[rows],
        bk[rows] if bk is not None else None,
        bv[rows] if bv is not None else None,
        cos, sin, head_dim=head_dim, use_rope=use_rope,
        use_pallas=use_pallas, interpret=interpret,
        kv_sharding=kv_sharding)
    G, S, KV = k.shape
    return (k.reshape(G, S, KV // head_dim, head_dim),
            v.reshape(G, S, KV // head_dim, head_dim))


# -------------------------------------------------------- param projections
def subset_blocks(model, params, idx: List[int]):
    """Stacked block params for the given global layer indices (legacy
    per-layer reference path — the executor now uses RestoreParamPack)."""
    arr = np.asarray(idx)
    blocks = (params["blocks"] if model.kind == "lm" else
              params["attn"] if model.kind == "hybrid" else
              params["dec_blocks"])
    if model.kind == "hybrid":
        # attn params are stacked per super-block; map layer->super idx
        k = model.h.k
        arr = np.asarray([i // k for i in idx])
    return jax.tree.map(lambda x: x[arr], blocks)


def project_hidden(model, blocks, hidden, pos):
    """K,V projection of saved hidden states (the paper's core GEMM).

    hidden: (L_sub, 1, n, D); returns (k, v): (L_sub, 1, n, Kv, hd).
    Reference implementation for the grouped device path above (the
    byte-equivalence tests compare the two)."""
    cfg, mh = model.cfg, model.h
    attn_h = mh.attn if hasattr(mh, "attn") else mh.lm.attn
    attn_key = "attn" if model.kind in ("lm", "hybrid") else "self_attn"

    def one(bp, hl):
        normed = apply_norm(bp["ln1"], hl, cfg.norm, cfg.norm_eps)
        ap = bp[attn_key] if attn_key in bp else bp
        return attn_lib.restore_kv(ap["wk"], ap["wv"], ap.get("bk"),
                                   ap.get("bv"), normed, attn_h,
                                   jnp.broadcast_to(pos, hl.shape[:2]))

    return jax.vmap(one)(blocks, hidden)


# --------------------------------------------------------------- executor
class RestorationExecutor:
    """Incremental, sink-directed execution of one session's restoration.

    Created by ``HCacheManager.begin_restore``. ``step(max_tasks)`` runs a
    bounded number of tasks, event-driven across the two virtual streams
    (whichever stream's clock is behind goes next, so layers finish in
    pipelined order); ``prefetch_step`` runs IO tasks only (no sink
    needed). All finished pieces flow to the sink immediately; pieces
    produced before a sink is attached are buffered (numpy/array handles,
    never a stacked B=1 cache) and flushed on ``attach_sink``.

    Projection tasks are GROUPS (``mgr.restore_group_size`` layers): one
    batched upload + one stacked projection + one grouped sink write per
    group. ``dispatch_count`` tallies the device dispatches the restore
    issued; ``project_wall`` the wall seconds inside projection calls —
    both surfaced by bench_restore_batch."""

    def __init__(self, mgr, params, session: str,
                 sink: Optional[RestoreSink] = None, start_token: int = 0):
        manifest = mgr.store.get_manifest(session)
        if manifest is None:
            raise KeyError(f"no stored state for session {session!r}")
        self.mgr = mgr
        self.model = mgr.model
        self.params = params
        self.session = session
        self.sink = sink
        self.n_tokens = int(manifest["n_tokens"])
        self.methods = tuple(manifest["methods"])
        # restore-skip (DESIGN.md §12): tokens [0, start_token) are
        # already resident in the slot via a shared prefix, so the task
        # graph restores only the suffix — IO reads start at the chunk
        # containing the divergence token, projections run at the
        # suffix's bucket, and sink writes land at the offset. The
        # recompute method rebuilds the residual stream from token 0 and
        # cannot skip (the engine passes start_token=0 for those).
        start_token = int(start_token)
        if start_token and any(m == "recompute" for m in self.methods):
            raise ValueError("restore-skip is incompatible with "
                             "recompute-method layers (the residual "
                             "stream rebuild starts at token 0)")
        if not 0 <= start_token < self.n_tokens:
            raise ValueError(f"start_token {start_token} outside "
                             f"[0, {self.n_tokens})")
        self.start_token = start_token
        self.n_eff = self.n_tokens - start_token
        self.schedule = Schedule(self.methods, 0.0, 0.0, 0.0, 0.0)
        self.compress = manifest.get("compress", mgr.compress)
        mgr.store.sync_clocks(0.0)

        kinds = mgr.cfg.block_kinds()
        adapter = self.model.adapter
        self._attn_layers = [i for i, k in enumerate(kinds)
                             if k == BlockKind.ATTENTION]
        self._row_of = {li: r for r, li in enumerate(self._attn_layers)}
        # enc-dec: cross restoration rides two dedicated tasks (io_enc +
        # project_cross) whose durations scale with the stored encoder
        # length; other families' state blobs stay zero-cost reads
        self.has_cross = adapter.has_cross
        self.enc_len = int(manifest.get("enc_len", 0))
        self.cross_times = (cross_restore_times(mgr, self.enc_len)
                            if self.has_cross else None)
        gs = mgr.resolve_group_size(self.n_eff, self.methods,
                                    enc_len=self.enc_len)
        # int = uniform width; tuple = fetch-aligned non-uniform partition
        self.group_size = (tuple(int(w) for w in gs)
                           if isinstance(gs, (tuple, list))
                           else max(int(gs), 1))
        self.pack: Optional[RestoreParamPack] = mgr.param_pack(params)
        # stable padded group width: every group in this restore uploads
        # and projects the same (G_pad, S_bucket, D) shape — for a
        # non-uniform partition that is the WIDEST group's width — so a
        # run compiles at most one projection per (bucket, codec)
        n_attn_hidden = sum(1 for i, m in enumerate(self.methods)
                            if m == "hidden" and i in self._row_of)
        max_w = (max(self.group_size) if isinstance(self.group_size, tuple)
                 else self.group_size)
        self._g_pad = min(max_w, max(n_attn_hidden, 1))
        # calibration inputs: the manager's measured profile (rates +
        # dispatch overhead, when sampled) and the engine-reported IO
        # multiplicity price this executor's virtual timeline the same
        # way the planner priced its schedule
        self.profile = getattr(mgr, "profile", None)
        self.io_streams = max(int(getattr(mgr, "io_streams", 1)), 1)
        # tensor-parallel mesh width (DESIGN.md §16): compute samples are
        # recorded into the mesh's own profiler cell and the per-launch
        # dispatch overhead is read back from it
        self.mesh = max(int(getattr(mgr.hw, "mesh_devices", 1)), 1)
        self.dispatch_overhead = getattr(mgr.hw, "dispatch_overhead", 0.0)
        if self.profile is not None:
            measured = self.profile.dispatch_overhead(mesh=self.mesh)
            if measured is not None:
                self.dispatch_overhead = measured
        self.tasks = compile_tasks(self.methods,
                                   n_blobs=adapter.n_state_blobs,
                                   group_size=self.group_size,
                                   cross=self.has_cross)
        self.costs = layer_costs(mgr.cfg, self.n_eff, mgr.dtype_bytes)
        # distributed store: per-layer IO priced on the links each
        # layer's stripes occupy; one-host stores degrade to the uniform
        # io_streams stretch inside link_priced_times
        topo_fn = getattr(mgr.store, "shard_topology", None)
        self.topology = topo_fn() if topo_fn is not None else None
        self.link_load = getattr(mgr, "link_load", None)
        self.times, self._layer_links = link_priced_times(
            self.costs, mgr.hw, profile=self.profile,
            io_streams=self.io_streams, topology=self.topology,
            link_load=self.link_load)
        self._task_links = task_links(self.tasks, self._layer_links)
        self.executed: List[int] = []
        self._done = [False] * len(self.tasks)
        # event-driven stream interleaving state (one virtual IO clock
        # per NIC link; one-host stores use the single queue 0)
        self._io_queue = [i for i, t in enumerate(self.tasks)
                          if t.stream == "io"]
        self._comp_queue = [i for i, t in enumerate(self.tasks)
                            if t.stream == "compute"]
        self._io_clock = 0.0
        self._io_clocks: Dict[int, float] = {}
        self._comp_clock = 0.0
        self._hbuf: Dict[int, np.ndarray] = {}
        # async submit/complete state: io_h tickets awaiting their
        # projection, io_kv tickets reaped as they land, the enc blob
        # ticket awaiting project_cross
        self._hio: Dict[int, tuple] = {}
        self._kvio: List[tuple] = []
        self._encio = None
        self._pending: List[Tuple[str, tuple]] = []   # sink-less buffer
        # recompute-prefix carry
        self._re_layers = [i for i, m in enumerate(self.methods)
                           if m == "recompute"]
        self._re_x = None
        self._re_pos = None
        self._re_windows = None
        self._re_next = 0
        self._finished = False
        # striped-device completion, relative to the device clocks at
        # executor start (the clocks are shared and monotonic across
        # restores; under concurrent restores this correctly includes
        # queueing behind the other session's reads)
        self._io_base = mgr.store.read_completion()
        self.io_measured = 0.0
        self.wall_time = 0.0
        self.project_wall = 0.0
        self.dispatch_count = 0
        self._enc_out: Optional[np.ndarray] = None
        # online profiling (DESIGN.md §13): per-task observed durations.
        # IO tasks read the striped store's accumulated service time
        # (virtual seconds on SimulatedSSD, nothing on plain DRAM);
        # compute tasks are wall-clocked, skipping any call that traced
        # (compile time is not dispatch time). Each sample is folded
        # into mgr.profile and kept here for ``measured_timeline``.
        self.observed: Dict[int, float] = {}
        self._bucket = s_bucket(max(self.n_eff, 1))
        self._enc_bucket = s_bucket(self.enc_len) if self.enc_len else 0
        n_timed = getattr(mgr.store, "n_timed_devices", None)
        self._n_timed = n_timed() if n_timed is not None else 0
        # the plan this graph was compiled under, for the engine's
        # predicted-vs-measured gauge (list order == compiled priority)
        self.predicted_makespan = replay(
            self.tasks, self.times,
            dispatch_overhead=self.dispatch_overhead,
            cross_times=self.cross_times,
            links=self._task_links).makespan

    # ------------------------------------------------------------- plumbing
    @property
    def done(self) -> bool:
        # with async IO, a dispatched io_kv task is not finished until
        # its ticket is reaped and the KV emitted to the sink
        return all(self._done) and not self._kvio

    def links_touched(self) -> Tuple[int, ...]:
        """NIC links this restore's IO occupies — what the engine folds
        into the fleet ``LinkLoad`` for contention pricing."""
        topo = self.topology
        if topo is None or topo.n_shards <= 1:
            return (0,)
        if topo.placement == "chunk":
            return tuple(range(topo.n_shards))
        return tuple(sorted({topo.links_for_layer(li)[0]
                             for li, m in enumerate(self.methods)
                             if m in ("hidden", "kv")}))

    def attach_sink(self, sink: RestoreSink) -> None:
        self.sink = sink
        for op, args in self._pending:
            getattr(sink, op)(*args)
        self._pending.clear()

    def _emit(self, op: str, *args) -> None:
        if self.sink is not None:
            getattr(self.sink, op)(*args)
        else:
            self._pending.append((op, args))

    def timeline(self):
        """Timeline derived from the order tasks actually executed in."""
        order = self.executed + [i for i in range(len(self.tasks))
                                 if not self._done[i]]
        return replay(self.tasks, self.times, order,
                      dispatch_overhead=self.dispatch_overhead,
                      cross_times=self.cross_times,
                      links=self._task_links)

    def measured_timeline(self):
        """``timeline()`` with each task's duration replaced by what it
        was *observed* to take (modeled values fill unmeasured tasks) —
        the "measured" side of the engine's predicted-vs-measured
        makespan gauge."""
        order = self.executed + [i for i in range(len(self.tasks))
                                 if not self._done[i]]
        return replay(self.tasks, self.times, order,
                      dispatch_overhead=self.dispatch_overhead,
                      cross_times=self.cross_times,
                      durations=self.observed,
                      links=self._task_links)

    # ------------------------------------------------------------ stepping
    def _ready(self, idx: int) -> bool:
        t = self.tasks[idx]
        if any(not self._done[d] for d in t.all_deps):
            return False
        if t.kind == "recompute":
            # prefix layers carry the residual stream in order
            return self._re_layers[self._re_next] == t.layer
        return True

    def _pick(self) -> Optional[int]:
        """Event-driven pick: advance whichever stream is behind."""
        io_idx = self._io_queue[0] if self._io_queue else None
        comp_idx = (self._comp_queue[0]
                    if self._comp_queue and self._ready(self._comp_queue[0])
                    else None)
        if io_idx is None:
            return comp_idx
        if comp_idx is None:
            return io_idx
        return comp_idx if self._comp_clock <= self._io_clock else io_idx

    def step(self, max_tasks: int = 4) -> bool:
        """Execute up to ``max_tasks`` tasks; True when restoration done.
        A projection group counts as one task."""
        t0 = time.perf_counter()
        for _ in range(max_tasks):
            idx = self._pick()
            if idx is None:
                break
            self._run_task(idx)
        # reap landed KV tickets opportunistically; once every task has
        # dispatched, block-drain the stragglers so done means done
        self._reap_kv(block=all(self._done))
        if self.done and not self._finished and self.sink is not None:
            self.sink.finish(self.n_tokens)
            self._finished = True
        self.wall_time += time.perf_counter() - t0
        return self.done

    def prefetch_step(self, max_tasks: int = 1) -> int:
        """Run up to ``max_tasks`` IO tasks (no sink required); returns
        the number executed. Used to warm queued sessions' reads."""
        n = 0
        while n < max_tasks and self._io_queue:
            self._run_task(self._io_queue[0])
            n += 1
        return n

    def run(self) -> None:
        while not self.step(max_tasks=max(len(self.tasks), 1)):
            pass

    # ---------------------------------------------------------- task bodies
    def _run_task(self, idx: int) -> None:
        t = self.tasks[idx]
        self._cur_idx = idx
        dur = task_duration(t, self.times, self.dispatch_overhead,
                            self.cross_times)
        if t.stream == "io":
            self._io_queue.remove(idx)
            link = (self._task_links.get(idx, 0)
                    if self._task_links else 0)
            self._io_clocks[link] = self._io_clocks.get(link, 0.0) + dur
            self._io_clock = max(self._io_clock, self._io_clocks[link])
        else:
            self._comp_queue.remove(idx)
            start = (self._comp_clock if not t.all_deps else
                     max(self._comp_clock, self._io_clock))
            self._comp_clock = max(self._comp_clock, start) + dur
        if self.profile is not None:
            self._run_profiled(idx, t)
        else:
            getattr(self, "_exec_" + t.kind)(t)
        self._done[idx] = True
        self.executed.append(idx)

    def _task_work(self, t: Task) -> float:
        """Work units of one task: bytes for IO kinds, FLOPs for compute
        kinds — the x-axis of the profiler's time fits, on the same cost
        basis ``method_times`` predicts with."""
        if t.kind == "io_h":
            return self.costs[t.layer].io_hidden
        if t.kind == "io_kv":
            c = self.costs[t.layer]
            return c.io_kv or c.io_state
        if t.kind == "recompute":
            return self.costs[t.layer].c_token
        if t.kind == "project":
            return sum(self.costs[li].c_hidden for li in t.members
                       if self._is_attn(li))
        if t.kind in ("io_enc", "project_cross") and self.enc_len:
            costs = layer_costs(self.mgr.cfg, self.enc_len,
                                self.mgr.dtype_bytes)
            return (costs[0].io_hidden if t.kind == "io_enc"
                    else sum(c.c_hidden for c in costs))
        return 0.0

    def _run_profiled(self, idx: int, t: Task) -> None:
        """Execute one task with its real duration observed and folded
        into the manager's ``MeasuredProfile``.

        IO tasks: the striped store accumulates per-device read service
        time; the delta across this task, divided by the device count
        (stripes are read in parallel), is the contention-free stream
        seconds the cost model predicts. Plain DRAM backends accumulate
        nothing and record nothing. Compute tasks: wall seconds, thrown
        away when the call traced (JIT compile time is not dispatch
        time — folding it in would poison the overhead fit)."""
        bucket = (self._enc_bucket
                  if t.kind in ("io_enc", "project_cross")
                  else self._bucket)
        if t.kind in IO_KINDS:
            # with an IO engine attached, service accrues in the shard
            # workers — an inline delta would attribute racing reads of
            # other tasks to this one; those tasks record at reap time
            # from their tickets' own service (``_observe_read``)
            inline = (self._n_timed and
                      getattr(self.mgr.store, "io_engine", None) is None)
            base = self.mgr.store.read_service_total() if inline else 0.0
            getattr(self, "_exec_" + t.kind)(t)
            if inline:
                delta = ((self.mgr.store.read_service_total() - base)
                         / self._n_timed)
                if delta > 0.0:
                    self.observed[idx] = delta
                    self.profile.record(t.kind, bucket,
                                        self._task_work(t), delta)
            return
        traces = projection_trace_count()
        t0 = time.perf_counter()
        getattr(self, "_exec_" + t.kind)(t)
        wall = time.perf_counter() - t0
        if wall > 0.0 and projection_trace_count() == traces:
            self.observed[idx] = wall
            # a tp-sharded launch records into its mesh's own cell
            # (profiler.mesh_kind) so single-device fits stay clean
            self.profile.record(t.kind, bucket, self._task_work(t), wall,
                                mesh=self.mesh if self.mesh > 1 else None)

    def _is_attn(self, layer: int) -> bool:
        return layer in self._row_of

    def _measure(self, *completions: float) -> None:
        done = max(completions, default=0.0)
        if done:
            self.io_measured = max(self.io_measured, done - self._io_base)

    def _observe_read(self, idx: int, kind: str, tickets,
                      work: float, bucket: int) -> None:
        """Fold a reaped async read into the profiler. The sync path
        records via ``_run_profiled``'s service-total delta; tickets
        completed by IO workers instead carry their own per-shard
        service seconds, measured inside the worker (thread-confined).
        Single-shard reads (layer placement) record the per-link cell
        too, so heterogeneous NICs get their own learned rates."""
        if self.profile is None or idx in self.observed:
            return
        # stripes across shards run in parallel: the task's stream
        # duration is the slowest shard's service, not the sum
        dur = max((tk.service for tk in tickets), default=0.0)
        if dur <= 0.0:
            return
        self.observed[idx] = dur
        shard_ids = {tk.shard_id for tk in tickets}
        link = (shard_ids.pop() if len(shard_ids) == 1
                and self.topology is not None else None)
        self.profile.record(kind, bucket, work, dur, link=link)

    def _collect_hidden(self, layer: int) -> np.ndarray:
        """Hidden states of one fetched layer: from the staging buffer
        (sync path) or by completing the layer's submitted tickets."""
        got = self._hio.pop(layer, None)
        if got is None:
            return self._hbuf.pop(layer)
        idx, lr, ls = got
        ar = lr.wait()
        tickets = list(lr.tickets)
        if ls is not None:
            sr = ls.wait()
            tickets += list(ls.tickets)
            self._measure(ar.completion, sr.completion)
            data = dequantize_hidden_int8(ar.data, sr.data)
        else:
            self._measure(ar.completion)
            data = ar.data
        self._observe_read(idx, "io_h", tickets,
                           self._task_work(self.tasks[idx]), self._bucket)
        return data

    def _reap_kv(self, block: bool = False) -> None:
        """Complete landed io_kv tickets and emit their KV to the sink;
        ``block=True`` drains every outstanding ticket (end of graph)."""
        if not self._kvio:
            return
        cfg, dtype = self.mgr.cfg, self.model.dtype
        remaining = []
        for entry in self._kvio:
            idx, layer, rk, rv = entry
            if not block and not (rk.ready() and rv.ready()):
                remaining.append(entry)
                continue
            ak, av = rk.wait(), rv.wait()
            self._measure(ak.completion, av.completion)
            self._observe_read(idx, "io_kv",
                               list(rk.tickets) + list(rv.tickets),
                               self._task_work(self.tasks[idx]),
                               self._bucket)
            hd = cfg.head_dim_
            ne = self.n_eff
            k = jnp.asarray(ak.data).reshape(1, ne, cfg.n_kv_heads, hd)
            v = jnp.asarray(av.data).reshape(1, ne, cfg.n_kv_heads, hd)
            self.dispatch_count += 3           # 2 uploads + 1 sink write
            self._emit("put_kv", self._row_of[layer], k.astype(dtype),
                       v.astype(dtype), self.start_token)
        self._kvio = remaining

    def _exec_io_h(self, t: Task) -> None:
        if not self._is_attn(t.layer):
            return          # mamba layers restore via the state blob
        store, sess, n = self.mgr.store, self.session, self.n_tokens
        d = self.start_token
        submit = getattr(store, "submit_layer_read", None)
        if submit is None:                     # store without async API
            if self.compress == "int8":
                q = store.read_layer_async(sess, "h", t.layer, n,
                                           start_token=d)
                s = store.read_layer_async(sess, "hs", t.layer, n,
                                           start_token=d)
                self._measure(q.completion, s.completion)
                self._hbuf[t.layer] = dequantize_hidden_int8(q.data, s.data)
            else:
                r = store.read_layer_async(sess, "h", t.layer, n,
                                           start_token=d)
                self._measure(r.completion)
                self._hbuf[t.layer] = r.data
            return
        # submit leg: tickets staged until the projection consumes them
        # (with the async engine attached the reads overlap compute on
        # the shard workers; without it they completed inline)
        lr = submit(sess, "h", t.layer, n, start_token=d)
        ls = (submit(sess, "hs", t.layer, n, start_token=d)
              if self.compress == "int8" else None)
        self._hio[t.layer] = (self._cur_idx, lr, ls)

    def _exec_io_kv(self, t: Task) -> None:
        if not self._is_attn(t.layer):
            return
        store, sess, n = self.mgr.store, self.session, self.n_tokens
        d = self.start_token
        submit = getattr(store, "submit_layer_read", None)
        if submit is None:
            cfg = self.mgr.cfg
            rk = store.read_layer_async(sess, "kvk", t.layer, n,
                                        start_token=d)
            rv = store.read_layer_async(sess, "kvv", t.layer, n,
                                        start_token=d)
            self._measure(rk.completion, rv.completion)
            hd = cfg.head_dim_
            ne = self.n_eff
            k = jnp.asarray(rk.data).reshape(1, ne, cfg.n_kv_heads, hd)
            v = jnp.asarray(rv.data).reshape(1, ne, cfg.n_kv_heads, hd)
            self.dispatch_count += 3           # 2 uploads + 1 sink write
            self._emit("put_kv", self._row_of[t.layer],
                       k.astype(self.model.dtype),
                       v.astype(self.model.dtype), d)
            return
        rk = submit(sess, "kvk", t.layer, n, start_token=d)
        rv = submit(sess, "kvv", t.layer, n, start_token=d)
        self._kvio.append((self._cur_idx, t.layer, rk, rv))

    def _exec_project(self, t: Task) -> None:
        members = [li for li in t.members if self._is_attn(li)]
        if not members:
            return          # hidden-method mamba layers restore via blob
        pack = self.pack
        n = self.n_eff
        S = s_bucket(n)
        G = max(self._g_pad, len(members))
        # completing the submitted tickets here (not at io_h dispatch) is
        # what lets reads of later layers stream on the shard workers
        # while this projection computes
        fetched = {li: self._collect_hidden(li) for li in members}
        h0 = fetched[members[0]]
        stack = np.zeros((G, S, h0.shape[-1]), h0.dtype)
        rows = [self._row_of[li] for li in members]
        for g, li in enumerate(members):
            stack[g, :n] = fetched.pop(li)
        # pad to the stable group width with a repeated row id over zero
        # hidden states; padded outputs are sliced away below
        rows_pad = np.asarray(rows + [rows[-1]] * (G - len(rows)), np.int32)
        # RoPE at absolute positions: a suffix restore rotates with the
        # tables sliced at its divergence offset
        cos, sin = pack.rope_tables(S, self.start_token)
        t0 = time.perf_counter()
        # ONE host->device upload (replicated across the mesh under tp)
        hidden = pack.place_hidden(stack)
        k, v = _project_group_jit(
            hidden, jnp.asarray(rows_pad), pack.ln_scale, pack.ln_bias,
            pack.wk, pack.wv, pack.bk, pack.bv, cos, sin,
            norm_kind=pack.norm_kind, eps=pack.norm_eps,
            head_dim=pack.head_dim, use_rope=pack.use_rope,
            dtype=pack.dtype, use_pallas=ops.on_tpu(), interpret=None,
            kv_sharding=pack.out_sharding)
        jax.block_until_ready((k, v))
        self.project_wall += time.perf_counter() - t0
        g_real = len(members)
        self.dispatch_count += 3     # upload + projection + grouped write
        self._emit("put_kv_group", tuple(rows),
                   k[:g_real, None, :n], v[:g_real, None, :n],
                   self.start_token)

    def _exec_recompute(self, t: Task) -> None:
        from repro.models import transformer as tfm
        model, params = self.model, self.params
        mh = model.h
        if self._re_x is None:
            toks = jnp.asarray(
                self.mgr.store.get_blob(self.session, "tok", 0)
            )[None, :self.n_tokens]
            B, S = toks.shape
            self._re_pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
            self._re_x = tfm._embed_input(params, mh, toks, self._re_pos)
            self._re_windows = tfm.layer_windows(mh)
        j = self._re_next
        bp = jax.tree.map(lambda a: a[j], params["blocks"])
        win = (self._re_windows[j] if self._re_windows is not None else None)
        x, _, kv, _ = tfm.block_forward(bp, self._re_x, mh,
                                        positions=self._re_pos, window=win,
                                        emit_kv=True)
        self._re_x = x
        self._re_next += 1
        k, v = kv
        self.dispatch_count += 2               # block forward + sink write
        self._emit("put_kv", self._row_of[t.layer],
                   k.astype(model.dtype), v.astype(model.dtype))

    def _exec_blob(self, t: Task) -> None:
        store, sess = self.mgr.store, self.session
        conv = jnp.asarray(store.get_blob(sess, "state_conv", 0))
        ssm = jnp.asarray(store.get_blob(sess, "state_ssm", 0))
        self._emit("put_states", conv, ssm)

    def _exec_io_enc(self, t: Task) -> None:
        # the encoder blob lives whole on its owning shard; the submit
        # path overlaps the read with decoder-side restoration and the
        # cross-projection reaps it. Charged only on the virtual clock
        # (CrossTimes.io) and excluded from io_measured.
        submit = getattr(self.mgr.store, "submit_blob_read", None)
        if submit is None:
            self._enc_out = np.asarray(
                self.mgr.store.get_blob(self.session, "enc", 0))
            return
        self._encio = (self._cur_idx, submit(self.session, "enc", 0))

    def _exec_project_cross(self, t: Task) -> None:
        from repro.models import encdec as encdec_mod
        if self._encio is not None:
            idx, ticket = self._encio
            self._encio = None
            parts = ticket.wait()
            self._enc_out = np.asarray(parts[0])
            self._observe_read(idx, "io_enc", [ticket],
                               self._task_work(self.tasks[idx]),
                               self._enc_bucket)
        enc_out = jnp.asarray(self._enc_out)[None]
        self._enc_out = None
        ck, cv = encdec_mod.cross_kv(self.params, enc_out, self.model.h)
        self.dispatch_count += 2             # upload+projection, sink write
        self._emit("put_cross", ck, cv, enc_out.shape[1])
