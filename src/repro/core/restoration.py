"""Pipelined restoration executor (paper §4.1, DESIGN.md §5).

One source of truth for restoration: a ``Schedule`` compiles into an
ordered task graph (``compile_tasks``) of per-layer steps — striped
chunk-store IO reads, hidden→KV projections, recompute-prefix segments,
SSM/enc-dec blob loads. The same graph serves three consumers:

  * ``replay``                — virtual two-stream replay of a task order
                                under a hardware profile → ``Timeline``.
                                ``core.pipeline.simulate`` is exactly
                                ``replay(compile_tasks(methods), times)``.
  * ``RestorationExecutor``   — executes the graph *incrementally*
                                (``step(max_tasks)``), interleaving the IO
                                and compute streams event-driven, writing
                                each finished layer straight into a
                                ``RestoreSink`` (the serving engine's batch
                                slot — no intermediate B=1 cache).
  * prefetch                  — an executor without a sink may run IO
                                tasks early (queued sessions warm their
                                layer-0 reads before a slot frees).

The executor records the order tasks actually executed in; its reported
``Timeline`` is ``replay`` over that executed order, so the engine's
numbers and the analytic simulation can never drift apart.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.arch import BlockKind
from repro.core.cost_model import MethodTimes, layer_costs, method_times
from repro.core.scheduler import Schedule
from repro.models.layers.norm import apply_norm
from repro.models.layers import attention as attn_lib

# Task kinds. IO-stream: io_h (hidden fetch), io_kv (raw KV fetch),
# blob (state/encoder/token whole-object reads — O(1) in tokens, charged
# zero virtual time as in the paper's model). Compute-stream: recompute
# (one prefix layer from tokens), project (hidden → K,V GEMM).
IO_KINDS = ("io_h", "io_kv", "blob")
COMPUTE_KINDS = ("recompute", "project")


@dataclasses.dataclass(frozen=True)
class Task:
    kind: str                 # io_h | io_kv | blob | recompute | project
    layer: int                # global layer index (-1 for blob tasks)
    dep: Optional[int] = None  # task-list index that must execute first

    @property
    def stream(self) -> str:
        return "io" if self.kind in IO_KINDS else "compute"


def compile_tasks(methods: Sequence[str], *,
                  n_blobs: int = 0) -> List[Task]:
    """Compile a per-layer method assignment into the ordered task graph.

    List order encodes per-stream priority (paper §4.1): the IO stream
    runs hidden fetches first (layer order) so projections can start,
    then KV fetches fill the IO tail; the compute stream runs the
    recompute prefix from t=0, then projections in fetch order. A
    projection depends on its own fetch."""
    tasks: List[Task] = []
    io_of: Dict[int, int] = {}
    for i, m in enumerate(methods):
        if m == "hidden":
            io_of[i] = len(tasks)
            tasks.append(Task("io_h", i))
    for i, m in enumerate(methods):
        if m == "kv":
            tasks.append(Task("io_kv", i))
    for _ in range(n_blobs):
        tasks.append(Task("blob", -1))
    for i, m in enumerate(methods):
        if m == "recompute":
            tasks.append(Task("recompute", i))
    for i, m in enumerate(methods):
        if m == "hidden":
            tasks.append(Task("project", i, dep=io_of[i]))
    return tasks


def task_duration(task: Task, times: Sequence[MethodTimes]) -> float:
    if task.kind == "io_h":
        return times[task.layer].io_h
    if task.kind == "io_kv":
        return times[task.layer].io_kv
    if task.kind == "recompute":
        return times[task.layer].c_token
    if task.kind == "project":
        return times[task.layer].c_h
    return 0.0                                 # blob reads: O(1) in tokens


def replay(tasks: Sequence[Task], times: Sequence[MethodTimes],
           order: Optional[Sequence[int]] = None):
    """Two-stream virtual replay of ``tasks`` in ``order`` → Timeline.

    Each stream is serial; a compute task with a dep starts no earlier
    than the dep's completion on the IO stream. ``order`` defaults to
    list order (the compiled priority); the executor passes the order it
    actually ran."""
    from repro.core.pipeline import Timeline
    if order is None:
        order = range(len(tasks))
    done = [0.0] * len(tasks)
    io_t = comp_t = io_busy = comp_busy = 0.0
    for idx in order:
        t = tasks[idx]
        dur = task_duration(t, times)
        if t.stream == "io":
            io_t += dur
            io_busy += dur
            done[idx] = io_t
        else:
            start = comp_t if t.dep is None else max(comp_t, done[t.dep])
            comp_t = start + dur
            comp_busy += dur
            done[idx] = comp_t
    return Timeline(max(io_t, comp_t), io_busy, comp_busy, io_t, comp_t)


# ----------------------------------------------------- hidden-state codec
def quantize_hidden_int8(x: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Per-token int8 quantization of stored hidden states (save path in
    hcache, dequantized here on restore — one codec for both)."""
    scale = np.abs(x).max(axis=-1, keepdims=True).astype(np.float32) / 127.0
    scale = np.maximum(scale, 1e-8)
    q = np.clip(np.round(x / scale), -127, 127).astype(np.int8)
    return q, scale


def dequantize_hidden_int8(q: np.ndarray, scale: np.ndarray) -> np.ndarray:
    return q.astype(np.float32) * scale.astype(np.float32)


# ------------------------------------------------------------------- sinks
class RestoreSink:
    """Receives restored state one piece at a time, in any order."""

    def put_kv(self, row: int, k, v) -> None:
        """One attention layer's KV; row indexes the stacked-KV buffer
        (k, v: (1, n, kv_heads, head_dim))."""
        raise NotImplementedError

    def put_states(self, conv, ssm) -> None:
        raise NotImplementedError

    def put_cross(self, ck, cv, enc_len: int) -> None:
        raise NotImplementedError

    def finish(self, n_tokens: int) -> None:
        raise NotImplementedError


class CacheAssembler(RestoreSink):
    """Builds the family-specific B=1 cache dict — the standalone
    ``HCacheManager.restore`` API (tests, offline tools). The serving
    engine does NOT use this: its sink writes batch-slot buffers."""

    def __init__(self, model):
        self.model = model
        self.k_parts: Dict[int, jnp.ndarray] = {}
        self.v_parts: Dict[int, jnp.ndarray] = {}
        self.states = None
        self.cross = None
        self.cache: Optional[dict] = None

    def put_kv(self, row, k, v):
        self.k_parts[row] = k
        self.v_parts[row] = v

    def put_states(self, conv, ssm):
        self.states = (conv, ssm)

    def put_cross(self, ck, cv, enc_len):
        self.cross = (ck, cv, enc_len)

    def finish(self, n_tokens):
        model = self.model
        lengths = jnp.asarray([n_tokens], jnp.int32)
        if model.kind == "ssm":
            conv, ssm = self.states
            self.cache = {"conv": conv, "ssm": ssm, "lengths": lengths}
            return
        rows = sorted(self.k_parts)
        k = jnp.stack([self.k_parts[r] for r in rows]).astype(model.dtype)
        v = jnp.stack([self.v_parts[r] for r in rows]).astype(model.dtype)
        if model.kind == "lm":
            self.cache = {"k": k, "v": v, "lengths": lengths}
        elif model.kind == "hybrid":
            conv, ssm = self.states
            self.cache = {"attn_k": k, "attn_v": v, "conv": conv,
                          "ssm": ssm, "lengths": lengths}
        else:                                   # encdec
            ck, cv, enc_len = self.cross
            self.cache = {"self_k": k, "self_v": v, "cross_k": ck,
                          "cross_v": cv,
                          "enc_len": jnp.asarray(enc_len, jnp.int32),
                          "lengths": lengths}


# -------------------------------------------------------- param projections
def subset_blocks(model, params, idx: List[int]):
    """Stacked block params for the given global layer indices."""
    arr = np.asarray(idx)
    blocks = (params["blocks"] if model.kind == "lm" else
              params["attn"] if model.kind == "hybrid" else
              params["dec_blocks"])
    if model.kind == "hybrid":
        # attn params are stacked per super-block; map layer->super idx
        k = model.h.k
        arr = np.asarray([i // k for i in idx])
    return jax.tree.map(lambda x: x[arr], blocks)


def project_hidden(model, blocks, hidden, pos):
    """K,V projection of saved hidden states (the paper's core GEMM).

    hidden: (L_sub, 1, n, D); returns (k, v): (L_sub, 1, n, Kv, hd)."""
    cfg, mh = model.cfg, model.h
    attn_h = mh.attn if hasattr(mh, "attn") else mh.lm.attn
    attn_key = "attn" if model.kind in ("lm", "hybrid") else "self_attn"

    def one(bp, hl):
        normed = apply_norm(bp["ln1"], hl, cfg.norm, cfg.norm_eps)
        ap = bp[attn_key] if attn_key in bp else bp
        return attn_lib.restore_kv(ap["wk"], ap["wv"], ap.get("bk"),
                                   ap.get("bv"), normed, attn_h,
                                   jnp.broadcast_to(pos, hl.shape[:2]))

    return jax.vmap(one)(blocks, hidden)


# --------------------------------------------------------------- executor
class RestorationExecutor:
    """Incremental, sink-directed execution of one session's restoration.

    Created by ``HCacheManager.begin_restore``. ``step(max_tasks)`` runs a
    bounded number of tasks, event-driven across the two virtual streams
    (whichever stream's clock is behind goes next, so layers finish in
    pipelined order); ``prefetch_step`` runs IO tasks only (no sink
    needed). All finished pieces flow to the sink immediately; pieces
    produced before a sink is attached are buffered (numpy/array handles,
    never a stacked B=1 cache) and flushed on ``attach_sink``."""

    def __init__(self, mgr, params, session: str,
                 sink: Optional[RestoreSink] = None):
        manifest = mgr.store.get_manifest(session)
        if manifest is None:
            raise KeyError(f"no stored state for session {session!r}")
        self.mgr = mgr
        self.model = mgr.model
        self.params = params
        self.session = session
        self.sink = sink
        self.n_tokens = int(manifest["n_tokens"])
        self.methods = tuple(manifest["methods"])
        self.schedule = Schedule(self.methods, 0.0, 0.0, 0.0, 0.0)
        self.compress = manifest.get("compress", mgr.compress)
        mgr.store.sync_clocks(0.0)

        kinds = mgr.cfg.block_kinds()
        self._attn_layers = [i for i, k in enumerate(kinds)
                             if k == BlockKind.ATTENTION]
        self._row_of = {li: r for r, li in enumerate(self._attn_layers)}
        n_blobs = self._count_blobs()
        self.tasks = compile_tasks(self.methods, n_blobs=n_blobs)
        self.times = [method_times(c, mgr.hw)
                      for c in layer_costs(mgr.cfg, self.n_tokens,
                                           mgr.dtype_bytes)]
        self.executed: List[int] = []
        self._done = [False] * len(self.tasks)
        # event-driven stream interleaving state
        self._io_queue = [i for i, t in enumerate(self.tasks)
                          if t.stream == "io"]
        self._comp_queue = [i for i, t in enumerate(self.tasks)
                            if t.stream == "compute"]
        self._io_clock = 0.0
        self._comp_clock = 0.0
        self._hbuf: Dict[int, np.ndarray] = {}
        self._pending: List[Tuple[str, tuple]] = []   # sink-less buffer
        # recompute-prefix carry
        self._re_layers = [i for i, m in enumerate(self.methods)
                           if m == "recompute"]
        self._re_x = None
        self._re_pos = None
        self._re_windows = None
        self._re_next = 0
        self._finished = False
        # striped-device completion, relative to the device clocks at
        # executor start (the clocks are shared and monotonic across
        # restores; under concurrent restores this correctly includes
        # queueing behind the other session's reads)
        self._io_base = mgr.store.read_completion()
        self.io_measured = 0.0
        self.wall_time = 0.0

    # ------------------------------------------------------------- plumbing
    def _count_blobs(self) -> int:
        kind = self.model.kind
        if kind in ("ssm", "hybrid"):
            return 1                            # conv+ssm state blobs
        if kind == "encdec":
            return 1                            # encoder output blob
        return 0

    @property
    def done(self) -> bool:
        return all(self._done)

    def attach_sink(self, sink: RestoreSink) -> None:
        self.sink = sink
        for op, args in self._pending:
            getattr(sink, op)(*args)
        self._pending.clear()

    def _emit(self, op: str, *args) -> None:
        if self.sink is not None:
            getattr(self.sink, op)(*args)
        else:
            self._pending.append((op, args))

    def timeline(self):
        """Timeline derived from the order tasks actually executed in."""
        order = self.executed + [i for i in range(len(self.tasks))
                                 if not self._done[i]]
        return replay(self.tasks, self.times, order)

    # ------------------------------------------------------------ stepping
    def _ready(self, idx: int) -> bool:
        t = self.tasks[idx]
        if t.dep is not None and not self._done[t.dep]:
            return False
        if t.kind == "recompute":
            # prefix layers carry the residual stream in order
            return self._re_layers[self._re_next] == t.layer
        return True

    def _pick(self) -> Optional[int]:
        """Event-driven pick: advance whichever stream is behind."""
        io_idx = self._io_queue[0] if self._io_queue else None
        comp_idx = (self._comp_queue[0]
                    if self._comp_queue and self._ready(self._comp_queue[0])
                    else None)
        if io_idx is None:
            return comp_idx
        if comp_idx is None:
            return io_idx
        return comp_idx if self._comp_clock <= self._io_clock else io_idx

    def step(self, max_tasks: int = 4) -> bool:
        """Execute up to ``max_tasks`` tasks; True when restoration done."""
        t0 = time.perf_counter()
        for _ in range(max_tasks):
            idx = self._pick()
            if idx is None:
                break
            self._run_task(idx)
        if self.done and not self._finished and self.sink is not None:
            self.sink.finish(self.n_tokens)
            self._finished = True
        self.wall_time += time.perf_counter() - t0
        return self.done

    def prefetch_step(self, max_tasks: int = 1) -> int:
        """Run up to ``max_tasks`` IO tasks (no sink required); returns
        the number executed. Used to warm queued sessions' reads."""
        n = 0
        while n < max_tasks and self._io_queue:
            self._run_task(self._io_queue[0])
            n += 1
        return n

    def run(self) -> None:
        while not self.step(max_tasks=max(len(self.tasks), 1)):
            pass

    # ---------------------------------------------------------- task bodies
    def _run_task(self, idx: int) -> None:
        t = self.tasks[idx]
        dur = task_duration(t, self.times)
        if t.stream == "io":
            self._io_queue.remove(idx)
            self._io_clock += dur
        else:
            self._comp_queue.remove(idx)
            start = (self._comp_clock if t.dep is None else
                     max(self._comp_clock, self._io_clock))
            self._comp_clock = max(self._comp_clock, start) + dur
        getattr(self, "_exec_" + t.kind)(t)
        self._done[idx] = True
        self.executed.append(idx)

    def _is_attn(self, layer: int) -> bool:
        return layer in self._row_of

    def _measure(self, *completions: float) -> None:
        done = max(completions, default=0.0)
        if done:
            self.io_measured = max(self.io_measured, done - self._io_base)

    def _exec_io_h(self, t: Task) -> None:
        if not self._is_attn(t.layer):
            return          # mamba layers restore via the state blob
        store, sess, n = self.mgr.store, self.session, self.n_tokens
        if self.compress == "int8":
            q = store.read_layer_async(sess, "h", t.layer, n)
            s = store.read_layer_async(sess, "hs", t.layer, n)
            self._measure(q.completion, s.completion)
            self._hbuf[t.layer] = dequantize_hidden_int8(q.data, s.data)
        else:
            r = store.read_layer_async(sess, "h", t.layer, n)
            self._measure(r.completion)
            self._hbuf[t.layer] = r.data

    def _exec_io_kv(self, t: Task) -> None:
        if not self._is_attn(t.layer):
            return
        cfg = self.mgr.cfg
        store, sess, n = self.mgr.store, self.session, self.n_tokens
        rk = store.read_layer_async(sess, "kvk", t.layer, n)
        rv = store.read_layer_async(sess, "kvv", t.layer, n)
        self._measure(rk.completion, rv.completion)
        hd = cfg.head_dim_
        k = jnp.asarray(rk.data).reshape(1, n, cfg.n_kv_heads, hd)
        v = jnp.asarray(rv.data).reshape(1, n, cfg.n_kv_heads, hd)
        self._emit("put_kv", self._row_of[t.layer],
                   k.astype(self.model.dtype), v.astype(self.model.dtype))

    def _exec_project(self, t: Task) -> None:
        if not self._is_attn(t.layer):
            return
        h_np = self._hbuf.pop(t.layer)
        hidden = jnp.asarray(h_np, self.model.dtype)[None, None]  # (1,1,n,D)
        pos = jnp.arange(self.n_tokens)[None, :]
        sub = subset_blocks(self.model, self.params, [t.layer])
        k, v = project_hidden(self.model, sub, hidden, pos)
        self._emit("put_kv", self._row_of[t.layer],
                   k[0].astype(self.model.dtype),
                   v[0].astype(self.model.dtype))

    def _exec_recompute(self, t: Task) -> None:
        from repro.models import transformer as tfm
        model, params = self.model, self.params
        mh = model.h
        if self._re_x is None:
            toks = jnp.asarray(
                self.mgr.store.get_blob(self.session, "tok", 0)
            )[None, :self.n_tokens]
            B, S = toks.shape
            self._re_pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
            self._re_x = tfm._embed_input(params, mh, toks, self._re_pos)
            self._re_windows = tfm.layer_windows(mh)
        j = self._re_next
        bp = jax.tree.map(lambda a: a[j], params["blocks"])
        win = (self._re_windows[j] if self._re_windows is not None else None)
        x, _, kv, _ = tfm.block_forward(bp, self._re_x, mh,
                                        positions=self._re_pos, window=win,
                                        emit_kv=True)
        self._re_x = x
        self._re_next += 1
        k, v = kv
        self._emit("put_kv", self._row_of[t.layer],
                   k.astype(model.dtype), v.astype(model.dtype))

    def _exec_blob(self, t: Task) -> None:
        store, sess = self.mgr.store, self.session
        kind = self.model.kind
        if kind in ("ssm", "hybrid"):
            conv = jnp.asarray(store.get_blob(sess, "state_conv", 0))
            ssm = jnp.asarray(store.get_blob(sess, "state_ssm", 0))
            self._emit("put_states", conv, ssm)
        elif kind == "encdec":
            from repro.models import encdec as encdec_mod
            enc_out = jnp.asarray(store.get_blob(sess, "enc", 0))[None]
            ck, cv = encdec_mod.cross_kv(self.params, enc_out, self.model.h)
            self._emit("put_cross", ck, cv, enc_out.shape[1])
