"""HCache restoration cost model (paper §3.2), generalized to GQA/MoE/SSM.

All quantities are per-layer for a history of ``n_tokens``:

  IO_H    bytes to fetch hidden states      = n·D·dtype
  IO_KV   bytes to fetch the KV cache       = n·2·kv_dim·dtype
  C_H     FLOPs to project H -> K,V         = n·2·D·(2·kv_dim)
  C_RE    FLOPs to recompute from tokens    = attention + FFN (quadratic term)

For MHA (kv_dim == D) these reduce exactly to the paper's formulas:
IO_H = IO_KV/2 and C_RE/C_H = 6 + n/(4·D). For GQA the ratios shift (the
paper scopes this out in §7); the bubble-free scheduler consumes these
numbers and adapts — see DESIGN.md §3.

SSM layers (mamba) have no KV; their "restore" is the ssm-rescan (state
recompute from the layer's saved input), costed at the state-recurrence
FLOPs only.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.config.arch import ArchConfig, BlockKind
from repro.config.hardware import GEMM_EFFICIENCY, HardwareProfile


@dataclasses.dataclass(frozen=True)
class LayerCost:
    """Per-layer restoration costs for one layer *class*."""

    kind: str                     # "attention" | "mamba1" | "mamba2"
    io_hidden: float              # bytes
    io_kv: float                  # bytes (0 for SSM: state is tiny/kept)
    io_state: float               # bytes of the recurrent state (SSM)
    c_hidden: float               # FLOPs: restore from hidden
    c_token: float                # FLOPs: recompute from tokens (full layer)
    store_hidden: float           # bytes/token stored when managed as H
    store_kv: float               # bytes/token stored when managed as KV


def attn_layer_cost(cfg: ArchConfig, n_tokens: int,
                    dtype_bytes: int = 2) -> LayerCost:
    D = cfg.d_model
    kv = cfg.kv_dim
    n_q = cfg.n_heads * cfg.head_dim_
    io_h = n_tokens * D * dtype_bytes
    io_kv = n_tokens * 2 * kv * dtype_bytes
    # HCache restore: K and V projections (+ rope, negligible)
    c_h = n_tokens * 2 * D * (2 * kv)
    # full recompute: qkvo projections + scores/weighted-sum + FFN
    c_attn_proj = n_tokens * 2 * (D * n_q + 2 * D * kv + n_q * D)
    # causal: ~n²/2 (q,k) pairs × (QK^T + PV) × 2 FLOPs/MAC × n_q
    c_attn_quad = 2 * n_tokens * n_tokens * n_q
    if cfg.local_window:
        w = min(cfg.local_window, n_tokens)
        c_attn_quad = 4 * n_tokens * w * n_q
    ffn_mults = 3 if cfg.ffn_glu else 2
    if cfg.n_experts:
        c_ffn = n_tokens * 2 * ffn_mults * D * cfg.d_ff * cfg.experts_per_token
    else:
        c_ffn = n_tokens * 2 * ffn_mults * D * cfg.d_ff
    c_re = c_attn_proj + c_attn_quad + c_ffn
    return LayerCost("attention", io_h, io_kv, 0.0, c_h, c_re,
                     D * dtype_bytes, 2 * kv * dtype_bytes)


def mamba_layer_cost(cfg: ArchConfig, n_tokens: int, kind: BlockKind,
                     dtype_bytes: int = 2) -> LayerCost:
    D = cfg.d_model
    inner = cfg.ssm_expand * D
    N = cfg.ssm_state
    io_h = n_tokens * D * dtype_bytes
    # the recurrent state is O(1) in tokens; offloading it is the "KV" analog
    if kind == BlockKind.MAMBA2:
        n_heads = inner // cfg.ssm_headdim
        state_bytes = n_heads * cfg.ssm_headdim * N * 4
        # rescan: in_proj + conv + state recurrence (no output path)
        c_h = n_tokens * 2 * D * (2 * inner + 2 * N + n_heads) * 0.5 \
            + n_tokens * inner * N * 4
        c_re = n_tokens * 2 * D * (2 * inner + 2 * N + n_heads) \
            + n_tokens * inner * N * 6 + n_tokens * 2 * inner * D
    else:
        state_bytes = inner * N * 4
        dt_rank = max(D // 16, 1)
        c_h = n_tokens * 2 * D * inner + n_tokens * inner * N * 4
        c_re = (n_tokens * 2 * D * 2 * inner
                + n_tokens * 2 * inner * (dt_rank + 2 * N)
                + n_tokens * inner * N * 6 + n_tokens * 2 * inner * D)
    return LayerCost(kind.value, io_h, 0.0, state_bytes, c_h, c_re,
                     D * dtype_bytes, 0.0)


def layer_costs(cfg: ArchConfig, n_tokens: int,
                dtype_bytes: int = 2) -> list:
    """One LayerCost per layer of the stack, in order."""
    out = []
    for kind in cfg.block_kinds():
        if kind == BlockKind.ATTENTION:
            out.append(attn_layer_cost(cfg, n_tokens, dtype_bytes))
        else:
            out.append(mamba_layer_cost(cfg, n_tokens, kind, dtype_bytes))
    return out


# ------------------------------------------------------------------ timings
@dataclasses.dataclass(frozen=True)
class MethodTimes:
    """Seconds per layer under a hardware profile (paper §4.1.2 symbols)."""

    io_h: float       # IO_H
    io_kv: float      # IO_KV
    c_h: float        # C_H
    c_token: float    # C_Token

    @property
    def hcache_bound(self) -> float:
        return max(self.io_h, self.c_h)


def method_times(cost: LayerCost, hw: HardwareProfile,
                 gemm_eff: float = GEMM_EFFICIENCY, *,
                 profile=None, io_streams: int = 1,
                 link: Optional[int] = None) -> MethodTimes:
    """Seconds per layer. With a ``MeasuredProfile`` the observed marginal
    rates (seconds/byte, seconds/FLOP) replace the datasheet numbers for
    every kind that has samples; unmeasured kinds keep the static model.
    ``io_streams`` prices shared host-link/storage bandwidth: N sessions
    restoring concurrently each see 1/N of the link, so IO legs stretch
    N-fold while compute legs (per-chip) do not. ``link`` selects the
    per-NIC-link learned rate for the IO kinds when the profile has one
    (distributed store; see ``link_priced_times``).

    ``hw.mesh_devices`` > 1 (DESIGN.md §16) divides the projection
    compute across the tensor-parallel shards — each device projects
    only its KV heads, so C_H scales ÷shards. Recompute stays whole (the
    block-forward rebuild runs replicated, not head-sharded) and the IO
    legs are host-side, untouched by device multiplicity."""
    flops = hw.flops * gemm_eff
    bw = min(hw.storage_bw, hw.host_link_bw)
    m = max(int(io_streams), 1)
    shards = max(int(getattr(hw, "mesh_devices", 1)), 1)
    io_h = cost.io_hidden / bw
    io_kv = cost.io_kv / bw if cost.io_kv else cost.io_state / bw
    c_h = cost.c_hidden / (flops * shards)
    c_token = cost.c_token / flops
    if profile is not None:
        r = profile.rate("io_h", link=link)
        if r is not None:
            io_h = cost.io_hidden * r
        r = profile.rate("io_kv", link=link)
        if r is not None:
            io_kv = (cost.io_kv or cost.io_state) * r
        r = profile.rate("project", mesh=shards)
        if r is not None:
            c_h = cost.c_hidden * r
        r = profile.rate("recompute")
        if r is not None:
            c_token = cost.c_token * r
    return MethodTimes(io_h=io_h * m, io_kv=io_kv * m,
                       c_h=c_h, c_token=c_token)


class LinkLoad:
    """Concurrent restore-stream counts per NIC link.

    The engine reports, for each link of the distributed store, how many
    RESTORING sessions currently have IO in flight on it. Planners then
    charge contention only on the links a candidate restore actually
    touches — ``factor(links)`` is the max load over the touched links
    (the slowest link gates the stripe), replacing PR 7's global
    ``io_streams`` stretch which taxed every restore for every other
    restore even on disjoint links."""

    __slots__ = ("streams",)

    def __init__(self, streams: Optional[Dict[int, int]] = None):
        self.streams = {int(k): int(v)
                        for k, v in (streams or {}).items() if int(v) > 0}

    def factor(self, links: Sequence[int]) -> int:
        if not self.streams:
            return 1
        return max([self.streams.get(int(l), 0) for l in links] + [1])

    def key(self) -> Tuple[Tuple[int, int], ...]:
        """Hashable identity for plan-cache keys."""
        return tuple(sorted(self.streams.items()))

    def __repr__(self):
        return f"LinkLoad({self.streams})"


def link_priced_times(costs: Sequence[LayerCost], hw: HardwareProfile,
                      gemm_eff: float = GEMM_EFFICIENCY, *,
                      profile=None, io_streams: int = 1,
                      topology=None, link_load: Optional[LinkLoad] = None,
                      aggregate: bool = False)\
        -> Tuple[List[MethodTimes], Optional[Dict[int, int]]]:
    """Per-layer times priced on the links each layer's IO touches.

    Without a topology (one-host store) this is the legacy model: every
    IO leg stretched uniformly by ``io_streams``. With a sharded store:

      * ``layer`` placement — layer L's IO occupies exactly link L%N.
        Contention = load on that one link. Returns full per-layer IO
        durations plus a ``{layer: link}`` map; the restoration replay
        runs one virtual IO clock per link, so layers on different
        links genuinely overlap. ``aggregate=True`` (for planners that
        sum IO serially, e.g. the layer-split solver) instead divides
        the IO legs by N — the balanced-stripe approximation of the
        per-link max — and returns no map.
      * ``chunk`` placement — every layer stripes all N links: IO legs
        aggregate N links' bandwidth (÷N) but pay the max load across
        all of them. No per-layer map (no link-level parallelism left
        to expose between layers).

    ``topology`` is duck-typed (``n_shards``/``placement``/
    ``links_for_layer``) so planning code needs no storage import."""
    if topology is None or topology.n_shards <= 1:
        times = [method_times(c, hw, gemm_eff, profile=profile,
                              io_streams=io_streams) for c in costs]
        return times, None
    n = topology.n_shards
    chunk_mode = topology.placement == "chunk"
    all_links = tuple(range(n))
    times: List[MethodTimes] = []
    layer_links: Dict[int, int] = {}
    for li, c in enumerate(costs):
        links = all_links if chunk_mode else topology.links_for_layer(li)
        if link_load is not None:
            m = link_load.factor(links)
        else:
            m = max(int(io_streams), 1)
        link = None if chunk_mode else links[0]
        t = method_times(c, hw, gemm_eff, profile=profile,
                         io_streams=m, link=link)
        if chunk_mode or aggregate:
            t = dataclasses.replace(t, io_h=t.io_h / n, io_kv=t.io_kv / n)
        else:
            layer_links[li] = links[0]
        times.append(t)
    return times, (None if (chunk_mode or aggregate) else layer_links)


def restoration_time(cfg: ArchConfig, n_tokens: int, hw: HardwareProfile,
                     method: str, dtype_bytes: int = 2) -> float:
    """End-to-end restoration time for a *single-method* scheme.

    method in {"hcache", "kv_offload", "recompute"}. The HCache pipeline
    overlaps IO and compute (paper Fig 5): bound = max(ΣIO_H, ΣC_H) + one
    layer's lead-in (negligible, dropped as in §3.2)."""
    total_io_h = total_io_kv = total_c_h = total_c_re = 0.0
    for cost in layer_costs(cfg, n_tokens, dtype_bytes):
        t = method_times(cost, hw)
        total_io_h += t.io_h
        total_io_kv += t.io_kv
        total_c_h += t.c_h
        total_c_re += t.c_token
    if method == "hcache":
        return max(total_io_h, total_c_h)
    if method == "kv_offload":
        return total_io_kv
    if method == "recompute":
        return total_c_re
    raise ValueError(method)


def storage_per_token(cfg: ArchConfig, schedule, dtype_bytes: int = 2) -> float:
    """Bytes/token stored under a schedule (Table 3). ``schedule`` is a
    sequence of per-layer methods from repro.core.scheduler."""
    costs = layer_costs(cfg, 1, dtype_bytes)
    total = 0.0
    for cost, m in zip(costs, schedule):
        if m == "hidden":
            total += cost.store_hidden
        elif m == "kv":
            # SSM layers: "kv" = state-blob offload, O(1) in tokens
            total += cost.store_kv if cost.kind == "attention" else 0.0
        # recompute: nothing stored (tokens are negligible)
    return total
