from repro.core.cost_model import (LayerCost, MethodTimes, layer_costs,
                                   method_times, restoration_time,
                                   storage_per_token)
from repro.core.pipeline import (Timeline, decode_step_time, prefill_time,
                                 restore_timeline, simulate, ttft)
from repro.core.scheduler import METHODS, Schedule, closed_form, solve
