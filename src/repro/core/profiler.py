"""Online restoration profiler (DESIGN.md §13).

Every number the bubble-free scheduler plans with — host-link/storage
bandwidth, GEMM efficiency, per-dispatch overhead — starts life as a
guess in ``config/hardware.py``. The ``RestorationExecutor`` walks a
task graph of *real* work (striped chunk reads, grouped projections,
recompute segments); this module folds the wall/virtual seconds of those
tasks into a ``MeasuredProfile`` that ``cost_model.method_times`` (and
through it ``scheduler.solve``, ``capacity.restore_makespan`` and the
group-size planner) consume *in place of* the static profile, so the
(L_H, L_KV, L_RE) split and the restore-group boundaries are re-planned
from observed reality and converge within a few restores.

Model, per task kind: ``seconds = overhead + work / rate`` where work is
bytes for IO kinds and FLOPs for compute kinds. Observations are folded
as EMA-weighted ``(work, seconds)`` moments per power-of-two token
bucket; with two or more buckets the (overhead, rate) pair comes from a
weighted least-squares line over the bucket means, with one bucket the
fit degenerates to a through-origin rate. The intercept of the compute
kinds IS the measured per-dispatch overhead (the quantity
``HardwareProfile.dispatch_overhead`` guessed) — ``method_times`` uses
only the marginal rate for per-layer costs, and ``replay`` charges the
measured overhead once per compute task, exactly as the static model
did.

Plan-cache invalidation: consumers memoize schedules and group plans per
``epoch``. The epoch bumps only when a kind's fitted prediction drifts
more than ``drift`` (5% default) from its last-snapshotted fit — so
plans are re-derived while calibration is still moving and the memoized
zero-recompile guarantee returns once it has converged.

Persistence: ``save``/``load`` round-trip the bucket moments to JSON
(``launch/serve.py --hw-profile``), so a fleet restart starts from the
previous run's calibration instead of the datasheet guesses.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Optional, Tuple

# work units: bytes for IO-stream kinds, FLOPs for compute-stream kinds
IO_KINDS = ("io_h", "io_kv", "io_enc")
COMPUTE_KINDS = ("project", "recompute", "project_cross")
KINDS = IO_KINDS + COMPUTE_KINDS


def link_kind(kind: str, link: int) -> str:
    """Cell name for a per-NIC-link rate sample: ``io_h@L2`` = io_h
    served over link 2. The distributed store's links can be
    heterogeneous (mixed NIC generations, a degraded path), so the
    profiler keeps a per-link fit next to the aggregate one."""
    return f"{kind}@L{int(link)}"


def base_kind(kind: str) -> str:
    return kind.split("@", 1)[0]


def mesh_kind(kind: str, mesh: int) -> str:
    """Cell name for a per-mesh rate sample: ``project@M4`` = the grouped
    projection launched SPMD over a 4-device tensor-parallel mesh
    (DESIGN.md §16). Sharded launches have a genuinely different
    seconds-per-FLOP (the FLOPs are counted whole but each device runs
    1/tp of them), so each mesh width learns its own fit instead of
    poisoning the single-device cell."""
    return f"{kind}@M{int(mesh)}"


@dataclasses.dataclass
class _Bucket:
    """EMA moments of one (kind, token-bucket) cell."""

    work: float = 0.0        # EMA of observed work units per task
    seconds: float = 0.0     # EMA of observed seconds per task
    n: int = 0               # raw sample count (gauge + LS weight)

    def fold(self, work: float, seconds: float, alpha: float) -> None:
        if self.n == 0:
            self.work, self.seconds = work, seconds
        else:
            self.work += alpha * (work - self.work)
            self.seconds += alpha * (seconds - self.seconds)
        self.n += 1


class MeasuredProfile:
    """Per-kind, per-bucket observed task times + the derived cost fits.

    ``record`` is called by the executor once per real task;
    ``rate``/``overhead``/``predict`` are the planning-side reads. All
    methods fall back to ``None`` when a kind has no samples yet, so the
    static ``HardwareProfile`` keeps covering unmeasured kinds.
    """

    def __init__(self, alpha: float = 0.4, drift: float = 0.05):
        self.alpha = float(alpha)
        self.drift = float(drift)
        self.kinds: Dict[str, Dict[int, _Bucket]] = {}
        self.epoch = 0
        self._snap: Dict[str, Tuple[float, float]] = {}

    # ------------------------------------------------------------ recording
    def record(self, kind: str, bucket: int, work: float,
               seconds: float, link: Optional[int] = None,
               mesh: Optional[int] = None) -> None:
        """Fold one observed task: ``work`` units took ``seconds``.
        Non-positive observations are dropped (an untimed backend).
        ``link`` additionally folds the sample into the per-link cell
        (``io_h@L{link}``) so the planner can price heterogeneous NICs;
        the aggregate cell still learns every sample. ``mesh`` > 1
        redirects the sample to the per-mesh cell (``project@M{mesh}``)
        INSTEAD of the aggregate one — a tp-sharded launch's rate is not
        the single-device rate and must not contaminate its fit."""
        if base_kind(kind) not in KINDS or work <= 0.0 or seconds <= 0.0:
            return
        if mesh is not None and int(mesh) > 1:
            kind = mesh_kind(kind, mesh)
        for k in ((kind,) if link is None
                  else (kind, link_kind(kind, link))):
            cell = self.kinds.setdefault(k, {}).setdefault(int(bucket),
                                                           _Bucket())
            cell.fold(float(work), float(seconds), self.alpha)
            fit = self._fit(k)
            old = self._snap.get(k)
            if old is None or self._drifted(k, old, fit):
                self.epoch += 1
                self._snap[k] = fit

    def _drifted(self, kind: str, old: Tuple[float, float],
                 new: Tuple[float, float]) -> bool:
        # drift = the fit's PREDICTIONS moved, not its raw coefficients
        # (a 0 -> 1e-19 intercept wobble is float noise, not a new
        # machine). Evaluate both lines at the observed work range.
        probes = [c.work for c in self.kinds.get(kind, {}).values()
                  if c.n > 0] or [1.0]
        for w in (min(probes), max(probes)):
            a = old[0] + old[1] * w
            b = new[0] + new[1] * w
            scale = max(abs(a), abs(b))
            if scale > 0.0 and abs(a - b) / scale > self.drift:
                return True
        return False

    # -------------------------------------------------------------- fitting
    def _fit(self, kind: str) -> Optional[Tuple[float, float]]:
        """(overhead_seconds, seconds_per_work_unit) for ``kind``.

        Weighted least squares over the bucket means (weights = sample
        counts); a single bucket cannot separate fixed from marginal cost
        and degenerates to a through-origin rate."""
        cells = self.kinds.get(kind)
        if not cells:
            return None
        pts = [(c.work, c.seconds, float(c.n)) for c in cells.values()
               if c.n > 0]
        if not pts:
            return None
        sw = sum(w for _, _, w in pts)
        mx = sum(x * w for x, _, w in pts) / sw
        my = sum(y * w for _, y, w in pts) / sw
        var = sum(w * (x - mx) ** 2 for x, _, w in pts) / sw
        if len(pts) < 2 or var <= (1e-6 * mx) ** 2:
            return (0.0, my / mx if mx > 0 else 0.0)
        cov = sum(w * (x - mx) * (y - my) for x, y, w in pts) / sw
        slope = cov / var
        if slope <= 0.0:                    # noise inversion: rate fallback
            return (0.0, my / mx if mx > 0 else 0.0)
        intercept = max(my - slope * mx, 0.0)
        return (intercept, slope)

    # ------------------------------------------------------------- queries
    def samples(self, kind: Optional[str] = None) -> int:
        if kind is not None:
            return sum(c.n for c in self.kinds.get(kind, {}).values())
        return sum(self.samples(k) for k in self.kinds)

    def sample_counts(self) -> Dict[str, int]:
        return {k: self.samples(k) for k in sorted(self.kinds)}

    def rate(self, kind: str, link: Optional[int] = None,
             mesh: Optional[int] = None) -> Optional[float]:
        """Marginal seconds per work unit (slope), or None unmeasured.
        With ``link``, the per-link fit is preferred and the aggregate
        fit is the fallback (a link with no samples yet prices like the
        average link, not like the datasheet). With ``mesh`` > 1, the
        per-mesh cell is preferred; an unmeasured mesh falls back to the
        single-device slope divided by the mesh width — the ideal-scaling
        prior the static model uses — rather than pricing a 4-way launch
        at single-device speed."""
        if mesh is not None and int(mesh) > 1:
            fit = self._fit(mesh_kind(kind, mesh))
            if fit is not None and fit[1] > 0.0:
                return fit[1]
            base = self.rate(kind, link=link)
            return None if base is None else base / int(mesh)
        if link is not None:
            fit = self._fit(link_kind(kind, link))
            if fit is not None and fit[1] > 0.0:
                return fit[1]
        fit = self._fit(kind)
        return None if fit is None or fit[1] <= 0.0 else fit[1]

    def overhead(self, kind: str) -> Optional[float]:
        """Fixed per-task seconds (intercept), or None unmeasured."""
        fit = self._fit(kind)
        return None if fit is None else fit[0]

    def predict(self, kind: str, work: float) -> Optional[float]:
        """Full task seconds for ``work`` units (overhead + marginal)."""
        fit = self._fit(kind)
        if fit is None:
            return None
        return fit[0] + fit[1] * work

    def dispatch_overhead(self, mesh: Optional[int] = None)\
            -> Optional[float]:
        """Measured per-dispatch launch overhead: the fitted intercept of
        the grouped-projection kind (the compute kind with enough work
        variation to separate fixed from marginal cost). An SPMD launch
        pays this ONCE per launch, not per device — with ``mesh`` > 1 the
        per-mesh cell's intercept is preferred (it was measured around a
        sharded launch) and the single-device intercept is the fallback
        (launch cost does not scale with the mesh)."""
        if mesh is not None and int(mesh) > 1:
            got = self.overhead(mesh_kind("project", mesh))
            if got is not None:
                return got
        return self.overhead("project")

    # ---------------------------------------------------------- persistence
    def to_json(self) -> dict:
        return {
            "alpha": self.alpha, "drift": self.drift, "epoch": self.epoch,
            "kinds": {k: {str(b): {"work": c.work, "seconds": c.seconds,
                                   "n": c.n}
                          for b, c in cells.items()}
                      for k, cells in self.kinds.items()},
        }

    @classmethod
    def from_json(cls, data: dict) -> "MeasuredProfile":
        p = cls(alpha=data.get("alpha", 0.4), drift=data.get("drift", 0.05))
        for kind, cells in data.get("kinds", {}).items():
            for b, c in cells.items():
                p.kinds.setdefault(kind, {})[int(b)] = _Bucket(
                    work=float(c["work"]), seconds=float(c["seconds"]),
                    n=int(c["n"]))
            fit = p._fit(kind)
            if fit is not None:
                p._snap[kind] = fit
        p.epoch = int(data.get("epoch", 0))
        return p

    def save(self, path: str) -> None:
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_json(), f, indent=1)
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str) -> "MeasuredProfile":
        with open(path) as f:
            return cls.from_json(json.load(f))
