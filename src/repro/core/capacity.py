"""Capacity-driven session lifecycle policies (DESIGN.md §8).

HCache exists because GPU memory holds only a few contexts; this module
is the *policy layer* that turns the restoration mechanism into a
capacity-managed serving system:

  * ``AdmissionPolicy``   — which queued session gets the next free batch
                            slot (FIFO, restore-cost-aware/SJF, priority);
  * ``EvictionPolicy``    — which resident session is paused mid-stream
                            when the queue is backed up (LRU by admission
                            recency, restore-cost-weighted);
  * ``CapacityManager``   — host-storage byte budget enforcement: when
                            ``ChunkStore.bytes_used`` exceeds the budget,
                            idle sessions degrade down a ladder —
                            hot->cold tier demotion, fp16->int8 hidden
                            re-encode, hidden->token-only (restore by
                            recompute), and finally outright drop.

Policies are duck-typed over the engine's ``SequenceState`` (this module
never imports ``repro.serving``); restore-cost estimates come from the
same compiled task graph the executor runs (``core.restoration``), so a
policy's notion of "cheap to restore" and the engine's actual
restoration cost cannot drift apart.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cost_model import layer_costs, link_priced_times
from repro.core.restoration import (compile_tasks, cross_restore_times,
                                    replay, task_links)


# ----------------------------------------------------- restore-cost estimate
def restore_makespan(mgr, n_tokens: int,
                     methods: Optional[Sequence[str]] = None, *,
                     enc_len: int = 0) -> float:
    """Estimated restoration makespan (seconds under ``mgr.hw``) for a
    session of ``n_tokens`` — the two-stream replay of the same task
    graph the executor would run (including the enc-dec ``io_enc`` /
    ``project_cross`` pair when ``enc_len`` encoder positions are
    stored, and the auto group-size choice when the manager's
    ``restore_group_size`` is "auto")."""
    if n_tokens <= 0:
        return 0.0
    if methods is None:
        methods = mgr.plan(n_tokens).methods
    adapter = mgr.model.adapter
    cross = adapter.has_cross
    cross_times = cross_restore_times(mgr, enc_len) if cross else None
    # contention-aware pricing: the manager's measured profile (if any)
    # replaces datasheet rates; a one-host store stretches IO legs by
    # ``mgr.io_streams``, a distributed store prices each layer on the
    # links its stripes occupy (``mgr.link_load``) and replays the IO
    # stream per link — so admission/eviction cost a restore under the
    # bandwidth it would actually contend for, not exclusive access
    profile = getattr(mgr, "profile", None)
    streams = max(int(getattr(mgr, "io_streams", 1)), 1)
    topo_fn = getattr(mgr.store, "shard_topology", None)
    topology = topo_fn() if topo_fn is not None else None
    times, layer_links = link_priced_times(
        layer_costs(mgr.cfg, n_tokens, mgr.dtype_bytes), mgr.hw,
        profile=profile, io_streams=streams, topology=topology,
        link_load=getattr(mgr, "link_load", None))
    resolve = getattr(mgr, "resolve_group_size", None)
    if resolve is not None:
        group = resolve(n_tokens, methods, enc_len=enc_len)
    else:                        # duck-typed manager without the knob
        group = max(int(getattr(mgr, "restore_group_size", 1)), 1)
    if not isinstance(group, tuple):     # fetch-aligned plans are tuples
        group = max(int(group), 1)
    overhead = getattr(mgr.hw, "dispatch_overhead", 0.0)
    if profile is not None:
        measured = profile.dispatch_overhead()
        if measured is not None:
            overhead = measured
    tasks = compile_tasks(tuple(methods), n_blobs=adapter.n_state_blobs,
                          group_size=group, cross=cross)
    return replay(tasks, times, dispatch_overhead=overhead,
                  cross_times=cross_times,
                  links=task_links(tasks, layer_links)).makespan


def session_restore_cost(mgr, session_id: str) -> float:
    """Makespan estimate for a *stored* session, from its manifest
    (0.0 for a cold session with no stored state)."""
    man = mgr.store.get_manifest(session_id)
    if not man:
        return 0.0
    return restore_makespan(mgr, int(man.get("n_tokens", 0)),
                            man.get("methods"),
                            enc_len=int(man.get("enc_len", 0)))


# ------------------------------------------------------------- admission
class AdmissionPolicy:
    """Picks which queued sequence is admitted into a free batch slot."""

    name = "admission"

    def select(self, queue: Sequence, engine):
        raise NotImplementedError


class FIFOAdmission(AdmissionPolicy):
    name = "fifo"

    def select(self, queue, engine):
        return queue[0] if queue else None


class RestoreCostAwareAdmission(AdmissionPolicy):
    """Shortest-restore-first: admit the session whose time-to-resume is
    smallest (cold sessions estimate 0 — prompt prefill is paid either
    way). Minimizes mean TTFT; pure SJF starves long-history sessions,
    so an aging credit (seconds of makespan per engine step waited,
    measured from ``SequenceState.enqueue_step``) lowers a request's
    effective cost the longer it queues — any session eventually ages
    below the cheapest newcomer and must be admitted."""

    name = "restore_cost"

    def __init__(self, aging: float = 0.0):
        self.aging = aging

    def select(self, queue, engine):
        if not queue:
            return None
        now = getattr(engine, "step_count", 0)

        def key(s):
            waited = max(now - getattr(s, "enqueue_step", 0), 0)
            cost = session_restore_cost(engine.mgr, s.request.session_id)
            return (cost - self.aging * waited, s.request.request_id)

        return min(queue, key=key)


class PriorityAdmission(AdmissionPolicy):
    """Highest ``Request.priority`` first; FIFO within a priority tier."""

    name = "priority"

    def select(self, queue, engine):
        if not queue:
            return None
        return max(queue, key=lambda s: (s.request.priority,
                                         -s.request.request_id))


# -------------------------------------------------------------- eviction
class EvictionPolicy:
    """Picks the resident victim to pause when the queue is backed up."""

    name = "eviction"

    def select_victim(self, candidates: Sequence, engine):
        raise NotImplementedError


class LRUEviction(EvictionPolicy):
    """Evict the longest-resident session (earliest admission). With a
    FIFO queue this degenerates to round-robin time slicing."""

    name = "lru"

    def select_victim(self, candidates, engine):
        if not candidates:
            return None
        return min(candidates, key=lambda s: (s.admit_step,
                                              s.request.request_id))


class RestoreCostAwareEviction(EvictionPolicy):
    """Evict the session that will be cheapest to bring back: its future
    restoration covers ``total_len - 1`` tokens (the last sampled token
    is re-fed, not restored). Keeps the expensive long-history sessions
    resident, so the restore traffic the eviction churn generates is
    minimized — the knob ``bench_capacity`` compares against LRU."""

    name = "restore_cost"

    def select_victim(self, candidates, engine):
        if not candidates:
            return None

        def key(s):
            # price the cross side of enc-dec sessions exactly like the
            # admission path does (session_restore_cost): the stored
            # encoder length comes from the session's manifest
            man = engine.mgr.store.get_manifest(s.request.session_id) or {}
            return (restore_makespan(engine.mgr, max(s.total_len - 1, 0),
                                     enc_len=int(man.get("enc_len", 0))),
                    s.request.request_id)

        return min(candidates, key=key)


EVICTION_POLICIES = {"lru": LRUEviction,
                     "restore_cost": RestoreCostAwareEviction}
ADMISSION_POLICIES = {"fifo": FIFOAdmission,
                      "restore_cost": RestoreCostAwareAdmission,
                      "priority": PriorityAdmission}


# ------------------------------------------------------------ capacity
class CapacityManager:
    """Host-storage budget enforcement + per-session footprint tracking.

    Wired two ways (both optional, both safe together):

      * engine-driven — ``maintain(engine)`` once per engine step keeps
        recency fresh and runs the reclaim ladder;
      * store-driven  — when the hot tier is a ``StorageArray`` with a
        ``budget_bytes``, the manager registers a pressure callback so a
        write burst (e.g. the two-stage saver draining) triggers reclaim
        without waiting for the next engine step.

    Resident and prefetching sessions are protected: their streams are
    being appended to / read from and must not be re-encoded under a
    live executor. The ladder stages, mildest first:

      cold       move all chunks hot->cold tier (needs ``store.cold``)
      int8       re-encode 'h' fp16 -> int8 (+ per-token scales)
      recompute  drop 'h'/'kv' streams; token-only, restore by recompute
      drop       evict the session outright (last resort)
    """

    LADDER = ("cold", "int8", "recompute", "drop")

    def __init__(self, mgr, *, host_budget_bytes: Optional[int] = None,
                 ladder: Sequence[str] = LADDER):
        self.mgr = mgr
        self.store = mgr.store
        self.ladder = tuple(ladder)
        self.host_budget_bytes = host_budget_bytes
        self.actions: List[Tuple[str, str]] = []   # (stage, session) log
        self._last_active: Dict[str, int] = {}
        self._engine = None
        self._reclaiming = False
        array = self.store.devices
        if hasattr(array, "on_pressure"):
            if host_budget_bytes is not None:
                array.budget_bytes = host_budget_bytes
            elif array.budget_bytes is not None:
                self.host_budget_bytes = array.budget_bytes
            array.on_pressure(lambda _arr: self.ensure_host_budget())

    # ------------------------------------------------------------ tracking
    def attach_engine(self, engine) -> None:
        self._engine = engine

    def touch(self, session_id: str, step: int) -> None:
        self._last_active[session_id] = step

    def over_budget(self) -> bool:
        return (self.host_budget_bytes is not None
                and self.store.bytes_used > self.host_budget_bytes)

    def footprint(self, session_id: str) -> int:
        return self.store.bytes_for(session_id)

    def _protected(self) -> set:
        """Sessions the ladder must not touch: resident (streams being
        appended), prefetching (a live executor reads their chunks), and
        queued (in-flight requests — dropping a PAUSED session's stored
        state would silently lose its history)."""
        eng = self._engine
        if eng is None:
            return set()
        resident = {s.request.session_id for s in eng.slots if s is not None}
        queued = {s.request.session_id for s in eng.queue}
        return resident | queued | set(eng._prefetch)

    def _candidates(self, protected: set) -> List[str]:
        """Evictable stored sessions, coldest (least recently active)
        first; never-seen sessions sort coldest of all."""
        sids = [s for s in self.store.sessions() if s not in protected]
        return sorted(sids, key=lambda s: (self._last_active.get(s, -1), s))

    # ------------------------------------------------------------- reclaim
    def maintain(self, engine) -> None:
        """Per-engine-step upkeep: refresh recency for resident sessions
        and enforce the budget."""
        for s in engine.slots:
            if s is not None:
                self.touch(s.request.session_id, engine.step_count)
        self.ensure_host_budget()

    # ---------------------------------------------------------- promotion
    def consider_promotion(self, session_id: str) -> bool:
        """Anti-entropy, minimal on-save variant: when a session demoted
        to the int8 hidden codec is saved again while the byte budget has
        headroom, re-encode its 'h' stream at fp16 so the stream stops
        accumulating quantization loss and restores at full speed. The
        engine calls this after every save (``_after_save``); it is a
        no-op without a budget, for non-demoted sessions, or when the
        fp16 re-encode (~2x the int8 'h' bytes, written to the hot tier)
        would not fit."""
        if self.host_budget_bytes is None:
            return False
        eng = self._engine
        if eng is not None:
            # same rule as the demotion ladder's _protected(): never
            # re-encode streams a live prefetch executor may be reading —
            # a *queued* duplicate request for this (resident) session
            # can have chunk reads in flight against the int8 layout
            queued = {s.request.session_id for s in eng.queue}
            if session_id in queued or session_id in eng._prefetch:
                return False
        man = self.mgr.store.get_manifest(session_id)
        if not man or man.get("compress", "none") != "int8":
            return False
        headroom = self.host_budget_bytes - self.store.bytes_used
        # int8 'h' bytes == element count; the re-encode lands in the hot
        # tier at store_dtype width (fp16 per the paper, fp32 when the
        # functional model runs fp32 — NOT a hard-coded 2 bytes)
        itemsize = np.dtype(self.mgr.store_dtype).itemsize
        extra = itemsize * self.store.bytes_for(session_id, "h")
        if headroom < extra:
            return False
        if self.mgr.promote_hidden_fp16(session_id):
            self.actions.append(("promote", session_id))
            return True
        return False

    def sweep_promotions(self, limit: int = 1) -> int:
        """Anti-entropy promotion sweep (the background half the on-save
        hook cannot cover): walk idle int8-demoted sessions and re-encode
        up to ``limit`` of them back to the full-fidelity codec while the
        byte budget has headroom — a session that went idle right after
        its demotion no longer has to wait for its next save to stop
        accumulating quantization loss. Called from the engine's idle
        steps; warmest (most recently active) sessions first, since they
        are the likeliest to return. A no-op without a budget or without
        headroom (``consider_promotion`` re-checks the fp16 re-encode
        fits before touching any stream). Returns promotions taken."""
        if self.host_budget_bytes is None or self._reclaiming:
            return 0
        taken = 0
        prot = self._protected()
        sids = [s for s in self.store.sessions() if s not in prot]
        sids.sort(key=lambda s: (-self._last_active.get(s, -1), s))
        for sid in sids:
            if taken >= limit:
                break
            if self.consider_promotion(sid):
                taken += 1
        return taken

    def _apply(self, stage: str, sid: str) -> bool:
        if stage == "cold":
            return self.store.demote_session_to_cold(sid) > 0
        if stage == "int8":
            return self.mgr.demote_hidden_int8(sid)
        if stage == "recompute":
            return self.mgr.degrade_to_recompute(sid)
        if stage == "drop":
            self._last_active.pop(sid, None)
            self.mgr.evict(sid)
            return True
        raise ValueError(stage)

    def ensure_host_budget(self, protected: Sequence[str] = ()) -> int:
        """Walk the demotion ladder, coldest sessions first within each
        stage, until the hot tier fits the budget (or nothing evictable
        remains — resident sessions alone may exceed it). Returns the
        number of actions taken."""
        if self._reclaiming or not self.over_budget():
            return 0
        self._reclaiming = True
        taken = 0
        try:
            prot = set(protected) | self._protected()
            for stage in self.ladder:
                for sid in self._candidates(prot):
                    if not self.over_budget():
                        return taken
                    if self.store.bytes_for(sid, include_cold=False) == 0:
                        # dedup-aware (DESIGN.md §12): a fully-aliased
                        # session — an undiverged fork, or one whose
                        # chunks were shadowed out to sharers — pays for
                        # no hot bytes; degrading it would destroy its
                        # history while reclaiming nothing
                        continue
                    if self._apply(stage, sid):
                        self.actions.append((stage, sid))
                        taken += 1
                if not self.over_budget():
                    return taken
        finally:
            self._reclaiming = False
        return taken
