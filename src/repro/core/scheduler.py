"""Bubble-free restoration scheduler (paper §4.1).

Partitions the model's layers between restoration methods so the compute
stream and the IO stream finish (nearly) simultaneously:

    argmin_{L_H, L_O}  max(C_H·L_H,  IO_H·L_H + IO_KV·L_O)
    s.t. L_H + L_O = N_layers                       (paper min-max)

Two solvers:
  * ``closed_form`` — the paper's §4.1.2 formulas (two-method schemes).
  * ``solve``       — exhaustive search over (L_H, L_KV, L_RE) including the
    three-method mix and heterogeneous layer classes (attention vs mamba),
    which the paper does not need (its models are homogeneous MHA) but our
    assigned archs do. For N ≤ 128 layers this is exact and instant.

Layer placement follows the paper: recompute layers must be a *prefix*
(layer i's recompute consumes layer i-1's output), KV/H layers are ordered
to keep the IO stream busy from t=0.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

from repro.config.arch import ArchConfig
from repro.config.hardware import HardwareProfile
from repro.core.cost_model import (LayerCost, MethodTimes, layer_costs,
                                   link_priced_times, method_times)

METHODS = ("hidden", "kv", "recompute")


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Per-layer restoration methods + predicted timing."""

    methods: Tuple[str, ...]          # len == n_layers, in layer order
    compute_time: float               # seconds on the compute stream
    io_time: float                    # seconds on the IO stream
    makespan: float
    bubble: float                     # |compute - io| / makespan

    @property
    def counts(self):
        return {m: self.methods.count(m) for m in METHODS}

    def tasks(self):
        """The ordered restoration task graph this schedule compiles to —
        the same graph the executor runs and ``pipeline.simulate``
        replays (core/restoration.compile_tasks)."""
        from repro.core.restoration import compile_tasks
        return compile_tasks(self.methods)

    def summary(self) -> str:
        c = self.counts
        return (f"{c['hidden']} H + {c['kv']} KV + {c['recompute']} RE | "
                f"compute {self.compute_time * 1e3:.2f}ms io "
                f"{self.io_time * 1e3:.2f}ms bubble {self.bubble:.1%}")


def closed_form(n_layers: int, t: MethodTimes) -> Tuple[int, int]:
    """Paper §4.1.2: (L_H, L_O). Complementary method is KV offload when
    compute is the bottleneck (C_H > IO_H), token recompute otherwise."""
    if t.c_h > t.io_h:
        denom = t.io_kv + t.c_h - t.io_h
        l_h = math.ceil(n_layers * t.io_kv / denom) if denom > 0 else n_layers
    else:
        denom = t.c_token + t.io_h - t.c_h
        l_h = math.ceil(n_layers * t.c_token / denom) if denom > 0 else n_layers
    l_h = max(0, min(n_layers, l_h))
    return l_h, n_layers - l_h


def _evaluate(counts_per_class, class_times, class_ids) -> Tuple[float, float]:
    """(compute_time, io_time) for per-class (n_h, n_kv, n_re) choices."""
    compute = io = 0.0
    for cid, (n_h, n_kv, n_re) in counts_per_class.items():
        t = class_times[cid]
        compute += n_h * t.c_h + n_re * t.c_token
        io += n_h * t.io_h + n_kv * t.io_kv
    return compute, io


def solve(cfg: ArchConfig, n_tokens: int, hw: HardwareProfile, *,
          dtype_bytes: int = 2, allow_recompute: bool = True,
          allow_kv: bool = True, force_hidden: bool = False,
          profile=None, io_streams: int = 1,
          topology=None, link_load=None) -> Schedule:
    """Exact min-max schedule over (possibly heterogeneous) layers.

    ``profile`` (a ``MeasuredProfile``) substitutes observed rates for the
    static hardware numbers; contention pricing shifts layers from IO
    methods toward recompute. One-host store: ``io_streams`` stretches
    every IO leg (N restores share one host link). Distributed store
    (``topology``/``link_load``): each layer's IO is priced on the links
    it touches only — the aggregate (balanced-stripe) form, since this
    solver's IO objective is a serial sum (see ``link_priced_times``)."""
    costs = layer_costs(cfg, n_tokens, dtype_bytes)
    times_per_layer, _ = link_priced_times(
        costs, hw, profile=profile, io_streams=io_streams,
        topology=topology, link_load=link_load, aggregate=True)
    # group layers into classes — identical (cost, priced time); per-link
    # pricing can split equal-cost layers into distinct classes when their
    # links carry different loads
    class_of: List[int] = []
    class_costs: List[LayerCost] = []
    class_times: List[MethodTimes] = []
    for c, t in zip(costs, times_per_layer):
        for i, (cc, ct) in enumerate(zip(class_costs, class_times)):
            if cc == c and ct == t:
                class_of.append(i)
                break
        else:
            class_costs.append(c)
            class_times.append(t)
            class_of.append(len(class_costs) - 1)
    n_per_class = [class_of.count(i) for i in range(len(class_costs))]

    # the exhaustive search is prod over classes of O(n_c^2) options;
    # unequal per-link loads can split every cost class N_links-ways.
    # When that blows past an exact-search budget, coarsen back to
    # cost-only classes with layer-count-weighted mean times — the split
    # decision degrades gracefully to average-link pricing while
    # restore_makespan keeps the exact per-link replay.
    search = 1.0
    for n in n_per_class:
        search *= (n + 1) * (n + 2) / 2
    if search > 2e5:
        class_of, class_costs = [], []
        acc: List[List[float]] = []
        for c, t in zip(costs, times_per_layer):
            for i, cc in enumerate(class_costs):
                if cc == c:
                    class_of.append(i)
                    a = acc[i]
                    a[0] += t.io_h
                    a[1] += t.io_kv
                    a[2] += 1
                    break
            else:
                class_costs.append(c)
                acc.append([t.io_h, t.io_kv, 1])
                class_of.append(len(class_costs) - 1)
        class_times = []
        for c, (io_h, io_kv, n) in zip(class_costs, acc):
            base = method_times(c, hw, profile=profile, io_streams=1)
            class_times.append(dataclasses.replace(
                base, io_h=io_h / n, io_kv=io_kv / n))
        n_per_class = [class_of.count(i) for i in range(len(class_costs))]

    # SSM classes have no KV-offload analog with io==0; their "kv" method is
    # the state offload, costed via io_state inside method_times.
    best = None

    def rec(cid, chosen):
        nonlocal best
        if cid == len(class_costs):
            compute, io = _evaluate(
                {i: c for i, c in enumerate(chosen)}, class_times,
                class_of)
            makespan = max(compute, io)
            if best is None or makespan < best[0]:
                best = (makespan, list(chosen), compute, io)
            return
        n = n_per_class[cid]
        if force_hidden:
            options = [(n, 0, 0)]
        else:
            options = []
            for n_re in range(0, n + 1 if allow_recompute else 1):
                for n_kv in range(0, n - n_re + 1 if allow_kv else 1):
                    options.append((n - n_re - n_kv, n_kv, n_re))
        for opt in options:
            chosen.append(opt)
            rec(cid + 1, chosen)
            chosen.pop()

    rec(0, [])
    makespan, per_class, compute, io = best

    # materialize per-layer methods: recompute layers must be a prefix.
    remaining = {i: list(c) for i, c in enumerate(per_class)}
    methods: List[Optional[str]] = [None] * len(costs)
    for li, cid in enumerate(class_of):          # recompute prefix first
        if remaining[cid][2] > 0:
            methods[li] = "recompute"
            remaining[cid][2] -= 1
    for li, cid in enumerate(class_of):
        if methods[li] is None:
            if remaining[cid][0] > 0:
                methods[li] = "hidden"
                remaining[cid][0] -= 1
            else:
                methods[li] = "kv"
                remaining[cid][1] -= 1
    bubble = abs(compute - io) / makespan if makespan > 0 else 0.0
    return Schedule(tuple(methods), compute, io, makespan, bubble)


def schedule_all_methods(cfg: ArchConfig, n_tokens: int,
                         hw: HardwareProfile, dtype_bytes: int = 2):
    """Schedules for the paper's baselines + HCache (benchmark helper)."""
    n = cfg.n_layers
    return {
        "hcache": solve(cfg, n_tokens, hw, dtype_bytes=dtype_bytes),
        "hcache_only": solve(cfg, n_tokens, hw, dtype_bytes=dtype_bytes,
                             force_hidden=True),
        "kv_offload": Schedule(tuple(["kv"] * n), 0.0, 0.0, 0.0, 0.0),
        "recompute": Schedule(tuple(["recompute"] * n), 0.0, 0.0, 0.0, 0.0),
    }
