"""HCacheManager — the paper's system glued together.

Responsibilities (paper Fig 7):
  * decide the per-layer restoration schedule (bubble-free scheduler);
  * SAVE: prefill/decode hidden states into the chunk store
    (layer-before-token order, two-stage saving off the critical path),
    offloaded-KV layers and SSM state blobs, plus the token stream and a
    manifest (crash recovery);
  * RESTORE: rebuild the exact KV cache / SSM states for a session from
    host storage — recompute-prefix from tokens, projections from hidden
    states, raw reads for KV layers — delegated to the pipelined
    RestorationExecutor (core/restoration.py): the serving engine steps it
    incrementally into batch-slot buffers, while ``restore`` here runs it
    to completion into a B=1 cache for offline/test use. The reported
    timeline derives from the executed task order under a hardware
    profile (this container has no real accelerator/SSD).

Optional beyond-paper extension: int8 per-token quantization of stored
hidden states (`compress="int8"`), halving IO/storage again at a measured
(small) restoration error — the paper cites quantization as composable
future work (§7).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.config.arch import BlockKind
from repro.config.hardware import HardwareProfile, TPU_V5E
from repro.core.pipeline import Timeline
from repro.core.restoration import (CacheAssembler, RestorationExecutor,
                                    build_param_pack, quantize_hidden_int8)
from repro.core.scheduler import Schedule, solve
from repro.models.model import Model
from repro.storage.chunk_store import ChunkStore
from repro.storage.two_stage import SnapshotTask, TwoStageSaver


@dataclasses.dataclass
class RestoreResult:
    cache: dict                      # family-specific cache pieces (B=1)
    schedule: Schedule
    timeline: Timeline               # simulated restoration timing
    wall_time: float                 # actual CPU seconds (functional path)
    n_tokens: int


class HCacheManager:
    def __init__(self, model: Model, store: ChunkStore, *,
                 hw: HardwareProfile = TPU_V5E, saver: Optional[TwoStageSaver]
                 = None, compress: str = "none", dtype_bytes: int = 2,
                 schedule_override: Optional[str] = None,
                 store_dtype=np.float16, restore_group_size=8,
                 profile=None):
        self.model = model
        self.cfg = model.cfg
        self.store = store
        # plan caches must exist before the hw property setter (which
        # invalidates them) runs
        self._plans: Dict[tuple, Schedule] = {}
        self._group_plans: Dict[tuple, object] = {}
        self._hw = hw
        # online calibration (DESIGN.md §13): a MeasuredProfile the
        # executors fold observed task times into and every planning
        # call (plan / resolve_group_size / capacity.restore_makespan)
        # prices with. None (the default) keeps the static
        # HardwareProfile model exactly — planning stays deterministic.
        self.profile = profile
        # IO-stream multiplicity: how many sessions are restoring
        # concurrently (the engine updates this every step); admission
        # and scheduling price shared host-link/storage bandwidth with
        # it instead of assuming exclusive access
        self.io_streams = 1
        # distributed-store contention: per-NIC-link restore-stream
        # counts (cost_model.LinkLoad) reported by the engine; None on
        # one-host stores, where ``io_streams`` is the whole story
        self.link_load = None
        # projection group plan for the batched restoration data path
        # (DESIGN.md §10): one stacked device call per group instead of
        # one per layer; 1 recovers the per-layer graph exactly; "auto"
        # lets each restore pick the makespan-argmin over uniform widths
        # {1, 2, 4, 8, L} AND the fetch-aligned non-uniform partition;
        # "fetch" forces the fetch-aligned partition
        # (restoration.choose_group_size / fetch_aligned_partition); an
        # explicit tuple of widths pins a non-uniform plan directly
        if restore_group_size in ("auto", "fetch"):
            self.restore_group_size = restore_group_size
        elif isinstance(restore_group_size, tuple):
            self.restore_group_size = tuple(
                max(int(w), 1) for w in restore_group_size)
        else:
            self.restore_group_size = max(int(restore_group_size), 1)
        # once-per-(model, params) restoration weight pack, built lazily
        # on the first restore and shared by every executor; `_tp` is the
        # TPContext the pack's weight stacks are sharded under (None =
        # single device)
        self._pack = None
        self._pack_params = None
        self._tp = None
        # dtype of stored hidden states. fp16 is the paper's setting (its
        # models run fp16, so storage is lossless); when the functional
        # model runs fp32, passing float32 makes pause/restore cycles
        # bit-exact at 2x the 'h' footprint.
        self.store_dtype = store_dtype
        self.saver = saver or TwoStageSaver(store)
        self.compress = compress
        self.dtype_bytes = dtype_bytes
        self.schedule_override = schedule_override   # None|hidden|kv|recompute
        # per-session compression overrides (capacity demotion ladder);
        # synced from the manifest on resume so a fresh manager over a
        # demoted store keeps appending in the session's stored codec
        self._session_compress: Dict[str, str] = {}

    def _compress_for(self, session: str) -> str:
        return self._session_compress.get(session, self.compress)

    # ----------------------------------------------- plan-cache invalidation
    @property
    def hw(self) -> HardwareProfile:
        return self._hw

    @hw.setter
    def hw(self, value: HardwareProfile) -> None:
        # regression guard (ISSUE 7 satellite): schedules and group plans
        # are memoized against the hardware numbers — swapping the
        # profile without flushing them left restores running stale
        # widths/splits forever
        if value is not self._hw:
            self._hw = value
            self.invalidate_plans()

    def invalidate_plans(self) -> None:
        """Flush every memoized schedule and group plan. Called on any
        hardware-profile swap; measured-profile drift and IO-multiplicity
        changes need no flush because both are part of the cache keys
        (``_price_key``)."""
        self._plans.clear()
        self._group_plans.clear()

    def set_profile(self, profile) -> None:
        """Attach (or detach) a MeasuredProfile; memoized plans priced
        under the old profile are flushed."""
        if profile is not self.profile:
            self.profile = profile
            self.invalidate_plans()

    def set_io_streams(self, n: int) -> None:
        """Engine-reported restore multiplicity. No cache flush: plans
        are memoized per multiplicity (``_price_key``), so flipping
        between 1-way and 4-way reuses both sets of plans."""
        self.io_streams = max(int(n), 1)

    def set_link_load(self, load) -> None:
        """Engine-reported per-link restore multiplicity (distributed
        store). Memoized like ``io_streams``: the load's identity is part
        of ``_price_key``, so recurring fleet states reuse their plans."""
        self.link_load = load

    def shard_topology(self):
        """The store's placement policy, None for one-host stores (or
        stores without the distributed API)."""
        topo_fn = getattr(self.store, "shard_topology", None)
        return topo_fn() if topo_fn is not None else None

    def _price_key(self) -> tuple:
        """The planning-relevant calibration state: plans computed under
        a different profile epoch, IO multiplicity, per-link load or
        tensor-parallel mesh width must not be reused — resharding the
        engine (hw.with_mesh) changes the projection-compute price and
        invalidates every memoized schedule and group plan."""
        epoch = self.profile.epoch if self.profile is not None else -1
        load = self.link_load.key() if self.link_load is not None else None
        return (epoch, self.io_streams, load,
                getattr(self.hw, "mesh_devices", 1))

    def set_tp(self, tp_ctx) -> None:
        """Attach the engine's tensor-parallel context: the restoration
        weight pack is rebuilt sharded over its mesh (KV output axis) and
        the hardware profile is re-priced for the mesh width, which in
        turn flushes memoized plans (``hw`` setter + ``_price_key``)."""
        if tp_ctx is not self._tp:
            self._tp = tp_ctx
            self._pack = None
            self._pack_params = None
        self.hw = self._hw.with_mesh(tp_ctx.tp if tp_ctx is not None
                                     and tp_ctx.spmd else 1)

    def param_pack(self, params):
        """Device-stacked restoration weights (wk/wv/bk/bv/ln1 + RoPE
        tables) for ``params`` — built once, then reference-cached so no
        restoration task ever re-gathers params. Holding the params
        reference keeps the identity check sound (the cached object
        cannot be collected and aliased). Under an attached TPContext the
        stacks are committed sharded on the KV output axis, so the
        grouped projection runs SPMD with each device projecting only its
        heads (DESIGN.md §16)."""
        if self._pack is None or self._pack_params is not params:
            self._pack = build_param_pack(self.model, params,
                                          tp_ctx=self._tp)
            self._pack_params = params
        return self._pack

    # ------------------------------------------------------------- planning
    def resolve_group_size(self, n_tokens: int, methods, *,
                           enc_len: int = 0):
        """Concrete projection group plan for one restore: the fixed
        width, or — under ``restore_group_size="auto"``/``"fetch"`` —
        the bucket-stable makespan argmin over uniform widths plus the
        fetch-aligned non-uniform partition (``"fetch"`` forces the
        partition). Returns an int width or a tuple of widths. Memoized
        per (S-bucket, methods, enc-bucket, price state) like ``plan``'s
        ``_plans`` cache: a profile-epoch bump or multiplicity change
        re-plans, a converged profile memoizes again. The single
        resolution point for the executor and
        ``capacity.restore_makespan``."""
        if self.restore_group_size not in ("auto", "fetch"):
            return self.restore_group_size
        from repro.core.restoration import (choose_group_size,
                                            fetch_aligned_partition,
                                            s_bucket)
        adapter = self.model.adapter
        cross = adapter.has_cross and enc_len > 0
        key = (s_bucket(max(int(n_tokens), 1)), tuple(methods),
               s_bucket(enc_len) if cross else 0, self._price_key())
        got = self._group_plans.get(key)
        if got is None:
            if self.restore_group_size == "fetch":
                got = self._fetch_partition(n_tokens, methods)
            else:
                got = choose_group_size(self.cfg, self.hw, n_tokens,
                                        methods,
                                        dtype_bytes=self.dtype_bytes,
                                        n_blobs=adapter.n_state_blobs,
                                        cross=adapter.has_cross,
                                        enc_len=enc_len,
                                        profile=self.profile,
                                        io_streams=self.io_streams,
                                        topology=self.shard_topology(),
                                        link_load=self.link_load,
                                        fetch_aligned=True)
            self._group_plans[key] = got
        return got

    def _fetch_partition(self, n_tokens: int, methods):
        """The forced fetch-aligned partition (``restore_group_size=
        "fetch"``), priced at the S-bucket under the current profile and
        multiplicity; a degenerate all-equal partition collapses to its
        uniform int width."""
        from repro.core.cost_model import layer_costs, link_priced_times
        from repro.core.restoration import (fetch_aligned_partition,
                                            s_bucket)
        bucket = s_bucket(max(int(n_tokens), 1))
        times, layer_links = link_priced_times(
            layer_costs(self.cfg, bucket, self.dtype_bytes), self.hw,
            profile=self.profile, io_streams=self.io_streams,
            topology=self.shard_topology(), link_load=self.link_load)
        overhead = getattr(self.hw, "dispatch_overhead", 0.0)
        if self.profile is not None:
            measured = self.profile.dispatch_overhead(
                mesh=getattr(self.hw, "mesh_devices", 1))
            if measured is not None:
                overhead = measured
        part = fetch_aligned_partition(methods, times,
                                       dispatch_overhead=overhead,
                                       links=layer_links)
        if not part:
            return 1
        return part[0] if len(set(part)) == 1 else part

    def plan(self, n_tokens: int) -> Schedule:
        """Bucketed bubble-free schedule (power-of-two token buckets),
        priced under the measured profile and current IO multiplicity
        when calibration is on (part of the memoization key)."""
        if self.schedule_override:
            m = self.schedule_override
            methods = tuple(
                m if bk == BlockKind.ATTENTION else "hidden"
                for bk in self.cfg.block_kinds())
            return Schedule(methods, 0.0, 0.0, 0.0, 0.0)
        bucket = 1 << max(int(np.ceil(np.log2(max(n_tokens, 128)))), 7)
        key = (bucket, self._price_key())
        if key not in self._plans:
            # recompute-prefix is only defined where the adapter says so
            # (hybrid: an attention block's recompute would depend on
            # interleaved mamba layers; encdec: on the cross context)
            allow_re = self.model.adapter.supports_recompute
            self._plans[key] = solve(self.cfg, bucket, self.hw,
                                     dtype_bytes=self.dtype_bytes,
                                     allow_recompute=allow_re,
                                     profile=self.profile,
                                     io_streams=self.io_streams,
                                     topology=self.shard_topology(),
                                     link_load=self.link_load)
        return self._plans[key]

    # ----------------------------------------------------------------- save
    def save_prefill(self, session: str, tokens: np.ndarray, prefill_out:
                     dict, *, start: int = 0) -> None:
        """Persist one sequence's prefill state (B must be 1 in `out`).
        The mapping between prefill outputs and persisted pieces
        (hidden/KV row naming) is the FamilyAdapter's."""
        adapter = self.model.adapter
        prev = self.store.get_manifest(session) if start > 0 else None
        if prev and prev.get("methods"):
            # a resumed session must keep appending under its stored
            # per-layer methods and codec: re-planning could flip a layer
            # hidden<->kv across a bucket boundary (or fight a capacity
            # demotion) and leave the stream with a hole at [0, start)
            methods = list(prev["methods"])
            comp = prev.get("compress", self.compress)
            if comp != self.compress:
                self._session_compress[session] = comp
        else:
            methods = list(self.plan(start + tokens.shape[-1]).methods)
        toks = np.asarray(tokens).reshape(-1)
        self.store.put_blob(session, "tok", 0, toks if start == 0 else
                            np.concatenate([self._tokens(session), toks]))
        kinds = self.cfg.block_kinds()
        for li, method in enumerate(methods):
            if kinds[li] != BlockKind.ATTENTION:
                continue  # SSM layers handled via state blobs below
            if method == "hidden":
                self._append_hidden(session, li, start,
                                    adapter.prefill_hidden(prefill_out, li))
            elif method == "kv":
                k, v = adapter.prefill_kv(prefill_out, li)
                self.store.append_tokens(session, "kvk", li, start,
                                         k.reshape(k.shape[0], -1))
                self.store.append_tokens(session, "kvv", li, start,
                                         v.reshape(v.shape[0], -1))
        self._save_ssm_states(session, prefill_out)
        manifest = {
            "n_tokens": int(start + tokens.shape[-1]),
            "methods": methods,
            "arch": self.cfg.name, "compress": self._compress_for(session),
        }
        if adapter.has_cross:
            if "enc_out" in prefill_out:
                self.store.put_blob(session, "enc", 0,
                                    np.asarray(prefill_out["enc_out"][0]))
                manifest["enc_len"] = int(prefill_out["enc_out"].shape[1])
            elif prev:
                # resume prefill (no encoder pass): keep the stored
                # encoder length so restore cost modeling stays honest
                manifest["enc_len"] = int(prev.get("enc_len", 0))
        self.store.flush(session)
        self.store.put_manifest(session, manifest)

    def save_session_pause(self, session: str, cache: dict,
                           n_tokens: int, *, tokens_tail: np.ndarray) -> None:
        """On eviction after decoding: dump kv-layer tails + SSM states from
        the live cache (they are accelerator-resident; decode only streamed
        the hidden states). Keeps the store restorable at ``n_tokens``."""
        manifest = self.store.get_manifest(session) or {
            "methods": list(self.plan(n_tokens).methods),
            "compress": self.compress, "arch": self.cfg.name}
        prev_n = int(manifest.get("n_tokens", 0))
        methods = manifest["methods"]
        if tokens_tail is not None and len(tokens_tail):
            old = (self._tokens(session)
                   if self.store.get_manifest(session) else
                   np.zeros((0,), np.int32))
            self.store.put_blob(session, "tok", 0, np.concatenate(
                [old[:prev_n], np.asarray(tokens_tail).reshape(-1)]))
        kinds = self.cfg.block_kinds()
        adapter = self.model.adapter
        k_name, v_name = adapter.kv_names or ("k", "v")
        for li, method in enumerate(methods):
            if kinds[li] != BlockKind.ATTENTION or method != "kv":
                continue
            idx = adapter.kv_row(li)
            k = np.asarray(cache[k_name][idx][0][prev_n:n_tokens])
            v = np.asarray(cache[v_name][idx][0][prev_n:n_tokens])
            self.store.append_tokens(session, "kvk", li, prev_n,
                                     k.reshape(k.shape[0], -1))
            self.store.append_tokens(session, "kvv", li, prev_n,
                                     v.reshape(v.shape[0], -1))
        if "ssm" in cache:
            self.store.put_blob(session, "state_conv", 0,
                                np.asarray(cache["conv"]))
            self.store.put_blob(session, "state_ssm", 0,
                                np.asarray(cache["ssm"]))
        self.store.flush(session)
        manifest["n_tokens"] = int(n_tokens)
        self.store.put_manifest(session, manifest)

    def _append_hidden(self, session: str, layer: int, start: int,
                       h: np.ndarray) -> None:
        if self._compress_for(session) == "int8":
            q, scale = quantize_hidden_int8(h)
            self.store.append_tokens(session, "h", layer, start, q)
            self.store.append_tokens(session, "hs", layer, start, scale)
        else:
            self.store.append_tokens(session, "h", layer, start,
                                     h.astype(self.store_dtype))

    def _save_ssm_states(self, session: str, out: dict) -> None:
        states = out.get("states") or out.get("mamba_states")
        if states is None:
            return
        conv, ssm = states
        self.store.put_blob(session, "state_conv", 0, np.asarray(conv))
        self.store.put_blob(session, "state_ssm", 0, np.asarray(ssm))

    def save_decode_hidden(self, session_ids: Sequence[Optional[str]],
                           hidden, lengths: np.ndarray) -> float:
        """Two-stage save of one decode step's hidden states.

        hidden: (L, B, 1, D); lengths: (B,) position of the new token.
        Returns the stage-1 (snapshot) virtual cost in seconds.

        The whole step is ONE layer-stacked (L, B', 1, D) snapshot for
        the plain-codec rows (the device buffer is already layer-major —
        stage 1 is a single contiguous copy, not L ring submissions);
        the stage-2 daemon splits per (layer, sequence). The snapshot
        byte count — and so ``snapshot_cost`` accounting — is unchanged
        from the per-layer form."""
        h = np.asarray(hidden)
        L = h.shape[0]
        all_layers = list(range(L))
        cost = 0.0
        starts = [int(x) for x in lengths]
        ids = list(session_ids)
        # sessions demoted to the int8 codec must keep their 'h' stream
        # dtype-consistent: quantize their rows before the snapshot and
        # route the scales to 'hs' (per-token scales, so row-at-a-time
        # quantization matches the bulk codec exactly)
        int8_rows = [b for b, s in enumerate(ids)
                     if s is not None and self._compress_for(s) == "int8"]
        plain_rows = [b for b in range(len(ids)) if b not in int8_rows]
        plain_ids = [ids[b] for b in plain_rows]
        if plain_rows:
            # slice the demoted rows out of the bulk snapshot so the
            # stage-1 copy cost covers only bytes actually written
            data = h[:, plain_rows].astype(self.store_dtype)
            cost += self.saver.snapshot(SnapshotTask(
                session_ids=plain_ids, stream="h", layer=-1,
                start_tokens=[starts[b] for b in plain_rows], data=data,
                layers=all_layers))
        for b in int8_rows:
            # per-token scales make the row-major stacked quantization
            # identical to the per-layer form (each (li, b) row is
            # normalized independently along D)
            q, scale = quantize_hidden_int8(
                h[:, b:b + 1].astype(np.float32))
            cost += self.saver.snapshot(SnapshotTask(
                [ids[b]], "h", -1, [starts[b]], q, layers=all_layers))
            cost += self.saver.snapshot(SnapshotTask(
                [ids[b]], "hs", -1, [starts[b]], scale, layers=all_layers))
        return cost

    # -------------------------------------------------------------- restore
    def _tokens(self, session: str) -> np.ndarray:
        return np.asarray(self.store.get_blob(session, "tok", 0))

    def begin_restore(self, params, session: str, sink=None,
                      start_token: int = 0) -> RestorationExecutor:
        """Start an incremental restoration (serving path). The returned
        executor is stepped by the engine a bounded number of tasks per
        engine iteration; finished layers stream into ``sink``.

        ``start_token > 0`` is restore-skip (DESIGN.md §12): tokens
        [0, start_token) are already resident in the target slot via a
        shared prefix, so the task graph starts at the divergence token —
        makespan shrinks with the shared-prefix ratio."""
        return RestorationExecutor(self, params, session, sink=sink,
                                   start_token=start_token)

    def fork_session(self, src: str, dst: str, *, share: bool = True)\
            -> dict:
        """Clone ``src``'s persisted state under ``dst`` (conversation
        trees). ``share=True`` aliases chunks/blobs content-addressed in
        the store (dedup: the bytes exist once until either side
        diverges); ``share=False`` materializes real copies — identical
        semantics, used as the no-sharing reference. Returns the cloned
        manifest."""
        man = self.store.get_manifest(src)
        if man is None:
            raise KeyError(f"cannot fork {src!r}: no stored state")
        if self.store.get_manifest(dst) is not None:
            raise ValueError(f"fork target {dst!r} already has state")
        self.store.share_session(src, dst, copy=not share)
        self.store.put_manifest(dst, dict(man))
        if src in self._session_compress:
            self._session_compress[dst] = self._session_compress[src]
        return dict(man)

    def restore(self, params, session: str) -> RestoreResult:
        """Rebuild the session's accelerator state from host storage.

        Standalone (offline/test) API: runs the pipelined executor to
        completion into a B=1 ``CacheAssembler``. The serving engine
        instead steps the executor incrementally with a batch-slot sink
        (see serving/engine.py)."""
        t0 = time.perf_counter()
        sink = CacheAssembler(self.model)
        ex = self.begin_restore(params, session, sink=sink)
        ex.run()
        wall = time.perf_counter() - t0
        return RestoreResult(sink.cache, ex.schedule, ex.timeline(), wall,
                             ex.n_tokens)

    # --------------------------------------------------- capacity demotion
    def demote_hidden_int8(self, session: str) -> bool:
        """Re-encode a session's stored hidden states to the int8 codec
        (halves the 'h' footprint). Future appends for the session follow
        the codec (per-session override + manifest), and restoration
        dequantizes transparently. Returns False when not applicable."""
        man = self.store.get_manifest(session)
        if not man or man.get("compress", "none") == "int8":
            return False
        n = int(man.get("n_tokens", 0))
        kinds = self.cfg.block_kinds()
        layers = [li for li, m in enumerate(man["methods"])
                  if m == "hidden" and kinds[li] == BlockKind.ATTENTION
                  and self.store.layer_available(session, "h", li, n)]
        if n == 0 or not layers:
            return False
        # remember which tier the stream came from: re-appending always
        # lands hot, so a cold-demoted session's re-encode must be moved
        # back afterwards or the int8 stage *increases* budgeted bytes
        was_cold = self.store.stream_in_cold(session, "h")
        data = {li: np.asarray(self.store.read_layer(session, "h", li, n))
                for li in layers}
        self.store.drop_stream(session, "h")
        self.store.drop_stream(session, "hs")
        for li, h in data.items():
            q, scale = quantize_hidden_int8(h.astype(np.float32))
            self.store.append_tokens(session, "h", li, 0, q)
            self.store.append_tokens(session, "hs", li, 0, scale)
        self.store.flush(session)
        if was_cold:
            self.store.demote_stream_to_cold(session, "h")
            self.store.demote_stream_to_cold(session, "hs")
        man["compress"] = "int8"
        self.store.put_manifest(session, man)
        if was_cold:
            # put_manifest re-hots the manifest (hot copy authoritative);
            # a fully cold-demoted session's metadata follows its chunks
            # so the int8 stage leaves the budgeted tier untouched
            self.store.demote_stream_to_cold(session, "meta")
        self._session_compress[session] = "int8"
        return True

    def promote_hidden_fp16(self, session: str) -> bool:
        """Inverse of ``demote_hidden_int8`` (capacity anti-entropy):
        re-encode the session's int8 'h' stream at the manager's
        store_dtype and drop the scales, so future appends and restores
        run the full-fidelity codec again. The already-quantized prefix
        keeps its int8-level error (the fp16 values are dequantized int8)
        — promotion stops *further* loss, it cannot undo past loss.
        Returns False when not applicable."""
        man = self.store.get_manifest(session)
        if not man or man.get("compress", "none") != "int8":
            return False
        n = int(man.get("n_tokens", 0))
        kinds = self.cfg.block_kinds()
        layers = [li for li, m in enumerate(man["methods"])
                  if m == "hidden" and kinds[li] == BlockKind.ATTENTION
                  and self.store.layer_available(session, "h", li, n)
                  and self.store.layer_available(session, "hs", li, n)]
        if n == 0 or not layers:
            return False
        from repro.core.restoration import dequantize_hidden_int8
        data = {}
        for li in layers:
            q = np.asarray(self.store.read_layer(session, "h", li, n))
            s = np.asarray(self.store.read_layer(session, "hs", li, n))
            data[li] = dequantize_hidden_int8(q, s).astype(self.store_dtype)
        self.store.drop_stream(session, "h")
        self.store.drop_stream(session, "hs")
        for li, h in data.items():
            self.store.append_tokens(session, "h", li, 0, h)
        self.store.flush(session)
        man["compress"] = "none"
        self.store.put_manifest(session, man)
        self._session_compress[session] = "none"
        return True

    def degrade_to_recompute(self, session: str) -> bool:
        """Drop a session's hidden/KV streams entirely, keeping only the
        token blob + manifest: the session stays restorable by full
        recompute (LM stacks only — hybrid recompute is undefined).
        The cheapest possible storage state before dropping outright."""
        if not self.model.adapter.supports_recompute:
            return False
        man = self.store.get_manifest(session)
        if not man or all(m == "recompute" for m in man["methods"]):
            return False
        if not self.store.has_blob(session, "tok", 0):
            return False
        if self._tokens(session).shape[0] < int(man.get("n_tokens", 0)):
            return False
        for stream in ("h", "hs", "kvk", "kvv"):
            self.store.drop_stream(session, stream)
        man["methods"] = ["recompute"] * len(man["methods"])
        man["compress"] = "none"
        self._session_compress.pop(session, None)
        self.store.put_manifest(session, man)
        return True

    # -------------------------------------------------------------- eviction
    def evict(self, session: str) -> None:
        self._session_compress.pop(session, None)
        self.store.drop_session(session)

    def sessions(self) -> List[str]:
        return self.store.sessions()
