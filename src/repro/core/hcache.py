"""HCacheManager — the paper's system glued together.

Responsibilities (paper Fig 7):
  * decide the per-layer restoration schedule (bubble-free scheduler);
  * SAVE: prefill/decode hidden states into the chunk store
    (layer-before-token order, two-stage saving off the critical path),
    offloaded-KV layers and SSM state blobs, plus the token stream and a
    manifest (crash recovery);
  * RESTORE: rebuild the exact KV cache / SSM states for a session from
    host storage — recompute-prefix from tokens, projections from hidden
    states, raw reads for KV layers — delegated to the pipelined
    RestorationExecutor (core/restoration.py): the serving engine steps it
    incrementally into batch-slot buffers, while ``restore`` here runs it
    to completion into a B=1 cache for offline/test use. The reported
    timeline derives from the executed task order under a hardware
    profile (this container has no real accelerator/SSD).

Optional beyond-paper extension: int8 per-token quantization of stored
hidden states (`compress="int8"`), halving IO/storage again at a measured
(small) restoration error — the paper cites quantization as composable
future work (§7).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.config.arch import BlockKind
from repro.config.hardware import HardwareProfile, TPU_V5E
from repro.core.pipeline import Timeline
from repro.core.restoration import (CacheAssembler, RestorationExecutor,
                                    quantize_hidden_int8)
from repro.core.scheduler import Schedule, solve
from repro.models.model import Model
from repro.storage.chunk_store import ChunkStore
from repro.storage.two_stage import SnapshotTask, TwoStageSaver


@dataclasses.dataclass
class RestoreResult:
    cache: dict                      # family-specific cache pieces (B=1)
    schedule: Schedule
    timeline: Timeline               # simulated restoration timing
    wall_time: float                 # actual CPU seconds (functional path)
    n_tokens: int


class HCacheManager:
    def __init__(self, model: Model, store: ChunkStore, *,
                 hw: HardwareProfile = TPU_V5E, saver: Optional[TwoStageSaver]
                 = None, compress: str = "none", dtype_bytes: int = 2,
                 schedule_override: Optional[str] = None):
        self.model = model
        self.cfg = model.cfg
        self.store = store
        self.hw = hw
        self.saver = saver or TwoStageSaver(store)
        self.compress = compress
        self.dtype_bytes = dtype_bytes
        self.schedule_override = schedule_override   # None|hidden|kv|recompute
        self._plans: Dict[int, Schedule] = {}

    # ------------------------------------------------------------- planning
    def plan(self, n_tokens: int) -> Schedule:
        """Bucketed bubble-free schedule (power-of-two token buckets)."""
        if self.schedule_override:
            m = self.schedule_override
            methods = tuple(
                m if bk == BlockKind.ATTENTION else "hidden"
                for bk in self.cfg.block_kinds())
            return Schedule(methods, 0.0, 0.0, 0.0, 0.0)
        bucket = 1 << max(int(np.ceil(np.log2(max(n_tokens, 128)))), 7)
        if bucket not in self._plans:
            # recompute-prefix is undefined for hybrid stacks (an attention
            # block's recompute would depend on interleaved mamba layers)
            allow_re = self.model.kind == "lm"
            self._plans[bucket] = solve(self.cfg, bucket, self.hw,
                                        dtype_bytes=self.dtype_bytes,
                                        allow_recompute=allow_re)
        return self._plans[bucket]

    # ----------------------------------------------------------------- save
    def _hidden_for_layer(self, out: dict, li: int):
        """Layer li's saved hidden states (S, D) from a prefill output."""
        kind = self.model.kind
        if kind == "hybrid":
            k = self.model.h.k
            return np.asarray(out["attn_hidden"][li // k][0])
        return np.asarray(out["hidden"][li][0])

    def _kv_for_layer(self, out: dict, li: int):
        kind = self.model.kind
        idx = li // self.model.h.k if kind == "hybrid" else li
        if kind == "lm":
            idx = [i for i, bk in enumerate(self.cfg.block_kinds())
                   if bk == BlockKind.ATTENTION].index(li)
        return (np.asarray(out["kv"][0][idx][0]),
                np.asarray(out["kv"][1][idx][0]))

    def save_prefill(self, session: str, tokens: np.ndarray, prefill_out:
                     dict, *, start: int = 0) -> None:
        """Persist one sequence's prefill state (B must be 1 in `out`)."""
        sched = self.plan(start + tokens.shape[-1])
        toks = np.asarray(tokens).reshape(-1)
        self.store.put_blob(session, "tok", 0, toks if start == 0 else
                            np.concatenate([self._tokens(session), toks]))
        kinds = self.cfg.block_kinds()
        for li, method in enumerate(sched.methods):
            if kinds[li] != BlockKind.ATTENTION:
                continue  # SSM layers handled via state blobs below
            if method == "hidden":
                self._append_hidden(session, li, start,
                                    self._hidden_for_layer(prefill_out, li))
            elif method == "kv":
                k, v = self._kv_for_layer(prefill_out, li)
                self.store.append_tokens(session, "kvk", li, start,
                                         k.reshape(k.shape[0], -1))
                self.store.append_tokens(session, "kvv", li, start,
                                         v.reshape(v.shape[0], -1))
        self._save_ssm_states(session, prefill_out)
        if self.cfg.is_encoder_decoder and "enc_out" in prefill_out:
            self.store.put_blob(session, "enc", 0,
                                np.asarray(prefill_out["enc_out"][0]))
        self.store.flush(session)
        self.store.put_manifest(session, {
            "n_tokens": int(start + tokens.shape[-1]),
            "methods": list(sched.methods),
            "arch": self.cfg.name, "compress": self.compress,
        })

    def save_session_pause(self, session: str, cache: dict,
                           n_tokens: int, *, tokens_tail: np.ndarray) -> None:
        """On eviction after decoding: dump kv-layer tails + SSM states from
        the live cache (they are accelerator-resident; decode only streamed
        the hidden states). Keeps the store restorable at ``n_tokens``."""
        manifest = self.store.get_manifest(session) or {
            "methods": list(self.plan(n_tokens).methods),
            "compress": self.compress, "arch": self.cfg.name}
        prev_n = int(manifest.get("n_tokens", 0))
        methods = manifest["methods"]
        if tokens_tail is not None and len(tokens_tail):
            old = (self._tokens(session)
                   if self.store.get_manifest(session) else
                   np.zeros((0,), np.int32))
            self.store.put_blob(session, "tok", 0, np.concatenate(
                [old[:prev_n], np.asarray(tokens_tail).reshape(-1)]))
        kinds = self.cfg.block_kinds()
        k_name = "attn_k" if self.model.kind == "hybrid" else \
            "self_k" if self.model.kind == "encdec" else "k"
        v_name = k_name.replace("k", "v") if k_name != "k" else "v"
        for li, method in enumerate(methods):
            if kinds[li] != BlockKind.ATTENTION or method != "kv":
                continue
            idx = li // self.model.h.k if self.model.kind == "hybrid" else li
            if self.model.kind == "lm":
                idx = [i for i, bk in enumerate(kinds)
                       if bk == BlockKind.ATTENTION].index(li)
            k = np.asarray(cache[k_name][idx][0][prev_n:n_tokens])
            v = np.asarray(cache[v_name][idx][0][prev_n:n_tokens])
            self.store.append_tokens(session, "kvk", li, prev_n,
                                     k.reshape(k.shape[0], -1))
            self.store.append_tokens(session, "kvv", li, prev_n,
                                     v.reshape(v.shape[0], -1))
        if "ssm" in cache:
            self.store.put_blob(session, "state_conv", 0,
                                np.asarray(cache["conv"]))
            self.store.put_blob(session, "state_ssm", 0,
                                np.asarray(cache["ssm"]))
        self.store.flush(session)
        manifest["n_tokens"] = int(n_tokens)
        self.store.put_manifest(session, manifest)

    def _append_hidden(self, session: str, layer: int, start: int,
                       h: np.ndarray) -> None:
        if self.compress == "int8":
            q, scale = quantize_hidden_int8(h)
            self.store.append_tokens(session, "h", layer, start, q)
            self.store.append_tokens(session, "hs", layer, start, scale)
        else:
            self.store.append_tokens(session, "h", layer, start,
                                     h.astype(np.float16))

    def _save_ssm_states(self, session: str, out: dict) -> None:
        states = out.get("states") or out.get("mamba_states")
        if states is None:
            return
        conv, ssm = states
        self.store.put_blob(session, "state_conv", 0, np.asarray(conv))
        self.store.put_blob(session, "state_ssm", 0, np.asarray(ssm))

    def save_decode_hidden(self, session_ids: Sequence[Optional[str]],
                           hidden, lengths: np.ndarray) -> float:
        """Two-stage save of one decode step's hidden states.

        hidden: (L, B, 1, D); lengths: (B,) position of the new token.
        Returns the stage-1 (snapshot) virtual cost in seconds."""
        h = np.asarray(hidden)
        L = h.shape[0]
        cost = 0.0
        for li in range(L):
            cost += self.saver.snapshot(SnapshotTask(
                session_ids=session_ids, stream="h", layer=li,
                start_tokens=[int(x) for x in lengths],
                data=h[li].astype(np.float16)))
        return cost

    # -------------------------------------------------------------- restore
    def _tokens(self, session: str) -> np.ndarray:
        return np.asarray(self.store.get_blob(session, "tok", 0))

    def begin_restore(self, params, session: str, sink=None
                      ) -> RestorationExecutor:
        """Start an incremental restoration (serving path). The returned
        executor is stepped by the engine a bounded number of tasks per
        engine iteration; finished layers stream into ``sink``."""
        return RestorationExecutor(self, params, session, sink=sink)

    def restore(self, params, session: str) -> RestoreResult:
        """Rebuild the session's accelerator state from host storage.

        Standalone (offline/test) API: runs the pipelined executor to
        completion into a B=1 ``CacheAssembler``. The serving engine
        instead steps the executor incrementally with a batch-slot sink
        (see serving/engine.py)."""
        t0 = time.perf_counter()
        sink = CacheAssembler(self.model)
        ex = self.begin_restore(params, session, sink=sink)
        ex.run()
        wall = time.perf_counter() - t0
        return RestoreResult(sink.cache, ex.schedule, ex.timeline(), wall,
                             ex.n_tokens)

    # -------------------------------------------------------------- eviction
    def evict(self, session: str) -> None:
        self.store.drop_session(session)

    def sessions(self) -> List[str]:
        return self.store.sessions()
