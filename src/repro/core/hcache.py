"""HCacheManager — the paper's system glued together.

Responsibilities (paper Fig 7):
  * decide the per-layer restoration schedule (bubble-free scheduler);
  * SAVE: prefill/decode hidden states into the chunk store
    (layer-before-token order, two-stage saving off the critical path),
    offloaded-KV layers and SSM state blobs, plus the token stream and a
    manifest (crash recovery);
  * RESTORE: rebuild the exact KV cache / SSM states for a session from
    host storage — recompute-prefix from tokens, projections from hidden
    states, raw reads for KV layers — with the pipelined timeline simulated
    against a hardware profile (this container has no real accelerator/SSD).

Optional beyond-paper extension: int8 per-token quantization of stored
hidden states (`compress="int8"`), halving IO/storage again at a measured
(small) restoration error — the paper cites quantization as composable
future work (§7).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.arch import BlockKind
from repro.config.hardware import HardwareProfile, TPU_V5E
from repro.core.cost_model import layer_costs, method_times
from repro.core.pipeline import Timeline, simulate
from repro.core.scheduler import Schedule, solve
from repro.models.layers.norm import apply_norm
from repro.models.layers import attention as attn_lib
from repro.models.model import Model
from repro.storage.chunk_store import ChunkStore
from repro.storage.two_stage import SnapshotTask, TwoStageSaver


@dataclasses.dataclass
class RestoreResult:
    cache: dict                      # family-specific cache pieces (B=1)
    schedule: Schedule
    timeline: Timeline               # simulated restoration timing
    wall_time: float                 # actual CPU seconds (functional path)
    n_tokens: int


def _quantize_int8(x: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    scale = np.abs(x).max(axis=-1, keepdims=True).astype(np.float32) / 127.0
    scale = np.maximum(scale, 1e-8)
    q = np.clip(np.round(x / scale), -127, 127).astype(np.int8)
    return q, scale


def _dequantize_int8(q: np.ndarray, scale: np.ndarray) -> np.ndarray:
    return q.astype(np.float32) * scale


class HCacheManager:
    def __init__(self, model: Model, store: ChunkStore, *,
                 hw: HardwareProfile = TPU_V5E, saver: Optional[TwoStageSaver]
                 = None, compress: str = "none", dtype_bytes: int = 2,
                 schedule_override: Optional[str] = None):
        self.model = model
        self.cfg = model.cfg
        self.store = store
        self.hw = hw
        self.saver = saver or TwoStageSaver(store)
        self.compress = compress
        self.dtype_bytes = dtype_bytes
        self.schedule_override = schedule_override   # None|hidden|kv|recompute
        self._plans: Dict[int, Schedule] = {}

    # ------------------------------------------------------------- planning
    def plan(self, n_tokens: int) -> Schedule:
        """Bucketed bubble-free schedule (power-of-two token buckets)."""
        if self.schedule_override:
            m = self.schedule_override
            methods = tuple(
                m if bk == BlockKind.ATTENTION else "hidden"
                for bk in self.cfg.block_kinds())
            return Schedule(methods, 0.0, 0.0, 0.0, 0.0)
        bucket = 1 << max(int(np.ceil(np.log2(max(n_tokens, 128)))), 7)
        if bucket not in self._plans:
            # recompute-prefix is undefined for hybrid stacks (an attention
            # block's recompute would depend on interleaved mamba layers)
            allow_re = self.model.kind == "lm"
            self._plans[bucket] = solve(self.cfg, bucket, self.hw,
                                        dtype_bytes=self.dtype_bytes,
                                        allow_recompute=allow_re)
        return self._plans[bucket]

    # ----------------------------------------------------------------- save
    def _hidden_for_layer(self, out: dict, li: int):
        """Layer li's saved hidden states (S, D) from a prefill output."""
        kind = self.model.kind
        if kind == "hybrid":
            k = self.model.h.k
            return np.asarray(out["attn_hidden"][li // k][0])
        return np.asarray(out["hidden"][li][0])

    def _kv_for_layer(self, out: dict, li: int):
        kind = self.model.kind
        idx = li // self.model.h.k if kind == "hybrid" else li
        if kind == "lm":
            idx = [i for i, bk in enumerate(self.cfg.block_kinds())
                   if bk == BlockKind.ATTENTION].index(li)
        return (np.asarray(out["kv"][0][idx][0]),
                np.asarray(out["kv"][1][idx][0]))

    def save_prefill(self, session: str, tokens: np.ndarray, prefill_out:
                     dict, *, start: int = 0) -> None:
        """Persist one sequence's prefill state (B must be 1 in `out`)."""
        sched = self.plan(start + tokens.shape[-1])
        toks = np.asarray(tokens).reshape(-1)
        self.store.put_blob(session, "tok", 0, toks if start == 0 else
                            np.concatenate([self._tokens(session), toks]))
        kinds = self.cfg.block_kinds()
        for li, method in enumerate(sched.methods):
            if kinds[li] != BlockKind.ATTENTION:
                continue  # SSM layers handled via state blobs below
            if method == "hidden":
                self._append_hidden(session, li, start,
                                    self._hidden_for_layer(prefill_out, li))
            elif method == "kv":
                k, v = self._kv_for_layer(prefill_out, li)
                self.store.append_tokens(session, "kvk", li, start,
                                         k.reshape(k.shape[0], -1))
                self.store.append_tokens(session, "kvv", li, start,
                                         v.reshape(v.shape[0], -1))
        self._save_ssm_states(session, prefill_out)
        if self.cfg.is_encoder_decoder and "enc_out" in prefill_out:
            self.store.put_blob(session, "enc", 0,
                                np.asarray(prefill_out["enc_out"][0]))
        self.store.flush(session)
        self.store.put_manifest(session, {
            "n_tokens": int(start + tokens.shape[-1]),
            "methods": list(sched.methods),
            "arch": self.cfg.name, "compress": self.compress,
        })

    def save_session_pause(self, session: str, cache: dict,
                           n_tokens: int, *, tokens_tail: np.ndarray) -> None:
        """On eviction after decoding: dump kv-layer tails + SSM states from
        the live cache (they are accelerator-resident; decode only streamed
        the hidden states). Keeps the store restorable at ``n_tokens``."""
        manifest = self.store.get_manifest(session) or {
            "methods": list(self.plan(n_tokens).methods),
            "compress": self.compress, "arch": self.cfg.name}
        prev_n = int(manifest.get("n_tokens", 0))
        methods = manifest["methods"]
        if tokens_tail is not None and len(tokens_tail):
            old = (self._tokens(session)
                   if self.store.get_manifest(session) else
                   np.zeros((0,), np.int32))
            self.store.put_blob(session, "tok", 0, np.concatenate(
                [old[:prev_n], np.asarray(tokens_tail).reshape(-1)]))
        kinds = self.cfg.block_kinds()
        k_name = "attn_k" if self.model.kind == "hybrid" else \
            "self_k" if self.model.kind == "encdec" else "k"
        v_name = k_name.replace("k", "v") if k_name != "k" else "v"
        for li, method in enumerate(methods):
            if kinds[li] != BlockKind.ATTENTION or method != "kv":
                continue
            idx = li // self.model.h.k if self.model.kind == "hybrid" else li
            if self.model.kind == "lm":
                idx = [i for i, bk in enumerate(kinds)
                       if bk == BlockKind.ATTENTION].index(li)
            k = np.asarray(cache[k_name][idx][0][prev_n:n_tokens])
            v = np.asarray(cache[v_name][idx][0][prev_n:n_tokens])
            self.store.append_tokens(session, "kvk", li, prev_n,
                                     k.reshape(k.shape[0], -1))
            self.store.append_tokens(session, "kvv", li, prev_n,
                                     v.reshape(v.shape[0], -1))
        if "ssm" in cache:
            self.store.put_blob(session, "state_conv", 0,
                                np.asarray(cache["conv"]))
            self.store.put_blob(session, "state_ssm", 0,
                                np.asarray(cache["ssm"]))
        self.store.flush(session)
        manifest["n_tokens"] = int(n_tokens)
        self.store.put_manifest(session, manifest)

    def _append_hidden(self, session: str, layer: int, start: int,
                       h: np.ndarray) -> None:
        if self.compress == "int8":
            q, scale = _quantize_int8(h)
            self.store.append_tokens(session, "h", layer, start, q)
            self.store.append_tokens(session, "hs", layer, start, scale)
        else:
            self.store.append_tokens(session, "h", layer, start,
                                     h.astype(np.float16))

    def _save_ssm_states(self, session: str, out: dict) -> None:
        states = out.get("states") or out.get("mamba_states")
        if states is None:
            return
        conv, ssm = states
        self.store.put_blob(session, "state_conv", 0, np.asarray(conv))
        self.store.put_blob(session, "state_ssm", 0, np.asarray(ssm))

    def save_decode_hidden(self, session_ids: Sequence[Optional[str]],
                           hidden, lengths: np.ndarray) -> float:
        """Two-stage save of one decode step's hidden states.

        hidden: (L, B, 1, D); lengths: (B,) position of the new token.
        Returns the stage-1 (snapshot) virtual cost in seconds."""
        h = np.asarray(hidden)
        L = h.shape[0]
        cost = 0.0
        for li in range(L):
            cost += self.saver.snapshot(SnapshotTask(
                session_ids=session_ids, stream="h", layer=li,
                start_tokens=[int(x) for x in lengths],
                data=h[li].astype(np.float16)))
        return cost

    # -------------------------------------------------------------- restore
    def _tokens(self, session: str) -> np.ndarray:
        return np.asarray(self.store.get_blob(session, "tok", 0))

    def restore(self, params, session: str) -> RestoreResult:
        """Rebuild the session's accelerator state from host storage."""
        t0 = time.perf_counter()
        manifest = self.store.get_manifest(session)
        if manifest is None:
            raise KeyError(f"no stored state for session {session!r}")
        n = manifest["n_tokens"]
        sched = Schedule(tuple(manifest["methods"]), 0, 0, 0, 0)
        self.store.sync_clocks(0.0)
        cache = self._restore_family(params, session, n, sched.methods)
        wall = time.perf_counter() - t0
        times = [method_times(c, self.hw)
                 for c in layer_costs(self.cfg, n, self.dtype_bytes)]
        timeline = simulate(sched.methods, times)
        return RestoreResult(cache, sched, timeline, wall, n)

    # ---- family-specific assembly -----------------------------------------
    def _restore_family(self, params, session, n, methods):
        kind = self.model.kind
        if kind in ("lm", "hybrid"):
            return self._restore_attn_like(params, session, n, methods)
        if kind == "ssm":
            conv = jnp.asarray(self.store.get_blob(session, "state_conv", 0))
            ssm = jnp.asarray(self.store.get_blob(session, "state_ssm", 0))
            return {"conv": conv, "ssm": ssm,
                    "lengths": jnp.asarray([n], jnp.int32)}
        # encdec: cross KV from the saved encoder output + self KV from H
        enc_out = jnp.asarray(self.store.get_blob(session, "enc", 0))[None]
        from repro.models import encdec as encdec_mod
        ck, cv = encdec_mod.cross_kv(params, enc_out, self.model.h)
        self_kv = self._restore_attn_like(params, session, n, methods)
        return {"self_k": self_kv["k"], "self_v": self_kv["v"],
                "cross_k": ck, "cross_v": cv,
                "enc_len": jnp.asarray(enc_out.shape[1], jnp.int32),
                "lengths": jnp.asarray([n], jnp.int32)}

    def _read_hidden(self, session: str, layer: int, n: int) -> np.ndarray:
        if self.compress == "int8":
            q = self.store.read_layer(session, "h", layer, n)
            s = self.store.read_layer(session, "hs", layer, n)
            return _dequantize_int8(q, s)
        return self.store.read_layer(session, "h", layer, n)

    def _restore_attn_like(self, params, session: str, n: int,
                           methods: Sequence[str]) -> dict:
        cfg = self.cfg
        kinds = cfg.block_kinds()
        attn_layers = [i for i, k in enumerate(kinds)
                       if k == BlockKind.ATTENTION]
        pos = jnp.arange(n)[None, :]
        hd = cfg.head_dim_

        h_idx = [i for i in attn_layers if methods[i] == "hidden"]
        kv_idx = [i for i in attn_layers if methods[i] == "kv"]
        re_idx = [i for i in attn_layers if methods[i] == "recompute"]

        k_parts: Dict[int, jnp.ndarray] = {}
        v_parts: Dict[int, jnp.ndarray] = {}

        # 1. recompute prefix from tokens (must be layers 0..len(re)-1)
        if re_idx:
            toks = jnp.asarray(self._tokens(session))[None, :n]
            k_re, v_re = self._recompute_prefix(params, toks, len(re_idx))
            for j, li in enumerate(sorted(re_idx)):
                k_parts[li], v_parts[li] = k_re[j], v_re[j]

        # 2. hidden-state layers: fetch + project (pipelined on hardware;
        #    functionally a vmap over the H-layer subset here)
        if h_idx:
            hs = np.stack([self._read_hidden(session, li, n) for li in h_idx])
            hidden = jnp.asarray(hs, self.model.dtype)[:, None]  # (Lh,1,n,D)
            sub = self._subset_blocks(params, h_idx)
            k_h, v_h = self._project_subset(sub, hidden, pos)
            for j, li in enumerate(h_idx):
                k_parts[li], v_parts[li] = k_h[j], v_h[j]

        # 3. raw KV reads
        for li in kv_idx:
            k = self.store.read_layer(session, "kvk", li, n)
            v = self.store.read_layer(session, "kvv", li, n)
            k_parts[li] = jnp.asarray(k).reshape(1, n, cfg.n_kv_heads, hd)
            v_parts[li] = jnp.asarray(v).reshape(1, n, cfg.n_kv_heads, hd)

        k_stack = jnp.stack([k_parts[i] for i in attn_layers])
        v_stack = jnp.stack([v_parts[i] for i in attn_layers])
        out = {"k": k_stack.astype(self.model.dtype),
               "v": v_stack.astype(self.model.dtype),
               "lengths": jnp.asarray([n], jnp.int32)}
        if self.model.kind == "hybrid":
            conv = jnp.asarray(self.store.get_blob(session, "state_conv", 0))
            ssm = jnp.asarray(self.store.get_blob(session, "state_ssm", 0))
            out = {"attn_k": out["k"], "attn_v": out["v"], "conv": conv,
                   "ssm": ssm, "lengths": out["lengths"]}
        return out

    def _subset_blocks(self, params, idx: List[int]):
        arr = np.asarray(idx)
        blocks = (params["blocks"] if self.model.kind == "lm" else
                  params["attn"] if self.model.kind == "hybrid" else
                  params["dec_blocks"])
        if self.model.kind == "hybrid":
            # attn params are stacked per super-block; map layer->super idx
            k = self.model.h.k
            arr = np.asarray([i // k for i in idx])
        return jax.tree.map(lambda x: x[arr], blocks)

    def _project_subset(self, blocks, hidden, pos):
        cfg, mh = self.cfg, self.model.h
        attn_h = mh.attn if hasattr(mh, "attn") else mh.lm.attn
        attn_key = ("attn" if self.model.kind in ("lm", "hybrid")
                    else "self_attn")
        ln_key = "ln1"

        def one(bp, hl):
            normed = apply_norm(bp[ln_key], hl, cfg.norm, cfg.norm_eps)
            ap = bp[attn_key] if attn_key in bp else bp
            return attn_lib.restore_kv(ap["wk"], ap["wv"], ap.get("bk"),
                                       ap.get("bv"), normed, attn_h,
                                       jnp.broadcast_to(pos, hl.shape[:2]))

        return jax.vmap(one)(blocks, hidden)

    def _recompute_prefix(self, params, tokens, n_layers: int):
        """Run the embedding + first ``n_layers`` blocks, emitting KV."""
        from repro.models import transformer as tfm
        mh = self.model.h
        sliced = dict(params)
        sliced["blocks"] = jax.tree.map(lambda x: x[:n_layers],
                                        params["blocks"])
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x = tfm._embed_input(sliced, mh, tokens, positions)
        windows = tfm.layer_windows(mh)
        windows = windows[:n_layers] if windows is not None else None

        def body(x, xs):
            bp, win = xs
            x, _, kv, _ = tfm.block_forward(bp, x, mh, positions=positions,
                                            window=win, emit_kv=True)
            return x, kv

        _, (k, v) = jax.lax.scan(body, x, (sliced["blocks"], windows))
        return k, v

    # -------------------------------------------------------------- eviction
    def evict(self, session: str) -> None:
        self.store.drop_session(session)

    def sessions(self) -> List[str]:
        return self.store.sessions()
