"""Restoration pipeline: event-driven timeline of the two streams.

The paper overlaps per-layer hidden-state transmission with the previous
layer's KV projection (Fig 5). On TPU the same structure holds (host→HBM
DMA vs MXU GEMMs); since this container is CPU-only the *timing* comes from
replaying the restoration executor's task graph over a hardware profile,
while the *functional* restoration (actual tensors) runs through the same
graph in ``core/restoration.py`` — one source of truth for both.

Stream rules (paper §4.1):
  * recompute layers form a prefix and run on the compute stream from t=0;
  * hidden-state fetches go first on the IO stream (so projections can
    start), KV fetches fill the IO tail;
  * a layer's projection starts when its fetch has completed and the
    compute stream is free.

``simulate`` returns per-stream busy/idle so benchmarks can report bubble
fractions (Fig 12) and the TTFT decomposition (Figs 9/10).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.config.arch import ArchConfig
from repro.config.hardware import GEMM_EFFICIENCY, HardwareProfile
from repro.core.cost_model import MethodTimes, layer_costs, method_times


@dataclasses.dataclass(frozen=True)
class Timeline:
    makespan: float
    io_busy: float
    compute_busy: float
    io_finish: float
    compute_finish: float

    @property
    def io_bubble(self) -> float:
        return 1.0 - self.io_busy / self.makespan if self.makespan else 0.0

    @property
    def compute_bubble(self) -> float:
        return (1.0 - self.compute_busy / self.makespan
                if self.makespan else 0.0)


def simulate(methods: Sequence[str], times: Sequence[MethodTimes], *,
             group_size=1,
             dispatch_overhead: float = 0.0,
             cross: bool = False, cross_times=None) -> Timeline:
    """Simulate a restoration schedule. methods[i] in {hidden, kv, recompute}.

    Thin wrapper over the restoration executor's task graph: the same
    ``compile_tasks`` + ``replay`` that drive the serving engine's
    incremental execution produce this timeline, so the simulated and the
    executed orders cannot drift apart (see core/restoration.py).
    ``group_size`` — a uniform width or a tuple of widths (fetch-aligned
    partition) — coalesces projections into grouped compute tasks and
    ``dispatch_overhead`` charges the per-dispatch launch cost once per
    compute task — the batched data path's makespan knob (DESIGN.md §10).
    ``cross``/``cross_times`` add the enc-dec encoder-blob read and
    cross-KV projection tasks (DESIGN.md §11)."""
    from repro.core.restoration import compile_tasks, replay
    return replay(compile_tasks(methods, group_size=group_size, cross=cross),
                  times, dispatch_overhead=dispatch_overhead,
                  cross_times=cross_times)


def restore_timeline(cfg: ArchConfig, n_tokens: int, hw: HardwareProfile,
                     methods: Sequence[str],
                     dtype_bytes: int = 2, *,
                     group_size=1, profile=None,
                     io_streams: int = 1) -> Timeline:
    times = [method_times(c, hw, profile=profile, io_streams=io_streams)
             for c in layer_costs(cfg, n_tokens, dtype_bytes)]
    overhead = getattr(hw, "dispatch_overhead", 0.0)
    if profile is not None:
        measured = profile.dispatch_overhead()
        if measured is not None:
            overhead = measured
    return simulate(methods, times, group_size=group_size,
                    dispatch_overhead=overhead)


# --------------------------------------------------------- serving estimates
def prefill_time(cfg: ArchConfig, n_new: int, n_hist: int,
                 hw: HardwareProfile,
                 gemm_eff: float = GEMM_EFFICIENCY) -> float:
    """Prefill of ``n_new`` prompt tokens attending over restored history."""
    D, n_q, kv = cfg.d_model, cfg.n_heads * cfg.head_dim_, cfg.kv_dim
    flops = 0.0
    from repro.config.arch import BlockKind
    for kind in cfg.block_kinds():
        if kind == BlockKind.ATTENTION:
            proj = n_new * 2 * (D * n_q + 2 * D * kv + n_q * D)
            ctx = n_hist + n_new
            if cfg.local_window:
                ctx = min(ctx, cfg.local_window)
            quad = 2 * n_new * ctx * n_q * 2
            ffn_mults = 3 if cfg.ffn_glu else 2
            k = cfg.experts_per_token if cfg.n_experts else 1
            ffn = n_new * 2 * ffn_mults * D * cfg.d_ff * k
            flops += proj + quad + ffn
        else:
            inner = cfg.ssm_expand * D
            flops += n_new * (2 * D * 4 * inner + inner * cfg.ssm_state * 6)
    flops += n_new * 2 * D * cfg.vocab_size  # lm head (last token only, ~0)
    return flops / (hw.flops * gemm_eff)


def decode_step_time(cfg: ArchConfig, batch: int, ctx: int,
                     hw: HardwareProfile) -> float:
    """One decode step: max(compute, HBM-bound weight+KV reads)."""
    n_active = cfg.active_param_count()
    flops_t = 2 * n_active * batch / hw.flops
    kv_bytes = cfg.n_layers * 2 * cfg.kv_dim * ctx * 2 * batch
    mem_t = (n_active * 2 + kv_bytes) / hw.hbm_bw
    return max(flops_t, mem_t)


def ttft(cfg: ArchConfig, n_hist: int, n_new: int, hw: HardwareProfile,
         methods: Sequence[str], dtype_bytes: int = 2) -> float:
    """Restoration + prefill = time-to-first-token (paper's headline metric)."""
    restore = restore_timeline(cfg, n_hist, hw, methods, dtype_bytes).makespan
    return restore + prefill_time(cfg, n_new, n_hist, hw)
