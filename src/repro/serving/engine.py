"""Inference engine: continuous batching with an HCache restoration phase
and a capacity-driven session lifecycle.

Request lifecycle (paper §5, DESIGN.md §6/§8):

    WAITING -> [RESTORING]   if the session has evicted state in the store,
                             an incremental RestorationExecutor runs a
                             bounded number of pipeline tasks per engine
                             step, writing each finished layer straight
                             into the sequence's batch-slot buffers. Any
                             number of sessions restore concurrently, and
                             restoring sessions never block the decode
                             batch of active ones. Queued sessions with
                             stored state get their first hidden-layer IO
                             prefetched before a slot even frees;
            -> PREFILL       chunked prompt prefill (SplitFuse-style: at most
                             ``prefill_chunk`` prompt tokens per engine step,
                             so decode iterations stay interleaved);
            -> DECODE        joins the continuous decode batch; every step
                             streams the new token's hidden states to the
                             two-stage saver;
            -> PAUSED        mid-stream eviction under slot pressure: after
                             ``preempt_quantum`` steps of residency a
                             victim (EvictionPolicy) is dumped via
                             ``save_session_pause``, its slot handed to a
                             queued session (AdmissionPolicy), and it
                             re-enters through RESTORING with the last
                             sampled token as a 1-token resume prefill —
                             N sessions >> max_batch slots time-share the
                             batch with no generation-visible difference;
            -> DONE          on EOS/max-tokens: KV-layer tails + SSM states
                             are dumped (``save_session_pause``) and the slot
                             is freed — the session remains restorable.

Cache state lives behind a ``KVCacheBackend`` (serving/kv_cache.py,
DESIGN.md §9/§11): the classic ``contiguous`` layout (max_seq positions
per slot), the block-table ``paged`` layout — where admission reserves
only the pages a session can actually use, so a full page pool, not a
full slot table, is what back-pressures the queue — or the paired
self/cross ``encdec`` layout for whisper-family models. The engine
touches cache state exclusively through per-slot ``CacheView`` handles
(restore writes, history gathers, pause/retire snapshots, frees), and
every family-specific decision goes through the ``FamilyAdapter`` seam
(models/adapter.py) — this module contains no per-family branching.

Admission is pluggable (FIFO / restore-cost-aware / priority — see
core/capacity.py), as is victim selection (LRU / restore-cost-weighted).
An optional CapacityManager enforces a host-storage byte budget by
degrading idle sessions (cold tier, int8, token-only, drop).

Crash recovery: a fresh engine over the same ChunkStore can resume any
session (`recoverable_sessions`) — serving-side fault tolerance is HCache
itself.

Metrics per request: wall TTFT, simulated restoration time (hardware
profile, restored sessions only), TBT; engine-level counters plus
occupancy/fragmentation gauges for the benchmark harness.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import deque
from typing import Dict, List, Optional, Union

import jax.numpy as jnp
import numpy as np

from repro.core.capacity import (CapacityManager, EvictionPolicy,
                                 AdmissionPolicy, FIFOAdmission, LRUEviction)
from repro.core.hcache import HCacheManager
from repro.distributed import tp as tp_lib
from repro.models.model import Model
from repro.serving.kv_cache import (KVCacheBackend, PagedBackend, ViewSink,
                                    make_backend)
from repro.serving.prefix_index import HostPin, PrefixIndex
from repro.serving.request import Phase, Request, SequenceState
from repro.serving.sampling import sample


@dataclasses.dataclass
class EngineMetrics:
    ttft_wall: List[float] = dataclasses.field(default_factory=list)
    # two TTFT populations: sessions that went through restoration vs
    # cold starts. ``ttft_sim`` holds simulated restoration makespans for
    # restored sessions ONLY (a cold start has no restoration to
    # simulate; recording 0.0 for it would pollute the mean).
    ttft_sim: List[float] = dataclasses.field(default_factory=list)
    ttft_wall_restored: List[float] = dataclasses.field(default_factory=list)
    ttft_wall_cold: List[float] = dataclasses.field(default_factory=list)
    tbt_wall: List[float] = dataclasses.field(default_factory=list)
    # every completed restoration's simulated makespan — includes resumes
    # of mid-stream-evicted sessions, not only first tokens; the resume
    # subset is the victim-selection bake-off metric in bench_capacity
    restore_sim_all: List[float] = dataclasses.field(default_factory=list)
    restore_sim_resume: List[float] = dataclasses.field(default_factory=list)
    preemptions: int = 0                # mid-stream evictions (PAUSED)
    restored_tokens: int = 0
    restore_steps: int = 0              # engine steps that ran restore tasks
    restore_io_measured: float = 0.0    # striped-device completion (sim SSD)
    decode_steps: int = 0
    snapshot_cost: float = 0.0
    # occupancy / fragmentation gauges (KVCacheBackend.occupancy, sampled
    # once per engine step while any slot is occupied). live = tokens in
    # occupied slots; reserved = capacity handed out to them — the gap is
    # internal fragmentation (max_seq over-reservation under contiguous,
    # page rounding under paged).
    live_tokens: int = 0                # last sample
    reserved_tokens: int = 0
    free_blocks: int = 0
    live_tokens_peak: int = 0
    reserved_tokens_peak: int = 0
    concurrent_peak: int = 0            # max sessions resident at once
    # running (sum, count) rather than a per-step list: a long-lived
    # serving process must not grow memory linearly with engine steps
    occupancy_sum: float = 0.0
    occupancy_count: int = 0
    alloc_stalls: int = 0               # admissions deferred: pool exhausted
    # cross-session prefix sharing gauges (DESIGN.md §12) — all zero
    # unless the engine runs with prefix_sharing=True
    prefix_lookups: int = 0
    prefix_hits: int = 0
    prefix_hit_tokens: int = 0
    restore_skipped_tokens: int = 0     # tokens adopted instead of
    #                                     restored/prefilled
    cow_copies: int = 0                 # pages privatized on divergence
    shared_pages: int = 0               # refcount > 1 (last sample)
    private_pages: int = 0              # refcount == 1 (last sample)
    dedup_host_bytes: int = 0           # host bytes sharing avoided
    forks: int = 0
    # self-calibrating scheduler gauges (DESIGN.md §13). Per completed
    # restore: the observed bubble fraction (idle share of the slack
    # stream in the measured-duration replay) and the relative error of
    # the planned makespan against the measured one. Running (sum, n)
    # pairs, same rationale as occupancy above. profiler_samples is the
    # MeasuredProfile's per-kind sample-count snapshot (empty when the
    # engine runs uncalibrated).
    restore_bubble_sum: float = 0.0
    restore_bubble_n: int = 0
    makespan_err_sum: float = 0.0
    makespan_err_n: int = 0
    io_streams_peak: int = 1            # max concurrent RESTORING slots
    profiler_samples: Dict[str, int] = dataclasses.field(
        default_factory=dict)
    # tensor-parallel gauges (DESIGN.md §16): one row per mesh device —
    # page-pool occupancy / free pages (replicated page structure, so
    # equal across devices) plus the restore-projection utilization of
    # the SPMD launches each device participates in. Single-device
    # engines report one row.
    device_gauges: List[dict] = dataclasses.field(default_factory=list)
    restore_project_wall: float = 0.0   # sum over completed restores
    restore_wall_sum: float = 0.0

    @property
    def restore_bubble_mean(self) -> float:
        return (self.restore_bubble_sum / self.restore_bubble_n
                if self.restore_bubble_n else 0.0)

    @property
    def makespan_err_mean(self) -> float:
        return (self.makespan_err_sum / self.makespan_err_n
                if self.makespan_err_n else 0.0)

    @property
    def prefix_hit_rate(self) -> float:
        return (self.prefix_hits / self.prefix_lookups
                if self.prefix_lookups else 0.0)

    @property
    def occupancy_mean(self) -> float:
        return (self.occupancy_sum / self.occupancy_count
                if self.occupancy_count else 0.0)

    @property
    def fragmentation_mean(self) -> float:
        return 1.0 - self.occupancy_mean if self.occupancy_count else 0.0

    @staticmethod
    def _summary(xs: List[float]) -> Dict[str, float]:
        if not xs:
            return {"n": 0, "mean": 0.0, "p50": 0.0, "p99": 0.0, "max": 0.0}
        a = np.asarray(xs, np.float64)
        return {"n": int(a.size), "mean": float(a.mean()),
                "p50": float(np.percentile(a, 50)),
                "p99": float(np.percentile(a, 99)),
                "max": float(a.max())}

    def to_dict(self) -> dict:
        """JSON-serializable dump of every counter/gauge; per-request
        populations (TTFT/TBT/makespans) summarized as n/mean/p50/p99/max.
        This is what ``serve.py --metrics-json`` writes and what the SLO
        harness consumes — benches never scrape printed text."""
        out = {}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if f.name == "device_gauges":
                out[f.name] = [dict(r) for r in v]
            elif isinstance(v, list):
                out[f.name] = self._summary(v)
            elif isinstance(v, dict):
                out[f.name] = {str(k): int(n) for k, n in v.items()}
            else:
                out[f.name] = v
        for prop in ("restore_bubble_mean", "makespan_err_mean",
                     "prefix_hit_rate", "occupancy_mean",
                     "fragmentation_mean"):
            out[prop] = float(getattr(self, prop))
        return out


class InferenceEngine:
    def __init__(self, model: Model, params, manager: HCacheManager, *,
                 max_batch: int = 4, max_seq: int = 512,
                 prefill_chunk: int = 128, save_hidden: bool = True,
                 temperature: float = 0.0, restore_tasks_per_step: int = 8,
                 prefetch_sessions: int = 2,
                 admission: Optional[AdmissionPolicy] = None,
                 eviction: Optional[EvictionPolicy] = None,
                 preempt_quantum: Optional[int] = None,
                 capacity: Optional[CapacityManager] = None,
                 backend: Union[str, KVCacheBackend] = "contiguous",
                 block_size: int = 16,
                 cache_blocks: Optional[int] = None,
                 enc_seq: Optional[int] = None,
                 prefix_sharing: bool = False,
                 tp: int = 1):
        self.model = model
        # every family-specific decision (prefill chunk policy, output->
        # cache mapping, resume support, save naming) goes through the
        # FamilyAdapter seam — the engine itself is family-agnostic
        self.adapter = model.adapter
        self.params = params
        self.mgr = manager
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.prefill_chunk = prefill_chunk
        self.save_hidden = save_hidden
        self.temperature = temperature
        self.restore_tasks_per_step = restore_tasks_per_step
        self.prefetch_sessions = prefetch_sessions
        self.admission = admission or FIFOAdmission()
        self.eviction = eviction or LRUEviction()
        # preempt_quantum: minimum resident steps before a DECODE session
        # is eviction-eligible; None disables mid-stream eviction
        self.preempt_quantum = preempt_quantum
        self.capacity = capacity
        if capacity is not None:
            capacity.attach_engine(self)

        # tensor-parallel context (DESIGN.md §16): a paged lm backend
        # shards its page pool over the mesh and the manager prices /
        # shards its restoration packs the same way. tp falls back to
        # single-device when the host exposes fewer devices (spmd False
        # keeps every seam an identity — one code path).
        self.tp = tp_lib.TPContext(tp)
        set_tp = getattr(manager, "set_tp", None)
        if set_tp is not None:
            set_tp(self.tp)

        # all cache state (contiguous slots or a paged pool + block
        # tables) lives behind the backend; the engine only holds views
        self.kv = make_backend(backend, model, max_batch, max_seq,
                               block_size=block_size,
                               num_blocks=cache_blocks, enc_seq=enc_seq,
                               tp=self.tp)
        # cross-session prefix sharing (DESIGN.md §12): host chunk
        # aliasing on fork works on every backend; the device-side
        # token-hash index needs pages, so it exists only under paged
        self.prefix_sharing = bool(prefix_sharing)
        self.prefix_index: Optional[PrefixIndex] = None
        self._fork_pages: Dict[str, dict] = {}   # parked page holds
        if self.prefix_sharing and isinstance(self.kv, PagedBackend):
            self.prefix_index = PrefixIndex(self.kv)
            self.prefix_index.store = manager.store
            self.kv.prefix_index = self.prefix_index
        # token-callback seam (DESIGN.md §14): the front door's engine
        # pump fans emitted tokens out to per-request async queues
        # through these hooks. on_token fires exactly once per emitted
        # token (the resume feed after a pause replays an EXISTING token
        # through prefill and does not re-fire); on_finish fires exactly
        # once per request, at retire, with reason "stop" (EOS) or
        # "length"; on_pause fires at each mid-stream eviction. All run
        # on the engine-stepping thread.
        self.on_token = None               # fn(seq, tok)
        self.on_finish = None              # fn(seq, reason)
        self.on_pause = None               # fn(seq)
        self.queue: deque = deque()
        self.slots: List[Optional[SequenceState]] = [None] * max_batch
        self.sessions: Dict[str, SequenceState] = {}
        self._prefetch: Dict[str, object] = {}   # session -> warm executor
        self.metrics = EngineMetrics()
        self.step_count = 0

    # ----------------------------------------------------------- submission
    def submit(self, request: Request) -> SequenceState:
        seq = SequenceState(request=request)
        if request.arrival_time == 0.0:
            # the front door pre-stamps arrival at ingress so TTFT covers
            # its own queueing; direct callers are stamped here
            seq.request.arrival_time = time.perf_counter()
        if request.arrival_step < 0:
            seq.request.arrival_step = self.step_count
        seq.enqueue_step = self.step_count
        self.queue.append(seq)
        return seq

    def recoverable_sessions(self) -> List[str]:
        return self.mgr.sessions()

    # ------------------------------------------------------------ lifecycle
    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def _tokens_needed(self, seq: SequenceState) -> int:
        """Worst-case final token length of this residency: stored
        history + the pending prompt + the decode tokens still owed.
        What a paged reservation must cover (contiguous always reserves
        max_seq)."""
        manifest = self.mgr.store.get_manifest(seq.request.session_id)
        stored = (int(manifest["n_tokens"]) if manifest
                  else seq.history_len)
        need = (stored + len(seq.effective_prompt)
                + seq.request.max_new_tokens - len(seq.generated))
        fork = self._fork_pages.get(seq.request.session_id)
        if fork is not None and fork["partial"]:
            # adopting a fork's partial tail page shares it with the
            # donor; the resume-feed write privatizes it, costing one
            # extra pool page while both holds are live
            need += self.kv.block_size
        return need

    def _host_align(self, m: int) -> int:
        """Floor a device prefix match so its host analogue aliases only
        whole chunks (the adopted length must be page- AND chunk-
        aligned)."""
        C = self.mgr.store.chunk_tokens
        bs = self.kv.block_size
        align = bs * C // math.gcd(bs, C)
        return (m // align) * align

    def _shared_prefix_estimate(self, seq: SequenceState) -> int:
        """Tokens an admission of ``seq`` would cover via shared pages
        (parked fork pages or a prefix-index hit) — those pages arrive by
        incref, not from the free pool."""
        if self.prefix_index is None:
            return 0
        sid = seq.request.session_id
        man = self.mgr.store.get_manifest(sid)
        fork = self._fork_pages.get(sid)
        if (fork is not None and man is not None
                and fork["n_tokens"] == int(man["n_tokens"])):
            bs = self.kv.block_size
            return (fork["n_tokens"] // bs) * bs
        if man is not None:
            if (man.get("compress", self.mgr.compress) != "none"
                    or "recompute" in list(man["methods"])):
                return 0
            try:
                toks = self.mgr._tokens(sid)
            except KeyError:
                return 0
            n = int(man["n_tokens"])
            _, m, _ = self.prefix_index.match(toks[:n], limit=n,
                                              record=False)
            return m
        prompt = np.asarray(seq.effective_prompt).reshape(-1)
        _, m, _ = self.prefix_index.match(prompt, limit=len(prompt) - 1,
                                          need_host=self.save_hidden,
                                          record=False)
        return self._host_align(m) if self.save_hidden else m

    def _can_reserve_for(self, seq: SequenceState) -> bool:
        """Admission gate: ``kv.can_reserve``, made sharing-aware."""
        need = self._tokens_needed(seq)
        return self.kv.can_reserve(
            max(need - self._shared_prefix_estimate(seq), 1))

    def _admit(self) -> None:
        while self.queue:
            slot = self._free_slot()
            if slot is None:
                break
            seq = self.admission.select(tuple(self.queue), self)
            if seq is None:
                break
            if not self._can_reserve_for(seq):
                # allocator backpressure: a free slot exists but the page
                # pool cannot hold the session — wait for retires/frees
                self.metrics.alloc_stalls += 1
                break
            self.queue.remove(seq)
            if not self._place(seq, slot):
                break
        self._prefetch_queued()

    def _adopt_shared_prefix(self, seq: SequenceState, slot: int) -> int:
        """Map the longest shared prefix of this session into the free
        slot's block table before ``reserve`` tops it up with private
        pages. Three sources, tried in order: parked fork pages (the
        fork adopts the donor's saved history wholesale), a prefix-index
        hit on the session's stored token history (restore-skip), or a
        prefix-index hit on a fresh prompt (prefill-skip — the host
        analogue aliases the publisher's pinned chunks so the session
        is a complete stored session of the matched length). Returns the
        adopted token count."""
        if self.prefix_index is None:
            return 0
        sid = seq.request.session_id
        man = self.mgr.store.get_manifest(sid)
        fork = self._fork_pages.pop(sid, None)
        if fork is not None:
            if man is not None and fork["n_tokens"] == int(man["n_tokens"]):
                self.kv.adopt_shared(slot, fork["blocks"], owned=True)
                return fork["n_tokens"]
            # the source saved more state since the fork: the parked
            # pages are stale — drop the holds, fall back to the index
            self.kv.release_blocks(fork["blocks"])
        if man is not None:
            if (man.get("compress", self.mgr.compress) != "none"
                    or "recompute" in list(man["methods"])):
                # shared pages hold exact fp16 KV; a session whose
                # no-sharing restore would go through another codec must
                # not mix sources (byte-equivalence to the reference run)
                return 0
            try:
                toks = self.mgr._tokens(sid)
            except KeyError:
                return 0
            n = int(man["n_tokens"])
            blocks, m, _ = self.prefix_index.match(toks[:n], limit=n)
            if m:
                self.kv.adopt_shared(slot, blocks)
            return m
        prompt = np.asarray(seq.effective_prompt).reshape(-1)
        blocks, m, entry = self.prefix_index.match(
            prompt, limit=len(prompt) - 1, need_host=self.save_hidden)
        if m and self.save_hidden:
            m = self._host_align(m)
            blocks = blocks[:m // self.kv.block_size]
        if not m:
            return 0
        self.kv.adopt_shared(slot, blocks)
        if self.save_hidden:
            self._alias_host_prefix(sid, prompt[:m], entry)
        else:
            seq.history_len = m
        seq.pending_prompt = prompt[m:]
        return m

    def _alias_host_prefix(self, sid: str, prefix_tokens,
                           entry) -> None:
        """Host-side analogue of a fresh-prompt prefix hit: the new
        session's streams alias the publisher's pinned chunks for the
        matched tokens and a manifest is committed, so every later code
        path (resume prefill, pause, restore) sees an ordinary stored
        session of ``m`` tokens. The aliases cost no bytes until the
        session diverges onto its own chunks."""
        store = self.mgr.store
        m = len(prefix_tokens)
        pin: HostPin = entry.pin
        n_chunks = -(-m // store.chunk_tokens)
        store.put_blob(sid, "tok", 0, np.asarray(prefix_tokens, np.int32))
        for (stream, li), ids in pin.pins.items():
            for ci in range(min(n_chunks, len(ids))):
                store.alias_chunk(sid, stream, li, ci, ids[ci])
        store.put_manifest(sid, {"n_tokens": m,
                                 "methods": list(pin.methods),
                                 "arch": self.mgr.cfg.name,
                                 "compress": "none"})

    def _place(self, seq: SequenceState, slot: int) -> bool:
        """Bind a (possibly resuming) sequence to a free batch slot.
        False iff the backend could not reserve capacity (the sequence is
        requeued and the slot stays free)."""
        sid = seq.request.session_id
        adopted = self._adopt_shared_prefix(seq, slot)
        if not self.kv.reserve(slot, self._tokens_needed(seq)):
            if self.prefix_index is not None and self.kv.slot_blocks[slot]:
                self.kv.free_slot(slot)      # drop adopted page holds
            if adopted and self.mgr.store.get_manifest(sid) is None:
                # no-save fresh match: nothing persisted — undo the trim
                seq.pending_prompt = None
                seq.history_len = 0
            self.metrics.alloc_stalls += 1
            self.queue.appendleft(seq)
            return False
        seq.slot = slot
        seq.admit_step = self.step_count
        seq.view = self.kv.view(slot)
        self.slots[slot] = seq
        self.sessions[sid] = seq
        if self.capacity is not None:
            self.capacity.touch(sid, self.step_count)
        manifest = self.mgr.store.get_manifest(sid)
        if manifest:
            n_man = int(manifest["n_tokens"])
            d = min(adopted, n_man)
            if d:
                self.metrics.restore_skipped_tokens += d
            if d >= n_man and n_man > 0:
                # the whole stored history is already resident via
                # shared pages — no restoration work at all
                self._prefetch.pop(sid, None)
                seq.restored = True
                seq.history_len = n_man
                seq.restore_sim = 0.0
                seq.restore_wall = 0.0
                self.kv.set_length(slot, n_man)
                seq.phase = Phase.PREFILL
                self._prefill_step(seq)
                return True
            seq.phase = Phase.RESTORING
            ex = self._prefetch.pop(sid, None)
            if ex is not None and (
                    ex.n_tokens != n_man
                    or list(ex.methods) != list(manifest["methods"])
                    or ex.compress != manifest.get("compress",
                                                   self.mgr.compress)
                    or getattr(ex, "start_token", 0) != d):
                # the session saved more state (or was demoted to another
                # codec by the capacity ladder) after the prefetch
                # started, or a shared prefix moved the start token: the
                # warm executor is stale — restart from the current
                # manifest
                ex = None
            if ex is None:
                # this restore joins the already-RESTORING slots on the
                # shared host link: plan it at the new multiplicity
                # (this slot already shows RESTORING — no extra)
                self._update_io_streams()
                ex = self.mgr.begin_restore(self.params, sid,
                                            start_token=d)
            ex.attach_sink(ViewSink(seq.view))
            seq.executor = ex
            # reserve [0, n) now: concurrent decode steps park their
            # scratch KV write at position n (later overwritten by
            # this session's own prefill), never inside the restored
            # range
            self.kv.set_length(slot, ex.n_tokens)
        else:
            seq.phase = Phase.PREFILL
            if seq.history_len:
                # no-save prefix hit: the adopted range is live history
                self.kv.set_length(slot, seq.history_len)
            self._prefill_step(seq)
        return True

    # ----------------------------------------------------------- preemption
    def _maybe_preempt(self) -> None:
        """Mid-stream eviction under slot pressure (one victim per step):
        pause a resident DECODE session past its quantum, hand its slot
        to the admission policy's next pick. The victim re-enters through
        the RESTORING pipeline."""
        # resume replays the last sampled token through a prefill over
        # restored state — families without that path (ssm/hybrid, whose
        # recurrent states would restart from zero) are not preemptable
        if (self.preempt_quantum is None or not self.save_hidden
                or not self.adapter.supports_resume or not self.queue):
            return
        if self._free_slot() is not None:
            # a slot is open, so preemption is only justified when the
            # second admission gate — the page pool — is what's blocking
            # the queue; pausing a victim recycles its pages
            seq = self.admission.select(tuple(self.queue), self)
            if seq is None or self._can_reserve_for(seq):
                return
        candidates = [s for s in self.slots
                      if s is not None and s.phase == Phase.DECODE
                      and s.generated and not s.finished()
                      and self.step_count - s.admit_step
                      >= self.preempt_quantum]
        victim = self.eviction.select_victim(candidates, self)
        if victim is None:
            return
        slot = victim.slot
        self._pause_slot(slot)
        waiting = [s for s in self.queue if s is not victim]
        seq = self.admission.select(tuple(waiting), self)
        if seq is not None:
            self.queue.remove(seq)
            self._place(seq, slot)

    def _pause_slot(self, i: int) -> None:
        """Evict the resident of slot ``i`` mid-decode: dump restorable
        state (``view.snapshot()``), free the slot's pages, requeue the
        sequence as PAUSED. The last sampled token (whose KV does not
        exist yet) becomes the 1-token resume prefill after restoration."""
        s = self.slots[i]
        sid = s.request.session_id
        n = s.total_len
        self.mgr.saver.drain()
        self.mgr.save_session_pause(
            sid, s.view.snapshot(), n - 1,
            tokens_tail=np.asarray(s.generated[s.tok_saved:-1], np.int32))
        self._after_save(sid)
        self._publish_slot(s)
        s.tok_saved = len(s.generated) - 1
        s.gen_absorbed = len(s.generated)
        s.pending_prompt = np.asarray([s.generated[-1]], np.int32)
        s.pending_from_gen = True
        s.prefill_done = 0
        s.history_len = 0              # re-set when restoration completes
        s.phase = Phase.PAUSED
        s.slot = -1
        s.executor = None
        s.view.free()
        s.view = None
        s.pauses += 1
        s.enqueue_step = self.step_count
        self.slots[i] = None
        self.queue.append(s)
        self.metrics.preemptions += 1
        if self.on_pause is not None:
            self.on_pause(s)

    # ------------------------------------------------------ prefix sharing
    def _host_pin_fn(self, sid: str, man: dict):
        """``pin_fn`` for ``PrefixIndex.publish``: pins every persisted
        stream's chunks covering ``depth`` pages, or None when the
        coverage is not (fully) flushed — the entry then serves
        device-only consumers (restore-skip), not fresh-prompt hits."""
        if not self.save_hidden:
            return None
        methods = list(man["methods"])
        if any(m == "recompute" for m in methods):
            return None
        store = self.mgr.store
        C = store.chunk_tokens
        bs = self.kv.block_size

        def pin(depth: int):
            n_tok = depth * bs
            n_chunks = -(-n_tok // C)
            targets = []
            for li, m in enumerate(methods):
                for stream in (("h",) if m == "hidden" else ("kvk", "kvv")):
                    for ci in range(n_chunks):
                        if (store.chunk_rows(sid, stream, li, ci)
                                < min(C, n_tok - ci * C)):
                            return None
                    targets.append((stream, li))
            pins = {(stream, li): store.pin_chunks(sid, stream, li,
                                                   list(range(n_chunks)))
                    for stream, li in targets}
            return HostPin(methods=methods, pins=pins, n_chunks=n_chunks)
        return pin

    def _publish_slot(self, seq: SequenceState) -> None:
        """Index the slot's full pages for cross-session sharing — at
        prefill completion and again right before the slot frees at
        pause/retire (published pages are incref'd, so they outlive the
        publisher's residency)."""
        if self.prefix_index is None or seq.view is None or seq.slot < 0:
            return
        blks = self.kv.slot_blocks[seq.slot]
        if not blks:
            return
        sid = seq.request.session_id
        length = int(self.kv.get_lengths()[seq.slot])
        if self.save_hidden:
            man = self.mgr.store.get_manifest(sid)
            if not man or man.get("compress", self.mgr.compress) != "none":
                return                     # demoted codecs are not shared
            try:
                tokens = self.mgr._tokens(sid)
            except KeyError:
                return
            self.prefix_index.publish(tokens, min(length, len(tokens)),
                                      blks, self._host_pin_fn(sid, man))
        else:
            if seq.pending_from_gen:
                return       # token history not reconstructible sans store
            tokens = np.concatenate(
                [np.asarray(seq.request.prompt, np.int64).reshape(-1),
                 np.asarray(seq.generated, np.int64)])
            self.prefix_index.publish(tokens, min(length, len(tokens)),
                                      blks, None)

    def fork_session(self, src: str, new_id: str) -> dict:
        """Fork ``src``'s conversation state as ``new_id`` (DESIGN.md
        §12): host streams are shared content-addressed (bytes exist
        once until a side diverges; with prefix_sharing off they are
        materialized as real copies), and — with sharing on, a paged
        backend and the source resident — the saved history's device
        pages are parked for the fork to adopt CoW-shared at admission,
        making its restoration a no-op. A resident source is
        checkpointed first (the same dump as a pause, without losing its
        slot), so the fork point is the full history through the last
        sampled token's predecessor."""
        seq = self.sessions.get(src)
        if seq is not None and seq.view is not None:
            if seq.phase != Phase.DECODE or not seq.generated:
                raise ValueError(
                    f"cannot fork {src!r} mid-{seq.phase.value}; fork "
                    f"before admission or once it is decoding")
            if not self.save_hidden:
                raise ValueError(
                    "forking a resident session requires save_hidden "
                    "(its history lives only in streams it never saved)")
            n = seq.total_len
            self.mgr.saver.drain()
            self.mgr.save_session_pause(
                src, seq.view.snapshot(), n - 1,
                tokens_tail=np.asarray(seq.generated[seq.tok_saved:-1],
                                       np.int32))
            self._after_save(src)
            seq.tok_saved = len(seq.generated) - 1
        man = self.mgr.fork_session(src, new_id,
                                    share=self.prefix_sharing)
        if (self.prefix_index is not None and seq is not None
                and seq.view is not None):
            n_saved = int(man["n_tokens"])
            pages = -(-n_saved // self.kv.block_size)
            blocks = [int(b) for b in
                      self.kv.slot_blocks[seq.slot][:pages]]
            for b in blocks:
                self.kv.allocator.incref(b)
            self._fork_pages[new_id] = {
                "blocks": blocks, "n_tokens": n_saved,
                "partial": n_saved % self.kv.block_size != 0}
        self.metrics.forks += 1
        return man

    def release_fork(self, new_id: str) -> None:
        """Drop the parked page holds of a fork that will never be
        submitted (the host-side state stays forkable)."""
        fork = self._fork_pages.pop(new_id, None)
        if fork is not None:
            self.kv.release_blocks(fork["blocks"])

    # ----------------------------------------------------------- restoration
    def _prefetch_queued(self) -> None:
        """Warm the first IO reads of queued sessions with stored state
        before a slot frees (their executor starts part-done on admit)."""
        for seq in list(self.queue)[:self.prefetch_sessions]:
            sid = seq.request.session_id
            ex = self._prefetch.get(sid)
            if ex is None and self.mgr.store.get_manifest(sid):
                ex = self.mgr.begin_restore(self.params, sid)
                self._prefetch[sid] = ex
            if ex is not None:
                ex.prefetch_step(1)

    def _update_io_streams(self, extra: int = 0) -> None:
        """Report the restore multiplicity to the planner: how many
        sessions are (about to be) pulling the shared host link at once.
        ``extra`` counts a restore being placed this instant, before its
        slot shows RESTORING.

        Distributed store: additionally fold each restoring executor's
        touched NIC links into a per-link ``LinkLoad`` — contention is
        then charged only on the links a candidate restore shares with
        the in-flight ones, not globally (an ``extra`` placement has no
        executor yet and conservatively counts on every link)."""
        restoring = [s.executor for s in self.slots
                     if s is not None and s.phase == Phase.RESTORING
                     and s.executor is not None]
        n = max(len(restoring) + extra, 1)
        setter = getattr(self.mgr, "set_io_streams", None)
        if setter is not None:
            setter(n)
        load_setter = getattr(self.mgr, "set_link_load", None)
        topo_fn = getattr(self.mgr, "shard_topology", None)
        topo = topo_fn() if topo_fn is not None else None
        if load_setter is not None and topo is not None \
                and topo.n_shards > 1:
            from repro.core.cost_model import LinkLoad
            streams: Dict[int, int] = {}
            for ex in restoring:
                for link in ex.links_touched():
                    streams[link] = streams.get(link, 0) + 1
            for link in range(topo.n_shards):
                streams[link] = streams.get(link, 0) + extra
            load_setter(LinkLoad(streams))
        self.metrics.io_streams_peak = max(self.metrics.io_streams_peak, n)

    def _restore_step(self) -> None:
        """Advance every RESTORING session by a bounded number of pipeline
        tasks. Several sessions restore concurrently; the decode batch of
        active sessions runs in the same engine step regardless."""
        ran = False
        for seq in self.slots:
            if seq is None or seq.phase != Phase.RESTORING:
                continue
            ran = True
            if seq.executor.step(self.restore_tasks_per_step):
                ex = seq.executor
                seq.executor = None
                seq.restored = True
                seq.history_len = ex.n_tokens
                seq.restore_sim = ex.timeline().makespan
                seq.restore_wall = ex.wall_time
                self.metrics.restored_tokens += (
                    ex.n_tokens - getattr(ex, "start_token", 0))
                self.metrics.restore_sim_all.append(seq.restore_sim)
                if seq.pending_from_gen:       # resume of a paused session
                    self.metrics.restore_sim_resume.append(seq.restore_sim)
                self.metrics.restore_io_measured = max(
                    self.metrics.restore_io_measured, ex.io_measured)
                self.metrics.restore_project_wall += getattr(
                    ex, "project_wall", 0.0)
                self.metrics.restore_wall_sum += ex.wall_time
                self._record_calibration(ex)
                seq.phase = Phase.PREFILL
        if ran:
            self.metrics.restore_steps += 1

    def _record_calibration(self, ex) -> None:
        """Scheduler-calibration gauges from one finished restore:
        observed bubble fraction and planned-vs-measured makespan error.
        Only meaningful when the executor observed task durations (a
        timed store and/or calibration on)."""
        if not getattr(ex, "observed", None):
            return
        m = self.metrics
        tl = ex.measured_timeline()
        if tl.makespan > 0:
            # the bottleneck stream's bubble is ~0 by construction; the
            # slack stream's idle share is the bubble the scheduler
            # exists to close
            m.restore_bubble_sum += max(tl.io_bubble, tl.compute_bubble)
            m.restore_bubble_n += 1
            predicted = getattr(ex, "predicted_makespan", 0.0)
            if predicted > 0:
                m.makespan_err_sum += (abs(predicted - tl.makespan)
                                       / tl.makespan)
                m.makespan_err_n += 1
        profile = getattr(self.mgr, "profile", None)
        if profile is not None:
            m.profiler_samples = profile.sample_counts()

    # -------------------------------------------------------------- prefill
    def _prefill_step(self, seq: SequenceState) -> None:
        """Process up to ``prefill_chunk`` prompt tokens (SplitFuse;
        families whose adapter is not ``chunkable`` — recurrent-state and
        enc-dec stacks — take the whole prompt in one step).

        After a mid-stream eviction the "prompt" is the resume feed
        (``effective_prompt``): the last sampled token, whose KV is
        recreated here on top of the restored [0, n) range."""
        if seq.phase != Phase.PREFILL:
            return
        ad = self.adapter
        prompt = seq.effective_prompt
        remaining = prompt[seq.prefill_done:]
        if len(remaining) == 0:
            seq.phase = Phase.DECODE
            return
        chunk = remaining[:self.prefill_chunk] if ad.chunkable else remaining
        hist = seq.history_len + seq.prefill_done
        out = ad.prefill_chunk(self.params, seq, chunk, hist,
                               capture_hidden=self.save_hidden)
        ad.absorb_prefill(seq.view, out, len(chunk), hist)
        seq.view.set_length(hist + len(chunk))
        if self.save_hidden:
            sid = seq.request.session_id
            self.mgr.save_prefill(sid, np.asarray(chunk), out, start=hist)
            self._after_save(sid)
        seq.prefill_done += len(chunk)
        if seq.pending_from_gen and self.save_hidden:
            seq.tok_saved += len(chunk)   # resume feed landed in tok blob
        if seq.prefill_done >= len(prompt):
            seq.phase = Phase.DECODE
            self._publish_slot(seq)
            lg = out["logits"]
            tok = int(sample(lg, temperature=self.temperature)[0])
            self._emit_token(seq, tok)

    # --------------------------------------------------------------- decode
    def _emit_token(self, seq: SequenceState, tok: int) -> None:
        seq.generated.append(tok)
        if seq.first_token_step is None:
            seq.first_token_step = self.step_count
            seq.ttft_wall = time.perf_counter() - seq.request.arrival_time
            self.metrics.ttft_wall.append(seq.ttft_wall)
            if seq.restored:
                self.metrics.ttft_sim.append(seq.restore_sim)
                self.metrics.ttft_wall_restored.append(seq.ttft_wall)
            else:
                self.metrics.ttft_wall_cold.append(seq.ttft_wall)
        if self.on_token is not None:
            self.on_token(seq, tok)

    def _decode_batch(self) -> None:
        active = [s for s in self.slots
                  if s is not None and s.phase == Phase.DECODE
                  and not s.finished()]
        if not active:
            return
        t0 = time.perf_counter()
        tokens = np.zeros((self.max_batch, 1), np.int32)
        for s in self.slots:
            if s is not None and s.phase == Phase.DECODE and s.generated:
                tokens[s.slot, 0] = s.generated[-1]
        lg, hidden = self.kv.decode(self.params, jnp.asarray(tokens))
        # inactive slots advanced their length too — undo
        mask = np.zeros((self.max_batch,), bool)
        for s in active:
            mask[s.slot] = True
        lengths = self.kv.get_lengths()
        lengths[~mask] -= 1
        self.kv.set_lengths(lengths)
        toks = np.asarray(sample(lg, temperature=self.temperature))
        if self.save_hidden and hidden is not None:
            # only truly-active sessions: a session that finished at
            # prefill completion still sits in its slot in DECODE phase
            # until _retire, and saving its masked-out scratch step would
            # overwrite the last legitimate hidden row
            active_slots = {s.slot for s in active}
            sess = [s.request.session_id if (s is not None
                    and s.slot in active_slots) else None
                    for s in self.slots]
            h = self.adapter.decode_hidden(hidden)
            self.metrics.snapshot_cost += self.mgr.save_decode_hidden(
                sess, np.asarray(h), lengths - 1)
        dt = time.perf_counter() - t0
        for s in active:
            self._emit_token(s, int(toks[s.slot]))
            self.metrics.tbt_wall.append(dt)
        self.metrics.decode_steps += 1

    def _retire(self) -> None:
        for i, s in enumerate(self.slots):
            if s is None or not s.finished():
                continue
            sid = s.request.session_id
            n = s.total_len
            tail = np.asarray(s.generated[s.tok_saved:-1], np.int32)
            if self.save_hidden:
                self.mgr.saver.drain()
                self.mgr.save_session_pause(sid, s.view.snapshot(),
                                            n - 1, tokens_tail=tail)
                self._after_save(sid)
                s.tok_saved = len(s.generated) - 1
            self._publish_slot(s)
            s.phase = Phase.DONE
            s.view.free()
            s.view = None
            self.slots[i] = None
            if self.on_finish is not None:
                r = s.request
                reason = ("stop" if (r.eos_token is not None and s.generated
                                     and s.generated[-1] == r.eos_token)
                          else "length")
                self.on_finish(s, reason)

    def _after_save(self, sid: str) -> None:
        """On-save capacity hook: a demoted session whose stream was just
        extended is the anti-entropy ladder's re-promotion candidate."""
        if self.capacity is not None:
            self.capacity.consider_promotion(sid)

    # ------------------------------------------------------------ main loop
    def _sample_occupancy(self) -> None:
        occ = self.kv.occupancy()
        m = self.metrics
        m.live_tokens = occ.live_tokens
        m.reserved_tokens = occ.reserved_tokens
        m.free_blocks = occ.free_blocks
        m.live_tokens_peak = max(m.live_tokens_peak, occ.live_tokens)
        m.reserved_tokens_peak = max(m.reserved_tokens_peak,
                                     occ.reserved_tokens)
        resident = sum(1 for s in self.slots if s is not None)
        m.concurrent_peak = max(m.concurrent_peak, resident)
        if occ.reserved_tokens:
            m.occupancy_sum += occ.utilization
            m.occupancy_count += 1
        if self.prefix_sharing:
            m.dedup_host_bytes = int(self.mgr.store.dedup_bytes)
        if self.prefix_index is not None:
            pi = self.prefix_index
            m.prefix_lookups = pi.lookups
            m.prefix_hits = pi.hits
            m.prefix_hit_tokens = pi.hit_tokens
            m.cow_copies = self.kv.cow_copies
            m.shared_pages, m.private_pages = self.kv.shared_page_stats()
        # per-device gauges: pool rows from the backend, plus the share
        # of completed-restore wall spent inside the SPMD projection
        # launches (every mesh device participates in each launch, so the
        # utilization is common to all rows)
        util = (int(round(100.0 * m.restore_project_wall
                          / m.restore_wall_sum))
                if m.restore_wall_sum > 0 else 0)
        rows = self.kv.device_occupancy()
        for r in rows:
            r["proj_util_pct"] = util
        m.device_gauges = rows

    def step(self) -> None:
        self.step_count += 1
        # refresh the planner's view of restore contention (completed
        # restores lower the multiplicity; admission below may raise it)
        self._update_io_streams()
        self._admit()
        self._maybe_preempt()
        self._restore_step()
        prefilled = False
        for s in list(self.slots):
            if s is not None and s.phase == Phase.PREFILL:
                self._prefill_step(s)
                prefilled = True
        decoded_before = self.metrics.decode_steps
        self._decode_batch()
        self._sample_occupancy()
        self._retire()
        if self.capacity is not None:
            self.capacity.maintain(self)
            if not prefilled and self.metrics.decode_steps == decoded_before:
                # idle step (nothing prefilled or decoded — at most
                # restores ticked): run the anti-entropy promotion sweep
                # so demoted-but-idle sessions recover fp16 fidelity
                # without waiting for their next save
                self.capacity.sweep_promotions()

    def run(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.queue and all(s is None for s in self.slots):
                break
            self.step()
        self.mgr.saver.drain()

    def close(self) -> None:
        """Stop the two-stage saver's daemon threads (and surface any
        write error they captured). Call when done with the engine —
        tests that build many engines would otherwise leak threads."""
        self.mgr.saver.close()

    # --------------------------------------------------------------- output
    def result(self, session_id: str) -> List[int]:
        return list(self.sessions[session_id].generated)
