"""Token sampling (greedy / temperature)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(logits, *, temperature: float = 0.0, rng=None):
    """logits: (B, 1, V) -> (B,) int32."""
    lg = logits[:, -1, :]
    if temperature <= 0.0:
        return jnp.argmax(lg, axis=-1).astype(jnp.int32)
    rng = jax.random.PRNGKey(0) if rng is None else rng
    return jax.random.categorical(rng, lg / temperature, axis=-1).astype(
        jnp.int32)
