"""Request / sequence bookkeeping for the serving engine."""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import List, Optional

import numpy as np

_ids = itertools.count()


class Phase(str, enum.Enum):
    WAITING = "waiting"          # queued, not yet admitted
    RESTORING = "restoring"      # HCache restoration phase (paper §5)
    PREFILL = "prefill"          # chunked prompt prefill
    DECODE = "decode"            # in the continuous decode batch
    PAUSED = "paused"            # evicted mid-stream; requeued, state in
    DONE = "done"                # the store, resumes via RESTORING


@dataclasses.dataclass
class Request:
    session_id: str
    prompt: np.ndarray                       # (n,) int32 new prompt tokens
    max_new_tokens: int = 32
    eos_token: Optional[int] = None
    priority: int = 0                        # PriorityAdmission: higher wins
    # enc-dec (whisper) sessions: (S_enc, d_model) encoder frame
    # embeddings. Required on a session's FIRST residency (the encoder
    # runs once and the result persists as the 'enc' blob); later rounds
    # and resumes restore the cross context from the store instead.
    frames: Optional[np.ndarray] = None
    # arrival stamps. The engine fills both at submit() UNLESS the caller
    # pre-stamped them — the front door (frontend/pump.py) stamps
    # arrival_time at ingress so TTFT includes its queueing, and the SLO
    # harness keys per-request accounting off arrival_step ordering.
    arrival_time: float = 0.0                # perf_counter at arrival
    arrival_step: int = -1                   # engine step_count at arrival
    request_id: int = dataclasses.field(default_factory=lambda: next(_ids))


@dataclasses.dataclass
class SequenceState:
    request: Request
    phase: Phase = Phase.WAITING
    slot: int = -1                           # decode-batch slot
    history_len: int = 0                     # restored tokens
    prefill_done: int = 0                    # pending-prompt tokens processed
    generated: List[int] = dataclasses.field(default_factory=list)
    # mid-stream eviction (Phase.PAUSED) bookkeeping. ``generated`` spans
    # pauses (the full answer so far); the counters record how much of it
    # has been folded back into history / the pending prompt.
    pending_prompt: Optional[np.ndarray] = None  # overrides request.prompt
    pending_from_gen: bool = False           # pending tokens came from
    #                                          ``generated`` (resume feed)
    gen_absorbed: int = 0                    # generated tokens counted in
    #                                          history_len/pending_prompt
    tok_saved: int = 0                       # generated tokens persisted
    #                                          to the store's token blob
    admit_step: int = -1                     # engine step of last admission
    enqueue_step: int = 0                    # engine step of last (re)queue
    #                                          (admission aging baseline)
    pauses: int = 0                          # times evicted mid-stream
    # slot-bound CacheView handle (serving/kv_cache.py); set while the
    # sequence holds a batch slot, None when queued/paused/done
    view: Optional[object] = None
    # incremental restoration (core/restoration.py); set while RESTORING
    executor: Optional[object] = None
    restored: bool = False                   # completed a restoration
    # metrics
    ttft_wall: Optional[float] = None
    restore_sim: float = 0.0                 # simulated restoration seconds
    restore_wall: float = 0.0
    first_token_step: Optional[int] = None

    @property
    def effective_prompt(self) -> np.ndarray:
        """Tokens to prefill this residency: the original prompt, or the
        resume feed (last sampled token) after a mid-stream eviction."""
        return (self.pending_prompt if self.pending_prompt is not None
                else self.request.prompt)

    @property
    def total_len(self) -> int:
        """True token length of the session's stream (history + prompt +
        generated), counting each generated token once even after pauses
        folded a prefix of ``generated`` into ``history_len``."""
        return (self.history_len + self.prefill_done + len(self.generated)
                - self.gen_absorbed)

    def finished(self) -> bool:
        r = self.request
        if len(self.generated) >= r.max_new_tokens:
            return True
        return bool(self.generated and r.eos_token is not None
                    and self.generated[-1] == r.eos_token)
