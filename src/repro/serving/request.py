"""Request / sequence bookkeeping for the serving engine."""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import List, Optional

import numpy as np

_ids = itertools.count()


class Phase(str, enum.Enum):
    WAITING = "waiting"          # queued, not yet admitted
    RESTORING = "restoring"      # HCache restoration phase (paper §5)
    PREFILL = "prefill"          # chunked prompt prefill
    DECODE = "decode"            # in the continuous decode batch
    DONE = "done"


@dataclasses.dataclass
class Request:
    session_id: str
    prompt: np.ndarray                       # (n,) int32 new prompt tokens
    max_new_tokens: int = 32
    eos_token: Optional[int] = None
    arrival_time: float = 0.0
    request_id: int = dataclasses.field(default_factory=lambda: next(_ids))


@dataclasses.dataclass
class SequenceState:
    request: Request
    phase: Phase = Phase.WAITING
    slot: int = -1                           # decode-batch slot
    history_len: int = 0                     # restored tokens
    prefill_done: int = 0                    # prompt tokens processed
    generated: List[int] = dataclasses.field(default_factory=list)
    # incremental restoration (core/restoration.py); set while RESTORING
    executor: Optional[object] = None
    restored: bool = False                   # completed a restoration
    # metrics
    ttft_wall: Optional[float] = None
    restore_sim: float = 0.0                 # simulated restoration seconds
    restore_wall: float = 0.0
    first_token_step: Optional[int] = None

    @property
    def total_len(self) -> int:
        return (self.history_len + self.prefill_done + len(self.generated))

    def finished(self) -> bool:
        r = self.request
        if len(self.generated) >= r.max_new_tokens:
            return True
        return bool(self.generated and r.eos_token is not None
                    and self.generated[-1] == r.eos_token)
