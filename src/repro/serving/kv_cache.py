"""KV-cache backends behind a single ``CacheView`` seam (DESIGN.md §9).

The serving engine never touches cache buffers directly. All state lives
in a ``KVCacheBackend``:

  * ``ContiguousBackend`` — the classic layout: every batch slot owns
    ``max_seq`` contiguous positions of a stacked ``(L, B, Smax, Kv, hd)``
    buffer (decoder-only families: lm / ssm / hybrid).
  * ``PagedBackend``     — vLLM-style block tables over a physical page
    pool ``(L, num_blocks, block_size, Kv, hd)`` plus a ``BlockAllocator``
    free list. A slot reserves only the pages its session can actually
    use, so occupancy — not ``max_batch × max_seq`` — caps concurrency.
    LM family only (block tables have no SSM-state analog).
  * ``ShardedPagedBackend`` — the paged pool committed sharded on the
    KV-head axis over a tensor-parallel mesh (DESIGN.md §16): decode and
    restore-sink writes run as SPMD programs where each device touches
    only its own heads; page bookkeeping (allocator, block tables, CoW)
    is replicated structure, so it stays exactly the single-device code.
  * ``PagedEncDecBackend`` — the enc-dec pairing over pages: the decoder
    self-KV region rides the paged pool (same allocator/CoW machinery),
    while the cross context stays whole-object per slot — block tables
    have no analog for encoder state that never grows.
  * ``EncDecBackend``    — paired layout for enc-dec (whisper) models
    (DESIGN.md §11): a growing decoder self-KV region per slot (the
    contiguous machinery, keyed ``self_k``/``self_v``) PAIRED with
    whole-object per-slot cross state — ``cross_k``/``cross_v``
    ``(L, B, S_enc, H, hd)`` and a per-slot ``enc_len`` (B,) vector (the
    seed's scalar ``enc_len`` cannot batch sessions with different
    encoder lengths). Reservation/occupancy accounting is the
    contiguous slot model over decoder positions, so admission,
    back-pressure and PAUSED eviction work unchanged.

Consumers all go through a slot-bound ``CacheView`` handle:

    view.write_layer(row, k, v)   one restored layer (whole pages)
    view.write_kv(k, v, start)    stacked prefill KV at a token offset
    view.write_states(piece)      SSM / cross-attention whole objects
    view.gather_hist(hist)        restored-history KV for chunked prefill
    view.snapshot()               B=1 restorable dict for save_session_pause
    view.set_length(n)            live-length bookkeeping
    view.free()                   release the slot's pages (retire/evict)

``ViewSink`` adapts a ``CacheView`` to the restoration executor's
``RestoreSink`` protocol — the sink is layout-agnostic; the paged backend
lands restored layers as whole pages, the contiguous one as a donated
``dynamic_update_slice``. Decode runs through ``backend.decode`` (the
paged path gathers pages by block table inside the jitted step — see
``transformer.lm_decode_step_paged`` and the Pallas kernel in
``kernels/decode_attention.py``).

Greedy equivalence: masked attention probabilities are exactly zero past
the live length, so a paged gather at the same logical width is
byte-identical to the contiguous layout (tested in tests/test_paged.py).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.restoration import RestoreSink, s_bucket
from repro.distributed import tp as tp_lib
from repro.models.model import Model


def _colocate(val, buf):
    """Bring a committed multi-device array (the SPMD restoration
    projection's output under tensor parallelism) onto the target
    buffer's device before a single-device donated update. This is the
    one deliberate gather on the TP restore path: it fires only for
    backends whose decode is unsharded (contiguous / hybrid), where the
    projected heads must land in one place anyway. Uncommitted and
    single-device values pass through untouched."""
    if isinstance(val, jax.Array) and len(val.sharding.device_set) > 1 \
            and len(buf.sharding.device_set) == 1:
        return jax.device_put(val, next(iter(buf.sharding.device_set)))
    return val


@dataclasses.dataclass
class OccupancyStats:
    """Gauges for EngineMetrics / bench_paged: how much of the reserved
    cache capacity holds live tokens."""

    live_tokens: int            # tokens of occupied slots (sum of lengths)
    reserved_tokens: int        # capacity handed out to occupied slots
    capacity_tokens: int        # total backend capacity
    free_blocks: int            # paged: free pages; contiguous: free slots

    @property
    def utilization(self) -> float:
        """live / reserved — 1.0 means no internal fragmentation."""
        return (self.live_tokens / self.reserved_tokens
                if self.reserved_tokens else 0.0)

    @property
    def fragmentation(self) -> float:
        return 1.0 - self.utilization if self.reserved_tokens else 0.0


class BlockAllocator:
    """Refcounted LIFO free list over ``num_blocks`` physical pages (LIFO
    so pages freed by an eviction are immediately reused — cache-warm on
    real hardware, and deterministic for the reuse tests).

    Pages are reference counted so several block-table rows (and the
    prefix index) may map the same physical page: ``alloc`` hands a page
    out at refcount 1, ``incref`` adds a holder, and ``free`` drops one
    holder per page — the page returns to the free list only when its
    last holder releases it. Freeing a page that has no live holders
    raises instead of silently corrupting the free list (a double free
    used to append the page twice, letting the allocator grant the same
    physical page to two sessions)."""

    def __init__(self, num_blocks: int):
        self.num_blocks = num_blocks
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._ref: List[int] = [0] * num_blocks

    @property
    def free_count(self) -> int:
        return len(self._free)

    def refcount(self, block: int) -> int:
        return self._ref[block]

    def alloc(self, n: int) -> Optional[List[int]]:
        """``n`` pages, or None when the pool cannot satisfy the request
        (callers treat None as admission backpressure — never a partial
        grant)."""
        if n < 0 or n > len(self._free):
            return None
        taken = [self._free.pop() for _ in range(n)]
        for b in taken:
            self._ref[b] = 1
        return taken

    def incref(self, block: int) -> None:
        if self._ref[block] <= 0:
            raise RuntimeError(
                f"incref of unallocated page {block} (refcount "
                f"{self._ref[block]}) — sharing a page that is already "
                f"on the free list")
        self._ref[block] += 1

    def free(self, blocks: Sequence[int]) -> None:
        """Drop one holder per page; a page with no remaining holders
        returns to the free list (reversed, preserving LIFO reuse
        order for the common unshared case)."""
        for b in reversed(list(blocks)):
            if self._ref[b] <= 0:
                raise RuntimeError(
                    f"double free of page {b}: page is already free "
                    f"(refcount {self._ref[b]})")
            self._ref[b] -= 1
            if self._ref[b] == 0:
                self._free.append(b)


# -------------------------------------------------------------------- views
class CacheView:
    """Slot-bound handle; the only way engine/restoration/save code
    touches cache state."""

    def write_layer(self, row: int, k, v, start: int = 0) -> None:
        """One attention layer's restored KV at tokens [start, start+n);
        k, v: (1, n, Kv, hd); row indexes the stacked-KV buffer. A
        nonzero ``start`` is the restore-skip path: tokens [0, start)
        are already resident via a shared prefix (DESIGN.md §12)."""
        raise NotImplementedError

    def write_layer_group(self, rows: Sequence[int], k, v,
                          start: int = 0) -> None:
        """A whole restoration group's KV in one scatter; rows are
        stacked-KV buffer rows, k/v: (G, 1, n, Kv, hd). Default falls
        back to per-layer writes; both backends override with a single
        donated device call (DESIGN.md §10)."""
        for g, row in enumerate(rows):
            self.write_layer(row, k[g], v[g], start)

    def write_kv(self, k, v, start: int) -> None:
        """Stacked prefill KV (L, 1, n, Kv, hd) at token offset start."""
        raise NotImplementedError

    def write_states(self, piece: dict) -> None:
        """Whole-object pieces: conv/ssm states, cross KV, enc_len."""
        raise NotImplementedError

    def gather_hist(self, hist: int):
        """Restored-history KV, stacked (L, 1, hist, Kv, hd) pair."""
        raise NotImplementedError

    def cross_state(self):
        """Enc-dec only: the slot's live cross context — (cross_k,
        cross_v) stacked (L, 1, enc_len, H, hd) plus enc_len."""
        raise NotImplementedError

    def snapshot(self) -> dict:
        """B=1 restorable dict (what ``save_session_pause`` dumps); KV
        buffers cover at least the slot's live length."""
        raise NotImplementedError

    def set_length(self, n: int) -> None:
        raise NotImplementedError

    def free(self) -> None:
        """Release the slot's reserved capacity (retire / mid-stream
        eviction). The view must not be used afterwards."""
        raise NotImplementedError


class ViewSink(RestoreSink):
    """Layout-agnostic RestoreSink: every restored piece goes through the
    CacheView, so the executor neither knows nor cares whether the slot
    is contiguous or paged (pages land whole)."""

    def __init__(self, view: CacheView):
        self.view = view

    def put_kv(self, row, k, v, start=0):
        self.view.write_layer(row, k, v, start)

    def put_kv_group(self, rows, k, v, start=0):
        self.view.write_layer_group(rows, k, v, start)

    def put_states(self, conv, ssm):
        self.view.write_states({"conv": conv, "ssm": ssm})

    def put_cross(self, ck, cv, enc_len):
        self.view.write_states({"cross_k": ck, "cross_v": cv,
                                "enc_len": jnp.asarray(enc_len, jnp.int32)})

    def finish(self, n_tokens):
        self.view.set_length(n_tokens)


# ----------------------------------------------------------------- backends
class KVCacheBackend:
    """Owns all decode-cache state for the engine's ``max_batch`` slots."""

    name = "backend"

    def view(self, slot: int) -> CacheView:
        raise NotImplementedError

    def can_reserve(self, n_tokens: int) -> bool:
        """Admission backpressure check: could a slot hold ``n_tokens``?"""
        raise NotImplementedError

    def reserve(self, slot: int, n_tokens: int) -> bool:
        """Bind capacity for up to ``n_tokens`` to ``slot``. False means
        the pool is exhausted (the caller must requeue, not proceed)."""
        raise NotImplementedError

    def free_slot(self, slot: int) -> None:
        raise NotImplementedError

    def decode(self, params, tokens):
        """One batched decode step; advances every slot's length by one.
        Returns (logits, per-layer hidden states)."""
        raise NotImplementedError

    def get_lengths(self) -> np.ndarray:
        raise NotImplementedError

    def set_lengths(self, lengths: np.ndarray) -> None:
        raise NotImplementedError

    def set_length(self, slot: int, n: int) -> None:
        raise NotImplementedError

    def occupancy(self) -> OccupancyStats:
        raise NotImplementedError

    def device_occupancy(self) -> List[dict]:
        """Per-device gauges for EngineMetrics (DESIGN.md §16): one row
        per mesh device — single-device backends report one row. Keys:
        ``device``, ``free_pages``, ``occupancy_pct`` (reserved capacity
        in use), ``util_pct`` (live tokens / reserved capacity)."""
        occ = self.occupancy()
        pct = int(round(100.0 * occ.reserved_tokens
                        / max(occ.capacity_tokens, 1)))
        return [{"device": 0, "free_pages": int(occ.free_blocks),
                 "occupancy_pct": pct,
                 "util_pct": int(round(100.0 * occ.utilization))}]


# ------------------------------------------------------------- contiguous
class _ContiguousView(CacheView):
    def __init__(self, backend: "ContiguousBackend", slot: int):
        self.b = backend
        self.slot = slot

    def write_layer(self, row, k, v, start=0):
        b = self.b
        k_name, v_name = b.model.adapter.kv_names
        row = jnp.asarray(row)              # traced: no recompile per row
        slot = jnp.asarray(self.slot)
        for name, val in ((k_name, k), (v_name, v)):
            buf = b.cache[name]
            val = jnp.asarray(_colocate(val, buf), buf.dtype)[None]
            b.cache[name] = b._slot_update(buf, val, row, slot,
                                           jnp.asarray(start))

    def write_layer_group(self, rows, k, v, start=0):
        b = self.b
        k_name, v_name = b.model.adapter.kv_names
        kbuf, vbuf = b.cache[k_name], b.cache[v_name]
        b.cache[k_name], b.cache[v_name] = b._group_update(
            kbuf, vbuf,
            jnp.asarray(_colocate(k, kbuf), kbuf.dtype)[:, 0],  # (G,n,Kv,hd)
            jnp.asarray(_colocate(v, vbuf), vbuf.dtype)[:, 0],
            jnp.asarray(np.asarray(rows, np.int32)),
            jnp.asarray(self.slot), jnp.asarray(start))

    def write_kv(self, k, v, start):
        b = self.b
        k_name, v_name = b.model.adapter.kv_names
        for name, val in ((k_name, k), (v_name, v)):
            b.cache[name] = jax.lax.dynamic_update_slice(
                b.cache[name], val.astype(b.cache[name].dtype),
                (0, self.slot, start, 0, 0))

    def write_states(self, piece):
        # conv/ssm recurrent states only — enc-dec cross state lives in
        # _EncDecView (this backend is decoder-only: lm / ssm / hybrid)
        b, slot = self.b, self.slot
        for key, val in piece.items():
            buf = b.cache.get(key)
            if buf is None or key not in ("conv", "ssm"):
                continue
            val = jnp.asarray(val, buf.dtype)
            bdim = buf.ndim - val.ndim + 1  # batch dim position
            b.cache[key] = jax.lax.dynamic_update_slice(
                buf, val, (0,) * (bdim - 1) + (slot,)
                + (0,) * (buf.ndim - bdim))

    def gather_hist(self, hist):
        k_name, v_name = self.b.model.adapter.kv_names
        i = self.slot
        return (self.b.cache[k_name][:, i:i + 1, :hist],
                self.b.cache[v_name][:, i:i + 1, :hist])

    def snapshot(self):
        b, i = self.b, self.slot
        cache_slice = {k: (v[:, i:i + 1] if k in
                           ("k", "v", "attn_k", "attn_v") else v)
                       for k, v in b.cache.items()
                       if k not in ("lengths", "enc_len")}
        if b.model.kind in ("ssm", "hybrid"):
            cache_slice["conv"] = b._slot_state(b.cache["conv"], i)
            cache_slice["ssm"] = b._slot_state(b.cache["ssm"], i)
        return cache_slice

    def set_length(self, n):
        self.b.set_length(self.slot, n)

    def free(self):
        self.b.free_slot(self.slot)


class ContiguousBackend(KVCacheBackend):
    """The seed layout: ``max_seq`` contiguous positions per slot. Every
    model family; a slot reservation always costs ``max_seq`` capacity
    regardless of the session's true length."""

    name = "contiguous"

    def __init__(self, model: Model, max_batch: int, max_seq: int):
        self.model = model
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.cache = self._make_cache()
        self._reserved = [0] * max_batch
        self._decode_fn = jax.jit(model.decode_step_full)
        # donated so XLA updates the stacked KV buffer in place — a
        # per-layer restore write must not copy the whole (L,B,S,H,hd)
        # cache (retraces only per distinct restored length n). ``start``
        # is traced: restore-skip lands a suffix at the divergence token
        # without a new compile per offset
        self._slot_update = jax.jit(
            lambda buf, val, row, slot, start: jax.lax.dynamic_update_slice(
                buf, val, (row, slot, start, 0, 0)),
            donate_argnums=(0,))
        # grouped restore write: a whole projection group's K and V land
        # in one donated scatter (rows and start traced, so group
        # membership / token offset never retrace; retraces only per
        # distinct restored length n). Scatter grid rather than basic
        # slicing because the token offset is traced.
        def _gupd(kbuf, vbuf, kval, vval, rows, slot, start):
            pos = start + jnp.arange(kval.shape[1])
            return (kbuf.at[rows[:, None], slot, pos[None, :]].set(kval),
                    vbuf.at[rows[:, None], slot, pos[None, :]].set(vval))
        self._group_update = jax.jit(_gupd, donate_argnums=(0, 1))

    def _make_cache(self):
        return self.model.init_cache(self.max_batch, self.max_seq)

    def _slot_state(self, buf, slot):
        """Extract the batch=1 slice of a (…, B, …) state tensor."""
        if self.model.kind == "ssm":
            return buf[:, slot:slot + 1]
        return buf[:, :, slot:slot + 1]

    def view(self, slot):
        return _ContiguousView(self, slot)

    def can_reserve(self, n_tokens):
        # a free slot always implies a full max_seq reservation; sessions
        # longer than max_seq were never servable under this layout
        return True

    def reserve(self, slot, n_tokens):
        self._reserved[slot] = self.max_seq
        return True

    def free_slot(self, slot):
        self._reserved[slot] = 0

    def decode(self, params, tokens):
        lg, self.cache, hidden = self._decode_fn(params, self.cache, tokens)
        return lg, hidden

    def get_lengths(self):
        return np.array(self.cache["lengths"], copy=True)

    def set_lengths(self, lengths):
        self.cache["lengths"] = jnp.asarray(lengths, jnp.int32)

    def set_length(self, slot, n):
        self.cache["lengths"] = self.cache["lengths"].at[slot].set(n)

    def occupancy(self):
        lengths = np.asarray(self.cache["lengths"])
        live = int(sum(int(lengths[i]) for i, r in enumerate(self._reserved)
                       if r))
        reserved = int(sum(self._reserved))
        free_slots = sum(1 for r in self._reserved if not r)
        return OccupancyStats(live, reserved, self.max_batch * self.max_seq,
                              free_slots)


# ----------------------------------------------------------------- encdec
class _CrossStateMixin:
    """Cross-context handling shared by both enc-dec views: the cross
    buffers are per-slot whole objects regardless of how the decoder
    self-KV region is laid out (contiguous slots or pages). The backend
    provides ``cache['cross_k'/'cross_v'/'enc_len']``, ``enc_seq``,
    ``enc_len_np`` and the donated ``_cross_update``."""

    def write_states(self, piece):
        b, slot = self.b, self.slot
        for key, val in piece.items():
            if key in ("cross_k", "cross_v"):
                buf = b.cache[key]
                val = jnp.asarray(val, buf.dtype)
                n = val.shape[2]
                if n > b.enc_seq:
                    # admission gates count decoder positions only — an
                    # oversized encoder context must fail loudly here,
                    # not as an opaque shape error inside the update
                    raise ValueError(
                        f"encoder context of {n} frames "
                        f"exceeds the backend's enc_seq={b.enc_seq}; "
                        f"raise --enc-seq (or InferenceEngine(enc_seq=))")
                # pad the encoder dim to its power-of-two bucket (same
                # rule as the restoration projections) so varied audio
                # lengths share one compiled donated update; the zero
                # tail sits beyond enc_len and is masked everywhere
                cap = min(s_bucket(max(n, 1)), b.enc_seq)
                if cap > n:
                    val = jnp.pad(val, ((0, 0), (0, 0), (0, cap - n),
                                        (0, 0), (0, 0)))
                b.cache[key] = b._cross_update(buf, val,
                                               jnp.asarray(slot))
            elif key == "enc_len":
                n = int(val)
                b.cache["enc_len"] = b.cache["enc_len"].at[slot].set(n)
                b.enc_len_np[slot] = n

    def cross_state(self):
        b, i = self.b, self.slot
        n = int(b.enc_len_np[i])
        return (b.cache["cross_k"][:, i:i + 1, :n],
                b.cache["cross_v"][:, i:i + 1, :n], n)


class _EncDecView(_CrossStateMixin, _ContiguousView):
    """Self-KV writes/gathers ride the contiguous machinery (keys
    ``self_k``/``self_v`` via the adapter); cross state is per-slot."""

    def snapshot(self):
        # self-KV only: the cross context restores from the session's
        # persisted encoder blob ('enc'), saved at first prefill — a
        # pause never has to dump the (large) cross buffers
        b, i = self.b, self.slot
        return {"self_k": b.cache["self_k"][:, i:i + 1],
                "self_v": b.cache["self_v"][:, i:i + 1]}

    def free(self):
        b, i = self.b, self.slot
        b.enc_len_np[i] = 0
        b.cache["enc_len"] = b.cache["enc_len"].at[i].set(0)
        super().free()


class EncDecBackend(ContiguousBackend):
    """Paired self/cross cache for enc-dec models (DESIGN.md §11).

    The decoder self-KV region is the contiguous layout over ``max_seq``
    decoder positions per slot. The cross context is whole-object
    per-slot state: ``cross_k``/``cross_v`` hold up to ``enc_seq``
    encoder positions, with a per-slot ``enc_len`` (B,) so sessions with
    different encoder lengths batch together (the seed cache's scalar
    ``enc_len`` could not). Decode runs the family decode step — the
    (B,) ``enc_len`` broadcasts through the cross-attention mask."""

    name = "encdec"

    def __init__(self, model: Model, max_batch: int, max_seq: int, *,
                 enc_seq: Optional[int] = None):
        if model.kind != "encdec":
            raise NotImplementedError(
                f"the encdec KV cache requires an encoder-decoder model; "
                f"{model.cfg.name} is {model.kind!r}")
        self.enc_seq = int(enc_seq or max_seq)
        super().__init__(model, max_batch, max_seq)
        self.enc_len_np = np.zeros((max_batch,), np.int64)
        # donated in-place cross write (slot traced): the cross buffers
        # are the backend's largest tensors at real whisper scale, so a
        # first-residency prefill / restore must not copy them whole —
        # same rule as the self-KV _slot_update above; retraces only per
        # distinct encoder length
        self._cross_update = jax.jit(
            lambda buf, val, slot: jax.lax.dynamic_update_slice(
                buf, val, (0, slot, 0, 0, 0)),
            donate_argnums=(0,))

    def _make_cache(self):
        c = self.model.cfg
        L, H, hd = c.n_layers, c.n_heads, c.head_dim_

        def kv(S):
            return jnp.zeros((L, self.max_batch, S, H, hd),
                             self.model.dtype)

        return {"self_k": kv(self.max_seq), "self_v": kv(self.max_seq),
                "cross_k": kv(self.enc_seq), "cross_v": kv(self.enc_seq),
                "enc_len": jnp.zeros((self.max_batch,), jnp.int32),
                "lengths": jnp.zeros((self.max_batch,), jnp.int32)}

    def view(self, slot):
        return _EncDecView(self, slot)


# ------------------------------------------------------------------ paged
class _PagedView(CacheView):
    def __init__(self, backend: "PagedBackend", slot: int):
        self.b = backend
        self.slot = slot

    def _addr(self, positions: np.ndarray):
        """(physical block ids, in-block offsets) for logical positions."""
        b = self.b
        row = b.table_np[self.slot]
        return (jnp.asarray(row[positions // b.block_size]),
                jnp.asarray(positions % b.block_size))

    def write_layer(self, row, k, v, start=0):
        b = self.b
        n = k.shape[1]
        positions = start + np.arange(n)
        b._ensure_private(self.slot, positions // b.block_size)
        blk, off = self._addr(positions)
        row = jnp.asarray(row)
        for name, val in (("k_pool", k), ("v_pool", v)):
            pool = b.cache[name]
            val = b._place_kv(jnp.asarray(val, pool.dtype)[0], 1)  # (n,Kv,hd)
            b.cache[name] = b._write_layer(pool, val, row, blk, off)

    def write_layer_group(self, rows, k, v, start=0):
        b = self.b
        n = k.shape[2]
        positions = start + np.arange(n)
        b._ensure_private(self.slot, positions // b.block_size)
        blk, off = self._addr(positions)
        kp, vp = b.cache["k_pool"], b.cache["v_pool"]
        b.cache["k_pool"], b.cache["v_pool"] = b._write_group(
            kp, vp,
            b._place_kv(jnp.asarray(k, kp.dtype)[:, 0], 2),  # (G, n, Kv, hd)
            b._place_kv(jnp.asarray(v, vp.dtype)[:, 0], 2),
            jnp.asarray(np.asarray(rows, np.int32)), blk, off)

    def write_kv(self, k, v, start):
        b = self.b
        n = k.shape[2]
        positions = start + np.arange(n)
        b._ensure_private(self.slot, positions // b.block_size)
        blk, off = self._addr(positions)
        for name, val in (("k_pool", k), ("v_pool", v)):
            pool = b.cache[name]
            # (L, n, Kv, hd) lands at [:, blk[i], off[i]] per token
            val = b._place_kv(
                jnp.asarray(val, pool.dtype)[:, 0], 2)
            b.cache[name] = pool.at[:, blk, off].set(val)

    def write_states(self, piece):
        raise NotImplementedError(
            "the paged backend holds attention-history KV only; SSM "
            "state has no block-table analog — use backend='contiguous' "
            "for ssm/hybrid (enc-dec cross state pages via the "
            "paged-encdec pairing)")

    def gather_hist(self, hist):
        # _finish_gather is the sharded backend's seam back into
        # single-device code: the gathered history feeds the unsharded
        # prefill program, so it must leave the mesh here (identity on
        # the single-device backend)
        b = self.b
        nb = -(-hist // b.block_size)
        blocks = jnp.asarray(b.table_np[self.slot][:nb])
        k = b.cache["k_pool"][:, blocks]          # (L, nb, bs, Kv, hd)
        v = b.cache["v_pool"][:, blocks]
        L = k.shape[0]
        shp = (L, 1, nb * b.block_size) + k.shape[3:]
        return (b._finish_gather(k.reshape(shp)[:, :, :hist]),
                b._finish_gather(v.reshape(shp)[:, :, :hist]))

    def snapshot(self):
        b = self.b
        k_name, v_name = b.model.adapter.kv_names
        blocks = jnp.asarray(b.slot_blocks[self.slot], jnp.int32)
        k = b.cache["k_pool"][:, blocks]
        v = b.cache["v_pool"][:, blocks]
        L = k.shape[0]
        shp = (L, 1, len(b.slot_blocks[self.slot]) * b.block_size) \
            + k.shape[3:]
        return {k_name: b._finish_gather(k.reshape(shp)),
                v_name: b._finish_gather(v.reshape(shp))}

    def set_length(self, n):
        self.b.set_length(self.slot, n)

    def free(self):
        self.b.free_slot(self.slot)


class PagedBackend(KVCacheBackend):
    """Block-table paged KV cache (ROADMAP "paged KV cache").

    Physical pages ``(L, num_blocks, block_size, Kv, hd)`` are shared by
    all slots; ``block_table[slot, j]`` maps a slot's logical page *j* to
    a physical page (entries == ``num_blocks`` are unallocated
    sentinels: decode-step scatter drops them, gathers clamp them and the
    attention mask zeroes whatever they alias). Reservations are made in
    whole pages for the session's worst-case final length, so admission
    is bounded by actual need, not ``max_batch × max_seq``.
    """

    name = "paged"

    def __init__(self, model: Model, max_batch: int, max_seq: int, *,
                 block_size: int = 16, num_blocks: Optional[int] = None):
        if not model.adapter.supports_paged:
            raise NotImplementedError(
                f"paged KV cache requires an attention-history model "
                f"(lm, or enc-dec decoder self-KV); {model.cfg.name} "
                f"is {model.kind!r}")
        self.model = model
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.block_size = block_size
        self.blocks_per_seq = -(-max_seq // block_size)
        self.num_blocks = (max_batch * self.blocks_per_seq
                           if num_blocks is None else num_blocks)
        self.cache = model.init_paged_cache(max_batch, self.num_blocks,
                                            block_size, self.blocks_per_seq)
        self.table_np = np.asarray(self.cache["block_table"]).copy()
        self.allocator = BlockAllocator(self.num_blocks)
        self.slot_blocks: List[List[int]] = [[] for _ in range(max_batch)]
        # set by the engine when --prefix-sharing is on: pages held by the
        # index are reclaimable under pressure (see _alloc_pages)
        self.prefix_index = None
        self.cow_copies = 0
        self._decode_fn = jax.jit(model.decode_step_paged)
        # donated in-place page scatter, retraced per restored length n
        self._write_layer = jax.jit(
            lambda pool, val, row, blk, off:
            pool.at[row, blk, off].set(val),
            donate_argnums=(0,))
        # grouped restore write: every member layer's whole pages land
        # in one donated scatter (rows (G,) × token addresses (n,)
        # broadcast to a (G, n) scatter grid)
        self._write_group = jax.jit(
            lambda kp, vp, kval, vval, rows, blk, off:
            (kp.at[rows[:, None], blk[None, :], off[None, :]].set(kval),
             vp.at[rows[:, None], blk[None, :], off[None, :]].set(vval)),
            donate_argnums=(0, 1))
        # copy-on-write page clone: one physical page (all layers) copied
        # inside the donated pool update; dst/src traced so divergence at
        # any page never retraces
        self._copy_page = jax.jit(
            lambda pool, dst, src: pool.at[:, dst].set(pool[:, src]),
            donate_argnums=(0,))

    def _push_table(self) -> None:
        self.cache["block_table"] = jnp.asarray(self.table_np)

    def _finish_gather(self, x):
        """Seam for host-bound / single-device consumers of pool gathers
        (chunked-prefill history, pause snapshots). Identity here; the
        sharded backend collects the head shards onto one device."""
        return x

    def _place_kv(self, val, kv_axis: int):
        """Placement seam for values entering the pool. Identity here;
        the sharded backend reshards them to the pool's head sharding —
        prefill KV arrives committed to the prefill device and a
        committed single-device array cannot join a multi-device scatter
        (restored KV from the SPMD projection is already head-sharded,
        so its device_put is a no-op)."""
        return val

    def view(self, slot):
        return _PagedView(self, slot)

    # ---------------------------------------------- CoW page sharing
    def _alloc_pages(self, n: int) -> Optional[List[int]]:
        """Allocator grant, spilling LRU prefix-index pages on shortfall
        (index-held pages are a cache, never a reservation)."""
        got = self.allocator.alloc(n)
        if got is None and self.prefix_index is not None:
            short = n - self.allocator.free_count
            if self.prefix_index.release(short) > 0:
                got = self.allocator.alloc(n)
        return got

    def _ensure_private(self, slot: int, logical_pages) -> None:
        """CoW barrier: every listed logical page of ``slot`` that maps a
        shared physical page (refcount > 1) is copied to a fresh private
        page before the caller writes through it. Copies only the pages
        actually written — the rest of the prefix stays shared."""
        blks = self.slot_blocks[slot]
        touched = False
        for lp in sorted(set(int(p) for p in np.atleast_1d(logical_pages))):
            if lp >= len(blks) or self.allocator.refcount(blks[lp]) <= 1:
                continue
            fresh = self._alloc_pages(1)
            if fresh is None:
                raise RuntimeError(
                    "page pool exhausted during copy-on-write divergence "
                    "(no free page to privatize a shared page); raise "
                    "cache_blocks or lower concurrency")
            dst = fresh[0]
            src = blks[lp]
            d, s = jnp.asarray(dst), jnp.asarray(src)
            for name in ("k_pool", "v_pool"):
                self.cache[name] = self._copy_page(self.cache[name], d, s)
            self.allocator.free([src])          # drop this slot's hold
            blks[lp] = dst
            self.table_np[slot, lp] = dst
            self.cow_copies += 1
            touched = True
        if touched:
            self._push_table()

    def adopt_shared(self, slot: int, blocks: Sequence[int], *,
                     owned: bool = False) -> None:
        """Map an already-populated shared page run as the slot's logical
        prefix (prefix-index hit or fork adoption). ``owned=False``
        increfs each page (the donor keeps its hold); ``owned=True``
        transfers holds that the caller already owns (parked fork pages).
        Must run before ``reserve`` tops the row up with private pages."""
        if self.slot_blocks[slot]:
            raise RuntimeError(f"adopt_shared on a non-empty slot {slot}")
        blocks = [int(b) for b in blocks]
        if not owned:
            for b in blocks:
                self.allocator.incref(b)
        self.slot_blocks[slot] = list(blocks)
        row = self.table_np[slot]
        row[:] = self.num_blocks
        row[:len(blocks)] = blocks
        self._push_table()

    def release_blocks(self, blocks: Sequence[int]) -> None:
        """Drop caller-owned holds not bound to any slot (e.g. parked
        fork pages that will never be adopted)."""
        self.allocator.free(list(blocks))

    def shared_page_stats(self):
        """(shared, private) physical page counts for the gauges: a page
        is shared when more than one holder maps it."""
        shared = private = 0
        for b in range(self.num_blocks):
            r = self.allocator.refcount(b)
            if r > 1:
                shared += 1
            elif r == 1:
                private += 1
        return shared, private

    def _blocks_needed(self, n_tokens: int) -> int:
        need = max(-(-max(n_tokens, 1) // self.block_size), 1)
        # a session whose worst case exceeds max_seq (or the whole pool)
        # gets at most one full table row — matching the contiguous
        # layout, where overflow decode writes past the reservation are
        # silently dropped rather than crashing or wedging admission
        return min(need, self.blocks_per_seq, self.num_blocks)

    def can_reserve(self, n_tokens):
        avail = self.allocator.free_count
        if self.prefix_index is not None:
            avail += self.prefix_index.releasable()
        return self._blocks_needed(n_tokens) <= avail

    def reserve(self, slot, n_tokens):
        need = self._blocks_needed(n_tokens)
        have = self.slot_blocks[slot]
        if len(have) >= need:
            return True
        blocks = self._alloc_pages(need - len(have))
        if blocks is None:
            return False
        have.extend(blocks)
        row = self.table_np[slot]
        row[:] = self.num_blocks
        row[:len(have)] = have
        self._push_table()
        return True

    def free_slot(self, slot):
        self.allocator.free(self.slot_blocks[slot])
        self.slot_blocks[slot] = []
        self.table_np[slot, :] = self.num_blocks
        self._push_table()
        self.cache["lengths"] = self.cache["lengths"].at[slot].set(0)

    def decode(self, params, tokens):
        # CoW barrier before the batched scatter: the step writes one
        # token at lengths[slot] for EVERY occupied slot (the engine
        # rolls scratch writes back) — each slot's frontier page must be
        # private or the write would leak into a sibling's shared prefix
        lengths = np.asarray(self.cache["lengths"])
        for slot, blks in enumerate(self.slot_blocks):
            if blks:
                self._ensure_private(slot, [int(lengths[slot])
                                            // self.block_size])
        lg, self.cache, hidden = self._decode_fn(params, self.cache, tokens)
        return lg, hidden

    def get_lengths(self):
        return np.array(self.cache["lengths"], copy=True)

    def set_lengths(self, lengths):
        self.cache["lengths"] = jnp.asarray(lengths, jnp.int32)

    def set_length(self, slot, n):
        self.cache["lengths"] = self.cache["lengths"].at[slot].set(n)

    def occupancy(self):
        lengths = np.asarray(self.cache["lengths"])
        live = int(sum(int(lengths[i])
                       for i, blks in enumerate(self.slot_blocks) if blks))
        reserved = sum(len(b) for b in self.slot_blocks) * self.block_size
        return OccupancyStats(live, reserved,
                              self.num_blocks * self.block_size,
                              self.allocator.free_count)


# -------------------------------------------------------------- sharded
class DeviceAllocatorView:
    """Read-only per-device window onto the shared ``BlockAllocator``.

    Head-sharding replicates the page STRUCTURE: every mesh device holds
    the same page ids (1/tp of each page's bytes), so the free list and
    refcounts are common state — a per-device allocator would desync the
    block tables. The view therefore proxies the shared counts and
    scales only byte-denominated gauges by its shard."""

    def __init__(self, backend: "ShardedPagedBackend", device: int):
        self.b = backend
        self.device = device

    @property
    def num_blocks(self) -> int:
        return self.b.allocator.num_blocks

    @property
    def free_count(self) -> int:
        return self.b.allocator.free_count

    def refcount(self, block: int) -> int:
        return self.b.allocator.refcount(block)

    def pool_bytes(self) -> int:
        total = sum(int(self.b.cache[n].nbytes)
                    for n in ("k_pool", "v_pool"))
        return total // max(self.b.shards, 1)


class ShardedPagedBackend(PagedBackend):
    """Tensor-parallel paged backend (DESIGN.md §16).

    The physical page pool ``(L, NB, bs, Kv, hd)`` is committed sharded
    on the KV-head axis (3) over the TP mesh; block tables and lengths
    are replicated. Every jitted cache update — decode's token scatter,
    the restore sink's grouped page write, the CoW page clone — indexes
    only layer/page/offset axes, so under SPMD each device writes its
    own head slice with zero cross-device traffic; decode's one
    collective is the all-gather at the output-projection seam
    (``tp.logits_seam``). Allocator / table / CoW bookkeeping is Python
    over replicated structure: exactly the single-device code."""

    name = "paged-tp"

    def __init__(self, model: Model, max_batch: int, max_seq: int, *,
                 block_size: int = 16, num_blocks: Optional[int] = None,
                 tp_ctx: Optional[tp_lib.TPContext] = None):
        self.tp = tp_ctx if tp_ctx is not None else tp_lib.TPContext(1)
        self.shards = self.tp.tp if self.tp.spmd else 1
        if self.tp.spmd:
            self.tp.validate_heads(model.cfg.n_kv_heads)
        super().__init__(model, max_batch, max_seq, block_size=block_size,
                         num_blocks=num_blocks)
        if self.tp.spmd:
            sh = self.tp.kv_sharding(5, 3)
            for name in ("k_pool", "v_pool"):
                self.cache[name] = jax.device_put(self.cache[name], sh)
            for name in ("block_table", "lengths"):
                self.cache[name] = self.tp.replicate(self.cache[name])

    def _push_table(self):
        self.cache["block_table"] = self.tp.replicate(
            jnp.asarray(self.table_np))

    def set_lengths(self, lengths):
        self.cache["lengths"] = self.tp.replicate(
            jnp.asarray(lengths, jnp.int32))

    def _finish_gather(self, x):
        return self.tp.unshard(x)

    def _place_kv(self, val, kv_axis):
        return self.tp.shard_kv(val, kv_axis)

    def decode(self, params, tokens):
        # the seam context makes the jitted step constrain the pool
        # sharded and the attention output replicated — the same traced
        # program as tp=1 when the context is inactive
        with tp_lib.tp_seam(self.tp):
            return super().decode(params, tokens)

    def device_views(self) -> List[DeviceAllocatorView]:
        return [DeviceAllocatorView(self, d) for d in range(self.shards)]

    def device_occupancy(self):
        base = super().device_occupancy()[0]
        rows = []
        for view in self.device_views():
            row = dict(base)
            row["device"] = view.device
            row["pool_bytes"] = view.pool_bytes()
            rows.append(row)
        return rows


# -------------------------------------------------------- paged enc-dec
class _PagedEncDecView(_CrossStateMixin, _PagedView):
    """Decoder self-KV pages through the pool (keys ``self_k``/``self_v``
    in snapshots via the adapter); cross state is whole-object per slot,
    exactly the contiguous enc-dec pairing."""


class PagedEncDecBackend(PagedBackend):
    """Paged decoder self-KV + whole-object cross state (ROADMAP "paged
    KV for the enc-dec family").

    The self-KV region — the part that grows with decoded tokens —
    rides the page pool, so admission is bounded by actual decoder need
    and PAUSED eviction frees pages. The cross context never grows after
    the encoder runs, so it keeps the per-slot ``cross_k``/``cross_v``
    buffers and (B,) ``enc_len`` of ``EncDecBackend`` — there is no
    block-table analog for state with no append frontier."""

    name = "paged-encdec"

    def __init__(self, model: Model, max_batch: int, max_seq: int, *,
                 block_size: int = 16, num_blocks: Optional[int] = None,
                 enc_seq: Optional[int] = None):
        if model.kind != "encdec":
            raise NotImplementedError(
                f"the paged enc-dec KV cache requires an encoder-decoder "
                f"model; {model.cfg.name} is {model.kind!r}")
        self.enc_seq = int(enc_seq or max_seq)
        super().__init__(model, max_batch, max_seq, block_size=block_size,
                         num_blocks=num_blocks)
        c = model.cfg
        kv = jnp.zeros((c.n_layers, max_batch, self.enc_seq, c.n_heads,
                        c.head_dim_), model.dtype)
        self.cache["cross_k"] = kv
        self.cache["cross_v"] = jnp.zeros_like(kv)
        self.cache["enc_len"] = jnp.zeros((max_batch,), jnp.int32)
        self.enc_len_np = np.zeros((max_batch,), np.int64)
        # donated in-place cross write (slot traced) — see EncDecBackend
        self._cross_update = jax.jit(
            lambda buf, val, slot: jax.lax.dynamic_update_slice(
                buf, val, (0, slot, 0, 0, 0)),
            donate_argnums=(0,))

    def view(self, slot):
        return _PagedEncDecView(self, slot)

    def free_slot(self, slot):
        self.enc_len_np[slot] = 0
        self.cache["enc_len"] = self.cache["enc_len"].at[slot].set(0)
        super().free_slot(slot)


BACKENDS = {"contiguous": ContiguousBackend, "paged": PagedBackend,
            "encdec": EncDecBackend, "paged-tp": ShardedPagedBackend,
            "paged-encdec": PagedEncDecBackend}


def make_backend(spec: Union[str, KVCacheBackend], model: Model,
                 max_batch: int, max_seq: int, *, block_size: int = 16,
                 num_blocks: Optional[int] = None,
                 enc_seq: Optional[int] = None,
                 tp: Optional[tp_lib.TPContext] = None) -> KVCacheBackend:
    """Engine-facing factory: a name ('contiguous' | 'paged' | 'encdec')
    or an already-built backend instance (tests / custom layouts).
    Enc-dec models need the paired self/cross layout, so 'contiguous'
    transparently resolves to ``EncDecBackend`` for them and 'paged' to
    ``PagedEncDecBackend``. An SPMD ``tp`` context upgrades 'paged' to
    the mesh-sharded pool (``ShardedPagedBackend``); the contiguous
    family ignores ``tp`` — only its restoration pack shards, and the
    sink colocates projected heads back to the buffer's device."""
    if isinstance(spec, KVCacheBackend):
        return spec
    if spec not in BACKENDS:
        raise ValueError(f"unknown KV-cache backend {spec!r}; "
                         f"one of {sorted(BACKENDS)}")
    if spec in ("paged", "paged-tp", "paged-encdec"):
        if spec == "paged-encdec" or model.kind == "encdec":
            return PagedEncDecBackend(model, max_batch, max_seq,
                                      block_size=block_size,
                                      num_blocks=num_blocks,
                                      enc_seq=enc_seq)
        if spec == "paged-tp" or (tp is not None and tp.spmd):
            return ShardedPagedBackend(model, max_batch, max_seq,
                                       block_size=block_size,
                                       num_blocks=num_blocks, tp_ctx=tp)
        return PagedBackend(model, max_batch, max_seq,
                            block_size=block_size, num_blocks=num_blocks)
    if spec == "encdec" or model.kind == "encdec":
        return EncDecBackend(model, max_batch, max_seq, enc_seq=enc_seq)
    return ContiguousBackend(model, max_batch, max_seq)
