from repro.serving.engine import EngineMetrics, InferenceEngine
from repro.serving.kv_cache import (BACKENDS, BlockAllocator, CacheView,
                                    ContiguousBackend, EncDecBackend,
                                    KVCacheBackend, OccupancyStats,
                                    PagedBackend, ViewSink, make_backend)
from repro.serving.request import Phase, Request, SequenceState
from repro.serving.sampling import sample

__all__ = ["BACKENDS", "BlockAllocator", "CacheView", "ContiguousBackend",
           "EncDecBackend", "EngineMetrics", "InferenceEngine",
           "KVCacheBackend", "OccupancyStats", "PagedBackend", "Phase",
           "Request", "SequenceState", "ViewSink", "make_backend", "sample"]
