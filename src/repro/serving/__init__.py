from repro.serving.engine import EngineMetrics, InferenceEngine
from repro.serving.request import Phase, Request, SequenceState
from repro.serving.sampling import sample

__all__ = ["EngineMetrics", "InferenceEngine", "Phase", "Request",
           "SequenceState", "sample"]
