"""Device-side prefix index for cross-session KV sharing (DESIGN.md §12).

The index maps *page-granular token prefixes* to the physical pages of a
``PagedBackend`` pool that already hold their KV. Keying is a rolling
token-hash: page ``p``'s key is ``sha1(key[p-1] || tokens[p*bs:(p+1)*bs])``
— an incremental content address, so looking up a prompt walks one hash
per page and stops at the first miss (the longest indexed prefix). Each
entry additionally records its page's raw tokens and its parent entry, so
a hash collision can never alias two different prefixes: a match requires
the parent chain AND the page tokens to agree exactly.

Lifecycle: a session *publishes* its full pages when its prefill
completes (and again when it pauses/retires, just before its slot frees);
publishing increfs each page in the ``BlockAllocator``, so the pages
survive the publisher's eviction. Admission *matches* a new session's
prompt (or a stored session's token history — the restore-skip path) and
adopts the shared pages into the new slot with another incref; the CoW
machinery in the backend privatizes a page only when someone writes to
it. Index-held pages are a cache, not a reservation: under pool pressure
the backend spills least-recently-used entries whose page nobody else
maps (``release``), so sharing never deadlocks admission.

Host backing: entries may carry *pins* on the publisher's persisted
chunk streams (``ChunkStore.pin_chunks``). A fresh session admitted via
a prefix hit never computes — or saves — hidden states for the matched
tokens, so the engine aliases the pinned chunks into the new session's
streams at match time; later pause/restore cycles then find a complete
history. Entries without host backing still serve engines that never
save (``save_hidden=False``) and the restore-skip path (the stored
session owns its full streams already).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Tuple

import numpy as np


def roll_hash(prev: Optional[bytes], page) -> bytes:
    """One step of the rolling page hash: ``sha1(prev || page_tokens)``.
    The module-level form is shared with the session router
    (frontend/router.py), so device-page identity and router-side
    conversation matching agree on what "the same prefix" means."""
    h = hashlib.sha1(prev or b"\x00")
    h.update(np.ascontiguousarray(page, dtype=np.int64).tobytes())
    return h.digest()


def hash_chain(tokens, block_size: int,
               prev: Optional[bytes] = None) -> List[bytes]:
    """Rolling hashes of every FULL ``block_size`` page of ``tokens``.
    Passing the last element back as ``prev`` (with only the new tokens)
    extends a chain incrementally — the router grows per-conversation
    chains one round at a time this way."""
    toks = np.asarray(tokens).reshape(-1)
    chain: List[bytes] = []
    key = prev
    for p in range(len(toks) // block_size):
        key = roll_hash(key, toks[p * block_size:(p + 1) * block_size])
        chain.append(key)
    return chain


def common_chain_prefix(a: List[bytes], b: List[bytes]) -> int:
    """Length (in pages) of the common prefix of two hash chains. Each
    element already commits to its whole history, so equality at depth d
    implies equality at every shallower depth — one comparison per page."""
    n = 0
    for x, y in zip(a, b):
        if x != y:
            break
        n += 1
    return n


@dataclasses.dataclass
class HostPin:
    """Pinned host-chunk backing of one entry: enough chunks of each
    persisted stream to cover the entry's tokens [0, depth·bs)."""

    methods: List[str]                       # publisher's per-layer methods
    pins: Dict[Tuple[str, int], List[str]]   # (stream, layer) -> pin ids
    n_chunks: int

    def all_ids(self) -> List[str]:
        return [pid for ids in self.pins.values() for pid in ids]


@dataclasses.dataclass
class _Entry:
    key: bytes                 # rolling hash through this page
    depth: int                 # pages covered (tokens = depth * block_size)
    block: int                 # physical page holding page depth-1's KV
    page_tokens: Tuple[int, ...]   # raw tokens of page depth-1 (collision
    #                                guard: hashes index, tokens decide)
    parent: Optional[bytes]    # key of the depth-1 entry (chain identity)
    children: set = dataclasses.field(default_factory=set)
    pin: Optional[HostPin] = None
    used: int = 0              # LRU clock value of the last touch


class PrefixIndex:
    """Rolling token-hash → shared physical page map over one backend."""

    def __init__(self, backend):
        self.backend = backend             # PagedBackend (owns allocator)
        self.store = None                  # ChunkStore, set by the engine
        self._entries: Dict[bytes, _Entry] = {}
        self._clock = 0
        # gauges (mirrored into EngineMetrics by the engine)
        self.lookups = 0
        self.hits = 0
        self.hit_tokens = 0
        self.published_pages = 0
        self.released_pages = 0

    # --------------------------------------------------------------- keys
    @property
    def bs(self) -> int:
        return self.backend.block_size

    @staticmethod
    def _roll(prev: Optional[bytes], page: np.ndarray) -> bytes:
        return roll_hash(prev, page)

    def _touch(self, e: _Entry) -> None:
        self._clock += 1
        e.used = self._clock

    # -------------------------------------------------------------- match
    def match(self, tokens, limit: Optional[int] = None,
              need_host: bool = False, record: bool = True):
        """Longest indexed page-aligned prefix of ``tokens``.

        Returns ``(blocks, matched_tokens, deepest_entry)`` — the
        physical pages holding tokens [0, matched_tokens) in order. The
        caller adopts them (incref) before anything can release the
        entries. ``limit`` caps the match in tokens (a fresh session must
        keep at least one prompt token to produce its first logits);
        ``need_host`` restricts the walk to entries with pinned host
        chunks (engines that persist streams need the host-side analogue
        of the shared pages). ``record=False`` leaves the hit-rate
        gauges alone (admission estimates probe without consuming)."""
        bs = self.bs
        toks = np.asarray(tokens).reshape(-1)
        n = len(toks) if limit is None else min(len(toks), int(limit))
        if record:
            self.lookups += 1
        blocks: List[int] = []
        key: Optional[bytes] = None
        entry: Optional[_Entry] = None
        depth = 0
        while (depth + 1) * bs <= n:
            page = toks[depth * bs:(depth + 1) * bs]
            nxt = self._roll(key, page)
            e = self._entries.get(nxt)
            if (e is None or e.parent != key
                    or e.page_tokens != tuple(int(t) for t in page)
                    or (need_host and e.pin is None)):
                break
            key, entry, depth = nxt, e, depth + 1
            blocks.append(e.block)
            self._touch(e)
        if blocks and record:
            self.hits += 1
            self.hit_tokens += depth * bs
        return blocks, depth * bs, entry

    # ------------------------------------------------------------ publish
    def publish(self, tokens, n_tokens: int, slot_blocks, pin_fn=None)\
            -> int:
        """Index every full page of ``tokens[:n_tokens]`` held in
        ``slot_blocks``. Existing entries are touched (their pages are
        as good as ours — identical tokens project identical KV); new
        entries incref the publisher's page and, when ``pin_fn`` is
        given, pin host chunks covering their tokens
        (``pin_fn(depth_pages) -> HostPin | None``). Returns the number
        of newly indexed pages."""
        bs = self.bs
        toks = np.asarray(tokens).reshape(-1)
        pages = min(int(n_tokens), len(toks)) // bs
        pages = min(pages, len(slot_blocks))
        key: Optional[bytes] = None
        added = 0
        for depth in range(1, pages + 1):
            page = toks[(depth - 1) * bs:depth * bs]
            nxt = self._roll(key, page)
            e = self._entries.get(nxt)
            if (e is not None and e.parent == key
                    and e.page_tokens == tuple(int(t) for t in page)):
                self._touch(e)
                key = nxt
                continue
            if e is not None:
                # same hash, different content/chain (collision) — keep
                # the resident entry, stop extending ours
                break
            block = int(slot_blocks[depth - 1])
            try:
                self.backend.allocator.incref(block)
            except RuntimeError:
                break                      # page already freed: stale row
            e = _Entry(key=nxt, depth=depth, block=block,
                       page_tokens=tuple(int(t) for t in page),
                       parent=key, pin=pin_fn(depth) if pin_fn else None)
            self._entries[nxt] = e
            if key is not None and key in self._entries:
                self._entries[key].children.add(nxt)
            self._touch(e)
            self.published_pages += 1
            added += 1
            key = nxt
        return added

    # ------------------------------------------------------------ release
    def _remove(self, e: _Entry) -> None:
        self.backend.allocator.free([e.block])
        if e.pin is not None and self.store is not None:
            self.store.unpin(e.pin.all_ids())
        if e.parent is not None and e.parent in self._entries:
            self._entries[e.parent].children.discard(e.key)
        del self._entries[e.key]

    def releasable(self) -> int:
        """Pages the index could hand back to the pool right now (held
        only by the index — nobody's block table maps them). Because any
        matcher increfs every page up to its match depth, such entries
        always sit at the deep end of their chains, so releasing them
        never strands a reachable entry."""
        return sum(1 for e in self._entries.values()
                   if self.backend.allocator.refcount(e.block) == 1)

    def release(self, n_pages: int) -> int:
        """Spill up to ``n_pages`` least-recently-used index-only pages
        back to the allocator (leaf entries first, so every remaining
        entry stays reachable from the root of its chain)."""
        freed = 0
        while freed < max(int(n_pages), 1):
            cands = [e for e in self._entries.values()
                     if not e.children
                     and self.backend.allocator.refcount(e.block) == 1]
            if not cands:
                break
            victim = min(cands, key=lambda e: e.used)
            self._remove(victim)
            self.released_pages += 1
            freed += 1
        return freed

    def clear(self) -> int:
        """Drop every entry (engine close / tests): decrefs all held
        pages and unpins all host chunks."""
        n = 0
        while self._entries:
            leaves = [e for e in self._entries.values() if not e.children]
            for e in leaves:
                self._remove(e)
                n += 1
        return n

    def __len__(self) -> int:
        return len(self._entries)
