"""whisper-medium — enc-dec audio transformer, MHA, conv frontend stubbed.

[arXiv:2212.04356; unverified]  24L d_model=1024 16H (kv=16) d_ff=4096
vocab=51865.  Whisper uses LayerNorm + GELU non-GLU FFNs and learned
positions (no RoPE).  The audio conv frontend is a stub: ``input_specs()``
feeds precomputed frame embeddings directly to the encoder.
"""
from repro.config.arch import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    use_rope=False,
    ffn_activation="gelu",
    ffn_glu=False,
    norm="layernorm",
    norm_eps=1e-5,
    is_encoder_decoder=True,
    encoder_layers=24,
    max_source_positions=32768,   # expanded beyond whisper's 1500 for the assigned shapes
    frontend="audio_conv",
    frontend_dim=128,             # mel bins (stubbed)
    tie_embeddings=True,
    source="arXiv:2212.04356",
)
