"""falcon-mamba-7b — pure Mamba1 SSM LM (attention-free).

[arXiv:2410.05355; unverified]  64L d_model=4096 d_ff=0 vocab=65024,
ssm_state=16, expand=2 (inner 8192), dt_rank = d_model/16 = 256.

HCache applicability: no KV cache exists; state restoration uses the
``ssm-rescan`` mode (restore each layer's recurrent state from that layer's
saved input hidden states) — see DESIGN.md §3.
"""
from repro.config.arch import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=65024,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    use_rope=False,
    source="arXiv:2410.05355",
)
