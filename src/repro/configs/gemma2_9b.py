"""gemma2-9b — dense GQA, alternating local/global attention, logit softcap.

[arXiv:2408.00118; hf]  42L d_model=3584 16H (kv=8) d_ff=14336 vocab=256000,
head_dim=256, sliding window 4096 on local layers, attn softcap 50, final
logit softcap 30, GeGLU FFN, tied + scaled embeddings, post-attn/ffn norms.
"""
from repro.config.arch import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=256000,
    head_dim=256,
    rope_theta=10000.0,
    local_window=4096,
    layer_pattern="LG",
    logit_softcap=30.0,
    attn_softcap=50.0,
    ffn_activation="gelu",
    ffn_glu=True,
    tie_embeddings=True,
    embedding_scale=True,
    post_attn_norm=True,
    source="arXiv:2408.00118",
)
