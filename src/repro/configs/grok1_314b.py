"""grok-1-314b — large MoE, 8 experts top-2, attention logit capping.

[hf:xai-org/grok-1; unverified]  64L d_model=6144 48H (kv=8) d_ff=32768
vocab=131072, head_dim=128.
"""
from repro.config.arch import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    head_dim=128,
    n_experts=8,
    experts_per_token=2,
    attn_softcap=30.0,
    rope_theta=10000.0,
    source="hf:xai-org/grok-1",
)
