"""internvl2-26b — VLM: InternViT frontend (stubbed) + InternLM2-20B backbone.

[arXiv:2404.16821; hf]  48L d_model=6144 48H (kv=8) d_ff=16384 vocab=92553.
The ViT frontend is a stub: ``input_specs()`` provides precomputed patch
embeddings that occupy the first ``n_vis`` positions of the sequence.
"""
from repro.config.arch import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    rope_theta=1e6,
    frontend="vit_patch",
    frontend_dim=256,           # number of visual patch positions per request
    source="arXiv:2404.16821",
)
