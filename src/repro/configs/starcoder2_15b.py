"""starcoder2-15b — dense GQA code model, RoPE, LayerNorm + non-GLU GELU FFN.

[arXiv:2402.19173; hf]  40L d_model=6144 48H (kv=4) d_ff=24576 vocab=49152.
"""
from repro.config.arch import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    qkv_bias=True,
    rope_theta=1e5,
    ffn_activation="gelu",
    ffn_glu=False,
    norm="layernorm",
    norm_eps=1e-5,
    source="arXiv:2402.19173",
)
