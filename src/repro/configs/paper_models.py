"""The paper's own evaluation models (§6): Llama2-7B/13B, OPT-30B.

These drive the analytical replications of the paper's figures
(benchmarks/). All three are MHA — the paper's primary regime.
"""
from repro.config.arch import ArchConfig

LLAMA2_7B = ArchConfig(
    name="llama2-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab_size=32000,
    source="arXiv:2307.09288",
)

LLAMA2_13B = ArchConfig(
    name="llama2-13b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=13824,
    vocab_size=32000,
    source="arXiv:2307.09288",
)

OPT_30B = ArchConfig(
    name="opt-30b",
    family="dense",
    n_layers=48,
    d_model=7168,
    n_heads=56,
    n_kv_heads=56,
    d_ff=28672,
    vocab_size=50272,
    use_rope=False,
    ffn_activation="relu",
    ffn_glu=False,
    norm="layernorm",
    norm_eps=1e-5,
    source="arXiv:2205.01068",
)
