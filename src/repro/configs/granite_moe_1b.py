"""granite-moe-1b-a400m — fine-grained MoE, 32 experts top-8.

[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]  24L d_model=1024 16H (kv=8)
d_ff=512 (per expert) vocab=49155.
"""
from repro.config.arch import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    n_experts=32,
    experts_per_token=8,
    tie_embeddings=True,
    rope_theta=10000.0,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
