"""Architecture registry: ``--arch <id>`` resolution.

Assigned archs use their public ids (hyphenated); the paper's own models are
also registered for the benchmark suite.
"""
from __future__ import annotations

from typing import Dict

from repro.config.arch import ArchConfig
from repro.configs.falcon_mamba_7b import CONFIG as FALCON_MAMBA_7B
from repro.configs.gemma2_9b import CONFIG as GEMMA2_9B
from repro.configs.granite_moe_1b import CONFIG as GRANITE_MOE_1B
from repro.configs.grok1_314b import CONFIG as GROK1_314B
from repro.configs.internvl2_26b import CONFIG as INTERNVL2_26B
from repro.configs.paper_models import LLAMA2_13B, LLAMA2_7B, OPT_30B
from repro.configs.qwen2_7b import CONFIG as QWEN2_7B
from repro.configs.qwen2p5_14b import CONFIG as QWEN2P5_14B
from repro.configs.starcoder2_15b import CONFIG as STARCODER2_15B
from repro.configs.whisper_medium import CONFIG as WHISPER_MEDIUM
from repro.configs.zamba2_2p7b import CONFIG as ZAMBA2_2P7B

ASSIGNED: Dict[str, ArchConfig] = {
    c.name: c for c in (
        WHISPER_MEDIUM, ZAMBA2_2P7B, QWEN2_7B, STARCODER2_15B, GEMMA2_9B,
        QWEN2P5_14B, GRANITE_MOE_1B, GROK1_314B, INTERNVL2_26B,
        FALCON_MAMBA_7B,
    )
}

PAPER: Dict[str, ArchConfig] = {
    c.name: c for c in (LLAMA2_7B, LLAMA2_13B, OPT_30B)
}

REGISTRY: Dict[str, ArchConfig] = {**ASSIGNED, **PAPER}


def get_arch(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(REGISTRY)}")
    return REGISTRY[name]
