"""zamba2-2.7b — hybrid Mamba2 + shared attention blocks.

[arXiv:2411.15242; hf]  54L d_model=2560 32H (kv=32, MHA) d_ff=10240
vocab=32000, ssm_state=64.  We model the hybrid stack as Mamba2 blocks with
a full attention block every 6 blocks (zamba2 interleaves shared attention
at a similar rate; we use untied per-position attention blocks — see
DESIGN.md).
"""
from repro.config.arch import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_headdim=64,
    hybrid_attn_every=6,
    rope_theta=10000.0,
    source="arXiv:2411.15242",
)
