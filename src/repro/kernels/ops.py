"""Public jit'd wrappers for the Pallas kernels.

``use_pallas`` switches between the kernel (TPU target; interpret=True on
CPU) and the jnp oracle. Model code calls these via the attention/mamba
layers when built with kernels enabled; the dry-run lowers the jnp path
(Mosaic does not target the CPU backend) — see DESIGN.md.
"""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.decode_attention import (decode_attention_paged_pallas,
                                            decode_attention_pallas)
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.restore_kv import (restore_kv_grouped_pallas,
                                      restore_kv_pallas)
from repro.kernels.ssm_update import ssm_update_pallas


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def restore_kv(hidden, wk, wv, bk, bv, cos, sin, *, head_dim,
               use_rope=True, use_pallas=True, interpret=None):
    if not use_pallas:
        return ref.restore_kv_ref(hidden, wk, wv, bk, bv, cos, sin,
                                  head_dim=head_dim, use_rope=use_rope)
    interpret = (not on_tpu()) if interpret is None else interpret
    return restore_kv_pallas(hidden, wk, wv, bk, bv, cos, sin,
                             head_dim=head_dim, use_rope=use_rope,
                             interpret=interpret)


def restore_kv_grouped(hidden, wk, wv, bk, bv, cos, sin, *, head_dim,
                       use_rope=True, use_pallas=True, interpret=None,
                       kv_sharding=None):
    """Stacked restoration projection for a group of layers — one
    dispatch instead of G (see kernels/restore_kv.py and the batched
    executor in core/restoration.py).

    ``kv_sharding`` (NamedSharding over the flattened KV axis of the
    (G, S, KV) outputs, DESIGN.md §16) constrains the results so the
    SPMD partitioner keeps each device's projected heads local — the
    restore sink then scatters them into a same-sharded page pool with
    zero cross-device traffic."""
    if not use_pallas:
        k, v = ref.restore_kv_grouped_ref(hidden, wk, wv, bk, bv, cos, sin,
                                          head_dim=head_dim,
                                          use_rope=use_rope)
        if kv_sharding is not None:
            k = jax.lax.with_sharding_constraint(k, kv_sharding)
            v = jax.lax.with_sharding_constraint(v, kv_sharding)
        return k, v
    interpret = (not on_tpu()) if interpret is None else interpret
    return restore_kv_grouped_pallas(hidden, wk, wv, bk, bv, cos, sin,
                                     head_dim=head_dim, use_rope=use_rope,
                                     interpret=interpret,
                                     kv_sharding=kv_sharding)


def flash_attention(q, k, v, *, group=1, causal=True, window=None,
                    softcap=None, use_pallas=True, interpret=None):
    if not use_pallas:
        return ref.flash_attention_ref(q, k, v, group=group, causal=causal,
                                       window=window, softcap=softcap)
    interpret = (not on_tpu()) if interpret is None else interpret
    return flash_attention_pallas(q, k, v, group=group, causal=causal,
                                  window=window, softcap=softcap,
                                  interpret=interpret)


def decode_attention(q, k, v, kv_len, *, softcap=None, window=None,
                     use_pallas=True, interpret=None):
    if not use_pallas:
        return ref.decode_attention_ref(q, k, v, kv_len, softcap=softcap,
                                        window=window)
    interpret = (not on_tpu()) if interpret is None else interpret
    return decode_attention_pallas(q, k, v, kv_len, softcap=softcap,
                                   window=window, interpret=interpret)


def decode_attention_paged(q, k_pool, v_pool, block_table, kv_len, *,
                           softcap=None, window=None, use_pallas=True,
                           interpret=None, head_sharding=None):
    """Paged (block-table) decode attention — see decode_attention.py.
    ``head_sharding`` partitions the launch head-parallel over a
    tensor-parallel mesh (kernel path; the jnp oracle ignores it — its
    sharding comes from constraint propagation in the caller)."""
    if not use_pallas:
        return ref.decode_attention_paged_ref(
            q, k_pool, v_pool, block_table, kv_len, softcap=softcap,
            window=window)
    interpret = (not on_tpu()) if interpret is None else interpret
    return decode_attention_paged_pallas(
        q, k_pool, v_pool, block_table, kv_len, softcap=softcap,
        window=window, interpret=interpret, head_sharding=head_sharding)


def ssm_update(h, dt, x, A, B, C, d_skip, *, use_pallas=True,
               interpret=None):
    if not use_pallas:
        return ref.ssm_update_ref(h, dt, x, A, B, C, d_skip)
    interpret = (not on_tpu()) if interpret is None else interpret
    return ssm_update_pallas(h, dt, x, A, B, C, d_skip, interpret=interpret)
