"""Pure-jnp oracles for every Pallas kernel (shape/dtype-sweep targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def restore_kv_ref(hidden, wk, wv, bk, bv, cos, sin, *, head_dim: int,
                   use_rope: bool = True):
    """hidden (S,D) -> K,V (S,KV); K rotated with cos/sin (S, hd/2)."""
    h = hidden.astype(jnp.float32)
    k = h @ wk.astype(jnp.float32)
    v = h @ wv.astype(jnp.float32)
    if bk is not None:
        k = k + bk.astype(jnp.float32)
        v = v + bv.astype(jnp.float32)
    if use_rope:
        S, KV = k.shape
        nh = KV // head_dim
        kh = k.reshape(S, nh, head_dim)
        x1, x2 = kh[..., :head_dim // 2], kh[..., head_dim // 2:]
        c = cos[:, None, :].astype(jnp.float32)
        s = sin[:, None, :].astype(jnp.float32)
        k = jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c],
                            axis=-1).reshape(S, KV)
    return k.astype(hidden.dtype), v.astype(hidden.dtype)


def restore_kv_grouped_ref(hidden, wk, wv, bk, bv, cos, sin, *,
                           head_dim: int, use_rope: bool = True):
    """Grouped oracle: hidden (G,S,D), wk/wv (G,D,KV), bk/bv (G,KV) ->
    K,V (G,S,KV). Each group row g must equal restore_kv_ref on the g-th
    slices (the byte-equivalence contract the grouped executor relies
    on), so the math is the per-layer oracle under a batched einsum."""
    h = hidden.astype(jnp.float32)
    k = jnp.einsum("gsd,gdk->gsk", h, wk.astype(jnp.float32))
    v = jnp.einsum("gsd,gdk->gsk", h, wv.astype(jnp.float32))
    if bk is not None:
        k = k + bk.astype(jnp.float32)[:, None, :]
        v = v + bv.astype(jnp.float32)[:, None, :]
    if use_rope:
        G, S, KV = k.shape
        nh = KV // head_dim
        kh = k.reshape(G, S, nh, head_dim)
        x1, x2 = kh[..., :head_dim // 2], kh[..., head_dim // 2:]
        c = cos[None, :, None, :].astype(jnp.float32)
        s = sin[None, :, None, :].astype(jnp.float32)
        k = jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c],
                            axis=-1).reshape(G, S, KV)
    return k.astype(hidden.dtype), v.astype(hidden.dtype)


def flash_attention_ref(q, k, v, *, group: int = 1, causal: bool = True,
                        window=None, softcap=None):
    """q (BH,Sq,hd), k/v (BKv,Skv,hd); q row b uses kv row b//group."""
    BH, Sq, hd = q.shape
    kk = jnp.repeat(k, group, axis=0)
    vv = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("bqh,bkh->bqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * hd ** -0.5
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(kk.shape[1])[None, :]
    mask = jnp.ones((Sq, kk.shape[1]), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkh->bqh", p, vv.astype(jnp.float32)).astype(
        q.dtype)


def decode_attention_ref(q, k, v, kv_len, *, softcap=None, window=None):
    """q (BKv,G,hd); k/v (BKv,Smax,hd); kv_len (BKv,)."""
    s = jnp.einsum("bgh,bkh->bgk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * q.shape[-1] ** -0.5
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    kpos = jnp.arange(k.shape[1])[None, None, :]
    mask = kpos < kv_len[:, None, None]
    if window is not None:
        mask &= kpos > (kv_len[:, None, None] - 1 - window)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bgk,bkh->bgh", p, v.astype(jnp.float32)).astype(
        q.dtype)


def decode_attention_paged_ref(q, k_pool, v_pool, block_table, kv_len, *,
                               softcap=None, window=None):
    """jnp oracle for the paged kernel: materialize the logical layout by
    block-table gather (sentinel entries clamp; whatever they alias lies
    past ``kv_len`` and carries exactly-zero probability), then reuse the
    contiguous decode oracle. q (BKv,G,hd); pools (NB,bs,hd);
    block_table (BKv,MB); kv_len (BKv,)."""
    NB, bs, hd = k_pool.shape
    BKv, MB = block_table.shape
    tbl = jnp.minimum(block_table.astype(jnp.int32), NB - 1)
    k = k_pool[tbl].reshape(BKv, MB * bs, hd)
    v = v_pool[tbl].reshape(BKv, MB * bs, hd)
    return decode_attention_ref(q, k, v, kv_len, softcap=softcap,
                                window=window)


def ssm_update_ref(h, dt, x, A, B, C, d_skip):
    """Mamba1 decode update (see ssm_update.py)."""
    dtf = dt.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dA = jnp.exp(dtf[:, :, None] * A[None].astype(jnp.float32))
    h_new = dA * h + (dtf * xf)[:, :, None] * B[:, None, :].astype(
        jnp.float32)
    y = (h_new * C[:, None, :].astype(jnp.float32)).sum(-1) \
        + d_skip[None].astype(jnp.float32) * xf
    return h_new, y.astype(x.dtype)
