"""Pallas TPU kernel: fused HCache restoration — K/V projection + RoPE.

The paper issues a cuBLAS GEMM then a separate RoPE+copy kernel (§5). On
TPU we fuse: each grid cell loads one hidden-state tile into VMEM once,
produces MXU-native K and V tiles, applies the rotary transform to K
in-register, and writes both outputs — one pass over HBM for H, no
intermediate K buffer.

Tiling: grid = (S / BLOCK_S, KV / BLOCK_KV). The full contraction dim (D)
is kept resident per cell: worst assigned arch D=6144 → H tile
256×6144×2B = 3 MiB + two 6144×BLOCK_KV weight tiles ≈ 3 MiB < VMEM.
BLOCK_KV must cover whole heads (multiple of head_dim) so the rotate-half
pairing stays in-tile; MXU alignment wants multiples of 128.

``restore_kv_grouped_pallas`` is the batched-restoration variant: a
leading grid dimension G indexes a stack of per-layer weights, so one
kernel launch projects ``group_size`` layers' hidden states — the
serving-path executor coalesces ready projection tasks into one such
call instead of L per-layer dispatches (see core/restoration.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block_kv(KV: int, head_dim: int, block_kv: int) -> int:
    """Largest tile ≤ ``block_kv`` that divides KV *and* covers whole
    heads. Halving blindly (the old fallback) can drop below head_dim
    for non-power-of-two widths (KV=960, head_dim=96 → 64), splitting a
    head across tiles and silently corrupting the rotate-half pairing —
    so the search walks multiples of head_dim instead. head_dim always
    divides KV (KV = n_kv_heads · head_dim), so ≥ head_dim is reachable."""
    block_kv = block_kv or max(head_dim, min(KV, 512))
    n_heads = KV // head_dim
    bh = max(block_kv // head_dim, 1)
    while n_heads % bh:
        bh -= 1
    return bh * head_dim


def _pick_block_s(S: int, block_s: int) -> int:
    block_s = min(block_s, S)
    while S % block_s:
        block_s //= 2
    return block_s


def _rope_rotate(x, cos, sin, head_dim: int):
    """x: (BS, BKV) covering whole heads; rotate each head's halves."""
    bs, bkv = x.shape
    n_heads = bkv // head_dim
    xh = x.reshape(bs, n_heads, head_dim)
    x1 = xh[..., : head_dim // 2]
    x2 = xh[..., head_dim // 2:]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return rot.reshape(bs, bkv)


def _restore_kv_kernel(h_ref, wk_ref, wv_ref, bk_ref, bv_ref, cos_ref,
                       sin_ref, k_ref, v_ref, *, head_dim: int,
                       use_rope: bool):
    h = h_ref[...].astype(jnp.float32)
    k = jax.lax.dot(h, wk_ref[...].astype(jnp.float32),
                    precision=jax.lax.Precision.DEFAULT)
    v = jax.lax.dot(h, wv_ref[...].astype(jnp.float32),
                    precision=jax.lax.Precision.DEFAULT)
    if bk_ref is not None:
        k = k + bk_ref[...].astype(jnp.float32)
        v = v + bv_ref[...].astype(jnp.float32)
    if use_rope:
        cos = cos_ref[...][:, None, :]          # (BS, 1, hd/2)
        sin = sin_ref[...][:, None, :]
        k = _rope_rotate(k, cos, sin, head_dim)
    k_ref[...] = k.astype(k_ref.dtype)
    v_ref[...] = v.astype(v_ref.dtype)


@functools.partial(jax.jit, static_argnames=("head_dim", "use_rope",
                                             "block_s", "block_kv",
                                             "interpret"))
def restore_kv_pallas(hidden, wk, wv, bk, bv, cos, sin, *, head_dim: int,
                      use_rope: bool = True, block_s: int = 256,
                      block_kv: int = 0, interpret: bool = True):
    """hidden (S, D); wk/wv (D, KV); bk/bv (KV,) or None;
    cos/sin (S, head_dim//2). Returns K, V: (S, KV) (K rotated)."""
    S, D = hidden.shape
    KV = wk.shape[1]
    block_kv = _pick_block_kv(KV, head_dim, block_kv)
    block_s = _pick_block_s(S, block_s)
    grid = (S // block_s, KV // block_kv)

    has_bias = bk is not None
    in_specs = [
        pl.BlockSpec((block_s, D), lambda i, j: (i, 0)),          # hidden
        pl.BlockSpec((D, block_kv), lambda i, j: (0, j)),         # wk
        pl.BlockSpec((D, block_kv), lambda i, j: (0, j)),         # wv
    ]
    args = [hidden, wk, wv]
    if has_bias:
        in_specs += [pl.BlockSpec((block_kv,), lambda i, j: (j,)),
                     pl.BlockSpec((block_kv,), lambda i, j: (j,))]
        args += [bk, bv]
    in_specs += [pl.BlockSpec((block_s, head_dim // 2), lambda i, j: (i, 0)),
                 pl.BlockSpec((block_s, head_dim // 2), lambda i, j: (i, 0))]
    args += [cos, sin]

    kernel = functools.partial(
        _restore_kv_kernel if has_bias else _no_bias_kernel,
        head_dim=head_dim, use_rope=use_rope)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((block_s, block_kv), lambda i, j: (i, j)),
                   pl.BlockSpec((block_s, block_kv), lambda i, j: (i, j))],
        out_shape=[jax.ShapeDtypeStruct((S, KV), hidden.dtype),
                   jax.ShapeDtypeStruct((S, KV), hidden.dtype)],
        interpret=interpret,
    )(*args)
    return out


def _no_bias_kernel(h_ref, wk_ref, wv_ref, cos_ref, sin_ref, k_ref, v_ref,
                    *, head_dim: int, use_rope: bool):
    _restore_kv_kernel(h_ref, wk_ref, wv_ref, None, None, cos_ref, sin_ref,
                       k_ref, v_ref, head_dim=head_dim, use_rope=use_rope)


# ------------------------------------------------------- grouped variant
@functools.partial(jax.jit, static_argnames=("head_dim", "use_rope",
                                             "block_s", "block_kv",
                                             "interpret", "kv_sharding"))
def restore_kv_grouped_pallas(hidden, wk, wv, bk, bv, cos, sin, *,
                              head_dim: int, use_rope: bool = True,
                              block_s: int = 256, block_kv: int = 0,
                              interpret: bool = True, kv_sharding=None):
    """Stacked restoration projection for a *group* of layers.

    hidden (G, S, D); wk/wv (G, D, KV); bk/bv (G, KV) or None; cos/sin
    (S, head_dim//2) shared by all group members (same positions).
    Returns K, V: (G, S, KV). One launch instead of G — grid gains a
    leading group dimension that indexes the weight stack, and each
    (g, i, j) cell is exactly the per-layer kernel's (i, j) cell for
    layer g; the per-cell bodies are shared with the per-layer kernel.

    ``kv_sharding`` (static NamedSharding on the KV output axis) pins
    the outputs sharded over a tensor-parallel mesh — with the weight
    stacks committed KV-sharded the grid's j dimension partitions across
    devices and each device runs only its own heads' tiles
    (DESIGN.md §16). The KV tile never spans a shard boundary because
    both the shard size and the tile cover whole heads."""
    G, S, D = hidden.shape
    KV = wk.shape[2]
    block_kv = _pick_block_kv(KV, head_dim, block_kv)
    if kv_sharding is not None:
        # a tile must not straddle the per-device KV slice: cap it at
        # the shard width (whole heads by construction — validate_heads
        # guarantees tp | n_kv_heads)
        n_shards = kv_sharding.mesh.size
        block_kv = min(block_kv, _pick_block_kv(KV // n_shards, head_dim,
                                                block_kv))
    block_s = _pick_block_s(S, block_s)
    grid = (G, S // block_s, KV // block_kv)

    has_bias = bk is not None
    # leading None squeezes the group dim out of the per-cell refs, so
    # the kernel bodies stay rank-2 (shared with the per-layer variant)
    in_specs = [
        pl.BlockSpec((None, block_s, D), lambda g, i, j: (g, i, 0)),
        pl.BlockSpec((None, D, block_kv), lambda g, i, j: (g, 0, j)),
        pl.BlockSpec((None, D, block_kv), lambda g, i, j: (g, 0, j)),
    ]
    args = [hidden, wk, wv]
    if has_bias:
        in_specs += [pl.BlockSpec((None, block_kv), lambda g, i, j: (g, j)),
                     pl.BlockSpec((None, block_kv), lambda g, i, j: (g, j))]
        args += [bk, bv]
    in_specs += [pl.BlockSpec((block_s, head_dim // 2),
                              lambda g, i, j: (i, 0)),
                 pl.BlockSpec((block_s, head_dim // 2),
                              lambda g, i, j: (i, 0))]
    args += [cos, sin]

    kernel = functools.partial(
        _restore_kv_kernel if has_bias else _no_bias_kernel,
        head_dim=head_dim, use_rope=use_rope)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((None, block_s, block_kv),
                                lambda g, i, j: (g, i, j)),
                   pl.BlockSpec((None, block_s, block_kv),
                                lambda g, i, j: (g, i, j))],
        out_shape=[jax.ShapeDtypeStruct((G, S, KV), hidden.dtype),
                   jax.ShapeDtypeStruct((G, S, KV), hidden.dtype)],
        interpret=interpret,
    )(*args)
    if kv_sharding is not None:
        out = [jax.lax.with_sharding_constraint(o, kv_sharding)
               for o in out]
    return out
