"""Pallas TPU kernel: blockwise causal flash attention (prefill path).

Grid = (batch·q_heads, Sq/BQ, Skv/BK); running max/sum/accumulator live in
VMEM scratch and are finalized at the last KV block. Fully-masked KV blocks
(beyond the causal frontier or outside the sliding window) are *skipped*
(`pl.when`), which removes the ~2× causal-masking waste the pure-jnp path
pays — this is the kernel-level half of the §Perf attention story.

Supports GQA (kv head = q head // group), sliding windows (gemma2) and
attention-logit softcaps (gemma2 / grok-1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_k: int, causal: bool, window, softcap,
                  scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k
    # visit only blocks intersecting the causal/window band
    visible = True
    if causal:
        visible = k_start <= q_start + block_q - 1
    if window is not None:
        visible = jnp.logical_and(
            visible, k_start + block_k - 1 > q_start - window)

    @pl.when(visible)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (BQ, hd)
        k = k_ref[0].astype(jnp.float32)                  # (BK, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
        m_scr[...] = m_new
        pv = jax.lax.dot(p.astype(v_ref.dtype), v_ref[0])
        acc_scr[...] = acc_scr[...] * corr + pv.astype(jnp.float32)

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "block_q", "block_k", "group",
    "interpret"))
def flash_attention_pallas(q, k, v, *, group: int = 1, causal: bool = True,
                           window=None, softcap=None, block_q: int = 256,
                           block_k: int = 256, interpret: bool = True):
    """q: (BH, Sq, hd) — BH = batch·q_heads; k/v: (BKv, Skv, hd) with
    BKv = batch·kv_heads; q head h uses kv head h // group.
    Returns (BH, Sq, hd)."""
    BH, Sq, hd = q.shape
    Skv = k.shape[1]
    block_q = min(block_q, Sq)
    while Sq % block_q:
        block_q //= 2
    block_k = min(block_k, Skv)
    while Skv % block_k:
        block_k //= 2
    grid = (BH, Sq // block_q, Skv // block_k)
    scale = hd ** -0.5

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, causal=causal,
        window=window, softcap=softcap, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda b, i, j, group=group: (b // group, j, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda b, i, j, group=group: (b // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
