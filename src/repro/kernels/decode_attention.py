"""Pallas TPU kernel: single-token decode attention (flash-decoding style).

One query token per sequence against a long KV cache. Grid =
(batch·kv_heads, Skv/BK): each cell processes one KV block for all the
query heads of that kv group (GQA rows share the block), maintaining
running max/sum in VMEM scratch. Blocks past the live length are skipped.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, block_k: int, scale: float, softcap,
                   window):
    ki = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = len_ref[0]
    k_start = ki * block_k
    visible = k_start < kv_len
    if window is not None:
        visible = jnp.logical_and(visible,
                                  k_start + block_k > kv_len - window)

    @pl.when(visible)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (G, hd)
        k = k_ref[0].astype(jnp.float32)                  # (BK, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (G, BK)
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < kv_len
        if window is not None:
            mask &= kpos > kv_len - 1 - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
        m_scr[...] = m_new
        pv = jax.lax.dot(p.astype(v_ref.dtype), v_ref[0])
        acc_scr[...] = acc_scr[...] * corr + pv.astype(jnp.float32)

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("softcap", "window", "block_k",
                                             "interpret"))
def decode_attention_pallas(q, k, v, kv_len, *, softcap=None, window=None,
                            block_k: int = 512, interpret: bool = True):
    """q: (BKv, G, hd) — one query token, G = q heads per kv head;
    k/v: (BKv, Smax, hd); kv_len: (BKv,) live lengths (int32).
    Returns (BKv, G, hd)."""
    BKv, G, hd = q.shape
    Smax = k.shape[1]
    block_k = min(block_k, Smax)
    while Smax % block_k:
        block_k //= 2
    grid = (BKv, Smax // block_k)
    kernel = functools.partial(_decode_kernel, block_k=block_k,
                               scale=hd ** -0.5, softcap=softcap,
                               window=window)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, G, hd), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1,), lambda b, j: (b,)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BKv, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, kv_len)
