"""Pallas TPU kernels: single-token decode attention (flash-decoding style).

One query token per sequence against a long KV cache. Grid =
(batch·kv_heads, Skv/BK): each cell processes one KV block for all the
query heads of that kv group (GQA rows share the block), maintaining
running max/sum in VMEM scratch. Blocks past the live length are skipped.

Two cache layouts share the same kernel body:

  * ``decode_attention_pallas``       — contiguous (BKv, Smax, hd) caches;
  * ``decode_attention_paged_pallas`` — a physical page pool
    (num_blocks, block_size, hd) addressed through a per-sequence block
    table. The table rides in as a scalar-prefetch argument
    (``PrefetchScalarGridSpec``), so the K/V BlockSpec index maps read
    ``table[b, j]`` and the grid walks *logical* pages while DMA fetches
    *physical* ones — the vLLM paged-attention structure. The j-th grid
    cell still covers logical positions [j·bs, (j+1)·bs), so the masking
    arithmetic is unchanged from the contiguous kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, block_k: int, scale: float, softcap,
                   window):
    ki = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = len_ref[0]
    k_start = ki * block_k
    visible = k_start < kv_len
    if window is not None:
        visible = jnp.logical_and(visible,
                                  k_start + block_k > kv_len - window)

    @pl.when(visible)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (G, hd)
        k = k_ref[0].astype(jnp.float32)                  # (BK, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (G, BK)
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < kv_len
        if window is not None:
            mask &= kpos > kv_len - 1 - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
        m_scr[...] = m_new
        pv = jax.lax.dot(p.astype(v_ref.dtype), v_ref[0])
        acc_scr[...] = acc_scr[...] * corr + pv.astype(jnp.float32)

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def _paged_decode_kernel(tbl_ref, q_ref, k_ref, v_ref, len_ref, o_ref,
                         m_scr, l_scr, acc_scr, **kw):
    # the block table only changes *which* physical page the BlockSpec
    # index maps DMA'd in — positions/masking are identical, so the
    # contiguous kernel body is reused verbatim
    _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_scr, l_scr,
                   acc_scr, **kw)


@functools.partial(jax.jit, static_argnames=("softcap", "window",
                                             "interpret", "head_sharding"))
def decode_attention_paged_pallas(q, k_pool, v_pool, block_table, kv_len, *,
                                  softcap=None, window=None,
                                  interpret: bool = True,
                                  head_sharding=None):
    """Paged decode attention. q: (BKv, G, hd); k_pool/v_pool:
    (num_blocks, block_size, hd) physical pages; block_table: (BKv, MB)
    logical→physical page map — entries >= num_blocks are unallocated
    sentinels (clamped here; they can only alias pages past ``kv_len``,
    which the mask zeroes); kv_len: (BKv,) live lengths (int32).
    Returns (BKv, G, hd).

    ``head_sharding`` (static NamedSharding over the leading BKv axis,
    DESIGN.md §16) partitions the launch head-parallel across a
    tensor-parallel mesh: grid dimension 0 IS the (batch·kv_head) axis,
    so each device gathers pages and runs attention only for its own
    heads; each head's softmax/weighted-sum is computed whole on one
    device, so outputs stay bitwise identical to the unsharded launch
    (the caller all-gathers once at the output-projection seam)."""
    BKv, G, hd = q.shape
    if head_sharding is not None:
        q = jax.lax.with_sharding_constraint(q, head_sharding)
    NB, bs, _ = k_pool.shape
    MB = block_table.shape[1]
    tbl = jnp.minimum(block_table.astype(jnp.int32), NB - 1)
    kernel = functools.partial(_paged_decode_kernel, block_k=bs,
                               scale=hd ** -0.5, softcap=softcap,
                               window=window)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(BKv, MB),
        in_specs=[
            pl.BlockSpec((1, G, hd), lambda b, j, t: (b, 0, 0)),
            pl.BlockSpec((1, bs, hd), lambda b, j, t: (t[b, j], 0, 0)),
            pl.BlockSpec((1, bs, hd), lambda b, j, t: (t[b, j], 0, 0)),
            pl.BlockSpec((1,), lambda b, j, t: (b,)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda b, j, t: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((BKv, G, hd), q.dtype),
        interpret=interpret,
    )(tbl, q, k_pool, v_pool, kv_len)
    if head_sharding is not None:
        out = jax.lax.with_sharding_constraint(out, head_sharding)
    return out


@functools.partial(jax.jit, static_argnames=("softcap", "window", "block_k",
                                             "interpret"))
def decode_attention_pallas(q, k, v, kv_len, *, softcap=None, window=None,
                            block_k: int = 512, interpret: bool = True):
    """q: (BKv, G, hd) — one query token, G = q heads per kv head;
    k/v: (BKv, Smax, hd); kv_len: (BKv,) live lengths (int32).
    Returns (BKv, G, hd)."""
    BKv, G, hd = q.shape
    Smax = k.shape[1]
    block_k = min(block_k, Smax)
    while Smax % block_k:
        block_k //= 2
    grid = (BKv, Smax // block_k)
    kernel = functools.partial(_decode_kernel, block_k=block_k,
                               scale=hd ** -0.5, softcap=softcap,
                               window=window)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, G, hd), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1,), lambda b, j: (b,)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BKv, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, kv_len)
