"""Pallas TPU kernel: Mamba1 single-token state update (decode hot loop).

    h' = exp(dt ⊙ A) ⊙ h + (dt ⊙ x) ⊗ B
    y  = (h' · C) + D ⊙ x

Shapes: h (B, I, N) fp32, dt/x/D (B, I)/(I,), A (I, N), B/C (B, N).
Grid = (B, I/BI): the state slab stays in VMEM; everything is element-wise
plus one small N-reduction — purely memory-bound, so the kernel's job is a
single fused pass over the state (the jnp path materializes dA and dBx
separately = 3 passes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssm_update_kernel(h_ref, dt_ref, x_ref, a_ref, b_ref, c_ref, dskip_ref,
                       h_out_ref, y_ref):
    h = h_ref[0].astype(jnp.float32)                  # (BI, N)
    dt = dt_ref[0].astype(jnp.float32)                # (BI,)
    x = x_ref[0].astype(jnp.float32)                  # (BI,)
    A = a_ref[...].astype(jnp.float32)                # (BI, N)
    Bm = b_ref[0].astype(jnp.float32)                 # (N,)
    Cm = c_ref[0].astype(jnp.float32)                 # (N,)
    dA = jnp.exp(dt[:, None] * A)
    h_new = dA * h + (dt * x)[:, None] * Bm[None, :]
    y = (h_new * Cm[None, :]).sum(axis=-1) \
        + dskip_ref[...].astype(jnp.float32) * x
    h_out_ref[0] = h_new
    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_i", "interpret"))
def ssm_update_pallas(h, dt, x, A, B, C, d_skip, *, block_i: int = 512,
                      interpret: bool = True):
    """h: (Bt, I, N) fp32; dt/x: (Bt, I); A: (I, N); B/C: (Bt, N);
    d_skip: (I,). Returns (h_new, y) with y: (Bt, I)."""
    Bt, I, N = h.shape
    block_i = min(block_i, I)
    while I % block_i:
        block_i //= 2
    grid = (Bt, I // block_i)
    return pl.pallas_call(
        _ssm_update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_i, N), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_i), lambda b, i: (b, i)),
            pl.BlockSpec((1, block_i), lambda b, i: (b, i)),
            pl.BlockSpec((block_i, N), lambda b, i: (i, 0)),
            pl.BlockSpec((1, N), lambda b, i: (b, 0)),
            pl.BlockSpec((1, N), lambda b, i: (b, 0)),
            pl.BlockSpec((block_i,), lambda b, i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_i, N), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_i), lambda b, i: (b, i)),
        ],
        out_shape=[jax.ShapeDtypeStruct((Bt, I, N), jnp.float32),
                   jax.ShapeDtypeStruct((Bt, I), x.dtype)],
        interpret=interpret,
    )(h, dt, x, A, B, C, d_skip)
