"""Serving front door (DESIGN.md §14): OpenAI-compatible async API,
similarity-steered session router, engine pump, stdlib HTTP binding."""
from repro.frontend.api import SSE_DONE, FrontDoor, sse
from repro.frontend.pump import EnginePump, Overloaded, Subscription
from repro.frontend.router import (RouteDecision, RouterBusy, RouterSlot,
                                   SessionRouter, StoredSession)
from repro.frontend.server import HttpFrontDoor, serve_engine
from repro.frontend.tokenizer import ByteTokenizer, ChatTemplate

__all__ = ["ByteTokenizer", "ChatTemplate", "EnginePump", "FrontDoor",
           "HttpFrontDoor", "Overloaded", "RouteDecision", "RouterBusy",
           "RouterSlot", "SSE_DONE", "SessionRouter", "StoredSession",
           "Subscription", "serve_engine", "sse"]
