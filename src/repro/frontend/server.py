"""Thin stdlib HTTP binding for the front door (DESIGN.md §14).

An ``asyncio.start_server`` socket loop that parses just enough
HTTP/1.1 to serve the ``FrontDoor`` handler: request line, headers,
``Content-Length`` body. Responses are either a JSON document
(``Content-Length``-framed) or — when the handler returns an async
generator — an SSE stream written chunk-by-chunk with ``Connection:
close`` framing (the client reads until EOF), each chunk flushed with
``drain()`` so tokens leave the process the moment the pump posts them.

No third-party HTTP stack: the repo's container has none, and the
handler layer is where all the behavior lives anyway — this module is
deliberately only sockets and framing. ``serve_engine`` is the
``launch/serve.py --serve-http`` entry: it owns the pump/router/api
wiring and shuts everything down cleanly (pump quiesce → engine.close)
on cancellation or Ctrl-C.
"""
from __future__ import annotations

import asyncio
import json
from typing import Optional

from repro.frontend.api import FrontDoor
from repro.frontend.pump import EnginePump
from repro.frontend.router import SessionRouter

_MAX_BODY = 16 * 1024 * 1024


class HttpFrontDoor:
    """One listening socket bound to one ``FrontDoor``."""

    def __init__(self, api: FrontDoor, host: str = "127.0.0.1",
                 port: int = 0):
        self.api = api
        self.host = host
        self.port = port                   # 0 -> ephemeral, set by start()
        self._server: Optional[asyncio.AbstractServer] = None

    async def start(self) -> "HttpFrontDoor":
        self._server = await asyncio.start_server(self._client,
                                                  self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        return self

    async def close(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None

    # ------------------------------------------------------------ protocol
    async def _client(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            req = await self._read_request(reader)
            if req is None:
                return
            method, path, body = req
            try:
                status, payload = await self.api.handle(method, path, body)
            except Exception as e:         # noqa: BLE001 - last resort 500
                status, payload = 500, {"error": {"type": "internal",
                                                  "message": str(e)}}
            if hasattr(payload, "__aiter__"):
                await self._write_stream(writer, status, payload)
            else:
                await self._write_json(writer, status, payload)
        except (ConnectionError, asyncio.IncompleteReadError):
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    @staticmethod
    async def _read_request(reader: asyncio.StreamReader):
        try:
            head = await reader.readuntil(b"\r\n\r\n")
        except (asyncio.IncompleteReadError, asyncio.LimitOverrunError):
            return None
        lines = head.decode("latin-1").split("\r\n")
        parts = lines[0].split(" ")
        if len(parts) < 2:
            return None
        method, path = parts[0], parts[1]
        headers = {}
        for line in lines[1:]:
            if ":" in line:
                k, v = line.split(":", 1)
                headers[k.strip().lower()] = v.strip()
        n = min(int(headers.get("content-length", 0) or 0), _MAX_BODY)
        body = None
        if n:
            raw = await reader.readexactly(n)
            try:
                body = json.loads(raw)
            except json.JSONDecodeError:
                body = None
        return method, path, body

    @staticmethod
    async def _write_json(writer: asyncio.StreamWriter, status: int,
                          payload: dict) -> None:
        doc = json.dumps(payload).encode("utf-8")
        writer.write(
            f"HTTP/1.1 {status} {_reason(status)}\r\n"
            f"Content-Type: application/json\r\n"
            f"Content-Length: {len(doc)}\r\n"
            f"Connection: close\r\n\r\n".encode("latin-1") + doc)
        await writer.drain()

    @staticmethod
    async def _write_stream(writer: asyncio.StreamWriter, status: int,
                            agen) -> None:
        writer.write(
            f"HTTP/1.1 {status} {_reason(status)}\r\n"
            f"Content-Type: text/event-stream\r\n"
            f"Cache-Control: no-cache\r\n"
            f"Connection: close\r\n\r\n".encode("latin-1"))
        await writer.drain()
        async for chunk in agen:
            writer.write(chunk.encode("utf-8"))
            await writer.drain()           # one flush per token chunk


def _reason(status: int) -> str:
    return {200: "OK", 400: "Bad Request", 404: "Not Found",
            409: "Conflict", 429: "Too Many Requests",
            500: "Internal Server Error"}.get(status, "OK")


async def serve_engine(engine, host: str = "127.0.0.1", port: int = 8080,
                       *, max_pending: int = 64,
                       router: Optional[SessionRouter] = None,
                       ready: Optional[asyncio.Event] = None):
    """Wire pump → router → api → socket and serve until cancelled;
    tears the stack down in reverse (socket, pump quiesce, engine.close)."""
    pump = EnginePump(engine, max_pending=max_pending).start()
    api = FrontDoor(pump, router)
    srv = HttpFrontDoor(api, host, port)
    await srv.start()
    print(f"front door listening on http://{host}:{srv.port} "
          f"(model {api.model_name})", flush=True)
    if ready is not None:
        ready.set()
    try:
        await asyncio.Event().wait()       # until cancelled
    finally:
        await srv.close()
        pump.close()
