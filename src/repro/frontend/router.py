"""Similarity-steered session router (DESIGN.md §14).

Maps incoming conversations onto engine sessions the way proxycache
steers llama.cpp slots: a bounded table of router slots, each remembering
which engine session it steers, the exact token history that session's
cached/stored state covers, a rolling page-hash chain over that history
(the same ``sha1(prev || page)`` hashes serving/prefix_index.py keys
device pages by), and a heat score. Routing a prompt:

1. **exact** — the conversation id is already bound to a slot: reuse its
   session, submitting only the suffix past the cached history (the
   engine restores the stored history instead of re-prefilling it);
2. **restore-on-match** — no id binding, but some live slot's or stored
   session's ENTIRE history is an exact token prefix of the prompt and
   covers at least ``reuse_threshold`` of it: a returning conversation
   that resent its full transcript. The slot is (re)bound, the prompt
   trimmed to the suffix, and the engine's normal RESTORING path brings
   the state back — restoration instead of recomputation, the paper's
   claim measured end to end;
3. **fork-on-shared-prefix** — the matched session belongs to a
   *different, still-bound* conversation (a branch point, e.g. two users
   continuing from one checkpoint). With prefix sharing on, the source
   is forked (``InferenceEngine.fork_session``: content-addressed host
   chunk aliases + parked CoW pages) and the new conversation continues
   on the fork; with sharing off it falls through to a fresh session —
   stealing the slot would corrupt the still-live original;
4. **fresh** — free slot first, else the coldest idle slot is rebound
   (cold-first placement). The displaced session's state is already in
   the store (the engine saves at retire — save-to-store precedes any
   overwrite by construction) and moves to the router's stored registry,
   where restore-on-match can still find it.

The router itself never touches device state: it only decides session
ids and trims prompts; restoration, prefix-sharing and capacity policy
all stay in the engine. Thread-safe: ``route`` runs on the event loop,
``complete`` on the engine-pump thread.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional

import numpy as np

from repro.serving.prefix_index import common_chain_prefix, hash_chain


@dataclasses.dataclass
class RouterSlot:
    index: int
    session_id: Optional[str] = None
    conversation_id: Optional[str] = None
    # exact token history the session's stored state covers: the routed
    # prompt plus all but the last generated token (the engine keeps the
    # last sampled token as the resume feed, outside the stored range)
    tokens: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros((0,), np.int32))
    chain: List[bytes] = dataclasses.field(default_factory=list)
    heat: float = 0.0              # hits, decayed on overwrite scans
    last_used: int = 0             # router clock of the last route
    busy: bool = False             # a request is in flight on the session

    def free(self) -> bool:
        return self.session_id is None


@dataclasses.dataclass
class StoredSession:
    """A session displaced from the slot table; still restorable."""
    session_id: str
    tokens: np.ndarray
    chain: List[bytes]
    last_used: int = 0


@dataclasses.dataclass
class RouteDecision:
    session_id: str
    prompt: np.ndarray             # suffix to submit (full prompt if fresh)
    kind: str                      # exact | restore | fork | fresh
    full_tokens: np.ndarray        # the full rendered prompt (bookkeeping)
    matched_tokens: int = 0
    slot: Optional[RouterSlot] = None
    forked_from: Optional[str] = None


class RouterBusy(RuntimeError):
    """The conversation already has a request in flight."""


class SessionRouter:
    def __init__(self, engine=None, *, n_slots: int = 8,
                 block_size: int = 16, reuse_threshold: float = 0.5,
                 steer: bool = True, max_stored: int = 64):
        self.engine = engine
        self.n_slots = int(n_slots)
        self.block_size = int(block_size)
        self.reuse_threshold = float(reuse_threshold)
        # steer=False is the route-blind baseline the SLO harness
        # compares against: every request lands on a fresh session and
        # pays its full history as prefill
        self.steer = bool(steer)
        self.slots = [RouterSlot(i) for i in range(self.n_slots)]
        self.stored: Dict[str, StoredSession] = {}
        self.max_stored = int(max_stored)
        self._by_conv: Dict[str, RouterSlot] = {}
        self._lock = threading.Lock()
        self._clock = 0
        self._next_id = 0
        # gauges
        self.lookups = 0
        self.exact_hits = 0
        self.similarity_hits = 0
        self.forks = 0
        self.fresh = 0
        self.overwrites = 0
        self.overflow = 0

    @property
    def hit_rate(self) -> float:
        hits = self.exact_hits + self.similarity_hits + self.forks
        return hits / self.lookups if self.lookups else 0.0

    def stats(self) -> dict:
        return {"lookups": self.lookups, "exact_hits": self.exact_hits,
                "similarity_hits": self.similarity_hits,
                "forks": self.forks, "fresh": self.fresh,
                "overwrites": self.overwrites, "overflow": self.overflow,
                "hit_rate": self.hit_rate,
                "live_slots": sum(1 for s in self.slots if not s.free()),
                "stored_sessions": len(self.stored)}

    # ------------------------------------------------------------ matching
    def _full_prefix_len(self, cand_tokens: np.ndarray,
                         cand_chain: List[bytes],
                         tokens: np.ndarray,
                         chain: List[bytes]) -> int:
        """len(cand_tokens) iff the candidate's ENTIRE history is an
        exact prefix of ``tokens``, else 0. Hash chains cover full pages
        (one compare per page); the sub-page tail is verified on raw
        tokens — hashes accelerate, tokens decide."""
        n = len(cand_tokens)
        if n == 0 or n >= len(tokens):
            # a usable match must leave at least one suffix token to
            # prefill (the engine needs fresh logits for the next token)
            return 0
        bs = self.block_size
        pages = n // bs
        if common_chain_prefix(cand_chain, chain) < pages:
            return 0
        if not np.array_equal(cand_tokens[pages * bs:],
                              tokens[pages * bs:n]):
            return 0
        return n

    def _best_match(self, tokens: np.ndarray, chain: List[bytes]):
        """Longest full-history prefix match over live slots and the
        stored registry. Returns (kind, obj, matched) with kind in
        {"slot", "stored", None}."""
        best = (None, None, 0)
        for s in self.slots:
            if s.free() or s.busy:
                continue
            m = self._full_prefix_len(s.tokens, s.chain, tokens, chain)
            if m > best[2]:
                best = ("slot", s, m)
        for st in self.stored.values():
            m = self._full_prefix_len(st.tokens, st.chain, tokens, chain)
            if m > best[2]:
                best = ("stored", st, m)
        return best

    # ----------------------------------------------------------- placement
    def _place_slot(self) -> Optional[RouterSlot]:
        """Free slot first, else the coldest idle slot (heat, then
        recency); every slot busy -> None (untracked overflow)."""
        for s in self.slots:
            if s.free():
                return s
        idle = [s for s in self.slots if not s.busy]
        if not idle:
            return None
        victim = min(idle, key=lambda s: (s.heat, s.last_used))
        self._displace(victim)
        return victim

    def _displace(self, slot: RouterSlot) -> None:
        """Move the slot's session to the stored registry. Its state is
        already persisted — the engine saves every retiring session
        before its slot frees — so overwrite never loses state."""
        if slot.session_id is not None and len(slot.tokens):
            self.stored[slot.session_id] = StoredSession(
                slot.session_id, slot.tokens, slot.chain, slot.last_used)
            while len(self.stored) > self.max_stored:
                lru = min(self.stored.values(), key=lambda s: s.last_used)
                del self.stored[lru.session_id]
        if slot.conversation_id is not None:
            self._by_conv.pop(slot.conversation_id, None)
        for s in self.slots:
            s.heat *= 0.5          # decay: old hits fade across overwrites
        slot.session_id = None
        slot.conversation_id = None
        slot.tokens = np.zeros((0,), np.int32)
        slot.chain = []
        slot.heat = 0.0
        self.overwrites += 1

    def _bind(self, slot: RouterSlot, session_id: str,
              conversation_id: Optional[str]) -> None:
        if slot.conversation_id is not None:
            self._by_conv.pop(slot.conversation_id, None)
        slot.session_id = session_id
        slot.conversation_id = conversation_id
        if conversation_id is not None:
            self._by_conv[conversation_id] = slot
        slot.busy = True
        slot.heat += 1.0
        slot.last_used = self._clock

    def _fresh_id(self) -> str:
        self._next_id += 1
        return f"fd-{self._next_id}"

    # --------------------------------------------------------------- route
    def route(self, tokens, conversation_id: Optional[str] = None)\
            -> RouteDecision:
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        if len(tokens) == 0:
            raise ValueError("cannot route an empty prompt")
        chain = hash_chain(tokens, self.block_size)
        with self._lock:
            self._clock += 1
            self.lookups += 1
            if not self.steer:
                self.fresh += 1
                return RouteDecision(self._fresh_id(), tokens, "fresh",
                                     tokens)
            # 1. exact conversation-id binding
            slot = (self._by_conv.get(conversation_id)
                    if conversation_id else None)
            if slot is not None:
                if slot.busy:
                    raise RouterBusy(
                        f"conversation {conversation_id!r} already has a "
                        f"request in flight")
                m = self._full_prefix_len(slot.tokens, slot.chain,
                                          tokens, chain)
                if m:
                    self.exact_hits += 1
                    self._bind(slot, slot.session_id, conversation_id)
                    return RouteDecision(slot.session_id, tokens[m:],
                                         "exact", tokens,
                                         matched_tokens=m, slot=slot)
                # the client rewrote history: the cached state no longer
                # prefixes the prompt — unbind and fall through
                self._displace(slot)
            # 2/3. similarity: longest full-history prefix match
            kind, obj, m = self._best_match(tokens, chain)
            if m and m / len(tokens) >= self.reuse_threshold:
                if kind == "slot" and obj.conversation_id is not None \
                        and conversation_id is not None \
                        and obj.conversation_id != conversation_id:
                    # branch point: a DIFFERENT bound conversation owns
                    # the match — fork rather than steal (sharing on)
                    d = self._try_fork(obj.session_id, tokens, m,
                                       conversation_id)
                    if d is not None:
                        return d
                elif kind == "slot":
                    self.similarity_hits += 1
                    self._bind(obj, obj.session_id, conversation_id)
                    return RouteDecision(obj.session_id, tokens[m:],
                                         "restore", tokens,
                                         matched_tokens=m, slot=obj)
                else:                      # stored registry hit
                    slot = self._place_slot()
                    if slot is not None:
                        st: StoredSession = obj
                        del self.stored[st.session_id]
                        slot.tokens = st.tokens
                        slot.chain = st.chain
                        self.similarity_hits += 1
                        self._bind(slot, st.session_id, conversation_id)
                        return RouteDecision(st.session_id, tokens[m:],
                                             "restore", tokens,
                                             matched_tokens=m, slot=slot)
            # 4. fresh placement
            return self._route_fresh(tokens, conversation_id)

    def _route_fresh(self, tokens: np.ndarray,
                     conversation_id: Optional[str]) -> RouteDecision:
        sid = self._fresh_id()
        slot = self._place_slot()
        self.fresh += 1
        if slot is None:
            self.overflow += 1      # untracked: not matchable later
            return RouteDecision(sid, tokens, "fresh", tokens)
        self._bind(slot, sid, conversation_id)
        return RouteDecision(sid, tokens, "fresh", tokens,
                             slot=slot)

    def _try_fork(self, src: str, tokens: np.ndarray, m: int,
                  conversation_id: Optional[str])\
            -> Optional[RouteDecision]:
        """Fork ``src`` for a branching conversation. None when forking
        is unavailable (no engine, sharing off, source un-forkable) —
        the caller falls back to a fresh session."""
        eng = self.engine
        if eng is None or not getattr(eng, "prefix_sharing", False):
            return None
        new_id = self._fresh_id()
        try:
            eng.fork_session(src, new_id)
        except (KeyError, ValueError):
            return None
        slot = self._place_slot()
        self.forks += 1
        if slot is None:
            self.overflow += 1
            return RouteDecision(new_id, tokens[m:], "fork", tokens,
                                 matched_tokens=m, forked_from=src)
        slot.tokens = tokens[:m].copy()
        slot.chain = hash_chain(slot.tokens, self.block_size)
        self._bind(slot, new_id, conversation_id)
        return RouteDecision(new_id, tokens[m:], "fork", tokens,
                             matched_tokens=m, slot=slot,
                             forked_from=src)

    def cancel(self, decision: RouteDecision) -> None:
        """Submission failed after routing (e.g. backpressure): release
        the slot's in-flight mark so the conversation can retry."""
        with self._lock:
            slot = decision.slot
            if slot is not None and slot.session_id == decision.session_id:
                slot.busy = False

    def adopt_conversation(self, decision: RouteDecision,
                           conversation_id: str) -> None:
        """Bind a conversation id minted AFTER routing (the API mints one
        for clients that sent none, so their next round can hit exactly)."""
        with self._lock:
            slot = decision.slot
            if (slot is None or slot.session_id != decision.session_id
                    or slot.conversation_id is not None):
                return
            slot.conversation_id = conversation_id
            self._by_conv[conversation_id] = slot

    # ------------------------------------------------------------ complete
    def complete(self, decision: RouteDecision,
                 generated: List[int]) -> None:
        """Fold a finished round back into the slot: the session's
        stored history is now the full prompt plus all generated tokens
        but the last (the engine keeps the last sampled token as the
        resume feed, so the NEXT round's rendered prompt continues from
        exactly here)."""
        with self._lock:
            slot = decision.slot
            if slot is None or slot.session_id != decision.session_id:
                return             # overflow / already displaced
            hist = np.concatenate(
                [decision.full_tokens,
                 np.asarray(generated[:-1], np.int32)]).astype(np.int32)
            bs = self.block_size
            # the old history is a strict prefix of the new one (exact/
            # restore matched it; fresh started empty; fork copied it),
            # so the chain extends incrementally from its last full page
            prev = len(slot.chain)
            prev_key = slot.chain[-1] if slot.chain else None
            slot.chain = slot.chain + hash_chain(hist[prev * bs:], bs,
                                                 prev=prev_key)
            slot.tokens = hist
            slot.busy = False
            slot.last_used = self._clock
