"""OpenAI-compatible API layer, transport-agnostic (DESIGN.md §14).

``FrontDoor.handle(method, path, body)`` implements
``/v1/chat/completions`` and ``/v1/completions`` (plus ``/v1/models``,
``/healthz``, ``/metrics``) against the engine pump and session router —
no sockets anywhere, so tests and the SLO harness drive the exact
request path the HTTP binding (frontend/server.py) serves, byte for
byte. ``handle`` returns ``(status, payload)``; a streaming request's
payload is an async generator of SSE-framed strings
(``data: {json}\n\n`` … ``data: [DONE]\n\n``) the binding writes through
as chunks, one per emitted token — the first chunk leaves before
generation completes.

Round tracking: every response carries a ``conversation_id`` (client-
supplied or minted here). A client that passes it back gets an exact
router hit; a client that only resends its transcript is recovered by
the router's prefix-similarity match. Either way the engine restores the
conversation's stored state and prefills only the new suffix.

Backpressure maps to HTTP statuses: pump queue-depth cap →
429 ``overloaded``; a second in-flight request on one conversation →
409 ``conversation_busy``.
"""
from __future__ import annotations

import asyncio
import json
import time
from typing import Optional

import numpy as np

from repro.frontend.pump import EnginePump, Overloaded, Subscription
from repro.frontend.router import RouteDecision, RouterBusy, SessionRouter
from repro.frontend.tokenizer import ByteTokenizer, ChatTemplate
from repro.serving.request import Request


def sse(obj) -> str:
    return f"data: {json.dumps(obj)}\n\n"


SSE_DONE = "data: [DONE]\n\n"


def _error(status: int, etype: str, message: str):
    return status, {"error": {"type": etype, "message": message,
                              "code": status}}


class FrontDoor:
    def __init__(self, pump: EnginePump,
                 router: Optional[SessionRouter] = None, *,
                 model_name: str = "hcache-repro",
                 default_max_tokens: int = 16):
        self.pump = pump
        engine = pump.engine
        self.router = router if router is not None else SessionRouter(
            engine, block_size=getattr(engine.kv, "block_size", 16))
        self.model_name = model_name
        self.default_max_tokens = int(default_max_tokens)
        self.tokenizer = ByteTokenizer(engine.model.cfg.vocab_size)
        self.template = ChatTemplate(self.tokenizer)
        # fold finished rounds back into the router on the pump thread
        pump.on_request_finished = self._request_finished

    def _request_finished(self, sub: Subscription) -> None:
        decision = sub.meta.get("decision")
        if decision is not None:
            self.router.complete(decision, sub.tokens)

    # ------------------------------------------------------------ dispatch
    async def handle(self, method: str, path: str, body=None):
        """Returns ``(status, payload)``; payload is a JSON-able dict or,
        for streaming requests, an async generator of SSE strings."""
        method = method.upper()
        path = path.split("?", 1)[0].rstrip("/") or "/"
        if method == "GET" and path == "/healthz":
            return 200, {"status": "ok",
                         "pending": self.pump.pending()}
        if method == "GET" and path == "/v1/models":
            return 200, {"object": "list",
                         "data": [{"id": self.model_name,
                                   "object": "model",
                                   "owned_by": "repro"}]}
        if method == "GET" and path == "/metrics":
            fut = self.pump.call(self.pump.engine.metrics.to_dict)
            metrics = await asyncio.wrap_future(fut)
            return 200, {"engine": metrics,
                         "router": self.router.stats(),
                         "pump": {"pending": self.pump.pending(),
                                  "max_pending": self.pump.max_pending}}
        if method == "POST" and path == "/v1/chat/completions":
            return await self._chat(body or {})
        if method == "POST" and path == "/v1/completions":
            return await self._completions(body or {})
        return _error(404, "not_found", f"no route for {method} {path}")

    # ------------------------------------------------------------- routing
    async def _route_and_submit(self, tokens: np.ndarray, body: dict):
        """Route on the pump thread (router state + fork must not race
        ``engine.step()``), then submit. Returns ``(sub, decision,
        conversation_id)`` or raises the mapped API error."""
        conv_id = body.get("conversation_id") or body.get("session_id")
        decision: RouteDecision = await asyncio.wrap_future(
            self.pump.call(self.router.route, tokens, conv_id))
        max_tokens = int(body.get("max_tokens")
                         or self.default_max_tokens)
        eos = body.get("eos_token")
        request = Request(decision.session_id, decision.prompt,
                          max_new_tokens=max_tokens,
                          eos_token=int(eos) if eos is not None else None,
                          priority=int(body.get("priority", 0)))
        try:
            sub = self.pump.submit(request)
        except Overloaded:
            self.router.cancel(decision)
            raise
        sub.meta["decision"] = decision
        if conv_id is None:
            conv_id = f"conv-{request.request_id}"
            self.router.adopt_conversation(decision, conv_id)
        return sub, decision, conv_id

    @staticmethod
    def _route_info(decision: RouteDecision) -> dict:
        return {"session_id": decision.session_id,
                "route": decision.kind,
                "matched_tokens": int(decision.matched_tokens),
                "forked_from": decision.forked_from}

    # ---------------------------------------------------------------- chat
    async def _chat(self, body: dict):
        messages = body.get("messages")
        if not messages or not isinstance(messages, list):
            return _error(400, "invalid_request",
                          "messages must be a non-empty list")
        try:
            tokens = self.template.render(messages)
        except (TypeError, ValueError) as e:
            return _error(400, "invalid_request", f"bad messages: {e}")
        return await self._serve(tokens, body, chat=True)

    async def _completions(self, body: dict):
        prompt = body.get("prompt")
        if prompt is None:
            return _error(400, "invalid_request", "prompt is required")
        if isinstance(prompt, str):
            tokens = self.tokenizer.encode(prompt)
        else:
            try:
                tokens = (np.asarray(list(prompt), np.int32)
                          % self.tokenizer.vocab_size)
            except (TypeError, ValueError) as e:
                return _error(400, "invalid_request", f"bad prompt: {e}")
        if len(tokens) == 0:
            return _error(400, "invalid_request", "prompt is empty")
        return await self._serve(tokens, body, chat=False)

    async def _serve(self, tokens: np.ndarray, body: dict, *, chat: bool):
        try:
            sub, decision, conv_id = await self._route_and_submit(tokens,
                                                                  body)
        except RouterBusy as e:
            return _error(409, "conversation_busy", str(e))
        except Overloaded as e:
            return _error(429, "overloaded", str(e))
        oid = f"{'chatcmpl' if chat else 'cmpl'}-{sub.request.request_id}"
        if body.get("stream"):
            gen = (self._stream_chat(oid, conv_id, decision, sub) if chat
                   else self._stream_completion(oid, conv_id, decision,
                                                sub))
            return 200, gen
        async for _ in sub.events():
            pass
        return 200, self._final(oid, conv_id, decision, sub, chat=chat)

    def _final(self, oid: str, conv_id: str, decision: RouteDecision,
               sub: Subscription, *, chat: bool) -> dict:
        text = self.tokenizer.decode(sub.tokens)
        usage = {"prompt_tokens": int(len(decision.full_tokens)),
                 "completion_tokens": len(sub.tokens),
                 "total_tokens": (int(len(decision.full_tokens))
                                  + len(sub.tokens))}
        base = {"id": oid, "created": int(time.time()),
                "model": self.model_name, "conversation_id": conv_id,
                "usage": usage, "hcache": self._route_info(decision)}
        if chat:
            base["object"] = "chat.completion"
            base["choices"] = [{"index": 0,
                                "message": {"role": "assistant",
                                            "content": text},
                                "finish_reason": sub.finish_reason}]
        else:
            base["object"] = "text_completion"
            base["choices"] = [{"index": 0, "text": text,
                                "tokens": list(sub.tokens),
                                "finish_reason": sub.finish_reason}]
        return base

    # ------------------------------------------------------------- streams
    def _chunk(self, oid: str, conv_id: str, delta: dict,
               finish: Optional[str]) -> dict:
        return {"id": oid, "object": "chat.completion.chunk",
                "created": int(time.time()), "model": self.model_name,
                "conversation_id": conv_id,
                "choices": [{"index": 0, "delta": delta,
                             "finish_reason": finish}]}

    async def _stream_chat(self, oid, conv_id, decision, sub):
        yield sse(self._chunk(oid, conv_id, {"role": "assistant"}, None))
        async for ev in sub.events():
            kind = ev[0]
            if kind == "token":
                yield sse(self._chunk(
                    oid, conv_id,
                    {"content": self.tokenizer.decode([ev[1]])}, None))
            elif kind == "finish":
                final = self._chunk(oid, conv_id, {}, ev[1])
                final["hcache"] = self._route_info(decision)
                yield sse(final)
        yield SSE_DONE

    async def _stream_completion(self, oid, conv_id, decision, sub):
        async for ev in sub.events():
            kind = ev[0]
            if kind == "token":
                yield sse({"id": oid, "object": "text_completion",
                           "model": self.model_name,
                           "conversation_id": conv_id,
                           "choices": [{"index": 0,
                                        "text": self.tokenizer.decode(
                                            [ev[1]]),
                                        "token": int(ev[1]),
                                        "finish_reason": None}]})
            elif kind == "finish":
                yield sse({"id": oid, "object": "text_completion",
                           "model": self.model_name,
                           "conversation_id": conv_id,
                           "hcache": self._route_info(decision),
                           "choices": [{"index": 0, "text": "",
                                        "finish_reason": ev[1]}]})
        yield SSE_DONE
