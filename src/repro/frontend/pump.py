"""Engine pump: drives ``InferenceEngine.step()`` on a background thread
and fans emitted tokens out to per-request async queues (DESIGN.md §14).

Threading model — exactly two sides touch the engine:

* the **pump thread** owns every engine call: it drains an inbox of
  submitted requests, runs ``engine.step()`` while any work is pending,
  executes deferred calls (``call`` — the router's fork path runs here),
  and sleeps on a condition variable when idle (no busy-spin between
  request arrivals). The engine's ``on_token``/``on_finish``/``on_pause``
  callbacks therefore fire on this thread;
* the **event loop** (or any other thread) only enqueues: ``submit``
  appends to the inbox and wakes the pump; token fan-out crosses back via
  ``loop.call_soon_threadsafe`` into each request's ``asyncio.Queue``.

Backpressure: ``submit`` raises ``Overloaded`` once the number of
unfinished requests (inbox + engine queue + resident) reaches
``max_pending`` — the API layer maps that to HTTP 429 / ``overloaded``.
The queue-depth cap is what keeps p99 TTFT bounded under a burst: beyond
it, shedding beats queueing.

``close()`` quiesces (finishes in-flight work unless ``force``), stops
the thread, drains the two-stage saver and calls ``engine.close()`` — a
clean shutdown leaks no threads.
"""
from __future__ import annotations

import asyncio
import concurrent.futures
import threading
import time
from collections import deque
from typing import Dict, List, Optional

from repro.serving.request import Request


class Overloaded(RuntimeError):
    """Queue-depth cap reached; shed the request (HTTP 429)."""


class Subscription:
    """Per-request fan-out endpoint. The pump posts ``("token", id)``,
    ``("pause", None)`` and a final ``("finish", reason)`` event; with an
    event loop attached the same events also land in ``queue`` for async
    consumption. Timestamps are perf_counter at post time — the SLO
    harness reads TTFT/TBT straight from here."""

    def __init__(self, request: Request,
                 loop: Optional[asyncio.AbstractEventLoop] = None):
        self.request = request
        self.loop = loop
        self.queue: Optional[asyncio.Queue] = (
            asyncio.Queue() if loop is not None else None)
        self.tokens: List[int] = []
        self.token_times: List[float] = []
        self.submit_time = time.perf_counter()
        self.finish_time: Optional[float] = None
        self.finish_reason: Optional[str] = None
        self.pauses = 0
        self.done = threading.Event()
        self.meta: dict = {}       # API/router context (route decision)

    @property
    def first_token_time(self) -> Optional[float]:
        return self.token_times[0] if self.token_times else None

    @property
    def ttft(self) -> Optional[float]:
        if not self.token_times:
            return None
        return self.token_times[0] - self.submit_time

    @property
    def tbt(self) -> List[float]:
        return [b - a for a, b in zip(self.token_times,
                                      self.token_times[1:])]

    def post(self, event) -> None:
        kind, _ = event
        if kind == "token":
            self.tokens.append(event[1])
            self.token_times.append(time.perf_counter())
        elif kind == "pause":
            self.pauses += 1
        elif kind == "finish":
            self.finish_time = time.perf_counter()
            self.finish_reason = event[1]
        if self.queue is not None and self.loop is not None:
            try:
                self.loop.call_soon_threadsafe(self.queue.put_nowait,
                                               event)
            except RuntimeError:
                pass               # loop already closed: keep bookkeeping
        if kind == "finish":
            self.done.set()

    async def events(self):
        """Async iterator over events through the final ``finish``."""
        if self.queue is None:
            raise RuntimeError("subscription has no event loop attached")
        while True:
            ev = await self.queue.get()
            yield ev
            if ev[0] == "finish":
                return

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self.done.wait(timeout)


class EnginePump:
    def __init__(self, engine, *, max_pending: int = 64,
                 idle_wait: float = 0.05):
        self.engine = engine
        self.max_pending = int(max_pending)
        self.idle_wait = float(idle_wait)
        self._subs: Dict[int, Subscription] = {}   # request_id -> sub
        self._inbox: deque = deque()
        self._calls: deque = deque()
        self._cond = threading.Condition()
        self._stop = False
        self._force_stop = False
        self.on_request_finished = None            # fn(sub), pump thread
        engine.on_token = self._on_token
        engine.on_finish = self._on_finish
        engine.on_pause = self._on_pause
        self._thread = threading.Thread(target=self._run,
                                        name="engine-pump", daemon=True)
        self.closed = False

    # ------------------------------------------------------------- ingress
    def start(self) -> "EnginePump":
        self._thread.start()
        return self

    def pending(self) -> int:
        """Unfinished requests anywhere in the pipeline."""
        return len(self._inbox) + len(self._subs)

    def submit(self, request: Request,
               loop: Optional[asyncio.AbstractEventLoop] = None)\
            -> Subscription:
        """Thread-safe ingress. Raises ``Overloaded`` at the queue-depth
        cap. Pass ``loop`` (or call from a running loop) to receive
        events on an asyncio queue as well."""
        if loop is None:
            try:
                loop = asyncio.get_running_loop()
            except RuntimeError:
                loop = None
        with self._cond:
            if self.closed or self._stop:
                raise RuntimeError("pump is closed")
            if self.pending() >= self.max_pending:
                raise Overloaded(
                    f"{self.pending()} requests pending "
                    f"(max_pending={self.max_pending})")
            request.arrival_time = time.perf_counter()
            sub = Subscription(request, loop)
            self._subs[request.request_id] = sub
            self._inbox.append(request)
            self._cond.notify()
        return sub

    def call(self, fn, *args, **kw) -> concurrent.futures.Future:
        """Run ``fn`` on the pump thread between engine steps (engine
        internals are single-threaded — the router's fork path must not
        race ``step()``). Executes inline when the pump isn't running."""
        fut: concurrent.futures.Future = concurrent.futures.Future()
        if not self._thread.is_alive():
            try:
                fut.set_result(fn(*args, **kw))
            except BaseException as e:       # noqa: BLE001 - relayed
                fut.set_exception(e)
            return fut
        with self._cond:
            self._calls.append((fut, fn, args, kw))
            self._cond.notify()
        return fut

    # ----------------------------------------------------------- callbacks
    def _on_token(self, seq, tok: int) -> None:
        sub = self._subs.get(seq.request.request_id)
        if sub is not None:
            sub.post(("token", int(tok)))

    def _on_pause(self, seq) -> None:
        sub = self._subs.get(seq.request.request_id)
        if sub is not None:
            sub.post(("pause", None))

    def _on_finish(self, seq, reason: str) -> None:
        sub = self._subs.pop(seq.request.request_id, None)
        if sub is None:
            return
        if self.on_request_finished is not None:
            self.on_request_finished(sub)
        sub.post(("finish", reason))

    # ----------------------------------------------------------- main loop
    def _engine_busy(self) -> bool:
        eng = self.engine
        return bool(eng.queue) or any(s is not None for s in eng.slots)

    def _work(self) -> bool:
        return bool(self._inbox or self._calls or self._engine_busy())

    def _run(self) -> None:
        eng = self.engine
        was_busy = False
        while True:
            drain = False
            with self._cond:
                if not self._work() and not self._stop:
                    if was_busy:
                        # quiesce: flush the two-stage saver so stored
                        # state is complete while the engine idles (the
                        # run()-loop equivalent of its trailing drain)
                        was_busy = False
                        drain = True
                    else:
                        self._cond.wait(timeout=self.idle_wait)
                if self._stop and (self._force_stop or not self._work()):
                    break
                while self._inbox:
                    eng.submit(self._inbox.popleft())
                calls, self._calls = list(self._calls), deque()
            if drain:
                eng.mgr.saver.drain()
            for fut, fn, args, kw in calls:
                if fut.set_running_or_notify_cancel():
                    try:
                        fut.set_result(fn(*args, **kw))
                    except BaseException as e:   # noqa: BLE001 - relayed
                        fut.set_exception(e)
            if self._engine_busy():
                eng.step()
                was_busy = True

    # ------------------------------------------------------------ shutdown
    def close(self, force: bool = False, timeout: float = 60.0) -> None:
        """Quiesce (unless ``force``), stop the pump thread, drain the
        saver, close the engine. Idempotent."""
        if self.closed:
            return
        with self._cond:
            self._stop = True
            self._force_stop = force
            self._cond.notify()
        if self._thread.is_alive():
            self._thread.join(timeout)
        self.closed = True
        self.engine.mgr.saver.drain()
        self.engine.close()
