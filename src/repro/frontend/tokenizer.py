"""Deterministic tokenizer + chat template for the serving front door.

The repro models are randomly initialized and speak raw token ids, not
natural language, so the front door needs a tokenizer whose only job is
to be **deterministic and exactly round-trippable**: the same rendered
conversation must always produce the same token prefix (the router's
similarity matching and the engine's restore path both key off exact
token prefixes), and a model-generated token id must survive a
decode→re-encode cycle bit-exactly (round N+1 re-renders the assistant's
round-N reply as message content).

Two charsets:

* ordinary text encodes byte-level: each UTF-8 byte maps to
  ``byte % vocab_size`` (injective whenever vocab_size >= 256, which
  every config here satisfies — ``reduced_for_smoke`` pins vocab=256);
* model-generated ids decode into the Unicode supplementary private-use
  plane, ``chr(PUA_BASE + id)``, and those codepoints encode straight
  back to ``id``. Arbitrary ids round-trip exactly regardless of vocab.

The chat template is prefix-stable: rendering a conversation history is
always a strict token prefix of rendering that history plus more
messages, because every message renders self-contained
(``<|role|>content<|end|>``) and the trailing assistant header that ends
a prompt is exactly how the next assistant message starts.
"""
from __future__ import annotations

from typing import Iterable, List, Sequence, Union

import numpy as np

PUA_BASE = 0xF0000          # supplementary private-use area A (65536 slots)


class ByteTokenizer:
    """Byte-level text → tokens; PUA codepoints ↔ raw token ids."""

    def __init__(self, vocab_size: int):
        if vocab_size < 2:
            raise ValueError(f"vocab_size {vocab_size} too small")
        self.vocab_size = int(vocab_size)

    def encode(self, text: str) -> np.ndarray:
        ids: List[int] = []
        for ch in text:
            cp = ord(ch)
            if PUA_BASE <= cp < PUA_BASE + self.vocab_size:
                ids.append(cp - PUA_BASE)
            else:
                ids.extend(b % self.vocab_size for b in ch.encode("utf-8"))
        return np.asarray(ids, np.int32)

    def decode(self, ids: Iterable[int]) -> str:
        """Model-generated ids → text. Every id becomes a PUA codepoint,
        so ``encode(decode(ids)) == ids`` holds for ANY id sequence —
        byte-level decoding could not promise that (an id >= 128 is not
        a complete UTF-8 sequence)."""
        return "".join(chr(PUA_BASE + int(i) % self.vocab_size)
                       for i in ids)


Content = Union[str, Sequence[int], np.ndarray]


class ChatTemplate:
    """Messages → token prompt, rendered deterministically.

    Message content may be a string (tokenized byte-level / PUA) or an
    explicit token-id list (passed through — benches and tests use this
    to drive exact workloads through the OpenAI-shaped API)."""

    def __init__(self, tokenizer: ByteTokenizer):
        self.tok = tokenizer

    def _content_tokens(self, content: Content) -> np.ndarray:
        if isinstance(content, str):
            return self.tok.encode(content)
        return np.asarray(list(content), np.int32) % self.tok.vocab_size

    def render(self, messages: List[dict],
               add_assistant_header: bool = True) -> np.ndarray:
        parts = []
        for m in messages:
            role = str(m.get("role", "user"))
            parts.append(self.tok.encode(f"<|{role}|>"))
            parts.append(self._content_tokens(m.get("content", "")))
            parts.append(self.tok.encode("<|end|>"))
        if add_assistant_header:
            parts.append(self.tok.encode("<|assistant|>"))
        if not parts:
            return np.zeros((0,), np.int32)
        return np.concatenate(parts).astype(np.int32)
