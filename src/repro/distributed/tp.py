"""Tensor-parallel mesh context for the device-sharded serving path
(DESIGN.md §16).

One ``TPContext`` describes the 1-D tensor-parallel mesh the serving
engine shards device state over: the KV-head axis of the paged page
pool, the KV output axis of the ``RestoreParamPack`` weight stacks, and
the head axis of decode attention. Everything degrades to the classic
single-device path when ``tp == 1`` or the process has fewer devices
than requested (``spmd`` is False and every placement helper is the
identity) — the same code path serves a laptop and a pod slice.

Sharding discipline (the byte-identity invariant the tests pin):

  * every sharded tensor is sharded on a NON-contracted dimension (KV
    heads / flattened KV outputs), so each output element is still one
    full-depth contraction computed on exactly one device — restored
    caches and attention outputs are bitwise identical to the
    single-device program;
  * the restore sink path never crosses devices: projections emit
    KV-head-sharded values and the page pool is sharded the same way,
    so ``write_layer_group`` scatters are shard-local;
  * the ONE collective on the decode path is the all-gather the
    ``logits_seam`` constraint forces right before the attention output
    projection — replicating ``attn_out`` there keeps the ``wo``
    contraction (and everything downstream, through the logits) an
    unsharded full-depth matmul instead of a partial-sum + psum whose
    float reorder would break bitwise identity.

Tests and benches force devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set before jax
imports) so the SPMD path runs everywhere.
"""
from __future__ import annotations

import contextlib
from typing import List, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

TP_AXIS = "model"


class TPContext:
    """A 1-D tensor-parallel mesh over the first ``tp`` local devices.

    ``spmd`` is True only when the sharded path is actually live; all
    placement helpers are identities otherwise, so callers never branch.
    """

    def __init__(self, tp: int = 1, *, axis: str = TP_AXIS):
        self.tp = max(int(tp), 1)
        self.axis = axis
        devices = jax.devices()
        self.spmd = self.tp > 1 and len(devices) >= self.tp
        self.mesh = None
        if self.spmd:
            from repro.launch.mesh import make_mesh
            self.mesh = make_mesh((self.tp,), (axis,))
        self.device0 = devices[0]

    def __repr__(self):
        return f"TPContext(tp={self.tp}, spmd={self.spmd})"

    # hashable identity for plan-cache keys
    def key(self):
        return (self.tp, self.spmd)

    def validate_heads(self, n_kv_heads: int) -> None:
        if self.spmd and n_kv_heads % self.tp:
            raise ValueError(
                f"tensor-parallel width tp={self.tp} must divide the "
                f"model's n_kv_heads={n_kv_heads} (each device owns an "
                f"equal slice of the KV-head axis)")

    # ----------------------------------------------------------- shardings
    def kv_sharding(self, ndim: int, kv_axis: int)\
            -> Optional[NamedSharding]:
        """NamedSharding placing the mesh axis on dimension ``kv_axis``
        of an ``ndim``-rank tensor (None when not SPMD)."""
        if not self.spmd:
            return None
        spec = [None] * ndim
        spec[kv_axis] = self.axis
        return NamedSharding(self.mesh, P(*spec))

    def replicated(self) -> Optional[NamedSharding]:
        return NamedSharding(self.mesh, P()) if self.spmd else None

    # ----------------------------------------------------------- placement
    def shard_kv(self, x, kv_axis: int):
        """Commit ``x`` sharded on ``kv_axis`` across the mesh."""
        if not self.spmd:
            return x
        return jax.device_put(x, self.kv_sharding(x.ndim, kv_axis))

    def replicate(self, x):
        """Commit ``x`` replicated across the mesh."""
        if not self.spmd:
            return x
        return jax.device_put(x, self.replicated())

    def unshard(self, x):
        """Pull a (possibly sharded) array to the first device — the
        seam back into single-device code (gather_hist feeding an
        unsharded prefill, snapshots feeding the host store)."""
        if not self.spmd:
            return x
        return jax.device_put(x, self.device0)


# --------------------------------------------------------------- seam hooks
# The decode/restore jits of a sharded backend trace under the active
# context (``tp_seam``); the model code calls the seam functions below at
# the points where the sharding discipline must be pinned. With no
# active SPMD context both are identities, so unsharded callers compile
# the exact pre-TP program.
_ACTIVE: List[Optional[TPContext]] = [None]


@contextlib.contextmanager
def tp_seam(ctx: Optional[TPContext]):
    prev = _ACTIVE[0]
    _ACTIVE[0] = ctx if (ctx is not None and ctx.spmd) else None
    try:
        yield
    finally:
        _ACTIVE[0] = prev


def active() -> Optional[TPContext]:
    return _ACTIVE[0]


def kv_seam(x, kv_axis: int):
    """Constrain ``x`` sharded over KV heads on ``kv_axis`` (page pools
    and K/V tensors inside a sharded decode step)."""
    ctx = _ACTIVE[0]
    if ctx is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, ctx.kv_sharding(x.ndim, kv_axis))


def logits_seam(x):
    """The single small all-gather of the sharded decode path: replicate
    the per-head attention output right before the output projection, so
    the ``wo`` contraction and the logits stay bitwise identical to the
    single-device program (see module docstring)."""
    ctx = _ACTIVE[0]
    if ctx is None:
        return x
    return jax.lax.with_sharding_constraint(x, ctx.replicated())
