"""Fault tolerance: supervised training loop, failure injection, elastic
restore, straggler policy.

On a real 1000+-node deployment the supervisor is the cluster controller;
here it is the in-process loop that the launcher runs, with the same
contract: every step is restartable from the last committed checkpoint and
the data pipeline is a pure function of the step counter (training/data.py)
— so a restart is state-restore + skip-ahead, nothing else.

Straggler mitigation policy (documented for multi-host): each step has a
deadline = p50 × ``straggler_factor``; a host missing two consecutive
deadlines is declared slow, the job checkpoints, and the supervisor
restarts on the reduced/replaced slice (elastic restore reshapes the mesh).
In-process we implement deadline *detection* and surface it in metrics.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

from repro.training.checkpoint import CheckpointManager


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    """Deterministic failure at given steps (tests/drills)."""

    fail_at: tuple = ()
    fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise InjectedFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class SupervisorReport:
    steps_run: int = 0
    restarts: int = 0
    straggler_steps: int = 0
    final_step: int = 0


def run_supervised(step_fn: Callable[[int], Dict], *,
                   ckpt: CheckpointManager,
                   save_state: Callable[[], object],
                   load_state: Callable[[int, object], None],
                   n_steps: int,
                   ckpt_every: int = 10,
                   max_restarts: int = 5,
                   straggler_factor: float = 3.0) -> SupervisorReport:
    """Run ``step_fn(step)`` for n_steps with checkpoint/restart.

    ``save_state()`` returns the live train state; ``load_state(step,
    state)`` installs a restored one. step_fn may raise (hardware fault /
    injected failure) — the supervisor restores and resumes."""
    report = SupervisorReport()
    step = 0
    if ckpt.latest_step() is not None:
        restored = ckpt.restore(save_state())
        step = restored[0] + 1
        load_state(*restored)
    durations = []
    while step < n_steps:
        try:
            t0 = time.perf_counter()
            step_fn(step)
            dt = time.perf_counter() - t0
            if durations:
                p50 = sorted(durations)[len(durations) // 2]
                if dt > straggler_factor * p50:
                    report.straggler_steps += 1
            durations.append(dt)
            report.steps_run += 1
            if step % ckpt_every == 0:
                ckpt.save(step, save_state())
            step += 1
        except Exception:
            report.restarts += 1
            if report.restarts > max_restarts:
                raise
            ckpt.wait()
            latest = ckpt.latest_step()
            if latest is None:
                step = 0
                continue
            restored_step, state = ckpt.restore(save_state())
            load_state(restored_step, state)
            step = restored_step + 1
    ckpt.wait()
    report.final_step = step
    return report
