"""Logical-axis sharding rules (GSPMD style).

Model code annotates parameters and activations with *logical* axis names;
this module maps them onto the physical mesh axes of the production meshes
``(data=16, model=16)`` / ``(pod=2, data=16, model=16)``.

Key decisions (see DESIGN.md §4):
  * batch            -> (pod,) data        (pure DP; pods are DP islands)
  * heads / qkv_out  -> model              (TP attention; heads padded to a
                                            multiple of the model axis)
  * d_ff / vocab     -> model              (TP FFN + vocab-parallel CE)
  * kv_seq           -> model              (decode KV cache sharded along the
                                            context; flash-decoding style)
  * fsdp             -> data               (ZeRO-1/3: master params + optimizer
                                            state sharded over the data axis)
  * long-context batch=1 cells additionally shard kv_seq over (data, model).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    rules: Dict[str, MeshAxes]

    def spec(self, axes: Sequence[Optional[str]]) -> P:
        """Logical axes tuple -> PartitionSpec, dropping unknown axes."""
        parts, used = [], set()
        for ax in axes:
            m = self.rules.get(ax) if ax is not None else None
            if m is None:
                parts.append(None)
                continue
            ms = (m,) if isinstance(m, str) else tuple(m)
            ms = tuple(a for a in ms if a not in used)
            used.update(ms)
            parts.append(ms if len(ms) != 1 else ms[0])
            if not ms:
                parts[-1] = None
        return P(*parts)

    def named(self, mesh: Mesh, axes: Sequence[Optional[str]]) -> NamedSharding:
        return NamedSharding(mesh, self.spec(axes))

    def tree_specs(self, axes_tree):
        """Axes tree (from module.split) -> PartitionSpec tree."""
        return jax.tree.map(self.spec, axes_tree,
                            is_leaf=lambda x: isinstance(x, tuple))

    def tree_shardings(self, mesh: Mesh, axes_tree):
        return jax.tree.map(lambda s: NamedSharding(mesh, s),
                            self.tree_specs(axes_tree),
                            is_leaf=lambda s: isinstance(s, P))

    def with_rules(self, **updates) -> "ShardingRules":
        new = dict(self.rules)
        new.update(updates)
        return ShardingRules(new)


def data_axes_of(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def default_rules(mesh: Mesh, *, seq_shard: bool = False,
                  long_context: bool = False) -> ShardingRules:
    """Baseline rules; ``seq_shard`` enables sequence-parallel prefill
    (beyond-paper perf variant), ``long_context`` spreads the KV/context of
    batch=1 cells over both data and model axes."""
    data = data_axes_of(mesh)
    rules: Dict[str, MeshAxes] = {
        # activations — long-context cells have batch=1: replicate batch and
        # spread the context over (data, model) instead
        "batch": None if long_context else data,
        "seq": data if seq_shard else None,
        "kv_seq": (*data, "model") if long_context else "model",
        "d_model": None,
        "heads": "model",
        "kv_heads": None,           # kv heads < model axis: replicated
        "head_dim": None,
        # parameters
        "qkv_out": "model",
        "kv_out": "model",          # flattened kv projection out dim
        "o_in": "model",
        "d_ff": "model",
        "vocab": "model",
        "experts": None,
        "layers": None,
        "fsdp": None,               # weight-dim data sharding, enabled per-arch
        "opt_fsdp": data,           # optimizer state is ALWAYS data-sharded (ZeRO-1)
        # ssm
        "ssm_inner": "model",
        "ssm_state": None,
        "ssm_heads": "model",
        "conv_w": None,
        "dt_rank": None,
    }
    return ShardingRules(rules)


def fsdp_rules(mesh: Mesh, **kw) -> ShardingRules:
    """Weights 2D-sharded — required for grok-1-314b (628 GB bf16).

    The expert FFN width is sharded over (data × model) — 32768/256 = 128 —
    so the dominant weights (301B of 314B params) are consumed *sharded* and
    XLA never materializes a gathered expert stack. The residual "fsdp" axis
    handles optimizer-state/master-param ZeRO sharding."""
    data = data_axes_of(mesh)
    return default_rules(mesh, **kw).with_rules(
        fsdp=data, d_ff=(*data, "model"))


def current_mesh():
    """The ambient mesh (jax>=0.5 abstract mesh, else the 0.4.x
    thread-local physical mesh from a ``with mesh:`` context)."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh()
    from jax._src import mesh as mesh_lib
    return mesh_lib.thread_resources.env.physical_mesh


def mesh_context(mesh):
    """Context manager activating ``mesh`` (jax.set_mesh on jax>=0.5;
    on 0.4.x a Mesh is itself the context manager)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def constrain(x, rules: ShardingRules, *axes: Optional[str]):
    """with_sharding_constraint by logical axes (no-op outside a mesh
    context, so layer code runs unchanged in single-device tests)."""
    mesh = current_mesh()
    if mesh.empty:
        return x
    spec = rules.spec(axes)
    if all(p is None for p in spec):
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def pad_heads(n_heads: int, n_kv_heads: int, axis_size: int) -> Tuple[int, int]:
    """Pad q heads so (group size × kv heads) is divisible by the model axis.

    Returns (padded_heads, group_size). KV head count is never padded — KV
    tensors stay at their true width (they are replicated or kv_seq-sharded).
    """
    if n_heads == 0:
        return 0, 0
    group = max(n_heads // n_kv_heads, 1)
    padded = n_kv_heads * group
    while padded % axis_size:
        group += 1
        padded = n_kv_heads * group
    return padded, group


def mesh_axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1
