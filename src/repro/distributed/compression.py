"""Gradient/communication compression.

With GSPMD the backward all-reduces happen implicitly at the dtype the
gradients carry. Our mixed-precision train step computes the backward in
bf16 (half the DP collective bytes of fp32) and the optimizer's
error-feedback buffer (`AdamWConfig.error_feedback=True`) folds the
quantization residual into the next step — the 16-bit analog of 1-bit
Adam's compensation. `quantize_int8`/`dequantize_int8` provide the next
rung (per-tensor-scaled int8, 4× fewer DP bytes) for use inside an
explicit shard_map reduction when DCI (cross-pod) bandwidth, not ICI, is
the binding constraint; at 2 pods the hierarchical reduction XLA emits for
the nested (pod, data) batch sharding keeps the DCI leg to 1/16th of the
gradient bytes, so int8 is left opt-in.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(x, axis_name: str, *, int8: bool = False):
    """psum with optional int8 wire format (inside shard_map only)."""
    if not int8:
        return jax.lax.psum(x.astype(jnp.bfloat16), axis_name)
    q, scale = quantize_int8(x)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    scale = jax.lax.pmax(scale, axis_name)
    return total.astype(jnp.float32) * scale


def tree_cast_bf16(tree):
    """Gradient tree -> bf16 wire format (GSPMD reduces at this dtype)."""
    return jax.tree.map(
        lambda g: g.astype(jnp.bfloat16)
        if jnp.issubdtype(g.dtype, jnp.floating) else g, tree)
