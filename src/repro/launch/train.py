"""Training driver with supervised restarts.

CPU-runnable end-to-end: builds a (reduced, unless --full) model for any
--arch, trains with AdamW + checkpointing under the fault supervisor, and
optionally injects failures to exercise the restart path.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --steps 50 \
        --ckpt-dir /tmp/ckpt --fail-at 23
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.config.arch import reduced_for_smoke
from repro.configs import get_arch
from repro.distributed.fault import FailureInjector, run_supervised
from repro.distributed.sharding import default_rules
from repro.launch.mesh import make_mesh
from repro.models import Model
from repro.training import (AdamWConfig, CheckpointManager, DataConfig,
                            Trainer, batch_at)


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen2-7b")
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--lr", type=float, default=1e-3)
    p.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    p.add_argument("--ckpt-every", type=int, default=10)
    p.add_argument("--fail-at", type=int, nargs="*", default=[])
    p.add_argument("--full", action="store_true",
                   help="full config (needs a real pod)")
    p.add_argument("--mesh", default="1x1", help="e.g. 1x1, 2x2, 16x16")
    args = p.parse_args()

    shape = tuple(int(x) for x in args.mesh.split("x"))
    mesh = make_mesh(shape, ("data", "model"))
    rules = default_rules(mesh)
    cfg = get_arch(args.arch)
    if not args.full:
        cfg = reduced_for_smoke(cfg)
    model = Model(cfg, rules=rules, model_axis=shape[-1],
                  dtype=jnp.float32 if not args.full else jnp.bfloat16,
                  remat="full")
    trainer = Trainer(model, rules, AdamWConfig(lr=args.lr), loss_chunks=4)
    state, _ = trainer.init_state(jax.random.PRNGKey(0))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    global_batch=args.batch)
    step_jit = jax.jit(trainer.train_step)
    ckpt = CheckpointManager(args.ckpt_dir)
    injector = FailureInjector(fail_at=tuple(args.fail_at))
    live = {"state": state}

    def one_step(step: int):
        injector.check(step)
        batch = batch_at(dc, step)
        if cfg.is_encoder_decoder:
            B = args.batch
            batch = {"frames": jnp.zeros((B, args.seq, cfg.d_model),
                                         model.dtype),
                     "tokens": batch["tokens"], "targets": batch["targets"]}
        live["state"], metrics = step_jit(live["state"], batch)
        if step % 10 == 0:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f}")
        return metrics

    t0 = time.perf_counter()
    report = run_supervised(
        one_step, ckpt=ckpt,
        save_state=lambda: live["state"],
        load_state=lambda step, s: live.update(state=s),
        n_steps=args.steps, ckpt_every=args.ckpt_every)
    print(f"done: {report.steps_run} steps, {report.restarts} restarts, "
          f"{time.perf_counter() - t0:.1f}s")


if __name__ == "__main__":
    main()
