import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST be imported/executed before any other jax-touching module — the two
lines above run first so the host platform exposes 512 placeholder devices
(single-pod mesh uses the first 256).

Per cell this produces ``experiments/dryrun/<cell>.json`` holding
memory_analysis, cost_analysis, the collective-bytes breakdown parsed from
the compiled HLO, and compile wall time — the roofline inputs (§Roofline).

Usage:
    python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
    python -m repro.launch.dryrun --all [--multipod] [--variant base]
    python -m repro.launch.dryrun --list
"""
import argparse
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config.arch import ArchConfig
from repro.config.shapes import (ALL_SHAPES, SHAPES_BY_NAME, InputShape,
                                 shape_applicable)
from repro.configs import ASSIGNED, get_arch
from repro.distributed.sharding import ShardingRules, default_rules, fsdp_rules
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.models.model import Model
from repro.training.optimizer import AdamWConfig, opt_axes_tree
from repro.training.train_step import Trainer

# archs whose bf16 weights exceed one pod's model-axis shard (16 GB/chip)
FSDP_ARCHS = {"grok-1-314b"}


def _sds_tree(tree):
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
                        tree)


def build_rules(mesh, cfg: ArchConfig, shape: InputShape,
                variant: str) -> ShardingRules:
    from repro.distributed.sharding import data_axes_of
    kw = dict(long_context=(shape.name == "long_500k"),
              seq_shard=("seqshard" in variant and shape.kind != "decode"))
    if cfg.name in FSDP_ARCHS:
        if "ffmodel" in variant:
            # §Perf variant: ZeRO-3 style — d_ff model-only, weights 2D via
            # the fsdp axis (per-layer gather instead of 2D contraction)
            return default_rules(mesh, **kw).with_rules(
                fsdp=data_axes_of(mesh))
        return fsdp_rules(mesh, **kw)
    return default_rules(mesh, **kw)


def build_model(mesh, cfg: ArchConfig, shape: InputShape, variant: str
                ) -> Model:
    rules = build_rules(mesh, cfg, shape, variant)
    remat = "dots" if "dotsremat" in variant else "full"
    return Model(cfg, rules=rules, model_axis=mesh.shape["model"],
                 dtype=jnp.bfloat16,
                 remat=remat if shape.kind == "train" else "none",
                 attn_chunk=2048 if "bigchunk" in variant else 1024,
                 tri_prefill="triprefill" in variant,
                 moe_late_combine="latecombine" in variant)


def build_cell(mesh, cfg: ArchConfig, shape: InputShape, variant: str):
    """Returns (fn, arg_sds tuple, in_shardings tuple, donate_argnums)."""
    model = build_model(mesh, cfg, shape, variant)
    rules = model.rules
    data_size = mesh.shape["data"] * mesh.shape.get("pod", 1)

    values, axes = model.abstract_params()
    param_sh = rules.tree_shardings(mesh, axes)

    if shape.kind == "train":
        trainer = Trainer(model, rules, AdamWConfig())
        params_f32 = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), values)
        state_sds = {"params": params_f32,
                     "opt": {"m": params_f32, "v": params_f32,
                             "step": jax.ShapeDtypeStruct((), jnp.int32)}}
        st_axes = trainer.state_axes(axes, state_sds, data_size)
        state_sh = rules.tree_shardings(mesh, st_axes)
        batch_sds = model.train_batch_spec(shape)
        batch_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                model.train_batch_sharding(),
                                is_leaf=lambda x: isinstance(x, P))
        return (trainer.train_step, (state_sds, batch_sds),
                (state_sh, batch_sh), ())

    if shape.kind == "prefill":
        def serve_prefill(params, batch):
            out = model.prefill(params, batch)
            if model.kind == "lm":
                return out["logits"], out["kv"]
            if model.kind == "ssm":
                return out["logits"], out["states"]
            if model.kind == "hybrid":
                return out["logits"], out["kv"], out["mamba_states"]
            return out["logits"], out["kv"], out["cross_kv"]

        batch_sds = model.prefill_batch_spec(shape)
        batch_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                model.prefill_batch_sharding(),
                                is_leaf=lambda x: isinstance(x, P))
        return (serve_prefill, (_sds_tree(values), batch_sds),
                (param_sh, batch_sh), ())

    if shape.kind == "restore":
        # THE PAPER'S OP at production scale: stacked per-layer K,V from
        # stored hidden states (norm + projection + RoPE), 32 sessions'
        # histories restored as one batch.
        def restore_op(params, hidden):
            B, S = shape.global_batch, shape.seq_len
            pos = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32)[None], (B, S))
            return model.restore_kv_from_hidden(params, hidden,
                                                positions=pos)

        L = (model.h.n_super if model.kind == "hybrid"
             else cfg.encoder_layers if model.kind == "encdec"
             else cfg.n_layers)
        if model.kind == "encdec":
            L = cfg.n_layers
        hidden_sds = jax.ShapeDtypeStruct(
            (L, shape.global_batch, shape.seq_len, cfg.d_model),
            jnp.bfloat16)
        hidden_sh = NamedSharding(
            mesh, rules.spec(("layers", "batch", "kv_seq", "d_model")))
        return (restore_op, (_sds_tree(values), hidden_sds),
                (param_sh, hidden_sh), ())

    # decode
    def serve_decode(params, cache, tokens):
        lg, new_cache = model.decode_step(params, cache, tokens)
        return lg, new_cache

    cache_sds = model.cache_spec(shape.global_batch, shape.seq_len)
    cache_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                            model.cache_sharding(),
                            is_leaf=lambda x: isinstance(x, P))
    tok_sds = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    tok_sh = NamedSharding(mesh, rules.spec(("batch", None)))
    return (serve_decode, (_sds_tree(values), cache_sds, tok_sds),
            (param_sh, cache_sh, tok_sh), (1,))


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             variant: str = "base", out_dir: str = "experiments/dryrun",
             hlo_dir: Optional[str] = None) -> dict:
    cfg = get_arch(arch)
    shape = SHAPES_BY_NAME[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}__{variant}"
    skip = shape_applicable(cfg, shape)
    if shape.kind == "restore" and cfg.is_attention_free:
        skip = "attention-free arch: restoration is state-blob/ssm-rescan"
    if skip:
        rec = {"cell": cell_id, "arch": arch, "shape": shape_name,
               "mesh": mesh_name, "variant": variant, "skipped": skip}
        _write(out_dir, cell_id, rec)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = 512 if multi_pod else 256
    t0 = time.perf_counter()
    from repro.distributed.sharding import mesh_context
    with mesh_context(mesh):
        fn, args, shardings, donate = build_cell(mesh, cfg, shape, variant)
        lowered = jax.jit(fn, in_shardings=shardings,
                          donate_argnums=donate).lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
    t_compile = time.perf_counter() - t0 - t_lower

    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    # loop-aware accounting (cost_analysis counts scan bodies once)
    parsed = analyze_hlo(hlo)
    rec = {
        "cell": cell_id, "arch": arch, "shape": shape_name,
        "mesh": mesh_name, "chips": chips, "variant": variant,
        "flops": float(parsed["flops"]),
        "bytes_accessed": float(parsed["bytes"]),
        "bytes_all": float(parsed["bytes_all"]),
        "xla_flops_once": float(ca.get("flops", 0.0)),
        "xla_bytes_once": float(ca.get("bytes accessed", 0.0)),
        "collectives": parsed["collectives"],
        "collective_bytes": int(parsed["collective_bytes"]),
        "peak_memory": getattr(ma, "peak_memory_in_bytes", None),
        "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
        "arg_bytes": getattr(ma, "argument_size_in_bytes", None),
        "out_bytes": getattr(ma, "output_size_in_bytes", None),
        "lower_s": t_lower, "compile_s": t_compile,
    }
    print(f"[dryrun] {cell_id}: flops/dev={rec['flops']:.3e} "
          f"bytes/dev={rec['bytes_accessed']:.3e} "
          f"coll={rec['collective_bytes']:.3e}B "
          f"peak={(rec['peak_memory'] or 0) / 2**30:.2f}GiB "
          f"compile={t_compile:.1f}s")
    print("memory_analysis:", ma)
    if hlo_dir:
        os.makedirs(hlo_dir, exist_ok=True)
        with open(os.path.join(hlo_dir, cell_id + ".hlo"), "w") as f:
            f.write(hlo)
    _write(out_dir, cell_id, rec)
    return rec


def _write(out_dir: str, cell_id: str, rec: dict) -> None:
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, cell_id + ".json"), "w") as f:
        json.dump(rec, f, indent=2)


def all_cells():
    for arch in ASSIGNED:
        for shape in ALL_SHAPES:
            yield arch, shape.name


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch")
    p.add_argument("--shape")
    p.add_argument("--multipod", action="store_true")
    p.add_argument("--variant", default="base")
    p.add_argument("--all", action="store_true")
    p.add_argument("--list", action="store_true")
    p.add_argument("--out", default="experiments/dryrun")
    p.add_argument("--hlo-dir", default=None)
    p.add_argument("--skip-existing", action="store_true")
    args = p.parse_args()

    if args.list:
        for arch, shape in all_cells():
            cfg = get_arch(arch)
            skip = shape_applicable(cfg, SHAPES_BY_NAME[shape])
            print(f"{arch:24s} {shape:12s}"
                  + (f"  SKIP: {skip}" if skip else ""))
        return

    cells = (list(all_cells()) if args.all
             else [(args.arch, args.shape)])
    failures = []
    for arch, shape in cells:
        mesh_name = "2x16x16" if args.multipod else "16x16"
        cell_id = f"{arch}__{shape}__{mesh_name}__{args.variant}"
        path = os.path.join(args.out, cell_id + ".json")
        if args.skip_existing and os.path.exists(path):
            with open(path) as f:
                if "error" not in json.load(f):
                    continue
        try:
            run_cell(arch, shape, multi_pod=args.multipod,
                     variant=args.variant, out_dir=args.out,
                     hlo_dir=args.hlo_dir)
        except Exception as e:  # record, keep going
            traceback.print_exc()
            failures.append(cell_id)
            _write(args.out, cell_id,
                   {"cell": cell_id, "arch": arch, "shape": shape,
                    "mesh": mesh_name, "variant": args.variant,
                    "error": f"{type(e).__name__}: {e}"})
    if failures:
        print(f"[dryrun] FAILURES: {failures}")
        raise SystemExit(1)
    print("[dryrun] all cells OK")


if __name__ == "__main__":
    main()
