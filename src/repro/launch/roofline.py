"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all in seconds **per device** (the
SPMD module that XLA compiles and that ``cost_analysis`` reports on is the
per-device program — verified empirically, see EXPERIMENTS.md §Dry-run):

    compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device / HBM_bw_per_chip
    collective = collective_bytes_per_device / ICI_link_bw

collective_bytes is not in cost_analysis — we parse the compiled HLO and
sum the operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (async `-start` forms counted once,
`-done` skipped).

MODEL_FLOPS (the "useful" compute): 6·N·D for training, 2·N·D for
prefill/decode, N = active params, D = global tokens processed; the ratio
MODEL_FLOPS / (HLO_FLOPs · chips) exposes remat/padding/masking waste.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Iterable, List, Optional

from repro.config.arch import ArchConfig
from repro.config.hardware import TPU_V5E, HardwareProfile
from repro.config.shapes import InputShape

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s+\S+\s+(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes per collective kind from compiled HLO text."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done(" in line:
            continue
        kind = m.group(1)
        # operands: shapes inside the call parens
        call = line[m.end() - 1:]
        depth = 0
        end = 0
        for i, ch in enumerate(call):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = call[:end + 1]
        total = sum(_shape_bytes(d, dims)
                    for d, dims in _SHAPE_RE.findall(operands))
        out[kind] = out.get(kind, 0) + total
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_breakdown: Dict[str, int]
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    useful_ratio: float
    peak_memory_bytes: Optional[float] = None

    def row(self) -> str:
        return (f"| {self.arch} | {self.shape} | {self.mesh} | "
                f"{self.compute_s * 1e3:.3f} | {self.memory_s * 1e3:.3f} | "
                f"{self.collective_s * 1e3:.3f} | {self.bottleneck} | "
                f"{self.useful_ratio:.2f} | "
                f"{(self.peak_memory_bytes or 0) / 2**30:.2f} |")


def model_flops(cfg: ArchConfig, shape: InputShape) -> float:
    if shape.kind == "restore":
        # the paper's op: K/V projections over every stored layer-token
        from repro.core.cost_model import layer_costs
        tokens = shape.global_batch * shape.seq_len
        return sum(c.c_hidden for c in layer_costs(cfg, tokens))
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    factor = 6 if shape.kind == "train" else 2
    return factor * n_active * tokens


def analyze(cfg: ArchConfig, shape: InputShape, *, mesh_name: str,
            chips: int, flops_per_device: float, bytes_per_device: float,
            hlo_text: Optional[str] = None,
            coll_breakdown: Optional[Dict[str, int]] = None,
            peak_memory: Optional[float] = None,
            hw: HardwareProfile = TPU_V5E) -> RooflineReport:
    if coll_breakdown is None:
        coll_breakdown = collective_bytes(hlo_text or "")
    coll = sum(coll_breakdown.values())
    compute_s = flops_per_device / hw.flops
    memory_s = bytes_per_device / hw.hbm_bw
    collective_s = coll / hw.interconnect_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    total_hlo = flops_per_device * chips
    ratio = mf / total_hlo if total_hlo else 0.0
    return RooflineReport(
        arch=cfg.name, shape=shape.name, mesh=mesh_name, chips=chips,
        flops_per_device=flops_per_device, bytes_per_device=bytes_per_device,
        coll_bytes_per_device=coll, coll_breakdown=coll_breakdown,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck, model_flops=mf, useful_ratio=ratio,
        peak_memory_bytes=peak_memory)


HEADER = ("| arch | shape | mesh | compute ms | memory ms | collective ms "
          "| bottleneck | useful ratio | peak GiB/dev |\n"
          "|---|---|---|---|---|---|---|---|---|")


def report_from_json(path: str, hw: HardwareProfile = TPU_V5E
                     ) -> RooflineReport:
    from repro.config.shapes import SHAPES_BY_NAME
    from repro.configs import get_arch
    with open(path) as f:
        rec = json.load(f)
    return analyze(
        get_arch(rec["arch"]), SHAPES_BY_NAME[rec["shape"]],
        mesh_name=rec["mesh"], chips=rec["chips"],
        flops_per_device=rec["flops"], bytes_per_device=rec["bytes_accessed"],
        coll_breakdown=rec["collectives"],
        peak_memory=rec.get("peak_memory"), hw=hw)


def main() -> None:
    import argparse
    import glob
    import os

    p = argparse.ArgumentParser()
    p.add_argument("--dir", default="experiments/dryrun")
    p.add_argument("--variant", default="base")
    p.add_argument("--mesh", default=None)
    p.add_argument("--csv", action="store_true")
    args = p.parse_args()

    rows = []
    skips = []
    for path in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        import json as _json
        with open(path) as f:
            rec = _json.load(f)
        if rec.get("variant", "base") != args.variant:
            continue
        if args.mesh and rec.get("mesh") != args.mesh:
            continue
        if "skipped" in rec:
            skips.append(rec)
            continue
        if "error" in rec:
            print(f"ERROR CELL: {rec['cell']}: {rec['error']}")
            continue
        rows.append(report_from_json(path))

    print(HEADER)
    for r in sorted(rows, key=lambda r: (r.arch, r.shape, r.mesh)):
        print(r.row())
    print()
    for rec in skips:
        print(f"SKIP | {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
              f"{rec['skipped']}")
    if rows:
        from collections import Counter
        c = Counter(r.bottleneck for r in rows)
        print(f"\nbottlenecks: {dict(c)}")


if __name__ == "__main__":
    main()
