"""HLO text cost parser — loop-aware FLOPs / bytes / collective accounting.

``compiled.cost_analysis()`` counts every computation ONCE: a
scan-over-layers (while loop) body is charged a single iteration, which
under-counts a 64-layer model by ~64x. This parser rebuilds the cost from
the compiled HLO text:

  * splits the module into computations and instructions;
  * computes per-computation dot/convolution FLOPs (shape × contracting
    dims), HBM bytes (operand + result sizes of non-fused top-level ops),
    and collective bytes (operand sizes, resolved by name);
  * propagates call multiplicity: ENTRY = 1; `while` bodies multiply by the
    parsed trip count (jax scans lower to `compare(iv, constant(N)),
    direction=LT`); fusions/calls inherit the caller's multiplicity;
    conditional branches count once (upper bound of one path).

Used by launch/dryrun.py for the §Roofline terms; validated against known
matmul/scan programs in tests/test_roofline.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_COMP_HDR = re.compile(
    r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")


def _split_instr(line: str):
    """Parse '%name = SHAPE opcode(...)' with balanced-paren tuple shapes
    (which may contain '/*index=N*/' comments)."""
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    i = m.end()
    n = len(line)
    if i < n and line[i] == "(":            # tuple shape
        depth = 0
        j = i
        while j < n:
            if line[j] == "(":
                depth += 1
            elif line[j] == ")":
                depth -= 1
                if depth == 0:
                    break
            j += 1
        shape = line[i:j + 1]
        i = j + 1
    else:                                    # scalar/array shape
        j = i
        while j < n and not line[j].isspace():
            j += 1
        shape = line[i:j]
        i = j
    while i < n and line[i].isspace():
        i += 1
    j = i
    while j < n and (line[j].isalnum() or line[j] in "-_"):
        j += 1
    if j >= n or line[j] != "(":
        return None
    op = line[i:j]
    return name, shape, op, j

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")


def shape_bytes(shape_str: str) -> int:
    """Bytes of a shape string (handles tuples by summing)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_elems(shape_str: str) -> int:
    n_total = 0
    for _, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        n_total += n
    return n_total


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    line: str
    operands: List[str]


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    by_name: Dict[str, Instr]


def _parse_operands(line: str, open_idx: int) -> List[str]:
    depth = 0
    end = open_idx
    for i in range(open_idx, len(line)):
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    inner = line[open_idx + 1:end]
    ops = []
    for tok in re.findall(r"%([\w.\-]+)", inner):
        ops.append(tok)
    return ops


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        if stripped.endswith("{") and "->" in stripped:
            m = _COMP_HDR.match(stripped)
            if m:
                cur = Computation(m.group(1), [], {})
                comps[m.group(1)] = cur
                if stripped.startswith("ENTRY"):
                    comps["__entry__"] = cur
                continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        parsed = _split_instr(line)
        if parsed is None:
            continue
        name, shape, op, open_idx = parsed
        instr = Instr(name, shape, op, line, _parse_operands(line, open_idx))
        cur.instrs.append(instr)
        cur.by_name[name] = instr
    return comps


def _attr_comp(line: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-]+)", line)
    return m.group(1) if m else None


def _attr_comps(line: str, key: str) -> List[str]:
    m = re.search(key + r"=\{([^}]*)\}", line)
    if not m:
        one = _attr_comp(line, key)
        return [one] if one else []
    return re.findall(r"%?([\w.\-]+)", m.group(1))


def trip_count(cond: Computation) -> int:
    """Trip count of a jax-style while: compare(iv, constant(N)), LT."""
    const = None
    for ins in cond.instrs:
        m = re.search(r"constant\((\d+)\)", ins.line)
        if m and ins.shape.strip().startswith(("s32[]", "u32[]", "s64[]")):
            const = int(m.group(1))
    for ins in cond.instrs:
        if ins.op == "compare" and "direction=LT" in ins.line and const:
            return const
    return 1


def dot_flops(ins: Instr, comp: Computation) -> float:
    """2 × prod(lhs dims) × prod(rhs free dims)."""
    shapes = []
    inline = _SHAPE_RE.findall(
        ins.line[ins.line.index(ins.op + "("):])
    for operand in ins.operands[:2]:
        ref = comp.by_name.get(operand)
        if ref is not None:
            shapes.append(ref.shape)
    if len(shapes) < 2 and len(inline) >= 2:
        shapes = [f"{d}[{dims}]" for d, dims in inline[:2]]
    if len(shapes) < 2:
        return 0.0
    lhs_dims = [int(d) for d in _SHAPE_RE.findall(shapes[0])[0][1].split(",")
                if d]
    rhs_dims = [int(d) for d in _SHAPE_RE.findall(shapes[1])[0][1].split(",")
                if d]
    rb = re.search(r"rhs_batch_dims=\{([0-9,]*)\}", ins.line)
    rc = re.search(r"rhs_contracting_dims=\{([0-9,]*)\}", ins.line)
    rb_idx = {int(x) for x in rb.group(1).split(",")} if rb and rb.group(1) \
        else set()
    rc_idx = {int(x) for x in rc.group(1).split(",")} if rc and rc.group(1) \
        else set()
    lhs_prod = 1
    for d in lhs_dims:
        lhs_prod *= d
    rhs_free = 1
    for i, d in enumerate(rhs_dims):
        if i not in rb_idx and i not in rc_idx:
            rhs_free *= d
    return 2.0 * lhs_prod * rhs_free


_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "partition-id",
    "replica-id", "custom-call",
}


def analyze_hlo(text: str, hbm_threshold: int = 1 << 20) -> Dict[str, float]:
    """``hbm_threshold``: tensors smaller than this are assumed
    VMEM/register-resident inside loops (loop-carried SSM states, softmax
    stats, …) and are not charged as HBM traffic; weight slices and
    activation tiles above it are charged per loop iteration. ``bytes_all``
    reports the unfiltered upper bound."""
    comps = parse_module(text)
    entry = comps.get("__entry__")
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "bytes_all": 0.0,
                "collective_bytes": 0.0, "collectives": {}}

    # computations whose instructions never touch HBM directly (fusion
    # internals, reduce/sort comparators) — flops still count, bytes don't
    fused: set = set()
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.op == "fusion":
                fused.update(_attr_comps(ins.line, "calls"))
            elif ins.op in ("reduce", "reduce-window", "scatter", "sort",
                            "map", "all-reduce", "reduce-scatter",
                            "select-and-scatter"):
                fused.update(_attr_comps(ins.line, "to_apply"))

    # per-computation local costs
    local: Dict[str, Dict[str, float]] = {}
    for name, comp in comps.items():
        if name == "__entry__":
            continue
        flops = 0.0
        bytes_ = 0.0
        bytes_all = 0.0
        coll: Dict[str, float] = {}
        for ins in comp.instrs:
            if ins.op in ("dot", "convolution"):
                flops += dot_flops(ins, comp)
            if ins.op not in _SKIP_BYTES_OPS and name not in fused:
                result = shape_bytes(ins.shape)
                if ins.op == "dynamic-slice":
                    # reads only the sliced window (≈ result), not the
                    # whole operand buffer
                    shapes = [2 * result]
                elif ins.op == "dynamic-update-slice":
                    # reads+writes the update window
                    upd = 0
                    if len(ins.operands) > 1:
                        ref = comp.by_name.get(ins.operands[1])
                        upd = shape_bytes(ref.shape) if ref else 0
                    shapes = [2 * upd]
                else:
                    shapes = [result]
                    for operand in ins.operands:
                        ref = comp.by_name.get(operand)
                        if ref is None:
                            continue
                        ob = shape_bytes(ref.shape)
                        # a fusion reading a tiny window of a giant buffer
                        # (loop-state slicing) streams ~result bytes, which
                        # the result term already covers
                        if ob <= 64 * max(result, 1):
                            shapes.append(ob)
                bytes_all += sum(shapes)
                bytes_ += sum(s for s in shapes if s >= hbm_threshold)
            base_op = ins.op[:-6] if ins.op.endswith("-start") else ins.op
            if base_op in COLLECTIVES:
                cb = 0
                for operand in ins.operands:
                    ref = comp.by_name.get(operand)
                    if ref is not None:
                        cb += shape_bytes(ref.shape)
                if cb == 0:  # fall back to result size
                    cb = shape_bytes(ins.shape)
                coll[base_op] = coll.get(base_op, 0.0) + cb
        local[name] = {"flops": flops, "bytes": bytes_,
                       "bytes_all": bytes_all, "coll": coll}

    # multiplicity propagation (iterative; call graph is a DAG)
    mult: Dict[str, float] = {entry.name: 1.0}
    order = [entry.name]
    seen = {entry.name}
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps[cname]
        m = mult[cname]
        for ins in comp.instrs:
            callees: List[Tuple[str, float]] = []
            if ins.op == "while":
                body = _attr_comp(ins.line, "body")
                cond = _attr_comp(ins.line, "condition")
                # XLA records known trip counts in backend_config
                tm = re.search(r'known_trip_count[^0-9]*(\d+)', ins.line)
                if tm:
                    trips = int(tm.group(1))
                else:
                    trips = trip_count(comps[cond]) if cond in comps else 1
                if body in comps:
                    callees.append((body, m * trips))
                if cond in comps:
                    callees.append((cond, m * (trips + 1)))
            elif ins.op == "fusion":
                callees = [(c, m) for c in _attr_comps(ins.line, "calls")
                           if c in comps]
            elif ins.op in ("call", "map", "reduce", "reduce-window",
                            "scatter", "sort", "all-reduce",
                            "reduce-scatter"):
                callees = [(c, m) for c in _attr_comps(ins.line, "to_apply")
                           if c in comps]
            elif ins.op == "conditional":
                callees = [(c, m) for c in
                           _attr_comps(ins.line, "branch_computations")
                           if c in comps]
            for cal, cm in callees:
                mult[cal] = mult.get(cal, 0.0) + cm
                if cal not in seen:
                    seen.add(cal)
                    order.append(cal)

    total_flops = 0.0
    total_bytes = 0.0
    total_bytes_all = 0.0
    coll_total: Dict[str, float] = {}
    for name, m in mult.items():
        lc = local.get(name)
        if lc is None:
            continue
        total_flops += m * lc["flops"]
        total_bytes += m * lc["bytes"]
        total_bytes_all += m * lc["bytes_all"]
        for k, v in lc["coll"].items():
            coll_total[k] = coll_total.get(k, 0.0) + m * v
    return {"flops": total_flops, "bytes": total_bytes,
            "bytes_all": total_bytes_all,
            "collective_bytes": sum(coll_total.values()),
            "collectives": coll_total}
