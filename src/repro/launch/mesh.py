"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).

Single pod:  (data=16, model=16)            = 256 chips (one v5e pod)
Multi-pod:   (pod=2, data=16, model=16)     = 512 chips; the pod axis is
             pure data parallelism across DCI — gradients reduce
             hierarchically (ICI ring within a pod, DCI across), which XLA
             emits automatically for the nested (pod, data) batch sharding.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax

try:  # jax >= 0.5 — explicit-sharding axis types
    from jax.sharding import AxisType
except ImportError:  # jax 0.4.x: no AxisType; meshes are implicitly Auto
    AxisType = None


def _mesh_kwargs(n_axes: int) -> dict:
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(jax.devices())} — "
            "run under launch/dryrun.py (it forces 512 host devices)")
    return jax.make_mesh(shape, axes, devices=devices,
                         **_mesh_kwargs(len(axes)))


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Arbitrary mesh (tests / elastic restarts)."""
    n = 1
    for s in shape:
        n *= s
    return jax.make_mesh(tuple(shape), tuple(axes),
                         devices=jax.devices()[:n],
                         **_mesh_kwargs(len(axes)))


def single_device_mesh():
    return make_mesh((1, 1), ("data", "model"))
