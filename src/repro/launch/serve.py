"""Serving driver: HCache-enabled engine over a synthetic conversation
trace (CPU-runnable with reduced configs).

    PYTHONPATH=src python -m repro.launch.serve --arch llama2-7b \
        --sessions 4 --rounds 2
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.arch import reduced_for_smoke
from repro.config.hardware import PROFILES
from repro.configs import get_arch
from repro.core.capacity import (ADMISSION_POLICIES, CapacityManager,
                                 EVICTION_POLICIES,
                                 RestoreCostAwareAdmission)
from repro.core.hcache import HCacheManager
from repro.distributed.sharding import default_rules
from repro.launch.mesh import make_mesh
from repro.models import Model
from repro.models.module import split
from repro.serving import InferenceEngine, Request
from repro.serving.kv_cache import BACKENDS
from repro.storage import (AsyncIOEngine, ChunkStore, make_array,
                           make_shards)


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="llama2-7b")
    p.add_argument("--sessions", type=int, default=3)
    p.add_argument("--rounds", type=int, default=2)
    p.add_argument("--prompt-len", type=int, default=24)
    p.add_argument("--gen", type=int, default=8)
    p.add_argument("--max-batch", type=int, default=4)
    p.add_argument("--max-seq", type=int, default=256)
    p.add_argument("--profile", default="a100", choices=sorted(PROFILES))
    p.add_argument("--ssds", type=int, default=4)
    p.add_argument("--hosts", type=int, default=1,
                   help="distributed store: number of host shards, each "
                        "with --ssds simulated SSDs behind its own NIC "
                        "link (1 = classic one-host store)")
    p.add_argument("--nic-bw", type=float, default=None, metavar="GBPS",
                   help="per-shard NIC bandwidth in GB/s (default: the "
                        "hardware model's NIC_BW)")
    p.add_argument("--placement", default="layer",
                   choices=("layer", "chunk"),
                   help="shard placement: layer-striped (layer L on "
                        "shard L%%N, per-link scheduling) or token-chunk-"
                        "striped (every layer fans over all links)")
    p.add_argument("--async-io", action=argparse.BooleanOptionalAction,
                   default=None,
                   help="attach the per-shard async IO engine (default: "
                        "on when --hosts > 1)")
    p.add_argument("--full", action="store_true")
    p.add_argument("--preempt-quantum", type=int, default=None,
                   help="enable mid-stream eviction after N resident steps")
    p.add_argument("--eviction", default="lru",
                   choices=sorted(EVICTION_POLICIES))
    p.add_argument("--admission", default="fifo",
                   choices=sorted(ADMISSION_POLICIES))
    p.add_argument("--budget-kb", type=int, default=None,
                   help="host hot-tier byte budget (KiB); enables the "
                        "capacity demotion ladder with a DRAM cold tier")
    p.add_argument("--backend", default="contiguous",
                   choices=sorted(BACKENDS),
                   help="KV-cache layout: contiguous slots or a "
                        "block-table page pool (lm models)")
    p.add_argument("--block-size", type=int, default=16,
                   help="paged backend: tokens per physical page")
    p.add_argument("--tp", type=int, default=1,
                   help="tensor-parallel width: shard the paged KV pool "
                        "and the restoration projection over this many "
                        "devices (KV-head axis; falls back to 1 when the "
                        "host exposes fewer devices — set XLA_FLAGS="
                        "--xla_force_host_platform_device_count=N on CPU)")
    p.add_argument("--cache-blocks", type=int, default=None,
                   help="paged backend: physical pages in the pool "
                        "(default max_batch * max_seq / block_size)")
    p.add_argument("--admission-aging", type=float, default=0.0,
                   help="restore_cost admission: seconds of makespan "
                        "credit per queued engine step (anti-starvation)")
    p.add_argument("--restore-group-size", default="8",
                   help="projection layers per stacked restoration "
                        "dispatch (1 = per-layer; see DESIGN.md §10), "
                        "'auto' to pick the restore_makespan argmin over "
                        "{1, 2, 4, 8, L} + the fetch-aligned partition "
                        "per restore, or 'fetch' to force fetch-aligned "
                        "non-uniform group boundaries (DESIGN.md §13)")
    p.add_argument("--hw-profile", default=None, metavar="PATH",
                   help="online scheduler calibration (DESIGN.md §13): "
                        "load a MeasuredProfile JSON from PATH if it "
                        "exists, fold every restore's observed task "
                        "times into it, re-plan from it, and save it "
                        "back on exit — restores converge to measured "
                        "hardware behavior instead of datasheet numbers")
    p.add_argument("--enc-seq", type=int, default=None,
                   help="enc-dec models: encoder positions per slot in "
                        "the paired self/cross cache (default max-seq)")
    p.add_argument("--prefix-sharing", action=argparse.BooleanOptionalAction,
                   default=False,
                   help="cross-session prefix sharing (DESIGN.md §12): "
                        "refcounted CoW pages + token-hash prefix index "
                        "(paged backend) and content-addressed host chunk "
                        "dedup / session forking")
    p.add_argument("--metrics-json", default=None, metavar="PATH",
                   help="dump the final EngineMetrics counters/gauges as "
                        "JSON to PATH on exit (what bench_slo and CI "
                        "consume instead of scraping printed text)")
    p.add_argument("--serve-http", action="store_true",
                   help="serve the engine through the front door "
                        "(DESIGN.md §14): OpenAI-compatible HTTP API + "
                        "session router, instead of the synthetic trace; "
                        "Ctrl-C to stop")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=8080,
                   help="--serve-http listen port (0 = ephemeral)")
    p.add_argument("--max-pending", type=int, default=64,
                   help="--serve-http backpressure: queue-depth cap "
                        "before requests are shed with 429/overloaded")
    p.add_argument("--priority-levels", type=int, default=1,
                   help="synthetic trace: session s gets priority "
                        "s %% N (exercises --admission priority; 1 = all "
                        "equal)")
    args = p.parse_args()
    group_size = (args.restore_group_size
                  if args.restore_group_size in ("auto", "fetch")
                  else int(args.restore_group_size))

    mesh = make_mesh((1, 1), ("data", "model"))
    rules = default_rules(mesh)
    cfg = get_arch(args.arch)
    if not args.full:
        cfg = reduced_for_smoke(cfg)
    model = Model(cfg, rules=rules, model_axis=1, dtype=jnp.float32,
                  remat="none")
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    cold = make_array("dram", args.ssds) if args.budget_kb else None
    if args.hosts > 1:
        from repro.config.hardware import NIC_BW
        nic_bw = (args.nic_bw * 1e9 if args.nic_bw else NIC_BW)
        store = ChunkStore(shards=make_shards(args.hosts, args.ssds, "ssd",
                                              nic_bw=nic_bw),
                           chunk_tokens=64, cold_devices=cold,
                           placement=args.placement)
        if args.async_io is not False:
            store.attach_io_engine(AsyncIOEngine(args.hosts))
    else:
        store = ChunkStore(make_array("ssd", args.ssds), chunk_tokens=64,
                           cold_devices=cold)
        if args.async_io:
            store.attach_io_engine(AsyncIOEngine(1))
    measured = None
    if args.hw_profile:
        import os
        from repro.core.profiler import MeasuredProfile
        measured = (MeasuredProfile.load(args.hw_profile)
                    if os.path.exists(args.hw_profile)
                    else MeasuredProfile())
    mgr = HCacheManager(model, store, hw=PROFILES[args.profile],
                        restore_group_size=group_size, profile=measured)
    capacity = (CapacityManager(mgr, host_budget_bytes=args.budget_kb * 1024)
                if args.budget_kb else None)
    admission = (RestoreCostAwareAdmission(aging=args.admission_aging)
                 if args.admission == "restore_cost"
                 else ADMISSION_POLICIES[args.admission]())
    engine = InferenceEngine(model, params, mgr, max_batch=args.max_batch,
                             max_seq=args.max_seq,
                             preempt_quantum=args.preempt_quantum,
                             eviction=EVICTION_POLICIES[args.eviction](),
                             admission=admission,
                             capacity=capacity,
                             backend=args.backend,
                             block_size=args.block_size,
                             cache_blocks=args.cache_blocks,
                             enc_seq=args.enc_seq,
                             prefix_sharing=args.prefix_sharing,
                             tp=args.tp)
    if args.tp > 1 and not engine.tp.spmd:
        print(f"tp={args.tp} requested but only {len(jax.devices())} "
              f"device(s) visible — running single-device")

    if args.serve_http:
        import asyncio

        from repro.frontend import serve_engine
        try:
            asyncio.run(serve_engine(engine, args.host, args.port,
                                     max_pending=args.max_pending))
        except KeyboardInterrupt:
            pass
        _dump_metrics(engine, args.metrics_json)
        store.close()
        return

    rng = np.random.default_rng(0)
    for rnd in range(args.rounds):
        for s in range(args.sessions):
            prompt = rng.integers(0, cfg.vocab_size,
                                  args.prompt_len).astype(np.int32)
            # enc-dec sessions carry encoder frames on round 0 only —
            # later rounds restore the cross context from the store
            frames = None
            if model.kind == "encdec" and rnd == 0:
                frames = rng.standard_normal(
                    (args.prompt_len, cfg.d_model)).astype(np.float32) * 0.1
            engine.submit(Request(f"user{s}", prompt,
                                  max_new_tokens=args.gen, frames=frames,
                                  priority=s % max(args.priority_levels,
                                                   1)))
        engine.run()
        for s in range(args.sessions):
            seq = engine.sessions[f"user{s}"]
            print(f"round {rnd} user{s}: {len(seq.generated)} tokens, "
                  f"restore_sim {seq.restore_sim * 1e3:.2f} ms, "
                  f"ttft_wall {seq.ttft_wall:.3f} s")
    m = engine.metrics
    print(f"\nrestored {m.restored_tokens} tokens over "
          f"{len(m.ttft_wall)} requests; decode steps {m.decode_steps}; "
          f"preemptions {m.preemptions}; "
          f"store {store.bytes_used / 1e6:.1f} MB hot "
          f"/ {store.bytes_cold / 1e6:.1f} MB cold across "
          f"{len(store.devices)} devices")
    print(f"cache backend {engine.kv.name}: peak concurrency "
          f"{m.concurrent_peak} slots, peak live/reserved tokens "
          f"{m.live_tokens_peak}/{m.reserved_tokens_peak}, mean occupancy "
          f"{m.occupancy_mean:.2f} (fragmentation "
          f"{m.fragmentation_mean:.2f}), free blocks {m.free_blocks}, "
          f"alloc stalls {m.alloc_stalls}")
    if args.prefix_sharing:
        print(f"prefix sharing: hit rate {m.prefix_hit_rate:.2f} "
              f"({m.prefix_hits}/{m.prefix_lookups} lookups, "
              f"{m.prefix_hit_tokens} tokens), skipped "
              f"{m.restore_skipped_tokens} restore/prefill tokens, "
              f"{m.cow_copies} CoW copies, pages shared/private "
              f"{m.shared_pages}/{m.private_pages}, host dedup "
              f"{m.dedup_host_bytes / 1e6:.2f} MB, forks {m.forks}")
    for r in m.device_gauges:
        print(f"device {r['device']}: free pages {r['free_pages']}, "
              f"pool occupancy {r['occupancy_pct']}%, live/reserved "
              f"{r['util_pct']}%, restore-projection utilization "
              f"{r['proj_util_pct']}%"
              + (f", pool bytes {r['pool_bytes']}"
                 if "pool_bytes" in r else ""))
    if m.restore_bubble_n:
        print(f"scheduler calibration: observed bubble "
              f"{m.restore_bubble_mean:.1%} over {m.restore_bubble_n} "
              f"restores, planned-vs-measured makespan error "
              f"{m.makespan_err_mean:.1%}, peak restore concurrency "
              f"{m.io_streams_peak} streams")
    if measured is not None:
        counts = ", ".join(f"{k}={v}"
                           for k, v in measured.sample_counts().items())
        print(f"hw profile: epoch {measured.epoch}, samples "
              f"[{counts or 'none'}] -> {args.hw_profile}")
        measured.save(args.hw_profile)
    if capacity is not None and capacity.actions:
        print("capacity ladder actions:", capacity.actions)
    print("recoverable sessions:", engine.recoverable_sessions())
    _dump_metrics(engine, args.metrics_json)
    engine.close()
    store.close()                # joins the async IO workers, if attached


def _dump_metrics(engine, path) -> None:
    if not path:
        return
    import json
    with open(path, "w") as f:
        json.dump(engine.metrics.to_dict(), f, indent=2)
    print(f"metrics -> {path}")


if __name__ == "__main__":
    main()
