"""Storage backends for the chunk store.

Three tiers, all exposing the same byte-level API:

  DRAMBackend      — host memory (paper's cloud-server fallback).
  SimulatedSSD     — host memory + a bandwidth/latency model of one NVMe
                     device (PM9A3 by default). Reads/writes advance a
                     device-local clock so benchmarks measure contention and
                     striping gains without real disks.
  FileBackend      — real files (persistence across engine restarts —
                     the serving fault-tolerance path).

A ``StorageArray`` is N devices addressed round-robin by the chunk store.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
import urllib.parse
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.config.hardware import SSD_READ_BW, SSD_WRITE_BW


class Backend:
    """Byte-addressable key-value device."""

    def write(self, key: str, data: np.ndarray) -> float:
        raise NotImplementedError

    def read(self, key: str) -> np.ndarray:
        raise NotImplementedError

    def read_async(self, key: str) -> "Tuple[np.ndarray, float]":
        """Read + the device-local virtual completion time of this IO.

        Devices without a timing model complete instantly (0.0). The
        restoration executor uses the completion times to interleave
        striped reads with compute (see core/restoration.py)."""
        return self.read(key), 0.0

    def peek(self, key: str) -> np.ndarray:
        """Metadata-path read: no virtual-clock charge on timed devices
        (availability checks must not perturb the IO simulation)."""
        return self.read(key)

    def nrows(self, key: str) -> int:
        """Stored row count (first dim) without paying for a data read
        where the backend can avoid it."""
        return self.peek(key).shape[0]

    def nbytes(self, key: str) -> int:
        """Stored size of one key (accounting path — no clock charge)."""
        return self.peek(key).nbytes

    def delete(self, key: str) -> None:
        raise NotImplementedError

    def contains(self, key: str) -> bool:
        raise NotImplementedError

    def keys(self) -> List[str]:
        raise NotImplementedError

    @property
    def bytes_used(self) -> int:
        raise NotImplementedError


class DRAMBackend(Backend):
    def __init__(self):
        self._store: Dict[str, np.ndarray] = {}
        self._lock = threading.Lock()

    def write(self, key, data):
        stored = np.array(data, copy=True)
        # reads hand out this exact array (zero-copy); freezing it makes
        # cross-session aliasing bugs fail loudly instead of corrupting
        # every alias of a shared chunk. Mutating consumers must copy.
        stored.flags.writeable = False
        with self._lock:
            self._store[key] = stored
        return 0.0

    def read(self, key):
        with self._lock:
            return self._store[key]

    def delete(self, key):
        with self._lock:
            self._store.pop(key, None)

    def contains(self, key):
        with self._lock:
            return key in self._store

    def nbytes(self, key):
        with self._lock:
            return self._store[key].nbytes

    def keys(self):
        with self._lock:
            return list(self._store)

    @property
    def bytes_used(self):
        with self._lock:
            return sum(v.nbytes for v in self._store.values())


@dataclasses.dataclass
class SimClock:
    """Per-device virtual clock: busy-until timestamps for read & write."""

    read_busy_until: float = 0.0
    write_busy_until: float = 0.0


class SimulatedSSD(DRAMBackend):
    """DRAM-backed with an NVMe timing model (seq BW + per-IO latency)."""

    def __init__(self, read_bw: float = SSD_READ_BW,
                 write_bw: float = SSD_WRITE_BW, io_latency: float = 80e-6):
        super().__init__()
        self.read_bw = read_bw
        self.write_bw = write_bw
        self.io_latency = io_latency
        self.clock = SimClock()
        self.now = 0.0               # external virtual time (set by the store)
        self.read_time_total = 0.0
        self.write_time_total = 0.0
        # clock arithmetic is read-modify-write; async IO workers and the
        # engine thread may both charge this device
        self._clock_lock = threading.Lock()

    def write(self, key, data):
        super().write(key, data)
        with self._clock_lock:
            dur = self.io_latency + data.nbytes / self.write_bw
            start = max(self.now, self.clock.write_busy_until)
            self.clock.write_busy_until = start + dur
            self.write_time_total += dur
            return self.clock.write_busy_until

    def read(self, key):
        data = super().read(key)
        with self._clock_lock:
            dur = self.io_latency + data.nbytes / self.read_bw
            start = max(self.now, self.clock.read_busy_until)
            self.clock.read_busy_until = start + dur
            self.read_time_total += dur
        return data

    def read_async(self, key):
        return self.read(key), self.clock.read_busy_until

    def peek(self, key):
        return DRAMBackend.read(self, key)        # no clock charge

    def read_completion(self) -> float:
        return self.clock.read_busy_until


class FileBackend(Backend):
    """npy files under a directory — survives process restarts."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        # per-key size cache: bytes_used/nbytes sit on hot accounting
        # paths (budget checks per write) — one listdir walk at open,
        # then invalidated incrementally on write/delete
        self._sizes: Dict[str, int] = {
            urllib.parse.unquote(f[:-4]): os.path.getsize(
                os.path.join(root, f))
            for f in os.listdir(root) if f.endswith(".npy")}

    def _path(self, key: str) -> str:
        # percent-encoding is injective: a session id that legitimately
        # contains "__" (or "%") survives the keys() round-trip, unlike
        # the old "/" <-> "__" substitution
        return os.path.join(self.root,
                            urllib.parse.quote(key, safe="") + ".npy")

    def write(self, key, data):
        tmp = self._path(key) + ".tmp"
        with open(tmp, "wb") as f:               # np.save would append .npy
            np.save(f, data)
        os.replace(tmp, self._path(key))         # atomic commit
        self._sizes[key] = os.path.getsize(self._path(key))
        return 0.0

    def read(self, key):
        return np.load(self._path(key))

    def delete(self, key):
        self._sizes.pop(key, None)
        try:
            os.remove(self._path(key))
        except FileNotFoundError:
            pass

    def contains(self, key):
        return os.path.exists(self._path(key))

    def nrows(self, key):
        # mmap reads only the npy header, not the chunk data
        return np.load(self._path(key), mmap_mode="r").shape[0]

    def keys(self):
        return [urllib.parse.unquote(f[:-4]) for f in os.listdir(self.root)
                if f.endswith(".npy")]

    def nbytes(self, key):
        size = self._sizes.get(key)
        if size is None:                         # externally-written file
            size = self._sizes[key] = os.path.getsize(self._path(key))
        return size

    @property
    def bytes_used(self):
        return sum(self._sizes.values())


class StorageArray(list):
    """A device array with an optional byte budget.

    Behaves as a plain list of backends (the chunk store addresses it
    round-robin) but additionally tracks a ``budget_bytes`` ceiling and
    fires registered pressure callbacks — typically the capacity
    manager's reclaim ladder — when the tier's total footprint exceeds
    it. Reclaim is guarded by a non-blocking lock: a callback that
    itself writes or deletes through the store cannot recurse into
    another reclaim (same-thread acquire fails), and two threads — e.g.
    an async IO worker hitting a pressure callback while the engine
    thread writes — cannot run the reclaim ladder concurrently."""

    def __init__(self, devices: Sequence[Backend],
                 budget_bytes: Optional[int] = None):
        super().__init__(devices)
        self.budget_bytes = budget_bytes
        self._callbacks: List[Callable[["StorageArray"], None]] = []
        self._reclaim_lock = threading.Lock()

    @property
    def bytes_used(self) -> int:
        return sum(d.bytes_used for d in self)

    def over_budget(self) -> bool:
        return (self.budget_bytes is not None
                and self.bytes_used > self.budget_bytes)

    def on_pressure(self, callback: Callable[["StorageArray"], None]) -> None:
        self._callbacks.append(callback)

    def maybe_reclaim(self) -> None:
        if not self.over_budget():
            return
        if not self._reclaim_lock.acquire(blocking=False):
            return                       # reclaim already running
        try:
            if self.over_budget():       # re-check under the lock
                for cb in self._callbacks:
                    cb(self)
        finally:
            self._reclaim_lock.release()


def make_array(kind: str, n_devices: int, root: Optional[str] = None,
               budget_bytes: Optional[int] = None) -> StorageArray:
    if kind == "dram":
        devs = [DRAMBackend() for _ in range(n_devices)]
    elif kind == "ssd":
        devs = [SimulatedSSD() for _ in range(n_devices)]
    elif kind == "file":
        assert root is not None
        devs = [FileBackend(os.path.join(root, f"dev{i}"))
                for i in range(n_devices)]
    else:
        raise ValueError(kind)
    return StorageArray(devs, budget_bytes=budget_bytes)
